"""Architecture config registry.

``get_config(name)`` returns the full assigned config;
``get_config(name, reduced=True)`` returns the CPU-smoke variant
(≤2 layers, d_model ≤ 512, ≤4 experts).
"""
from repro.configs.base import ModelConfig, register, get_config, list_configs

# import for registration side effects
from repro.configs import (internvl2_76b, zamba2_1_2b, granite_8b,
                           command_r_plus_104b, qwen3_moe_235b_a22b,
                           mamba2_370m, llama4_maverick_400b_a17b,
                           qwen2_1_5b, yi_9b, whisper_medium)

__all__ = ["ModelConfig", "register", "get_config", "list_configs"]
