"""jit'd wrapper: PyTree-level partial restore backed by the Pallas kernel.

Drop-in for :func:`repro.core.blocks.select_blocks` (dst=live params,
src=checkpoint, mask=lost blocks).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.blocks import BlockPartition, leaf_block_view, split_global_mask
from repro.kernels.masked_restore.kernel import masked_restore_pallas
from repro.kernels.masked_restore.ref import masked_restore_ref

PyTree = Any


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


def masked_restore(dst: jnp.ndarray, src: jnp.ndarray, mask: jnp.ndarray,
                   use_pallas: bool = True,
                   interpret: bool | None = None) -> jnp.ndarray:
    if not use_pallas:
        return masked_restore_ref(dst, src, mask)
    if interpret is None:
        interpret = not _is_tpu()
    return masked_restore_pallas(dst, src, mask, interpret=interpret)


def arena_masked_restore(dst: PyTree, src_arena: jnp.ndarray, global_mask,
                         arena_layout) -> PyTree:
    """Partial restore whose *source* is a flat parameter arena
    (:mod:`repro.core.arena`) instead of a PyTree: each touched leaf
    decodes one contiguous arena slice, untouched leaves pass through as
    the same buffer. The arena-native sibling of
    :func:`tree_masked_restore` — the tier planner uses it when the
    replica snapshot is arena-form."""
    from repro.core.arena import arena_restore
    return arena_restore(dst, src_arena, global_mask, arena_layout)


def tree_masked_restore(dst: PyTree, src: PyTree, global_mask: jnp.ndarray,
                        partition: BlockPartition,
                        interpret: bool | None = None) -> PyTree:
    """select_blocks equivalent, kernel-backed."""
    dst_flat = jax.tree_util.tree_leaves(dst)
    src_flat = jax.tree_util.tree_leaves(src)
    masks = split_global_mask(global_mask, partition)
    out = []
    for d, s, m, leaf in zip(dst_flat, src_flat, masks, partition.leaves):
        dv = leaf_block_view(d, partition.block_rows)
        sv = leaf_block_view(s, partition.block_rows)
        rv = masked_restore(dv, sv, m, interpret=interpret)
        rows = max(leaf.rows, 1)
        flat = rv.reshape(-1, leaf.row_width)[:rows]
        out.append(flat.reshape(leaf.shape).astype(d.dtype))
    return jax.tree_util.tree_unflatten(partition.treedef, out)
