"""Pure-jnp oracle for the ssd_scan intra-chunk kernel."""
import jax.numpy as jnp


def ssd_intra_ref(la, dt, x, Bm, Cm):
    """Same contract as ssd_intra_pallas.

    la, dt: (B, nc, Q, H); x: (B, nc, Q, H, P); Bm, Cm: (B, nc, Q, N).
    Returns (y_intra (B, nc, Q, H, P), chunk_state (B, nc, H, N, P)).
    """
    Q = la.shape[2]
    cum = jnp.cumsum(la, axis=2)                               # (B,nc,Q,H)
    scores = jnp.einsum("bcin,bcjn->bcij", Cm, Bm)             # (B,nc,Q,Q)
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # (B,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    M = jnp.where(causal[None, None, :, :, None], jnp.exp(decay), 0.0) \
        * scores[..., None] * dt[:, :, None, :, :]
    y = jnp.einsum("bcijh,bcjhp->bcihp", M, x)
    w = jnp.exp(cum[:, :, -1:, :] - cum) * dt                  # (B,nc,Q,H)
    state = jnp.einsum("bcjh,bcjn,bcjhp->bchnp", w, Bm, x)
    return y, state
