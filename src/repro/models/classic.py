"""The paper's experiment models (§5.1): QP, MLR, MF (ALS), LDA (Gibbs), CNN.

Each is an *iterative-convergent* algorithm exposed through a common
protocol so the SCAR experiments (Figures 3/5/6/7/8) run identically over
all of them:

- ``init(rng)``            -> params pytree (the state SCAR checkpoints)
- ``step(params, rng, i)`` -> params' (one iteration of f)
- ``loss(params)``         -> scalar convergence metric (lower = better)
- ``x_star()``             -> optimum / reference params (for ||x - x*||)
- ``norm_aux``             -> per-leaf aux for the scaled-TV norm (LDA)

Datasets are synthetic stand-ins (offline container) with sizes matched to
the paper's regime; convergence criteria are chosen (as in the paper's
Appendix C) so an unperturbed run converges in roughly 60–100 iterations.

LDA note: the paper's collapsed Gibbs sampler is sequential per token; we
use the standard *parallel* approximation (resample all token topics given
the current counts, then rebuild counts) which preserves the
iterative-convergent structure the experiments need. The checkpointed
state is the document-topic distribution (+ token assignments implicitly);
word-topic counts are rebuilt, as in the paper's Appendix C.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import synthetic

PyTree = Any


def _reference_run(init, step, loss, n_iters: int, target_iter: int,
                   margin: float = 1.001, seed: int = 97):
    """One unperturbed reference run. Returns (x_star, eps, trajectory).

    eps is the loss reached at ``target_iter`` (+ tiny margin), so an
    unperturbed run converges in roughly ``target_iter`` iterations —
    matching the paper's Appendix C convergence-criteria setup.
    """
    p = init(jax.random.PRNGKey(0))
    traj = []
    for i in range(1, n_iters + 1):
        p = step(p, jax.random.fold_in(jax.random.PRNGKey(seed), i), i)
        traj.append(float(loss(p)))
    eps = traj[min(target_iter, n_iters) - 1] * margin
    x_star = jax.tree_util.tree_map(jnp.array, p)
    return x_star, eps, traj


@dataclasses.dataclass(frozen=True)
class IterativeModel:
    name: str
    init: Callable[[jax.Array], PyTree]
    step: Callable[[PyTree, jax.Array, int], PyTree]
    loss: Callable[[PyTree], jnp.ndarray]
    x_star: Callable[[], PyTree]
    eps: float                      # paper-style convergence criterion on loss
    norm_aux: Optional[dict] = None
    block_rows: int = 8             # fine-grained blocks for small models
    colocate: tuple = ()            # co-partitioned state groups (PS reality:
                                    # optimizer moments live WITH their params)

    def distance(self, params: PyTree) -> float:
        """||x − x*|| in the flat L2 sense (for c-estimation / bounds)."""
        d = jax.tree_util.tree_map(
            lambda a, b: jnp.sum((a.astype(jnp.float32)
                                  - b.astype(jnp.float32)) ** 2),
            params, self.x_star())
        return float(jnp.sqrt(jax.tree_util.tree_reduce(jnp.add, d, 0.0)))


# ---------------------------------------------------------------------------
# QP: gradient descent on a quadratic (Figure 3)
# ---------------------------------------------------------------------------

def make_qp(dim: int = 4, seed: int = 0, lr: Optional[float] = None,
            cond: float = 10.0) -> IterativeModel:
    rng = np.random.default_rng(seed)
    U, _ = np.linalg.qr(rng.normal(size=(dim, dim)))
    eig = np.linspace(1.0, cond, dim)
    Q = (U * eig) @ U.T
    b = rng.normal(size=(dim,))
    x_opt = np.linalg.solve(Q, b)
    Qj, bj, xj = jnp.asarray(Q, jnp.float32), jnp.asarray(b, jnp.float32), \
        jnp.asarray(x_opt, jnp.float32)
    if lr is None:
        lr = 1.0 / (eig.max() + eig.min())   # optimal GD step for quadratics

    @jax.jit
    def step(params, rng, i):
        x = params["x"]
        return {"x": x - lr * (Qj @ x - bj)}

    @jax.jit
    def loss(params):
        x = params["x"]
        return 0.5 * x @ Qj @ x - bj @ x

    return IterativeModel(
        name="qp",
        init=lambda rng: {"x": jax.random.normal(rng, (dim,)) * 5.0},
        step=step, loss=loss,
        x_star=lambda: {"x": xj},
        eps=float(0.5 * x_opt @ Q @ x_opt - b @ x_opt) + 1e-6,
        block_rows=1,
    )


# ---------------------------------------------------------------------------
# MLR: multinomial logistic regression with SGD (Figures 5/6/7/8)
# ---------------------------------------------------------------------------

def make_mlr(n: int = 2000, dim: int = 196, n_classes: int = 10,
             batch: int = 500, lr: float = 0.01, seed: int = 0,
             ref_iters: int = 120) -> IterativeModel:
    rng = np.random.default_rng(seed)
    x_np, y_np = synthetic.classification_data(rng, n=n, dim=dim,
                                               n_classes=n_classes)
    X = jnp.asarray(x_np)
    Y = jnp.asarray(y_np)

    def xent(w, xb, yb):
        logits = xb @ w["w"] + w["b"]
        return jnp.mean(jax.nn.logsumexp(logits, axis=-1)
                        - jnp.take_along_axis(logits, yb[:, None], 1)[:, 0])

    grad_fn = jax.jit(jax.grad(xent))

    @jax.jit
    def step(params, rng, i):
        idx = jax.random.choice(rng, n, (batch,), replace=False)
        g = grad_fn(params, X[idx], Y[idx])
        return jax.tree_util.tree_map(lambda p, gg: p - lr * gg, params, g)

    @jax.jit
    def loss(params):
        return xent(params, X, Y) * n   # paper reports total cross-entropy

    def init(rng):
        return {"w": jnp.zeros((dim, n_classes)), "b": jnp.zeros((n_classes,))}

    star, eps, _ = _reference_run(init, step, loss, ref_iters, target_iter=60)
    return IterativeModel(
        name="mlr", init=init, step=step, loss=loss, x_star=lambda: star,
        eps=eps, block_rows=8,
    )


# ---------------------------------------------------------------------------
# MF: matrix factorization by alternating least squares (Figures 7/8)
# ---------------------------------------------------------------------------

def make_mf(m: int = 400, n: int = 600, rank: int = 5, reg: float = 0.1,
            seed: int = 0) -> IterativeModel:
    rng = np.random.default_rng(seed)
    R_np, M_np = synthetic.ratings_matrix(rng, m=m, n=n, rank=rank)
    R = jnp.asarray(R_np)
    M = jnp.asarray(M_np)
    eye = jnp.eye(rank)

    @jax.jit
    def step(params, rng, i):
        L, Rt = params["L"], params["R"]          # (m,r), (r,n)

        def solve_rows(A, target, mask):
            # ridge solve per row: rows of target explained by A columns
            def one(t_row, m_row):
                Aw = A * m_row[:, None]
                G = Aw.T @ A + reg * eye
                return jnp.linalg.solve(G, Aw.T @ t_row)
            return jax.vmap(one)(target, mask)

        L_new = solve_rows(Rt.T, R, M)            # (m, r)
        R_new = solve_rows(L_new, R.T, M.T).T     # (r, n)
        return {"L": L_new, "R": R_new}

    @jax.jit
    def loss(params):
        pred = params["L"] @ params["R"]
        return jnp.sum(((pred - R) * M) ** 2)

    def init(rng):
        k1, k2 = jax.random.split(rng)
        return {"L": jax.random.uniform(k1, (m, rank)),
                "R": jax.random.uniform(k2, (rank, n))}

    star, eps, _ = _reference_run(init, step, loss, 80, target_iter=60)
    return IterativeModel(
        name="mf", init=init, step=step, loss=loss, x_star=lambda: star,
        eps=eps, block_rows=8,
    )


# ---------------------------------------------------------------------------
# LDA: (parallel-approximate) collapsed Gibbs sampling (Figures 6/7/8)
# ---------------------------------------------------------------------------

def make_lda(n_docs: int = 150, vocab: int = 300, n_topics: int = 10,
             alpha: float = 1.0, beta: float = 1.0, doc_len_mean: int = 80,
             seed: int = 0) -> IterativeModel:
    rng = np.random.default_rng(seed)
    tokens_np, doc_lens_np = synthetic.lda_corpus(
        rng, n_docs=n_docs, vocab=vocab, n_topics=n_topics,
        doc_len_mean=doc_len_mean)
    tokens = jnp.asarray(tokens_np)                 # (D, maxlen), -1 padded
    valid = tokens >= 0
    tok_safe = jnp.where(valid, tokens, 0)
    doc_lens = jnp.asarray(doc_lens_np, jnp.float32)
    D, maxlen = tokens.shape
    K, V = n_topics, vocab

    def counts_from_z(z):
        """z: (D, maxlen) topic assignments -> (doc_topic, word_topic)."""
        zoh = jax.nn.one_hot(z, K) * valid[..., None]
        doc_topic = jnp.sum(zoh, axis=1)                        # (D, K)
        wt = jnp.zeros((V, K))
        wt = wt.at[tok_safe.reshape(-1)].add(
            zoh.reshape(-1, K))
        return doc_topic, wt

    @jax.jit
    def step(params, rng, i):
        z = params["z"]
        doc_topic, word_topic = counts_from_z(z)
        topic_tot = jnp.sum(word_topic, axis=0)                 # (K,)
        # parallel resample of all token topics given current counts
        p_wt = (word_topic[tok_safe] + beta) / (topic_tot + V * beta)  # (D,m,K)
        p_dt = (doc_topic[:, None, :] + alpha)
        logits = jnp.log(p_wt * p_dt + 1e-30)
        z_new = jax.random.categorical(rng, logits, axis=-1)
        z_new = jnp.where(valid, z_new, 0)
        doc_topic_new, _ = counts_from_z(z_new)
        theta = (doc_topic_new + alpha)
        theta = theta / jnp.sum(theta, axis=-1, keepdims=True)
        return {"z": z_new, "theta": theta}

    @jax.jit
    def loss(params):
        """Negative predictive log-likelihood given current counts."""
        doc_topic, word_topic = counts_from_z(params["z"])
        topic_tot = jnp.sum(word_topic, axis=0)
        phi = (word_topic + beta) / (topic_tot + V * beta)      # (V, K)
        theta = (doc_topic + alpha)
        theta = theta / jnp.sum(theta, axis=-1, keepdims=True)  # (D, K)
        pw = jnp.einsum("dmk,dk->dm", phi[tok_safe], theta)
        return -jnp.sum(jnp.where(valid, jnp.log(pw + 1e-30), 0.0))

    def init(rng):
        z = jax.random.randint(rng, (D, maxlen), 0, K)
        z = jnp.where(valid, z, 0)
        doc_topic, _ = counts_from_z(z)
        theta = doc_topic + alpha
        theta = theta / jnp.sum(theta, axis=-1, keepdims=True)
        return {"z": z, "theta": theta}

    star, eps, _ = _reference_run(init, step, loss, 100, target_iter=60)
    return IterativeModel(
        name="lda", init=init, step=step, loss=loss, x_star=lambda: star,
        eps=eps,
        norm_aux={"['theta']": np.asarray(doc_lens_np, np.float32)},
        block_rows=8,
    )


# ---------------------------------------------------------------------------
# CNN: 2 conv + 3 FC with Adam (Figures 7/8)
# ---------------------------------------------------------------------------

def make_cnn(n: int = 512, size: int = 16, n_classes: int = 10,
             batch: int = 64, lr: float = 1e-3, seed: int = 0) -> IterativeModel:
    rng = np.random.default_rng(seed)
    x_np, y_np = synthetic.image_batch(rng, n=n, size=size, n_classes=n_classes)
    X = jnp.asarray(x_np)
    Y = jnp.asarray(y_np)

    c1, c2, f1, f2, f3 = 8, 16, 128, 64, n_classes
    flat = (size // 4) * (size // 4) * c2

    def init_net(rng):
        ks = jax.random.split(rng, 5)
        he = lambda k, s, fan: jax.random.normal(k, s) * np.sqrt(2.0 / fan)
        return {
            "conv1": he(ks[0], (3, 3, 1, c1), 9),
            "conv2": he(ks[1], (3, 3, c1, c2), 9 * c1),
            "fc1": he(ks[2], (flat, f1), flat),
            "fc2": he(ks[3], (f1, f2), f1),
            "fc3": he(ks[4], (f2, f3), f2),
            "b1": jnp.zeros((f1,)), "b2": jnp.zeros((f2,)),
            "b3": jnp.zeros((f3,)),
        }

    def forward(p, xb):
        h = jax.lax.conv_general_dilated(
            xb, p["conv1"], (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        h = jax.nn.relu(h)
        h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max,
                                  (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
        h = jax.lax.conv_general_dilated(
            h, p["conv2"], (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        h = jax.nn.relu(h)
        h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max,
                                  (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
        h = h.reshape(h.shape[0], -1)
        h = jax.nn.relu(h @ p["fc1"] + p["b1"])
        h = jax.nn.relu(h @ p["fc2"] + p["b2"])
        return h @ p["fc3"] + p["b3"]

    def xent(p, xb, yb):
        logits = forward(p, xb)
        return jnp.mean(jax.nn.logsumexp(logits, -1)
                        - jnp.take_along_axis(logits, yb[:, None], 1)[:, 0])

    grad_fn = jax.jit(jax.grad(xent))
    b1m, b2m, eps_adam = 0.9, 0.999, 1e-8

    @jax.jit
    def step(params, rng, i):
        net, mu, nu, t = params["net"], params["mu"], params["nu"], params["t"]
        idx = jax.random.choice(rng, n, (batch,), replace=False)
        g = grad_fn(net, X[idx], Y[idx])
        t = t + 1
        mu = jax.tree_util.tree_map(lambda m, gg: b1m * m + (1 - b1m) * gg, mu, g)
        nu = jax.tree_util.tree_map(lambda v, gg: b2m * v + (1 - b2m) * gg ** 2,
                                    nu, g)
        tf = t.astype(jnp.float32)
        net = jax.tree_util.tree_map(
            lambda p, m, v: p - lr * (m / (1 - b1m ** tf))
            / (jnp.sqrt(v / (1 - b2m ** tf)) + eps_adam),
            net, mu, nu)
        return {"net": net, "mu": mu, "nu": nu, "t": t}

    @jax.jit
    def loss(params):
        return xent(params["net"], X, Y) * n

    def init(rng):
        net = init_net(rng)
        zeros = jax.tree_util.tree_map(jnp.zeros_like, net)
        return {"net": net, "mu": zeros,
                "nu": jax.tree_util.tree_map(jnp.zeros_like, net),
                "t": jnp.zeros((), jnp.int32)}

    star, eps, _ = _reference_run(init, step, loss, 120, target_iter=60)
    return IterativeModel(
        name="cnn", init=init, step=step, loss=loss, x_star=lambda: star,
        eps=eps, block_rows=4,
        colocate=("net", "mu", "nu"),   # Adam moments fail/recover WITH weights
    )


_MODEL_CACHE: dict = {}


REGISTRY = {"qp": make_qp, "mlr": make_mlr, "mf": make_mf,
            "lda": make_lda, "cnn": make_cnn}


def make_model(name: str, **kw) -> IterativeModel:
    """Build (and cache — reference runs are not free) a classic model."""
    key = (name, tuple(sorted(kw.items())))
    if key not in _MODEL_CACHE:
        _MODEL_CACHE[key] = REGISTRY[name](**kw)
    return _MODEL_CACHE[key]
