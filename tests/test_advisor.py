"""Predictive checkpoint advisor (paper §7 future work, implemented)."""
import pytest

from repro.core.advisor import (RunObservations, advise,
                                expected_iteration_cost, expected_overhead)


def _obs(**kw):
    base = dict(drift_per_iter=0.05, x0_err=10.0, c=0.95, t_iter=1.0,
                t_dump_full=0.2, failure_rate=0.001, loss_fraction=0.5,
                current_iter=100)
    base.update(kw)
    return RunObservations(**base)


def test_cost_monotone_in_interval():
    obs = _obs()
    costs = [expected_iteration_cost(obs, 1.0, C) for C in (4, 16, 64)]
    assert costs[0] <= costs[1] <= costs[2]


def test_cost_monotone_in_loss_fraction():
    a = expected_iteration_cost(_obs(loss_fraction=0.25), 1.0, 8)
    b = expected_iteration_cost(_obs(loss_fraction=1.0), 1.0, 8)
    assert a <= b


def test_high_failure_rate_prefers_frequent_small_checkpoints():
    hot, _ = advise(_obs(failure_rate=0.05))
    cold, _ = advise(_obs(failure_rate=1e-6))
    # frequent failures -> smaller fraction saved more often (or at least
    # not a longer effective interval than the cold policy)
    assert hot.partial_interval <= cold.partial_interval


def test_zero_failures_prefers_cheapest_dumps():
    pol, rep = advise(_obs(failure_rate=0.0))
    # with no failures the advisor should pick the lowest amortized dump
    assert rep["expected_overhead_s"] == pytest.approx(
        min(rep["table"].values()))


def test_advise_returns_valid_policy():
    pol, rep = advise(_obs())
    assert 0 < pol.fraction <= 1.0
    assert pol.full_interval >= 1
    assert rep["chosen"] in {(r, C) for r in (1.0, 0.5, 0.25, 0.125, 0.0625)
                             for C in (4, 8, 16, 32, 64)}
