"""Quickstart: SCAR fault tolerance in 60 lines.

Trains a small classic model (multinomial logistic regression — one of the
paper's §5 workloads), takes prioritized partial checkpoints through the
**arena-resident** fault-tolerance path (the live params feed the fused
maintenance sweep and the partial save as one flat arena — the default),
kills half the parameters mid-training, partially recovers, and reports
the measured iteration cost next to the Theorem 3.2 bound plus the
per-iteration maintenance overhead actually observed.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core.iteration_cost import (estimate_contraction,
                                       single_perturbation_bound)
from repro.core.policy import CheckpointPolicy
from repro.fabric import FabricConfig
from repro.models.classic import make_model
from repro.telemetry import Recorder, format_report, run_report
from repro.training import run_clean, run_with_failure


def main():
    print("== SCAR quickstart: MLR + priority checkpoints + partial recovery")
    model = make_model("mlr", n=600, dim=64, n_classes=5, batch=200)

    # 1. unperturbed baseline (the κ(x, ε) reference)
    clean = run_clean(model, max_iters=150)["losses"]
    kappa_clean = int(np.argmax(np.asarray(clean) < model.eps))
    print(f"   clean run reaches ε in {kappa_clean} iterations")

    # 2. SCAR: prioritized 1/4-checkpoints at 4× frequency, partial
    # recovery, with the tiered redundancy fabric so the hot path runs
    # arena-resident (maintain + save over one flat arena, no per-step
    # tree pack inside the fault-tolerance machinery)
    scar = CheckpointPolicy.scar(fraction=0.25, interval=32)
    rec = Recorder()   # telemetry: events + spans + perturbation ledger
    res = run_with_failure(model, scar, fail_iter=25, fail_fraction=0.5,
                           max_iters=150, clean_losses=clean,
                           fabric=FabricConfig(), recorder=rec)
    tiers = {k: v for k, v in res["recovery"]["tier_counts"].items() if v}
    print(f"   failure at iter 25 lost 50% of blocks;"
          f" checkpoint-only recovery would apply ||δ'||²="
          f"{res['recovery']['partial_sq']:.2e} (full ||δ||²="
          f"{res['recovery']['full_sq']:.2e}); tiers used: {tiers}, "
          f"applied ||δ||²={res['recovery']['applied_sq']:.2e}")
    print(f"   SCAR iteration cost: {res['iteration_cost']}")
    fstats = res["fabric_stats"]
    print(f"   arena-native maintenance: {res['arena_state']}; overhead "
          f"{res['maint_seconds_per_iter']*1e3:.2f} ms/iter "
          f"({fstats['maintain_bytes_moved'] // max(fstats['parity_encodes'], 1) / 1e6:.2f} "
          f"MB/iter accounted incl. {fstats['live_packs']} runner-side "
          f"packs, {fstats['arena_maintains']} single-dispatch sweeps)")

    # 3. traditional full checkpoint-restore, same failure
    trad = run_with_failure(model, CheckpointPolicy.traditional(32),
                            fail_iter=25, fail_fraction=0.5, max_iters=150,
                            clean_losses=clean)
    print(f"   traditional iteration cost: {trad['iteration_cost']}")

    # 4. Theorem 3.2 bound for the SCAR perturbation
    c = estimate_contraction(np.sqrt(np.maximum(
        np.asarray(clean) - min(clean) * 0.98, 1e-9))[:100], burn_in=3)
    delta = float(np.sqrt(res["recovery"]["applied_sq"]))
    x0 = model.distance(model.init(jax.random.PRNGKey(1)))
    bound = single_perturbation_bound(delta, c, T=25, x0_err=x0)
    print(f"   Theorem 3.2 bound: {bound:.1f} iterations (c={c:.3f})")
    saved = trad["iteration_cost"] - res["iteration_cost"]
    print(f"== SCAR saved {saved} iterations vs traditional recovery")

    # 5. the same run through the telemetry layer: the ledger prices each
    # recovery with the exact bound above; pass out_dir= to Recorder()
    # for events.jsonl + a Perfetto-loadable trace.json
    rec.ledger.set_rates(c, x0)
    print("\n== telemetry run report (SCAR run)")
    print(format_report(run_report(rec, horizon=150)))


if __name__ == "__main__":
    main()
