"""Decoder-only transformer LM (dense / MoE / VLM-backbone).

- Layers are stacked along a leading L dim and executed with ``lax.scan``
  (keeps HLO size O(1) in depth — essential for 94-layer dry-run compiles).
- Training uses chunked attention + chunked vocab-sharded loss, with
  per-layer remat when ``cfg.remat``.
- Serving uses a KV cache: linear for full-attention decode, ring-buffer
  of ``sliding_window`` slots for the sub-quadratic long-context variant.
- VLM (internvl2): the stub vision frontend supplies patch embeddings
  (B, n_patches, vit_dim); a learned projector maps them to d_model and
  they are prepended to the token embeddings (prefix is loss-masked).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding.partition import DistContext

PyTree = Any


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def interleaved(cfg: ModelConfig) -> bool:
    """llama4-style: dense and MoE layers alternate (moe_every=2)."""
    return bool(cfg.n_experts) and cfg.moe_every > 1


def init_layer(rng, cfg: ModelConfig, *, moe: Optional[bool] = None) -> PyTree:
    dt = _dtype(cfg)
    ks = jax.random.split(rng, 2)
    use_moe = bool(cfg.n_experts) if moe is None else moe
    p = {
        "attn_norm": jnp.ones((cfg.d_model,), dt),
        "attn": L.init_attention(ks[0], cfg, dt),
        "mlp_norm": jnp.ones((cfg.d_model,), dt),
    }
    if use_moe:
        p["moe"] = L.init_moe(ks[1], cfg, dt)
    else:
        d_ff = cfg.d_ff_dense or cfg.d_ff
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, d_ff, dt)
    return p


def init_params(rng, cfg: ModelConfig) -> PyTree:
    dt = _dtype(cfg)
    k_embed, k_layers, k_proj = jax.random.split(rng, 3)
    if interleaved(cfg):
        n_pairs = cfg.n_layers // 2
        kd, km = jax.random.split(k_layers)
        layers = {
            "dense": jax.vmap(lambda k: init_layer(k, cfg, moe=False))(
                jax.random.split(kd, n_pairs)),
            "moe": jax.vmap(lambda k: init_layer(k, cfg, moe=True))(
                jax.random.split(km, n_pairs)),
        }
    else:
        layers = jax.vmap(lambda k: init_layer(k, cfg))(
            jax.random.split(k_layers, cfg.n_layers))
    p = {
        **L.init_embed(k_embed, cfg, dt),
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if cfg.family == "vlm":
        p["projector"] = {"proj": L.dense_init(k_proj, (cfg.vit_dim, cfg.d_model),
                                               cfg.vit_dim, dt)}
    return p


# ---------------------------------------------------------------------------
# forward (training / prefill share the layer body)
# ---------------------------------------------------------------------------

def _layer_fwd(x, lp, cfg: ModelConfig, ctx: DistContext, positions, *,
               window: int, q_chunk: int, kv_chunk: int):
    h = L.attention_block(L.rms_norm(x, lp["attn_norm"]), lp["attn"], cfg, ctx,
                          positions=positions, causal=True, window=window,
                          q_chunk=q_chunk, kv_chunk=kv_chunk)
    x = x + h
    hn = L.rms_norm(x, lp["mlp_norm"])
    if "moe" in lp:
        h2, (lb, zl) = L.moe_block(hn, lp["moe"], cfg, ctx)
    else:
        h2, lb, zl = L.mlp_block(hn, lp["mlp"], ctx), 0.0, 0.0
    return x + h2, (jnp.float32(lb), jnp.float32(zl))


def _stack_fwd(h, params, cfg: ModelConfig, ctx: DistContext, positions, *,
               window: int, q_chunk=1024, kv_chunk=1024):
    def layer_call(x, lp):
        x, aux = _layer_fwd(x, lp, cfg, ctx, positions, window=window,
                            q_chunk=q_chunk, kv_chunk=kv_chunk)
        # sequence-parallel residual stream between layers: the saved
        # activation (remat carry) is S-sharded over the model axis —
        # Megatron SP adapted to XLA SPMD (all-gather re-forms S inside
        # the next layer's attention; reduce-scatter closes it).
        return ctx.shard(x, "dp", ctx.tp, None), aux

    fn = layer_call
    if cfg.remat:
        fn = jax.checkpoint(layer_call,
                            policy=jax.checkpoint_policies.nothing_saveable)

    if interleaved(cfg):
        def body(carry, pair):
            x, lb, zl = carry
            x, (l1, l2) = fn(x, pair["dense"])
            x, (l3, l4) = fn(x, pair["moe"])
            return (x, lb + l1 + l3, zl + l2 + l4), None
    else:
        def body(carry, lp):
            x, lb, zl = carry
            x, (l1, l2) = fn(x, lp)
            return (x, lb + l1, zl + l2), None

    (h, lb, zl), _ = jax.lax.scan(body, (h, jnp.float32(0), jnp.float32(0)),
                                  params["layers"],
                                  unroll=L.UNROLL_FOR_COSTING)
    return L.rms_norm(h, params["final_norm"]), lb, zl


def _embed_batch(params, batch, cfg: ModelConfig, ctx: DistContext):
    """Token (+ optional VLM patch-prefix) embeddings -> (B, S_total, D)."""
    tok = L.embed_tokens(batch["tokens"], params, ctx)
    if cfg.family == "vlm" and "patches" in batch:
        prefix = jnp.einsum("bpv,vd->bpd",
                            batch["patches"].astype(_dtype(cfg)),
                            params["projector"]["proj"])
        tok = jnp.concatenate([prefix, tok], axis=1)
    return ctx.shard(tok, "dp", None, None)


def train_loss(params, batch, cfg: ModelConfig, ctx: DistContext,
               *, window_override: Optional[int] = None):
    h = _embed_batch(params, batch, cfg, ctx)
    B, S, _ = h.shape
    positions = jnp.arange(S)
    # training defaults to full causal attention; the sliding-window variant
    # (the long_500k sub-quadratic opt-in) is selected via window_override.
    window = 0 if window_override is None else window_override
    h, lb, zl = _stack_fwd(h, params, cfg, ctx, positions, window=window,
                           q_chunk=cfg.attn_chunk, kv_chunk=cfg.attn_chunk)
    labels = batch["labels"]
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    n_prefix = h.shape[1] - labels.shape[1]
    if n_prefix:  # VLM: no loss on the image prefix
        h = h[:, n_prefix:]
    loss = L.lm_loss_chunked(h, params, labels, mask, cfg, ctx)
    if cfg.n_experts:
        loss = loss + 0.01 * lb / cfg.n_layers + 0.001 * zl / cfg.n_layers
    return loss


# ---------------------------------------------------------------------------
# serving: prefill + single-token decode with KV cache
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CacheSpec:
    cache_len: int      # slots (== window for ring-buffer archs)
    ring: bool


def cache_spec(cfg: ModelConfig, seq_len: int, *, use_window: bool) -> CacheSpec:
    if use_window and cfg.sliding_window and seq_len > cfg.sliding_window:
        return CacheSpec(cache_len=cfg.sliding_window, ring=True)
    return CacheSpec(cache_len=seq_len, ring=False)


def init_cache(params_or_none, cfg: ModelConfig, batch: int, spec: CacheSpec,
               ctx: DistContext) -> PyTree:
    dt = jnp.int8 if cfg.kv_quant else _dtype(cfg)
    Hk, Dh, Ln = cfg.n_kv_heads, cfg.head_dim, cfg.n_layers
    shape = (Ln, batch, spec.cache_len, Hk, Dh)
    hspec = (None, "dp", None, ctx.tp, None)
    cache = {
        "k": ctx.shard(jnp.zeros(shape, dt), *hspec),
        "v": ctx.shard(jnp.zeros(shape, dt), *hspec),
        "kpos": jnp.full((spec.cache_len,), -1, jnp.int32),
        "pos": jnp.zeros((), jnp.int32),
    }
    if cfg.kv_quant:
        sshape = (Ln, batch, spec.cache_len, Hk)
        cache["k_scale"] = ctx.shard(jnp.zeros(sshape, jnp.float32),
                                     None, "dp", None, ctx.tp)
        cache["v_scale"] = ctx.shard(jnp.zeros(sshape, jnp.float32),
                                     None, "dp", None, ctx.tp)
    return cache


def decode_step(params, cache, tokens, cfg: ModelConfig, ctx: DistContext,
                spec: CacheSpec):
    """One decode step. tokens: (B, 1) -> logits (B, 1, V), updated cache."""
    x = L.embed_tokens(tokens, params, ctx)
    x = ctx.shard(x, "dp", None, None)
    pos = cache["pos"]
    positions = pos[None] + jnp.zeros((1,), jnp.int32)
    slot = (pos % spec.cache_len) if spec.ring else pos
    kpos = cache["kpos"].at[slot].set(pos)
    window = cfg.sliding_window if spec.ring else 0
    kv_chunk = min(cfg.attn_chunk, spec.cache_len)

    def one_layer(x, lp, kc, vc, ksc=None, vsc=None):
        xn = L.rms_norm(x, lp["attn_norm"])
        q, k, v = L.qkv_project(xn, lp["attn"], cfg, ctx, positions)
        if cfg.kv_quant:
            # §Perf C: int8 cache — quantize the new token, stream the
            # cache in int8 (halves the decode memory term)
            k8, ks_new = L.quantize_kv(k)
            v8, vs_new = L.quantize_kv(v)
            kc = jax.lax.dynamic_update_slice_in_dim(kc, k8, slot, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(vc, v8, slot, axis=1)
            ksc = jax.lax.dynamic_update_slice_in_dim(ksc, ks_new, slot, axis=1)
            vsc = jax.lax.dynamic_update_slice_in_dim(vsc, vs_new, slot, axis=1)
            o = L.flash_attention_kvq(q, kc, vc, ksc, vsc, positions, kpos,
                                      window=window, kv_chunk=kv_chunk,
                                      ctx=ctx)
        else:
            kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype),
                                                     slot, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype),
                                                     slot, axis=1)
            o = L.flash_attention(q, kc, vc, positions, kpos, causal=True,
                                  window=window, q_chunk=1, kv_chunk=kv_chunk,
                                  ctx=ctx)
        h = jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])
        x = x + ctx.shard(h, "dp", None, None)
        hn = L.rms_norm(x, lp["mlp_norm"])
        if "moe" in lp:
            h2, _ = L.moe_block(hn, lp["moe"], cfg, ctx)
        else:
            h2 = L.mlp_block(hn, lp["mlp"], ctx)
        if ksc is not None:
            return x + h2, kc, vc, ksc, vsc
        return x + h2, kc, vc

    quant = cfg.kv_quant
    if interleaved(cfg):
        n_pairs = cfg.n_layers // 2

        def pairify(a):
            return a.reshape((n_pairs, 2) + a.shape[1:])

        if quant:
            def body(x, xs):
                pair, kcs, vcs, kss, vss = xs
                x, k0, v0, s0, t0 = one_layer(x, pair["dense"], kcs[0],
                                              vcs[0], kss[0], vss[0])
                x, k1, v1, s1, t1 = one_layer(x, pair["moe"], kcs[1],
                                              vcs[1], kss[1], vss[1])
                return x, (jnp.stack([k0, k1]), jnp.stack([v0, v1]),
                           jnp.stack([s0, s1]), jnp.stack([t0, t1]))

            x, (k_new, v_new, ks_new, vs_new) = jax.lax.scan(
                body, x, (params["layers"], pairify(cache["k"]),
                          pairify(cache["v"]), pairify(cache["k_scale"]),
                          pairify(cache["v_scale"])),
                unroll=L.UNROLL_FOR_COSTING)
            k_new = k_new.reshape(cache["k"].shape)
            v_new = v_new.reshape(cache["v"].shape)
            ks_new = ks_new.reshape(cache["k_scale"].shape)
            vs_new = vs_new.reshape(cache["v_scale"].shape)
        else:
            def body(x, xs):
                pair, kcs, vcs = xs
                x, k0, v0 = one_layer(x, pair["dense"], kcs[0], vcs[0])
                x, k1, v1 = one_layer(x, pair["moe"], kcs[1], vcs[1])
                return x, (jnp.stack([k0, k1]), jnp.stack([v0, v1]))

            x, (k_new, v_new) = jax.lax.scan(
                body, x, (params["layers"], pairify(cache["k"]),
                          pairify(cache["v"])),
                unroll=L.UNROLL_FOR_COSTING)
            k_new = k_new.reshape(cache["k"].shape)
            v_new = v_new.reshape(cache["v"].shape)
    else:
        if quant:
            def body(x, xs):
                lp, kc, vc, ks, vs = xs
                x, kc, vc, ks, vs = one_layer(x, lp, kc, vc, ks, vs)
                return x, (kc, vc, ks, vs)

            x, (k_new, v_new, ks_new, vs_new) = jax.lax.scan(
                body, x, (params["layers"], cache["k"], cache["v"],
                          cache["k_scale"], cache["v_scale"]),
                unroll=L.UNROLL_FOR_COSTING)
        else:
            def body(x, xs):
                lp, kc, vc = xs
                x, kc, vc = one_layer(x, lp, kc, vc)
                return x, (kc, vc)

            x, (k_new, v_new) = jax.lax.scan(
                body, x, (params["layers"], cache["k"], cache["v"]),
                unroll=L.UNROLL_FOR_COSTING)
    h = L.rms_norm(x, params["final_norm"])
    logits = L.lm_logits(h, params, ctx)
    new_cache = {"k": k_new, "v": v_new, "kpos": kpos, "pos": pos + 1}
    if quant:
        new_cache["k_scale"] = ks_new
        new_cache["v_scale"] = vs_new
    return logits, new_cache


def prefill(params, batch, cfg: ModelConfig, ctx: DistContext,
            spec: CacheSpec):
    """Prefill over a full prompt; returns (logits_last, cache).

    For simplicity the production prefill materializes the cache by running
    the stacked forward and recomputing K/V per layer (ys of the scan).
    """
    h = _embed_batch(params, batch, cfg, ctx)
    B, S, _ = h.shape
    positions = jnp.arange(S)
    window = cfg.sliding_window if (cfg.sliding_window and spec.ring) else 0

    def one_layer(x, lp):
        xn = L.rms_norm(x, lp["attn_norm"])
        q, k, v = L.qkv_project(xn, lp["attn"], cfg, ctx, positions)
        if cfg.triangle_prefill and window == 0:
            # §Perf A: causal prefill skips the masked-out upper-triangle
            # kv tiles entirely (~2× fewer attention FLOPs at long S)
            o = L.flash_attention_triangle(
                q, k, v, positions, positions,
                q_chunk=min(cfg.attn_chunk, S),
                kv_chunk=min(cfg.attn_chunk, S), ctx=ctx)
        else:
            o = L.flash_attention(q, k, v, positions, positions, causal=True,
                                  window=window,
                                  q_chunk=min(cfg.attn_chunk, S),
                                  kv_chunk=min(cfg.attn_chunk, S), ctx=ctx)
        a = jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])
        x = x + ctx.shard(a, "dp", None, None)
        hn = L.rms_norm(x, lp["mlp_norm"])
        if "moe" in lp:
            h2, _ = L.moe_block(hn, lp["moe"], cfg, ctx)
        else:
            h2 = L.mlp_block(hn, lp["mlp"], ctx)
        if spec.ring:
            # place the last `cache_len` positions at their ring slots so
            # subsequent decode writes (slot = pos % cache_len) line up
            W = spec.cache_len
            kept_pos = jnp.arange(S - W, S)
            slots = kept_pos % W
            k_keep = jnp.zeros((k.shape[0], W) + k.shape[2:], _dtype(cfg))
            v_keep = jnp.zeros_like(k_keep)
            k_keep = k_keep.at[:, slots].set(k[:, -W:].astype(_dtype(cfg)))
            v_keep = v_keep.at[:, slots].set(v[:, -W:].astype(_dtype(cfg)))
        else:
            k_keep, v_keep = k.astype(_dtype(cfg)), v.astype(_dtype(cfg))
        return x + h2, (k_keep, v_keep)

    if interleaved(cfg):
        def body(x, pair):
            x, (k0, v0) = one_layer(x, pair["dense"])
            x, (k1, v1) = one_layer(x, pair["moe"])
            return x, (jnp.stack([k0, k1]), jnp.stack([v0, v1]))

        x, (ks, vs) = jax.lax.scan(body, h, params["layers"],
                                   unroll=L.UNROLL_FOR_COSTING)
        ks = ks.reshape((cfg.n_layers,) + ks.shape[2:])
        vs = vs.reshape((cfg.n_layers,) + vs.shape[2:])
    else:
        def body(x, lp):
            return one_layer(x, lp)

        x, (ks, vs) = jax.lax.scan(body, h, params["layers"])
    hfin = L.rms_norm(x, params["final_norm"])
    logits = L.lm_logits(hfin[:, -1:], params, ctx)
    if not spec.ring and spec.cache_len > S:
        # decode slack: room for subsequently generated tokens
        pad = spec.cache_len - S
        zk = jnp.zeros(ks.shape[:2] + (pad,) + ks.shape[3:], ks.dtype)
        ks = jnp.concatenate([ks, zk], axis=2)
        vs = jnp.concatenate([vs, zk], axis=2)
    kept = min(spec.cache_len, S)
    kpos = jnp.full((spec.cache_len,), -1, jnp.int32)
    kept_positions = jnp.arange(S - kept, S)
    kpos = kpos.at[kept_positions % spec.cache_len].set(kept_positions)
    cache = {"k": ks, "v": vs, "kpos": kpos,
             "pos": jnp.asarray(S, jnp.int32)}
    if cfg.kv_quant:
        cache["k"], cache["k_scale"] = L.quantize_kv(ks)
        cache["v"], cache["v_scale"] = L.quantize_kv(vs)
    return logits, cache
