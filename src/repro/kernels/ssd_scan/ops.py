"""jit'd wrapper: full SSD scan with kernel-backed intra-chunk compute.

``ssd_chunked_kernel`` matches :func:`repro.models.ssm.ssd_chunked`
(the pure-jnp reference the models use): kernel for the quadratic part,
jnp for the O(nc) inter-chunk recurrence + rank-1 correction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_intra_pallas
from repro.kernels.ssd_scan.ref import ssd_intra_ref


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ssd_intra(la, dt, x, Bm, Cm, use_pallas: bool = True,
              interpret: bool | None = None):
    if not use_pallas:
        return ssd_intra_ref(la, dt, x, Bm, Cm)
    if interpret is None:
        interpret = not _is_tpu()
    return ssd_intra_pallas(la, dt, x, Bm, Cm, interpret=interpret)


def ssd_chunked_kernel(x, dt, A, Bm, Cm, chunk: int, h0=None,
                       use_pallas: bool = True,
                       interpret: bool | None = None):
    """Full SSD scan. Same contract as models.ssm.ssd_chunked:

    x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm, Cm: (B,S,N)
    -> (y (B,S,H,P), h_final (B,H,P,N))
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    nc = S // Q
    assert nc * Q == S

    la = (dt * A).reshape(Bsz, nc, Q, H)
    xc = x.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)

    y_intra, chunk_state = ssd_intra(la, dtc, xc, Bc, Cc,
                                     use_pallas=use_pallas,
                                     interpret=interpret)
    # chunk_state from kernel: (B, nc, H, N, P) -> match (B, nc, H, P, N)
    chunk_state = jnp.swapaxes(chunk_state, -1, -2)

    cum = jnp.cumsum(la, axis=2)
    seg_total = cum[:, :, -1]                                # (B,nc,H)
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def body(h, xs):
        seg, st = xs
        h_out = h
        h = h * jnp.exp(seg)[:, :, None, None] + st
        return h, h_out

    h_final, h_prev = jax.lax.scan(
        body, h0, (jnp.moveaxis(seg_total, 1, 0),
                   jnp.moveaxis(chunk_state, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                      # (B,nc,H,P,N)

    y_inter = jnp.einsum("bcin,bchpn->bcihp", Cc, h_prev) \
        * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y, h_final
