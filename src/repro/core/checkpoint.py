"""Running checkpoint + selection strategies (paper §4.2, §4.3).

The *running checkpoint* lives in device memory (the paper's "in-memory
cache" on each PS node) and is mirrored to persistent storage asynchronously
by :mod:`repro.checkpoint_io`. It is initialized to ``x^{(0)}`` and updated
in place by partial checkpoints, so at any time it holds a mix of parameters
saved at different iterations — exactly the paper's construction.

``save_step`` is a pure jittable function: given the live params and the
current checkpoint it returns the new checkpoint plus the selected block
mask — the ``jnp.where`` fold rewrites every leaf, so it moves O(model)
bytes per save. It remains the reference semantics (and the
``FTController(inplace_save=False)`` path). The controller has two
faster, bit-equivalent save paths above it (both measured in
``bench_maintain``):

- **tree scatter** (no fabric): ``select_save_mask`` picks the mask, then
  :func:`repro.kernels.fused_maintain.ops.tree_scatter_save` scatters
  only the selected blocks into the donated checkpoint buffers —
  O(k·block_bytes), one dispatch per touched leaf;
- **arena scatter** (arena-capable fabric, the default): the checkpoint
  values live as a flat parameter arena (:mod:`repro.core.arena`) and the
  save is ONE donated tile scatter — O(k·seg_bytes) and a single dispatch
  for the whole model, which also wins on wall-clock where per-leaf
  dispatch overhead used to dominate. With **arena-resident training
  state** (the default trainer path) the scatter sources straight from
  the live arena itself — the training state IS this step's values, so
  there is no pack and no replica freshness gating; tree-stepping callers
  source from the maintenance sweep's replica arena instead (same
  values when fresh, else a one-off pack).

Selection strategies:

- PRIORITY     — top-k blocks by distance-since-last-save (paper §4.2).
- ROUND_ROBIN  — k blocks at a rotating cursor (paper §5.4 baseline).
- RANDOM       — k blocks uniformly at random (paper §5.4 baseline).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.blocks import BlockPartition, block_scores, select_blocks
from repro.core.norms import NormFn
from repro.core.policy import CheckpointPolicy, SelectionStrategy

PyTree = Any


@partial(jax.tree_util.register_dataclass,
         data_fields=["values", "saved_iter", "rr_cursor"],
         meta_fields=[])
@dataclasses.dataclass
class RunningCheckpoint:
    values: PyTree              # same structure/shapes as params
    saved_iter: jnp.ndarray     # (total_blocks,) int32 — iter each block was saved
    rr_cursor: jnp.ndarray      # () int32 — round-robin cursor


def init_running_checkpoint(params: PyTree, partition: BlockPartition) -> RunningCheckpoint:
    """Paper §4.2: the running checkpoint starts as x^{(0)}."""
    return RunningCheckpoint(
        values=jax.tree_util.tree_map(jnp.array, params),
        saved_iter=jnp.zeros((partition.total_blocks,), jnp.int32),
        rr_cursor=jnp.zeros((), jnp.int32),
    )


def _mask_from_indices(idx: jnp.ndarray, total: int) -> jnp.ndarray:
    return jnp.zeros((total,), bool).at[idx].set(True)


def select_save_mask(ckpt: RunningCheckpoint, params: PyTree, *,
                     policy: CheckpointPolicy, partition: BlockPartition,
                     norm_fn: NormFn, rng: Optional[jax.Array] = None,
                     scores: Optional[jnp.ndarray] = None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Choose which blocks to save. Returns (mask, new_rr_cursor).

    ``scores`` may be precomputed (e.g. by the Pallas block_dist kernel);
    otherwise they are computed with ``norm_fn`` for the PRIORITY strategy.
    """
    total = partition.total_blocks
    k = partition.blocks_for_k(policy.fraction)
    if policy.strategy == SelectionStrategy.PRIORITY:
        if scores is None:
            scores = block_scores(params, ckpt.values, partition, norm_fn)
        _, idx = jax.lax.top_k(scores, k)
        return _mask_from_indices(idx, total), ckpt.rr_cursor
    if policy.strategy == SelectionStrategy.ROUND_ROBIN:
        idx = (ckpt.rr_cursor + jnp.arange(k)) % total
        return _mask_from_indices(idx, total), (ckpt.rr_cursor + k) % total
    if policy.strategy == SelectionStrategy.RANDOM:
        if rng is None:
            raise ValueError("RANDOM strategy requires an rng key")
        idx = jax.random.choice(rng, total, (k,), replace=False)
        return _mask_from_indices(idx, total), ckpt.rr_cursor
    raise ValueError(f"unknown strategy {policy.strategy}")


def save_step(ckpt: RunningCheckpoint, params: PyTree, step: jnp.ndarray, *,
              policy: CheckpointPolicy, partition: BlockPartition,
              norm_fn: NormFn, rng: Optional[jax.Array] = None,
              scores: Optional[jnp.ndarray] = None,
              ) -> tuple[RunningCheckpoint, jnp.ndarray]:
    """One partial-checkpoint update. Pure & jittable (policy/partition static).

    Returns (new_checkpoint, saved_block_mask).
    """
    mask, cursor = select_save_mask(ckpt, params, policy=policy,
                                    partition=partition, norm_fn=norm_fn,
                                    rng=rng, scores=scores)
    new_values = select_blocks(ckpt.values, params, mask, partition)
    new_saved = jnp.where(mask, jnp.int32(step), ckpt.saved_iter)
    return RunningCheckpoint(new_values, new_saved, cursor), mask


def full_save(ckpt: RunningCheckpoint, params: PyTree,
              step: jnp.ndarray) -> RunningCheckpoint:
    """Traditional full checkpoint: overwrite everything (r = 1 fast path)."""
    return RunningCheckpoint(
        values=jax.tree_util.tree_map(jnp.array, params),
        saved_iter=jnp.full_like(ckpt.saved_iter, jnp.int32(step)),
        rr_cursor=ckpt.rr_cursor,
    )
