"""Pallas TPU kernel: fused masked block restore (SCAR partial recovery).

On recovery, the lost blocks take the checkpoint's values and survivors
keep their live values: ``out[b] = mask[b] ? src[b] : dst[b]``. Fusing the
select avoids materializing a full-size expanded boolean mask (the jnp
path builds a (rows, 1)-broadcast bool per leaf) and performs exactly one
HBM read per input element and one write — memory-roofline optimal.

Grid/layout identical to block_dist: (n_blocks, E) tiles of (BB, BE);
the (BB,) int32 mask block rides along the i axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BB = 8
BE = 512


def _masked_restore_kernel(dst_ref, src_ref, mask_ref, out_ref):
    m = mask_ref[...]                        # (BB,) int32
    sel = (m > 0)[:, None]
    out_ref[...] = jnp.where(sel, src_ref[...], dst_ref[...])


@functools.partial(jax.jit, static_argnames=("interpret",))
def masked_restore_pallas(dst: jnp.ndarray, src: jnp.ndarray,
                          mask: jnp.ndarray,
                          interpret: bool = False) -> jnp.ndarray:
    """dst, src: (n_blocks, E); mask: (n_blocks,) bool → (n_blocks, E)."""
    n, e = dst.shape
    n_pad = -n % BB
    e_pad = -e % BE
    mask_i = mask.astype(jnp.int32)
    if n_pad or e_pad:
        dst = jnp.pad(dst, ((0, n_pad), (0, e_pad)))
        src = jnp.pad(src, ((0, n_pad), (0, e_pad)))
        mask_i = jnp.pad(mask_i, (0, n_pad))
    np_, ep_ = dst.shape
    grid = (np_ // BB, ep_ // BE)
    out = pl.pallas_call(
        _masked_restore_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BB, BE), lambda i, j: (i, j)),
            pl.BlockSpec((BB, BE), lambda i, j: (i, j)),
            pl.BlockSpec((BB,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((BB, BE), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, ep_), dst.dtype),
        interpret=interpret,
    )(dst, src, mask_i)
    return out[:n, :e]
