"""Beyond-paper: tiered recovery fabric vs checkpoint-only SCAR.

For a *host-level correlated* failure (a whole failure domain dies, taking
every block homed there — the case Thm 4.2's uniform model misses), compare:

  ckpt-only — SCAR partial recovery from the running checkpoint,
  parity    — XOR parity groups (1/g memory overhead), replica tier off,
  tiered    — anti-affine peer replicas + parity + running ckpt + disk.

Reported per variant: applied perturbation ||δ'||² at the failure, measured
iteration cost ι (paper §5 methodology, mean over seeds), per-tier block
counts, and the estimated recovery latency. Also validates the Pallas
``parity_xor`` kernel against its jnp oracle (bit-exact) and times it.

Expected: replica/parity tiers recover live values — ||δ'||² ≈ 0, strictly
below ckpt-only's, and iteration cost does not increase.

A second, degraded-mode section drives a 3-event host-loss MTBF trace where
failed hosts stay dead between events, comparing the elastic placement
engine (re-home + re-seed + re-stripe after every loss) against
recover-in-place (redundancy wiring left pointing at dead devices): elastic
keeps every later recovery on the PEER_REPLICA/PARITY tiers, in-place falls
through to RUNNING_CKPT/DISK once the degraded topology eats its replicas.

Standalone: ``python -m benchmarks.bench_tiered_recovery [--quick]
[--out BENCH_tiered_recovery.json]`` (the CI smoke job's entry point).
"""
from __future__ import annotations

import argparse
import json

import numpy as np
import jax.numpy as jnp

from benchmarks.common import csv_row, summarize, timed
from repro.core.policy import CheckpointPolicy, RecoveryMode, SelectionStrategy
from repro.fabric import FabricConfig, FailureEvent
from repro.kernels.parity_xor.kernel import parity_xor_pallas
from repro.kernels.parity_xor.ref import parity_xor_ref
from repro.models.classic import make_model
from repro.training import run_clean, run_with_failure, run_with_trace

VARIANTS = {
    "ckpt_only": dict(replicate=False, parity=False),
    "parity": dict(replicate=False, parity=True),
    "tiered": dict(replicate=True, parity=True),
}


def _fabric_cfg(**kw) -> FabricConfig:
    # use_pallas auto-resolves: compiled kernel on TPU, jnp oracle on this
    # CPU host; the Pallas kernel itself is validated below (interpret mode)
    return FabricConfig(n_devices=8, devices_per_host=2, hosts_per_rack=2,
                        **kw)


def _kernel_check_rows(quick: bool) -> list[str]:
    rng = np.random.default_rng(3)
    n, g, e = (8, 3, 512) if quick else (32, 3, 2048)
    frames = jnp.asarray(rng.integers(-2**31, 2**31, (n, g, e)), jnp.int32)
    base = jnp.asarray(rng.integers(-2**31, 2**31, (n, e)), jnp.int32)
    keep = jnp.asarray(rng.random((n, g)) < 0.7, jnp.int32)
    got, us = timed(lambda: np.asarray(
        parity_xor_pallas(frames, base, keep, interpret=True)))
    want = np.asarray(parity_xor_ref(frames, base, keep))
    exact = bool((got == want).all())
    _, ref_us = timed(lambda: np.asarray(parity_xor_ref(frames, base, keep)))
    return [csv_row("tier_parity_xor_kernel", us,
                    f"matches_ref={exact};bit_exact_tol=0;"
                    f"shape={n}x{g}x{e};ref_us={ref_us:.1f}")]


def _soak_rows(model, policy, clean, max_iters: int) -> list[str]:
    """Degraded-mode soak: 3 host losses, no healing — elastic vs in-place."""
    trace = [FailureEvent(step=max_iters // 6, kind="host", index=0),
             FailureEvent(step=max_iters // 2 - 5, kind="host", index=1),
             FailureEvent(step=2 * max_iters // 3 + 5, kind="host", index=2)]
    rows = []
    fallthrough = {}
    costs = {}
    avail = {}
    for name, kw in (("elastic", dict(elastic=True)),
                     ("inplace", dict(elastic=False))):
        r = run_with_trace(model, policy, fabric=_fabric_cfg(**kw),
                           max_iters=max_iters, seed=0, clean_losses=clean,
                           trace=trace)
        avail[name] = r["availability"]
        events = [e for e in r["events"] if not e.get("skipped")]
        later = events[1:]
        ckpt_disk = sum(e["tier_counts"]["RUNNING_CKPT"]
                        + e["tier_counts"]["DISK"] for e in later)
        cheap = sum(e["tier_counts"]["PEER_REPLICA"]
                    + e["tier_counts"]["PARITY"] for e in later)
        sq_total = sum(e["applied_sq"] for e in events)
        fallthrough[name] = ckpt_disk
        costs[name] = max(r["iteration_cost"], 0)
        rows.append(csv_row(
            f"tier_soak_{name}", 0.0,
            f"events={len(events)};iter_cost={costs[name]:.1f};"
            f"applied_sq_total={sq_total:.3e};"
            f"later_replica_parity_blocks={cheap};"
            f"later_ckpt_disk_blocks={ckpt_disk}"))
    rows.append(csv_row(
        "tier_soak_headline", 0.0,
        f"elastic_avoids_ckpt_tiers={bool(fallthrough['elastic'] == 0)};"
        f"inplace_fellthrough_blocks={fallthrough['inplace']};"
        f"elastic_iter_cost={costs['elastic']:.1f};"
        f"inplace_iter_cost={costs['inplace']:.1f}"))
    # availability/goodput report aggregated from the per-event tier
    # accounting + per-step redundancy flags (ROADMAP "soak-run
    # availability report"): elastic re-planning restores full redundancy
    # within the failure step, recover-in-place never does
    for name, av in avail.items():
        ttf = av["mean_time_to_full"]
        rows.append(csv_row(
            f"tier_soak_availability_{name}", 0.0,
            f"frac_steps_full={av['frac_steps_full']:.3f};"
            f"mean_steps_to_full_redundancy="
            f"{'censored' if ttf is None else format(ttf, '.1f')};"
            f"censored_events={av['censored_events']};"
            f"cheap_tier_blocks={av['cheap_tier_blocks']};"
            f"ckpt_disk_blocks={av['ckpt_disk_blocks']}"))
    rows.append(csv_row(
        "tier_soak_availability", 0.0,
        f"elastic_frac_full={avail['elastic']['frac_steps_full']:.3f};"
        f"inplace_frac_full={avail['inplace']['frac_steps_full']:.3f};"
        f"elastic_more_available="
        f"{bool(avail['elastic']['frac_steps_full'] > avail['inplace']['frac_steps_full'])}"))
    return rows


def run(trials: int = 5, quick: bool = False) -> list[str]:
    if quick:
        trials = 3
    rows = _kernel_check_rows(quick)

    model = make_model("mlr", n=600, dim=64, n_classes=5, batch=200)
    max_iters = 120
    clean = run_clean(model, max_iters, seed=0)["losses"]
    # SCAR partial-checkpoint policy: the running ckpt holds a stale mix of
    # blocks, so its recovery perturbation is visibly nonzero mid-training
    policy = CheckpointPolicy(fraction=0.25, full_interval=8,
                              strategy=SelectionStrategy.ROUND_ROBIN,
                              recovery=RecoveryMode.PARTIAL,
                              block_rows=model.block_rows)

    results = {name: {"sq": [], "cost": [], "latency": [], "counts": {}}
               for name in VARIANTS}
    for seed in range(trials):
        fail_iter = 10 + int(np.random.default_rng(seed).geometric(0.08))
        fail_iter = min(fail_iter, 40)
        for name, kw in VARIANTS.items():
            r = run_with_failure(
                model, policy, fail_iter=fail_iter, fail_fraction=0.5,
                max_iters=max_iters, seed=seed, clean_losses=clean,
                fabric=_fabric_cfg(**kw), fail_domain="host")
            rec = r["recovery"]
            results[name]["sq"].append(rec["applied_sq"])
            results[name]["cost"].append(max(r["iteration_cost"], 0))
            results[name]["latency"].append(
                sum(rec["est_recovery_seconds"].values()))
            for k, v in rec["tier_counts"].items():   # aggregate over seeds
                results[name]["counts"][k] = \
                    results[name]["counts"].get(k, 0) + v

    for name, res in results.items():
        sq_m, _ = summarize(res["sq"])
        c_m, c_s = summarize(res["cost"])
        lat_m, _ = summarize(res["latency"])
        counts = ";".join(f"{k}={v}" for k, v in res["counts"].items()
                          if v and k != "SURVIVOR")
        rows.append(csv_row(
            f"tier_hostfail_{name}", 0.0,
            f"applied_sq={sq_m:.3e};iter_cost={c_m:.1f}±{c_s:.1f};"
            f"est_recovery_s={lat_m:.2e};tiers[{counts}]"))

    sq_ck = np.mean(results["ckpt_only"]["sq"])
    sq_tier = np.mean(results["tiered"]["sq"])
    sq_par = np.mean(results["parity"]["sq"])
    cost_ck = np.mean(results["ckpt_only"]["cost"])
    cost_tier = np.mean(results["tiered"]["cost"])
    rows.append(csv_row(
        "tier_headline", 0.0,
        f"tiered_sq_strictly_lower={bool(sq_tier < sq_ck)};"
        f"parity_sq_strictly_lower={bool(sq_par < sq_ck)};"
        f"iter_cost_not_worse={bool(cost_tier <= cost_ck)};"
        f"ckpt_sq={sq_ck:.3e};tiered_sq={sq_tier:.3e}"))

    rows.extend(_soak_rows(model, policy, clean, max_iters))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--trials", type=int, default=5)
    ap.add_argument("--out", default="",
                    help="also write rows as JSON (CI perf trajectory)")
    args = ap.parse_args()
    rows = run(trials=args.trials, quick=args.quick)
    print("name,us_per_call,derived")
    for row in rows:
        print(row, flush=True)
    if args.out:
        parsed = []
        for row in rows:
            name, us, derived = row.split(",", 2)
            parsed.append({"name": name, "us_per_call": float(us),
                           "derived": derived})
        with open(args.out, "w") as f:
            json.dump({"bench": "tiered_recovery", "quick": args.quick,
                       "rows": parsed}, f, indent=2)


if __name__ == "__main__":
    main()
