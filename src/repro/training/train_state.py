"""Train state containers for the SPMD LM trainer.

Two live representations:

- :class:`TrainState` — the classic PyTree form (params as a tree of
  leaf-shaped arrays). Kept as the fallback for non-arena-compatible
  models (exotic dtypes, custom scorers) behind
  ``TrainLoopConfig(arena_state=False)``.
- :class:`ArenaTrainState` — the arena-native form: the canonical live
  parameters are ONE contiguous f32 buffer laid out by an
  :class:`~repro.core.arena.ArenaLayout`, and the optimizer moments are
  flat mirrors of it. The fault-tolerance hot path (the fabric's
  maintenance sweep and the controller's partial save) consumes
  ``state.arena`` directly — no per-step ``pack_arena`` — and the jitted
  train step donates the arena through the optimizer update. The tree
  form the model's forward pass needs is decoded *inside* the step
  program; outside jit, :attr:`ArenaTrainState.params` materializes a
  lazily-cached tree view for analysis/examples (never the hot loop).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.optim.optimizers import OptState

PyTree = Any


@partial(jax.tree_util.register_dataclass,
         data_fields=["params", "opt_state", "step"], meta_fields=[])
@dataclasses.dataclass
class TrainState:
    params: PyTree
    opt_state: OptState
    step: jnp.ndarray

    @classmethod
    def create(cls, params: PyTree, optimizer) -> "TrainState":
        return cls(params=params, opt_state=optimizer.init(params),
                   step=jnp.zeros((), jnp.int32))


@partial(jax.tree_util.register_dataclass,
         data_fields=["arena", "opt_state", "step"], meta_fields=["layout"])
@dataclasses.dataclass
class ArenaTrainState:
    """Arena-resident training state: ``arena`` is the canonical live
    parameter representation (flat f32, ``layout.total_words`` long);
    ``opt_state`` moment buffers are flat mirrors of it.

    ``layout`` is static metadata (the ArenaLayout is identity-hashed, so
    the whole training run must thread the same instance — the one the
    controller's fabric built)."""
    arena: jnp.ndarray
    opt_state: OptState
    step: jnp.ndarray
    layout: Any = None

    @classmethod
    def create(cls, arena: jnp.ndarray, optimizer,
               layout) -> "ArenaTrainState":
        # Moments are flat f32 mirrors in the *value* domain
        # (total_values == total_words for all-f32 layouts, where this
        # degenerates to init-on-the-arena; larger for quantized layouts
        # whose words hold >1 element). The arena itself is a one-leaf
        # pytree, so optimizer.init applies unchanged (zeros stay zero
        # on pads). Shape is what matters — init only reads it.
        if layout is not None and layout.total_values != arena.size:
            seed = jnp.zeros((layout.total_values,), jnp.float32)
        else:
            seed = arena
        return cls(arena=arena, opt_state=optimizer.init(seed),
                   step=jnp.zeros((), jnp.int32), layout=layout)

    @property
    def params(self) -> PyTree:
        """Lazily-cached tree view of the arena (decoded on first access;
        analysis/recovery convenience — the hot loop never calls this).
        The cache is keyed on the arena buffer itself, so reassigning
        ``state.arena`` in place invalidates it rather than serving
        stale values."""
        assert self.layout is not None, \
            "ArenaTrainState needs its layout to decode params"
        cached = getattr(self, "_tree_view", None)
        if cached is None or cached[0] is not self.arena:
            from repro.core.arena import unpack_arena
            cached = (self.arena, unpack_arena(self.arena, self.layout))
            object.__setattr__(self, "_tree_view", cached)
        return cached[1]
