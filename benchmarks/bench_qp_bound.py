"""Figure 3: iteration cost vs Theorem 3.2 bound on a quadratic program.

(a) single random perturbation of varying size at a fixed iteration;
(b) same, cost plotted against Δ_T;
(c) perturbations generated with probability p each iteration.

The red line of the paper is the Thm 3.2 bound with empirically-fitted c.
Derived check: the bound upper-bounds every measured cost (within integer
slack) and is tight (≤ few iterations gap) for the worst trials.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, summarize
from repro.core.iteration_cost import (empirical_iteration_cost,
                                       estimate_contraction,
                                       iteration_cost_bound,
                                       single_perturbation_bound)
from repro.models.classic import make_model
from repro.training import run_clean, run_with_perturbation


def run(trials: int = 30, quick: bool = False) -> list[str]:
    if quick:
        trials = 10
    model = make_model("qp")
    max_iters = 500
    clean = run_clean(model, max_iters, seed=0)["losses"]
    # distance trajectory for c-fit
    c = 0.98  # GD on QP with our lr: fit from the loss decay instead
    errs = np.sqrt(np.maximum(np.asarray(clean) - min(clean) + 1e-12, 0))
    c = estimate_contraction(errs[:200], burn_in=5)
    x0_err = model.distance(model.init(__import__("jax").random.PRNGKey(1)))

    rows = []
    T = 30
    violations, gaps = 0, []
    for size in (0.5, 1.0, 2.0, 4.0):
        costs = []
        for seed in range(trials):
            r = run_with_perturbation(model, kind="random", at_iter=T,
                                      size=size, max_iters=max_iters,
                                      seed=seed, clean_losses=clean)
            costs.append(r["iteration_cost"])
        bound = single_perturbation_bound(size, c, T=T, x0_err=x0_err)
        mean, sem = summarize(costs)
        worst = max(costs)
        if worst > bound + 2:
            violations += 1
        gaps.append(bound - worst)
        rows.append(csv_row(f"fig3_qp_random_size{size}", 0.0,
                            f"mean_cost={mean:.1f}±{sem:.1f};worst={worst};"
                            f"bound={bound:.1f};c={c:.4f}"))
    rows.append(csv_row("fig3_qp_bound_holds", 0.0,
                        f"violations={violations}/4;min_gap={min(gaps):.1f}"))

    # (c) per-iteration perturbations with prob p (small) — measured only
    p = 0.02
    rng = np.random.default_rng(0)
    costs = []
    for seed in range(trials):
        model2 = make_model("qp")
        import jax
        params = model2.init(jax.random.PRNGKey(1))
        losses = []
        for i in range(1, max_iters + 1):
            if rng.random() < p:
                from repro.core.perturb import random_perturbation
                params, _ = random_perturbation(
                    jax.random.fold_in(jax.random.PRNGKey(seed), i), params, 1.0)
            params = model2.step(params, jax.random.PRNGKey(0), i)
            losses.append(float(model2.loss(params)))
        costs.append(empirical_iteration_cost(losses, clean, model2.eps))
    mean, sem = summarize(costs)
    rows.append(csv_row("fig3c_qp_repeated_perturbations", 0.0,
                        f"p={p};mean_cost={mean:.1f}±{sem:.1f}"))
    return rows
