"""Batched serving driver: prefill a batch of prompts, then decode greedily.

The decode loop is host-driven (one jitted ``decode_step`` per token) —
the production pattern for continuous batching; cache state stays on
device across steps.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import get_model
from repro.sharding.partition import DistContext

PyTree = Any


class Server:
    def __init__(self, cfg: ModelConfig, ctx: DistContext, params: PyTree):
        self.cfg, self.ctx = cfg, ctx
        self.ops = get_model(cfg)
        self.params = params
        self._prefill = jax.jit(
            lambda p, b: self.ops.prefill(p, b, cfg, ctx))
        self._decode = jax.jit(
            lambda p, c, t: self.ops.decode_step(p, c, t, cfg, ctx),
            donate_argnums=(1,))

    def generate(self, batch: dict, n_new: int,
                 temperature: float = 0.0,
                 rng: Optional[jax.Array] = None) -> jnp.ndarray:
        """Returns (B, n_new) generated token ids (greedy when T=0)."""
        logits, cache = self._prefill(self.params, batch)
        out = []
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
        for i in range(n_new - 1):
            logits, cache = self._decode(self.params, cache, tok)
            if temperature > 0:
                rng, sub = jax.random.split(rng)
                tok = jax.random.categorical(
                    sub, logits[:, -1] / temperature)[:, None].astype(jnp.int32)
            else:
                tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            out.append(tok)
        return jnp.concatenate(out, axis=1)
