"""Flat parameter arena: layout invariants, pack/unpack round-trip,
single-dispatch maintenance/save/restore equivalence vs the tree paths,
and the arena-segment persistent store.

Kernel checks run interpret=True on CPU (TPU is the compile target);
replica/parity are bit-exact vs the tree-path oracles, scores get a tight
allclose (different association order).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.arena import (ARENA_TILE, ArenaLayout, arena_compatible,
                              arena_restore, build_arena_layout,
                              frames_from_arena, frames_gather_index,
                              pack_arena, unpack_arena)
from repro.core.blocks import (block_scores, partition_pytree, select_blocks,
                               tree_sq_norm)
from repro.core.controller import FTController
from repro.core.norms import get_norm
from repro.core.policy import CheckpointPolicy, RecoveryMode, SelectionStrategy
from repro.fabric import CheckpointFabric, FabricConfig
from repro.fabric.domains import FailureDomainMap
from repro.fabric.parity import ParityCodec, pack_frames
from repro.fabric.placement import ClusterView
from repro.kernels.fused_maintain.ops import (ArenaMaintainProgram,
                                              arena_routing,
                                              arena_scatter_save)
from repro.sharding.partition import block_device_homes

RNG = np.random.default_rng(23)


def _tree_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _params():
    return {"w": jnp.asarray(RNG.normal(size=(50, 6)), jnp.float32),
            "emb": jnp.asarray(RNG.normal(size=(33, 8)), jnp.float32),
            "b": jnp.asarray(RNG.normal(size=(5,)), jnp.float32),
            "s": jnp.float32(2.5)}


def _drift(tree, scale=1.0):
    return jax.tree_util.tree_map(
        lambda x: x + jnp.asarray(RNG.normal(size=x.shape) * scale,
                                  x.dtype), tree)


def _codec(params, part, group_size=3):
    view = ClusterView(FailureDomainMap(8, 2, 2),
                       block_device_homes(part, 8))
    codec = ParityCodec(part, view, group_size=group_size, use_pallas=False)
    codec.encode(0, params)
    return codec


# ---------------------------------------------------------------------------
# layout invariants
# ---------------------------------------------------------------------------

def test_layout_invariants():
    params = _params()
    part = partition_pytree(params, 16)
    lay = build_arena_layout(part)
    # I1 (word-level): the data region and the whole buffer are tile
    # multiples; main-region segments are tile-aligned, tail-packed
    # segments word-contiguous and pad-free
    assert lay.total_words % ARENA_TILE == 0
    assert lay.data_words % ARENA_TILE == 0
    assert lay.has_tail     # _params has sub-tile leaves ("b", "s")
    prev_end = 0
    for ab in lay.blocks:                       # I2: disjoint, covering,
        if ab.offset < lay.tail_start:          # offset-ascending
            assert ab.offset % ARENA_TILE == 0
            assert ab.words % ARENA_TILE == 0
        else:
            assert ab.words == ab.payload       # tail: no intra-seg pad
        assert 0 < ab.payload <= ab.words
        assert ab.offset == prev_end
        prev_end = ab.offset + ab.words
    assert prev_end == lay.tail_end <= lay.data_words
    assert lay.n_tiles == lay.total_words // ARENA_TILE
    # tail packing strictly shrinks the buffer vs the aligned layout
    loose = build_arena_layout(part, tail_pack=False)
    assert lay.total_words < loose.total_words
    assert lay.padding_ratio < loose.padding_ratio
    assert not loose.has_tail
    gids = lay.tile_gids()
    assert gids.shape == (lay.n_tiles,)
    main_gids = {ab.gid for ab in lay.blocks if ab.offset < lay.tail_start}
    tail_tiles = set(range(lay.tail_start // ARENA_TILE,
                           lay.data_words // ARENA_TILE))
    assert {int(g) for g in gids if g >= 0} == main_gids
    assert {i for i, g in enumerate(gids) if g < 0} == tail_tiles


def test_layout_colocated_leaves_get_separate_segments():
    tree = {"net": {"w": jnp.zeros((16, 3), jnp.float32)},
            "mu": {"w": jnp.zeros((16, 3), jnp.float32)}}
    part = partition_pytree(tree, 8, colocate=("net", "mu"))
    lay = build_arena_layout(part)
    assert len(lay.blocks) == 2 * part.total_blocks
    # both leaves' segments for gid 0 are selected together
    tiles = lay.tiles_for_blocks([0])
    assert tiles.size == 2 * (lay.seg_words[0] // ARENA_TILE)


def test_arena_compatible_gates_dtypes():
    # word-packable dtypes — incl. the quantized set — are arena-native
    good = partition_pytree({"a": jnp.zeros((4,), jnp.bfloat16),
                             "b": jnp.zeros((4,), jnp.float32),
                             "c": jnp.zeros((4,), jnp.int8),
                             "d": jnp.zeros((4,), jnp.int32)}, 4)
    # only truly word-unpackable dtypes gate (f64/int64/bool/complex);
    # np array: jnp would silently downcast f64 -> f32 without x64 mode
    bad = partition_pytree({"a": np.zeros((4,), np.float64)}, 4)
    assert arena_compatible(good)
    assert not arena_compatible(bad)
    fab = CheckpointFabric(bad, FabricConfig())
    assert fab.arena_layout is None             # falls back to per-leaf


# ---------------------------------------------------------------------------
# pack/unpack round trip (I3) — hypothesis property
# ---------------------------------------------------------------------------

def test_pack_unpack_roundtrip_property():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    dtypes = [jnp.float32, jnp.bfloat16, jnp.float16]

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.tuples(
        st.sampled_from([(), (1,), (7,), (13, 3), (16, 4), (33, 5),
                         (128, 2), (130, 3)]),
        st.integers(0, 2)), min_size=1, max_size=5),
        st.sampled_from([4, 8, 16, 128]),
        st.integers(0, 2 ** 31 - 1))
    def prop(leaf_specs, block_rows, seed):
        r = np.random.default_rng(seed)
        tree = {f"l{i}": jnp.asarray(r.normal(size=shape) * 100,
                                     dtypes[d])
                for i, (shape, d) in enumerate(leaf_specs)}
        part = partition_pytree(tree, block_rows)
        lay = build_arena_layout(part)
        arena = pack_arena(tree, lay)
        assert arena.shape == (lay.total_words,)
        back = unpack_arena(arena, lay)
        for x, y in zip(jax.tree_util.tree_leaves(back),
                        jax.tree_util.tree_leaves(tree)):
            assert x.dtype == y.dtype
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        # I4: every pad word is exactly 0.0f
        a = np.asarray(arena)
        for ab in lay.blocks:
            assert not a[ab.offset + ab.payload:ab.offset + ab.words].any()

    prop()


def test_arena_restore_matches_select_blocks_property():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    params = _params()
    part = partition_pytree(params, 16)
    lay = build_arena_layout(part)

    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.integers(0, part.total_blocks - 1), min_size=1,
                    max_size=part.total_blocks),
           st.integers(0, 2 ** 31 - 1))
    def prop(ids, seed):
        r = np.random.default_rng(seed)
        src = jax.tree_util.tree_map(
            lambda x: x + jnp.asarray(r.normal(size=x.shape), x.dtype),
            params)
        mask = np.zeros((part.total_blocks,), bool)
        mask[np.unique(ids)] = True
        got = arena_restore(params, pack_arena(src, lay), mask, lay)
        want = select_blocks(params, src, jnp.asarray(mask), part)
        _tree_equal(got, want)

    prop()


# ---------------------------------------------------------------------------
# arena maintain: single dispatch vs tree-path reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_pallas", [False, True])
def test_arena_maintain_matches_tree_reference(use_pallas):
    params = _params()
    ck = _drift(params)
    part = partition_pytree(params, 16)
    codec = _codec(params, part)
    lay = build_arena_layout(part)
    prog = ArenaMaintainProgram(part, lay, codec.layout, codec.group_of,
                                codec.n_groups, use_pallas=use_pallas,
                                interpret=True)
    rep, sc, par = prog(params, pack_arena(ck, lay))
    np.testing.assert_array_equal(np.asarray(rep),
                                  np.asarray(pack_arena(params, lay)))
    np.testing.assert_array_equal(np.asarray(par), np.asarray(codec.parity))
    want = block_scores(params, ck, part, get_norm("l2"))
    np.testing.assert_allclose(np.asarray(sc), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # scoreless variant still produces the same replica + parity
    rep2, sc2, par2 = prog(params, None)
    np.testing.assert_array_equal(np.asarray(rep2), np.asarray(rep))
    np.testing.assert_array_equal(np.asarray(par2), np.asarray(par))
    assert not np.asarray(sc2).any()


def test_arena_maintain_colocated_leaves():
    tree = {"net": {"w": jnp.asarray(RNG.normal(size=(16, 3)), jnp.float32)},
            "mu": {"w": jnp.asarray(RNG.normal(size=(16, 3)), jnp.float32)},
            "t": jnp.float32(1.0)}
    ck = _drift(tree)
    part = partition_pytree(tree, 8, colocate=("net", "mu"))
    codec = _codec(tree, part, group_size=2)
    lay = build_arena_layout(part)
    for use_pallas in (False, True):
        prog = ArenaMaintainProgram(part, lay, codec.layout, codec.group_of,
                                    codec.n_groups, use_pallas=use_pallas,
                                    interpret=True)
        rep, sc, par = prog(tree, pack_arena(ck, lay))
        np.testing.assert_array_equal(np.asarray(par),
                                      np.asarray(codec.parity))
        want = block_scores(tree, ck, part, get_norm("l2"))
        np.testing.assert_allclose(np.asarray(sc), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def test_arena_routing_covers_every_tile_once():
    params = _params()
    part = partition_pytree(params, 16)
    codec = _codec(params, part)
    lay = build_arena_layout(part)
    r = arena_routing(lay, codec.layout, codec.group_of)
    # routing covers exactly the main-region tiles, each once; tail tiles
    # are swept by the word-granular epilogue instead
    main_tiles = list(range(lay.tail_start // ARENA_TILE))
    assert sorted(r.perm.tolist()) == main_tiles
    assert r.first[0] == 1
    listed = r.members[r.members >= 0]
    assert sorted(listed.tolist()) == main_tiles
    # the aligned (tail_pack=False) layout routes every tile
    loose = build_arena_layout(part, tail_pack=False)
    r2 = arena_routing(loose, codec.layout, codec.group_of)
    assert sorted(r2.perm.tolist()) == list(range(loose.n_tiles))


def test_frames_from_arena_matches_pack_frames():
    params = _params()
    part = partition_pytree(params, 16)
    codec = _codec(params, part)
    lay = build_arena_layout(part)
    idx = frames_gather_index(lay, codec.layout)
    got = frames_from_arena(pack_arena(params, lay), idx)
    want = pack_frames(params, part, codec.layout)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_parity_reconstruct_from_arena_matches_tree_path():
    params = _params()
    part = partition_pytree(params, 16)
    codec = _codec(params, part)
    lay = build_arena_layout(part)
    arena = pack_arena(params, lay)
    # lose one member of the tail group (single erasure, no device dead)
    tail = codec.members[-1]
    victim = int(tail[tail >= 0][-1])
    lost = np.zeros((part.total_blocks,), bool)
    lost[victim] = True
    rec_mask = codec.reconstructable(lost, ~lost, np.empty((0,), np.int32),
                                     step=0)
    assert rec_mask[victim]
    want = codec.reconstruct(params, rec_mask, ~lost)
    got = codec.reconstruct_from_arena(arena, lay, rec_mask, ~lost)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_recover_routes_parity_through_arena_frames(monkeypatch):
    """When the sweep's snapshot arena matches the parity encode step,
    recovery must source member frames from the arena gather — the
    full-tree pack_frames path must not run."""
    params = _params()
    part = partition_pytree(params, 16)
    fab = CheckpointFabric(part, FabricConfig())
    fab.maintain(5, params)
    # kill block 0's primary home AND its replica home: the block must
    # fall to the PARITY tier (its group's other members survive)
    failed = np.unique(np.asarray(
        [fab.view.homes[0], fab.replicas.replica_homes[0]], np.int32))
    lost = np.isin(fab.view.homes, failed)
    plan = fab.planner.plan(lost, failed, step=5)
    if not plan.counts["PARITY"]:
        pytest.skip("striping left no parity-tier block for this seed")
    monkeypatch.setattr(
        ParityCodec, "reconstruct",
        lambda *a, **k: pytest.fail("tree-path pack_frames used despite "
                                    "fresh snapshot arena"))
    ck = jax.tree_util.tree_map(jnp.array, params)
    recovered, stats = fab.planner.recover(params, ck, plan)
    assert float(tree_sq_norm(recovered, params)) == 0.0


# ---------------------------------------------------------------------------
# arena save path: controller equivalence + recovery
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", [SelectionStrategy.PRIORITY,
                                      SelectionStrategy.ROUND_ROBIN,
                                      SelectionStrategy.RANDOM])
def test_controller_arena_save_matches_rewrite(strategy):
    """Arena-mode saves are bit-equivalent to the seed jnp.where fold,
    strategy by strategy, over a multi-save run with maintenance."""
    params = _params()
    pol = CheckpointPolicy(fraction=0.25, full_interval=1,
                          strategy=strategy,
                          recovery=RecoveryMode.PARTIAL, block_rows=16)
    a = FTController(params, pol, fabric=FabricConfig(),
                     rng=jax.random.PRNGKey(5))
    b = FTController(params, pol, inplace_save=False,
                     rng=jax.random.PRNGKey(5))
    assert a._arena_layout is not None
    live = params
    for step in (1, 2, 3):
        live = _drift(live, scale=step)
        a.maintain(step, live)
        ma = a.checkpoint_now(step, live)
        mb = b.checkpoint_now(step, live)
        np.testing.assert_array_equal(np.asarray(ma), np.asarray(mb))
    _tree_equal(a.ckpt.values, b.ckpt.values)
    np.testing.assert_array_equal(np.asarray(a.ckpt.saved_iter),
                                  np.asarray(b.ckpt.saved_iter))
    assert a.stats["save_bytes_moved"] > 0
    assert a.fabric.stats["arena_maintains"] == 3


def test_arena_scatter_save_is_single_program():
    params = _params()
    part = partition_pytree(params, 16)
    lay = build_arena_layout(part)
    src = pack_arena(params, lay)
    dst = jnp.zeros_like(src)
    ids = np.asarray([1, 4, part.total_blocks - 1])
    out, moved = arena_scatter_save(dst, src, lay, ids, use_pallas=False)
    out_p, moved_p = arena_scatter_save(jnp.zeros_like(src), src, lay, ids,
                                        use_pallas=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out_p))
    assert moved == moved_p == lay.seg_bytes_for_blocks(ids)
    # untouched tiles stayed zero
    touched = lay.tiles_for_blocks(ids)
    o2 = np.asarray(out).reshape(-1, ARENA_TILE)
    untouched = np.setdiff1d(np.arange(lay.n_tiles), touched)
    assert not o2[untouched].any()


def test_arena_recovery_from_replica_and_ckpt_is_exact():
    """Domain loss with arena tiers: replica tier restores live values
    through contiguous arena slices; a degraded fallback recovers from
    the (arena-backed) running checkpoint."""
    params = _params()
    pol = CheckpointPolicy(fraction=0.5, full_interval=1,
                          strategy=SelectionStrategy.PRIORITY,
                          recovery=RecoveryMode.PARTIAL, block_rows=16)
    ctl = FTController(params, pol, fabric=FabricConfig(elastic=True),
                       rng=jax.random.PRNGKey(0))
    live = _drift(params)
    ctl.maintain(1, live)
    ctl.checkpoint_now(1, live)
    live2, info = ctl.on_domain_event(live, "host", 0, step=1)
    assert float(tree_sq_norm(live2, live)) == 0.0
    assert info["tier_counts"]["PEER_REPLICA"] > 0
    assert ctl.fabric.replicas.arena is not None


def test_arena_ckpt_tree_materialization_is_lazy_and_correct():
    params = _params()
    pol = CheckpointPolicy(fraction=0.25, full_interval=1,
                          strategy=SelectionStrategy.ROUND_ROBIN,
                          recovery=RecoveryMode.PARTIAL, block_rows=16)
    ctl = FTController(params, pol, fabric=FabricConfig())
    live = _drift(params)
    ctl.maintain(1, live)
    ctl.checkpoint_now(1, live)
    assert ctl._ckpt_dirty                      # hot path left it lazy
    vals = ctl.ckpt.values                      # materializes once
    assert not ctl._ckpt_dirty
    _tree_equal(vals, unpack_arena(ctl._ckpt_arena, ctl._arena_layout))


# ---------------------------------------------------------------------------
# arena-segment store
# ---------------------------------------------------------------------------

def test_arena_store_roundtrip_and_rekey(tmp_path):
    import os

    from repro.checkpoint_io import ShardedCheckpointStore

    params = _params()
    pol = CheckpointPolicy(fraction=0.25, full_interval=1,
                          strategy=SelectionStrategy.ROUND_ROBIN,
                          recovery=RecoveryMode.PARTIAL, block_rows=16)
    store = ShardedCheckpointStore(str(tmp_path))
    ctl = FTController(params, pol, store=store,
                       fabric=FabricConfig(elastic=True))
    assert store.arena_layout is not None
    live = params
    for step in (1, 2, 3):
        live = _drift(live)
        ctl.maintain(step, live)
        ctl.checkpoint_now(step, live)
    store.flush()
    _tree_equal(store.read_all(), ctl.ckpt.values)
    # partial read touches only the masked blocks
    mask = np.zeros((ctl.partition.total_blocks,), bool)
    mask[0] = True
    part_vals = store.read_blocks(mask)
    w = jax.tree_util.tree_leaves(part_vals)[0]
    want_w = jax.tree_util.tree_leaves(ctl.ckpt.values)[0]
    np.testing.assert_array_equal(np.asarray(w)[:16], np.asarray(want_w)[:16])
    # degrade placement, then re-key the mirror during compaction
    live, _ = ctl.on_domain_event(live, "host", 0, step=3)
    reclaimed = store.compact(rekey_homes=ctl.fabric.view.homes,
                              domains=ctl.fabric.domains)
    assert reclaimed >= 0
    _tree_equal(store.read_all(), ctl.ckpt.values)
    # every live segment now sits on its block's CURRENT home host
    want_hosts = ctl.fabric.domains.host_of(ctl.fabric.view.homes)
    np.testing.assert_array_equal(store.host_of_block, want_hosts)
    # a fresh save after the re-key lands in the new keying and reads back
    live = _drift(live)
    ctl.maintain(4, live)
    ctl.checkpoint_now(4, live)
    store.flush()
    _tree_equal(store.read_all(), ctl.ckpt.values)


def test_arena_store_one_append_write_per_host(tmp_path, monkeypatch):
    from repro.checkpoint_io import ShardedCheckpointStore

    params = _params()
    pol = CheckpointPolicy(fraction=0.5, full_interval=1,
                          strategy=SelectionStrategy.ROUND_ROBIN,
                          recovery=RecoveryMode.PARTIAL, block_rows=16)
    store = ShardedCheckpointStore(str(tmp_path))
    ctl = FTController(params, pol, store=store, fabric=FabricConfig())
    live = _drift(params)
    writes = []
    orig = ShardedCheckpointStore._do_write

    def spy(self, jobs, step):
        by_shard = {}
        for seg, _ in jobs:
            by_shard.setdefault(self._shard_path(seg), []).append(seg)
        writes.append(len(by_shard))
        return orig(self, jobs, step)

    monkeypatch.setattr(ShardedCheckpointStore, "_do_write", spy)
    ctl.maintain(1, live)
    ctl.checkpoint_now(1, live)
    store.flush()
    assert writes and all(n <= 4 for n in writes)   # ≤ one per host shard
