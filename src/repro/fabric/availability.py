"""Soak-run availability/goodput accounting.

A trace-driven soak (``run_with_trace`` / ``TrainLoopConfig.mtbf``) emits
per-event tier diagnostics into ``FTController.stats["events"]`` and — via
:meth:`CheckpointFabric.redundancy_state` — a per-step flag saying whether
every configured redundancy tier is fully placed on live hardware. This
module aggregates the two into the availability summary the ROADMAP asked
for: time-to-full-redundancy per event, the fraction of steps spent at
full redundancy (the window where the *next* failure is guaranteed cheap),
and how much recovery traffic stayed on the cheap tiers.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

# tiers that restore live values at ~zero perturbation vs the stale tiers
CHEAP_TIERS = ("PEER_REPLICA", "PARITY")
EXPENSIVE_TIERS = ("RUNNING_CKPT", "DISK")


def summarize_availability(events: Sequence[dict],
                           full_flags: Sequence[bool],
                           ) -> dict:
    """Aggregate per-event diagnostics + per-step redundancy flags.

    ``events``   — ``FTController.stats["events"]``-style dicts; entries
                   without a ``step`` (one-shot paper experiments) are
                   skipped for timing but still counted in tier totals.
    ``full_flags`` — ``full_flags[i]`` is the redundancy state *after*
                   step ``i + 1`` finished (events and maintenance
                   applied), as recorded by the soak loop.

    Returns::

        steps                 total steps observed
        n_events              recovery events
        frac_steps_full       goodput proxy: fraction of steps ending at
                              full redundancy
        time_to_full          per-event steps until full redundancy
                              returned (0 = same step, None = censored —
                              never restored within the run)
        mean_time_to_full     mean over restored events (None if none)
        censored_events       events never restored within the run
        lost_blocks           total blocks lost across events
        cheap_tier_blocks     blocks recovered from SURVIVOR-cost tiers
                              (replica/parity — live values, ~zero
                              perturbation)
        ckpt_disk_blocks      blocks that fell through to RUNNING_CKPT or
                              DISK (stale values — real perturbation)
    """
    flags = np.asarray(full_flags, bool)
    n_steps = int(flags.size)
    time_to_full: list[Optional[int]] = []
    lost = cheap = expensive = 0
    n_events = 0
    for ev in events:
        if ev.get("skipped"):
            continue
        n_events += 1
        counts = ev.get("tier_counts") or {}
        lost += int(ev.get("lost_blocks", 0))
        cheap += sum(int(counts.get(t, 0)) for t in CHEAP_TIERS)
        expensive += sum(int(counts.get(t, 0)) for t in EXPENSIVE_TIERS)
        step = ev.get("step")
        if step is None or not (1 <= int(step) <= n_steps):
            continue
        later = np.nonzero(flags[int(step) - 1:])[0]
        time_to_full.append(int(later[0]) if later.size else None)
    restored = [t for t in time_to_full if t is not None]
    return {
        "steps": n_steps,
        "n_events": n_events,
        "frac_steps_full": float(flags.mean()) if n_steps else 1.0,
        "time_to_full": time_to_full,
        "mean_time_to_full": (float(np.mean(restored)) if restored
                              else None),
        "censored_events": sum(1 for t in time_to_full if t is None),
        "lost_blocks": int(lost),
        "cheap_tier_blocks": int(cheap),
        "ckpt_disk_blocks": int(expensive),
    }
