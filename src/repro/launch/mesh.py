"""Production mesh construction (TPU v5e pods).

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state.

- single-pod: (16, 16)   axes ("data", "model")   — 256 chips
- multi-pod:  (2, 16, 16) axes ("pod", "data", "model") — 512 chips,
  pure data parallelism across pods (gradient all-reduce crosses DCI).
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5 exposes explicit axis types; older releases lack it
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def mesh_axis_kwargs(n_axes: int) -> dict:
    """``axis_types=`` kwargs for ``jax.make_mesh``, or ``{}`` on jax
    versions without ``jax.sharding.AxisType`` (everything is Auto there)."""
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` that works across jax versions (Auto axis types)."""
    return jax.make_mesh(shape, axes, **mesh_axis_kwargs(len(axes)))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over whatever devices exist (CPU tests)."""
    n = len(jax.devices())
    data = n // model
    return make_mesh_compat((data, model), ("data", "model"))


def mesh_devices(mesh) -> list:
    """Row-major device list of a mesh — position ``i`` here is fabric
    logical device ``i`` (the contract the elastic sharded-arena path
    uses to map ``ClusterView`` homes onto jax devices)."""
    import numpy as np
    return list(np.asarray(mesh.devices).reshape(-1))


def survivor_mesh(devices):
    """Mesh over an explicit surviving device list: ``(n, 1)`` with axes
    ``("data", "model")`` — model parallelism collapses on shrink (the
    survivor set need not tile the original model axis), data
    parallelism carries the remaining throughput. Re-grow rebuilds the
    original mesh shape via :func:`make_mesh_compat`."""
    import numpy as np
    from jax.sharding import Mesh
    devs = np.asarray(list(devices), dtype=object)
    return Mesh(devs.reshape(devs.size, 1), ("data", "model"))
