"""Training loops: classic (paper-experiment) runner + SPMD LM trainer."""
from repro.training.classic_runner import (run_clean, run_with_failure,
                                           run_with_perturbation,
                                           run_with_trace,
                                           iterations_to_converge)
from repro.training.train_loop import TrainLoop, TrainLoopConfig
from repro.training.train_state import ArenaTrainState, TrainState

__all__ = ["run_clean", "run_with_failure", "run_with_perturbation",
           "run_with_trace", "iterations_to_converge", "TrainLoop",
           "TrainLoopConfig", "TrainState", "ArenaTrainState"]
