"""jit'd wrapper: PyTree-level priority scoring backed by the Pallas kernel.

``tree_block_scores`` is drop-in for :func:`repro.core.blocks.block_scores`
with the L2 norm, wired into FTController via ``score_fn``. On CPU it runs
the kernel in interpret mode (correctness); on TPU it compiles natively.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.blocks import BlockPartition, leaf_block_view
from repro.kernels.block_dist.kernel import block_dist_pallas
from repro.kernels.block_dist.ref import block_dist_ref

PyTree = Any


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


def block_dist(a: jnp.ndarray, b: jnp.ndarray,
               use_pallas: bool = True,
               interpret: bool | None = None) -> jnp.ndarray:
    """(n_blocks, E) pair → (n_blocks,) squared distances."""
    if not use_pallas:
        return block_dist_ref(a, b)
    if interpret is None:
        interpret = not _is_tpu()
    return block_dist_pallas(a, b, interpret=interpret)


def tree_block_scores(params: PyTree, ckpt_values: PyTree,
                      partition: BlockPartition,
                      use_pallas: bool = True,
                      interpret: bool | None = None) -> jnp.ndarray:
    """Per-block squared distances over a whole PyTree -> (total_blocks,)."""
    a_flat = jax.tree_util.tree_leaves(params)
    b_flat = jax.tree_util.tree_leaves(ckpt_values)
    scores = []
    for xa, xb, leaf in zip(a_flat, b_flat, partition.leaves):
        va = leaf_block_view(xa.astype(jnp.float32), partition.block_rows)
        vb = leaf_block_view(xb.astype(jnp.float32), partition.block_rows)
        scores.append(block_dist(va, vb, use_pallas=use_pallas,
                                 interpret=interpret))
    return jnp.concatenate(scores) if len(scores) > 1 else scores[0]


def make_score_fn(partition: BlockPartition, interpret: bool | None = None):
    """score_fn for FTController(score_fn=...) — kernel-backed priority."""
    def score(params, ckpt_values):
        return tree_block_scores(params, ckpt_values, partition,
                                 interpret=interpret)
    return score
