"""Sharded parameter arena: layout math, elastic relayout, and SPMD
end-to-end equivalence.

Two halves. The in-process tests cover the host-side sharded-layout
arithmetic (pad tiles, data-region invariance, relayout round-trip, span
ownership) and the explicit misconfiguration paths. The SPMD tests need
more than one device, which tier-1 runs without (conftest forbids
XLA_FLAGS in-process so smoke tests see the real single CPU), so they
shell out to a driver with ``--xla_force_host_platform_device_count=8``.

Equivalence scope, stated honestly: arena-vs-PyTree bit-equality holds on
the SAME mesh (identical shardings → identical reduction orders). Across
topologies (1 device vs 8, 8 shards vs 4) the sharded RNG in param init
and the different all-reduce association orders change low bits, so
cross-topology claims are allclose at best and not asserted here.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.arena import (ARENA_TILE, arena_block_homes,
                              build_arena_layout, pack_arena, relayout_arena,
                              unpack_arena)
from repro.core.blocks import partition_pytree
from repro.core.policy import CheckpointPolicy
from repro.data.pipeline import ShardedLMDataset
from repro.fabric import CheckpointFabric, FabricConfig
from repro.launch.mesh import mesh_devices, survivor_mesh
from repro.sharding import single_device_ctx
from repro.telemetry.recorder import Recorder
from repro.training import TrainLoop, TrainLoopConfig, TrainState

RNG = np.random.default_rng(11)


def _params():
    return {"w": jnp.asarray(RNG.normal(size=(96, 40)), jnp.float32),
            "emb": jnp.asarray(RNG.normal(size=(65, 24)), jnp.float32),
            "b": jnp.asarray(RNG.normal(size=(33,)), jnp.float32),
            "s": jnp.float32(1.5)}


# ---------------------------------------------------------------------------
# sharded layout math (in-process, host-side)
# ---------------------------------------------------------------------------

def test_sharded_layout_invariants():
    """Sharding only appends zero pad tiles: the data region is byte-wise
    identical across shard counts, every shard owns whole tiles, and the
    pad is the minimal amount that makes the tile count divide."""
    part = partition_pytree(_params(), block_rows=8)
    base = build_arena_layout(part)               # shards=1
    for shards in (1, 2, 4, 8):
        lay = build_arena_layout(part, shards=shards)
        assert lay.shards == shards
        assert lay.data_words == base.data_words
        assert lay.n_tiles % shards == 0
        assert lay.shard_words * shards == lay.total_words
        assert lay.shard_words % ARENA_TILE == 0
        # minimal pad: removing one pad tile per shard would break I1
        assert lay.total_words - base.data_words < shards * ARENA_TILE
        # pad tiles report gid 0 — bit-neutral because pad words are zero
        # in every arena (I4), so per-gid reductions see an exact +0.0
        gids = lay.tile_gids()
        assert gids.shape == (lay.n_tiles,)
        n_pad_tiles = (lay.total_words - lay.data_words) // ARENA_TILE
        if n_pad_tiles:
            assert (gids[-n_pad_tiles:] == 0).all()

    with pytest.raises(ValueError):
        build_arena_layout(part, shards=0)


def test_relayout_arena_bit_exact_roundtrip():
    """shards=1 → 4 → 1 round-trips bit-exactly, pad tail is zero, and
    the decoded tree is unchanged at every shard count."""
    values = _params()
    part = partition_pytree(values, block_rows=8)
    l1 = build_arena_layout(part, shards=1)
    l4 = build_arena_layout(part, shards=4)
    a1 = pack_arena(values, l1)
    a4 = relayout_arena(a1, l1, l4)
    assert a4.shape == (l4.total_words,)
    np.testing.assert_array_equal(np.asarray(a4)[:l4.data_words],
                                  np.asarray(a1)[:l1.data_words])
    assert not np.asarray(a4)[l4.data_words:].any()
    for lay, arena in ((l1, a1), (l4, a4)):
        for x, y in zip(jax.tree_util.tree_leaves(values),
                        jax.tree_util.tree_leaves(
                            unpack_arena(jnp.asarray(arena), lay))):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    back = relayout_arena(a4, l4, l1)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(a1))

    # different partitions must refuse to relayout into each other
    other = partition_pytree({"w": jnp.zeros((16, 8), jnp.float32)},
                             block_rows=8)
    with pytest.raises(ValueError):
        relayout_arena(a1, l1, build_arena_layout(other, shards=4))


def test_arena_block_homes_span_ownership():
    """Each gid's home is the shard whose contiguous word span holds the
    first tile of its first arena block (checked against brute force)."""
    part = partition_pytree(_params(), block_rows=8)
    for shards in (1, 2, 4):
        lay = build_arena_layout(part, shards=shards)
        homes = arena_block_homes(lay)
        assert homes.shape == (part.total_blocks,)
        assert homes.min() >= 0 and homes.max() < shards
        sw = lay.shard_words
        for ab in lay.blocks:
            assert homes[ab.gid] == ab.offset // sw
    # shards=1: everything home 0
    assert (arena_block_homes(build_arena_layout(part)) == 0).all()
    # asking for a device count that doesn't divide the tiles is an error
    lay = build_arena_layout(part, shards=2)   # 28 tiles
    with pytest.raises(ValueError):
        arena_block_homes(lay, n_devices=5)


def test_survivor_mesh_and_mesh_devices():
    dev = jax.devices()[0]
    m = survivor_mesh([dev])
    assert m.devices.shape == (1, 1)
    assert m.axis_names == ("data", "model")
    assert mesh_devices(m) == [dev]


def test_meshed_fabric_size_mismatch_raises():
    """A mesh whose device count disagrees with cfg.n_devices is a
    misconfiguration, not a fallback."""
    part = partition_pytree(_params(), block_rows=8)
    m = survivor_mesh([jax.devices()[0]])
    with pytest.raises(ValueError, match="mesh"):
        CheckpointFabric(part, FabricConfig(n_devices=8), mesh=m)


def test_arena_gated_fallback_warns_and_records():
    """arena_state=True with a fabric that can't build an arena layout
    must not fall back silently: a warning fires and the recorder gets a
    ``fabric/arena_gated`` event (satellite: no silent PyTree fallback)."""
    ctx = single_device_ctx()
    cfg = get_config("qwen2-1.5b", reduced=True)
    rec = Recorder()
    loop = TrainLoop(cfg, ctx, loop_cfg=TrainLoopConfig(
        policy=CheckpointPolicy.scar(fraction=0.25, interval=2),
        fabric=FabricConfig(fused=False),       # gates the arena pipeline
        arena_state=True, recorder=rec))
    with pytest.warns(UserWarning, match="not arena-capable"):
        state = loop.init_state()
    assert isinstance(state, TrainState)        # fell back, loudly
    assert any(e["kind"] == "fabric/arena_gated" for e in rec.events)


# ---------------------------------------------------------------------------
# SPMD end-to-end (subprocess: forced 8-device CPU topology)
# ---------------------------------------------------------------------------

def _run_spmd(driver: str, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(driver)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)
    assert proc.returncode == 0, (
        f"SPMD driver failed\n--- stdout ---\n{proc.stdout}"
        f"\n--- stderr ---\n{proc.stderr}")
    return proc.stdout


_COMMON = """
import numpy as np
import jax
from repro.configs import get_config
from repro.core.policy import CheckpointPolicy
from repro.data.pipeline import ShardedLMDataset
from repro.fabric import FabricConfig
from repro.launch.mesh import make_mesh_compat
from repro.sharding.partition import make_dist_ctx
from repro.training import ArenaTrainState, TrainLoop, TrainLoopConfig

cfg = get_config("qwen2-1.5b", reduced=True)
mesh = make_mesh_compat((4, 2), ("data", "model"))
ctx = make_dist_ctx(mesh)
"""


def test_spmd_sharded_arena_bit_equal_to_pytree_same_mesh():
    """The acceptance criterion: on the SAME (4, 2) mesh the arena loop
    and the PyTree loop produce bit-identical losses, running checkpoint
    and final params — while the arena loop runs pack-free with the
    replica shipped over a genuinely rotated anti-affine placement."""
    out = _run_spmd(_COMMON + """
def run(arena_state):
    pol = CheckpointPolicy.scar(fraction=0.25, interval=2)
    loop = TrainLoop(cfg, ctx, loop_cfg=TrainLoopConfig(
        policy=pol, fabric=FabricConfig(), arena_state=arena_state))
    state = loop.init_state()
    ds = ShardedLMDataset(cfg, batch=8, seq=32, ctx=ctx)
    return loop, loop.run(state, iter(ds), 5)

la, sa = run(True)
lt, st = run(False)
assert isinstance(sa, ArenaTrainState), type(sa)
assert sa.layout.shards == 8
assert [m["loss"] for m in la.metrics] == [m["loss"] for m in lt.metrics]
assert (np.asarray(la.controller._ckpt_arena)
        == np.asarray(lt.controller._ckpt_arena)).all()
assert all(bool((np.asarray(x) == np.asarray(y)).all())
           for x, y in zip(jax.tree_util.tree_leaves(sa.params),
                           jax.tree_util.tree_leaves(st.params)))
fab = la.controller.fabric
assert fab.stats["live_packs"] == 0
assert fab.stats["arena_resident_maintains"] == fab.stats["arena_maintains"]
# the replica landed on a rotated device order (anti-affinity is real)
rot = [d.id for d in fab._replica_sharding.mesh.devices.reshape(-1)]
assert rot != sorted(rot), rot
assert fab.stats["ici_bytes_moved"] + fab.stats["dcn_bytes_moved"] > 0
print("SPMD-EQ-OK")
""")
    assert "SPMD-EQ-OK" in out


def test_spmd_elastic_shrink_heal_regrow():
    """Host loss at step 4 shrinks the mesh to the survivors (8 → 4
    shards, honoring batch divisibility), training continues with finite
    losses, and the heal at step 9 re-grows to the full mesh — the loop
    never leaves the arena representation and never packs."""
    out = _run_spmd(_COMMON + """
pol = CheckpointPolicy.scar(fraction=0.25, interval=2)
loop = TrainLoop(cfg, ctx, loop_cfg=TrainLoopConfig(
    policy=pol, fabric=FabricConfig(elastic=True),
    fail_schedule=[(4, "host", 1)], heal_after=5))
state = loop.init_state()
assert isinstance(state, ArenaTrainState)
ds = ShardedLMDataset(cfg, batch=8, seq=32, ctx=ctx)
state = loop.run(state, iter(ds), 12)
resizes = [(m["step"], m["mesh_resize"]) for m in loop.metrics
           if "mesh_resize" in m]
fab = loop.controller.fabric
assert all(np.isfinite(m["loss"]) for m in loop.metrics)
# 6 alive after host loss; batch=8 -> largest divisor k<=6 is 4
assert resizes[0][1]["shards"] == 4, resizes
assert resizes[1][1]["shards"] == 8, resizes
assert fab.view.n_alive_devices == 8
assert fab.arena_layout.shards == 8
assert fab.stats["mesh_resizes"] == 2
assert fab.stats["live_packs"] == 0
assert state.layout.shards == 8
assert all(np.isfinite(np.asarray(l)).all()
           for l in jax.tree_util.tree_leaves(state.params))
print("SPMD-ELASTIC-OK")
""")
    assert "SPMD-ELASTIC-OK" in out


def test_spmd_meshed_fabric_arena_gate_raises():
    """On a mesh the fabric cannot silently drop to the tree pipeline —
    an arena-incapable config plus a mesh is a hard ValueError."""
    out = _run_spmd(_COMMON + """
from repro.core.blocks import partition_pytree
from repro.fabric import CheckpointFabric
import jax.numpy as jnp
part = partition_pytree({"w": jnp.zeros((64, 8), jnp.float32)}, block_rows=8)
try:
    CheckpointFabric(part, FabricConfig(fused=False), mesh=mesh)
except ValueError as e:
    assert "arena" in str(e).lower(), e
    print("SPMD-GATE-OK")
else:
    raise AssertionError("meshed non-arena fabric did not raise")
""")
    assert "SPMD-GATE-OK" in out
