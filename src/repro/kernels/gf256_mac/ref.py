"""Reference GF(256) multiply-accumulate on packed int32 frames.

Pure-jnp oracle for the Pallas kernel: log/antilog table gathers per
byte plane. Each int32 frame word carries four GF(256) symbols; a frame
is scaled by its (per-group, per-member) coefficient byte and folded
into the accumulator with XOR (field addition). Zero padding is neutral
(0 * c = 0), so the same zero-padded ``FrameLayout`` frames the XOR tier
packs flow through unchanged.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .tables import GF_EXP, GF_LOG

_EXP = jnp.asarray(GF_EXP[:255], jnp.int32)
_LOG = jnp.asarray(GF_LOG, jnp.int32)


def _gf_scale_words(words: jax.Array, coeff: jax.Array) -> jax.Array:
    """Scale each byte of packed int32 ``words`` by GF coefficient bytes.

    ``coeff`` broadcasts against ``words[..., 0]`` (one coefficient per
    frame row, applied to every word of that frame).
    """
    coeff = coeff[..., None].astype(jnp.int32)
    log_c = jnp.take(_LOG, coeff, axis=0)
    out = jnp.zeros_like(words)
    for plane in range(4):
        b = (words >> (8 * plane)) & 0xFF
        prod = jnp.take(_EXP, (jnp.take(_LOG, b, axis=0) + log_c) % 255,
                        axis=0)
        prod = jnp.where((b == 0) | (coeff == 0), 0, prod)
        out = out | (prod << (8 * plane))
    return out


def gf256_mac_ref(frames: jax.Array, base: jax.Array,
                  coeff: jax.Array) -> jax.Array:
    """``base XOR sum_i gf_mul(coeff[:, i], frames[:, i, :])`` per group.

    frames: (n_groups, group, frame_elems) int32 — grouped frame words
    base:   (n_groups, frame_elems) int32 — accumulator seed
    coeff:  (n_groups, group) int32 — GF(256) coefficient bytes; 0 drops
            the member (the keep-mask generalization), 1 is plain XOR.
    """
    scaled = _gf_scale_words(frames.astype(jnp.int32),
                             coeff.astype(jnp.int32))
    folded = jax.lax.reduce(scaled, jnp.int32(0), jax.lax.bitwise_xor,
                            (1,))
    return base.astype(jnp.int32) ^ folded


def gf256_mac_np(frames: np.ndarray, base: np.ndarray,
                 coeff: np.ndarray) -> np.ndarray:
    """Numpy mirror of the oracle, for host-side tests."""
    frames = np.asarray(frames, np.int64) & 0xFFFFFFFF
    coeff = np.asarray(coeff, np.int64)
    acc = np.asarray(base, np.int64) & 0xFFFFFFFF
    for plane in range(4):
        b = (frames >> (8 * plane)) & 0xFF
        prod = GF_EXP[(GF_LOG[b] + GF_LOG[coeff[..., None]]) % 255]
        prod = np.where((b == 0) | (coeff[..., None] == 0), 0, prod)
        acc = acc ^ (np.bitwise_xor.reduce(prod, axis=1) << (8 * plane))
    return (acc & 0xFFFFFFFF).astype(np.uint32).view(np.int32)
