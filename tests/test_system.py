"""End-to-end behaviour tests for the SCAR system.

The headline behaviours of the paper, verified end-to-end on CPU:
1. partial recovery strictly shrinks the recovery perturbation,
2. the SCAR-configured trainer survives failures and keeps converging,
3. the full controller lifecycle (checkpoint → failure → recovery →
   persistent store) is consistent.
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint_io import ShardedCheckpointStore
from repro.configs import get_config
from repro.core.controller import FTController
from repro.core.policy import CheckpointPolicy, RecoveryMode, SelectionStrategy
from repro.data import lm_batch
from repro.data.pipeline import ShardedLMDataset
from repro.models.classic import make_model
from repro.sharding import single_device_ctx
from repro.training import TrainLoop, TrainLoopConfig, run_with_failure
from repro.training.serve import Server


def test_partial_beats_full_recovery_on_mlr():
    """Paper §5.3: partial recovery incurs lower iteration cost."""
    model = make_model("mlr", n=600, dim=64, n_classes=5, batch=200)
    kw = dict(fail_iter=25, fail_fraction=0.5, max_iters=150, seed=3)
    partial = run_with_failure(
        model, CheckpointPolicy(fraction=1.0, full_interval=8,
                                strategy=SelectionStrategy.ROUND_ROBIN,
                                recovery=RecoveryMode.PARTIAL,
                                block_rows=model.block_rows), **kw)
    full = run_with_failure(model, CheckpointPolicy.traditional(8), **kw)
    assert partial["recovery"]["applied_sq"] <= full["recovery"]["applied_sq"]
    assert partial["iteration_cost"] <= full["iteration_cost"]


def test_trainer_survives_failures_and_converges():
    ctx = single_device_ctx()
    cfg = get_config("qwen2-1.5b", reduced=True)
    pol = CheckpointPolicy.scar(fraction=0.25, interval=4)
    loop = TrainLoop(cfg, ctx, loop_cfg=TrainLoopConfig(policy=pol))
    state = loop.init_state()
    ds = ShardedLMDataset(cfg, batch=2, seq=64, ctx=ctx)
    state = loop.run(state, iter(ds), 8)
    state, info = loop.inject_failure(state, 0.5)
    assert info["partial_sq"] <= info["full_sq"] + 1e-6
    state = loop.run(state, iter(ds), 8)
    losses = [m["loss"] for m in loop.metrics]
    assert all(np.isfinite(l) for l in losses)
    assert np.mean(losses[-3:]) < losses[0]   # still making progress


def test_controller_with_persistent_store_lifecycle():
    params = {"w": jnp.arange(2000, dtype=jnp.float32).reshape(500, 4)}
    with tempfile.TemporaryDirectory() as d:
        store = ShardedCheckpointStore(d)
        ctl = FTController(params, CheckpointPolicy.scar(0.25, 8),
                           store=store)
        p = params
        for step in range(1, 9):
            p = jax.tree_util.tree_map(lambda x: x + 1.0, p)
            ctl.maybe_checkpoint(step, p)
        lost = ctl.sample_failure(0.5)
        rec, info = ctl.on_failure(p, lost)
        assert info["partial_sq"] <= info["full_sq"]
        store.flush()
        disk = store.read_all()
        np.testing.assert_allclose(np.asarray(disk["w"]),
                                   np.asarray(ctl.ckpt.values["w"]))
        # scar(0.25, 8): partial checkpoints every rC = 2 iters -> 4 saves
        assert ctl.stats["saves"] == 4
        assert ctl.stats["bytes_mirrored"] > 0


def test_kernel_backed_controller_matches_jnp(key):
    """FTController with the Pallas block_dist scorer selects the same
    priority blocks as the jnp path."""
    from repro.core.blocks import partition_pytree
    from repro.kernels.block_dist.ops import make_score_fn
    params = {"w": jnp.asarray(np.random.default_rng(0).normal(
        size=(256, 8)), jnp.float32)}
    pol = CheckpointPolicy.scar(0.25, 8)
    part = partition_pytree(params, pol.block_rows)
    ctl_jnp = FTController(params, pol)
    ctl_krn = FTController(params, pol,
                           score_fn=make_score_fn(part, interpret=True))
    p2 = {"w": params["w"].at[:64].add(50.0)}
    m1 = ctl_jnp.checkpoint_now(1, p2)
    m2 = ctl_krn.checkpoint_now(1, p2)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))


def test_server_generates(key):
    ctx = single_device_ctx()
    cfg = get_config("granite-8b", reduced=True)
    from repro.models import get_model
    ops = get_model(cfg)
    params = ops.init_params(key, cfg)
    srv = Server(cfg, ctx, params)
    batch = lm_batch(jax.random.PRNGKey(5), cfg, 2, 16)
    toks = srv.generate(batch, 4)
    assert toks.shape == (2, 4)
    assert int(toks.min()) >= 0 and int(toks.max()) < cfg.vocab


def test_microbatched_train_step_matches_single():
    """cfg.microbatch > 1 must give the same loss/update (grad averaging)."""
    import dataclasses
    ctx = single_device_ctx()
    cfg = get_config("qwen2-1.5b", reduced=True)
    cfg_mb = dataclasses.replace(cfg, microbatch=2)
    from repro.models import get_model
    from repro.optim.optimizers import sgd
    from repro.training.step import make_train_step
    from repro.training.train_state import TrainState
    ops = get_model(cfg)
    params = ops.init_params(jax.random.PRNGKey(0), cfg)
    batch = lm_batch(jax.random.PRNGKey(1), cfg, 4, 32)
    opt = sgd(0.1)
    s0 = TrainState.create(params, opt)
    s1, l1 = make_train_step(ops, cfg, ctx, opt)(s0, batch)
    s2, l2 = make_train_step(ops, cfg_mb, ctx, opt)(s0, batch)
    assert float(l1) == pytest.approx(float(l2), rel=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                    jax.tree_util.tree_leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-5)
