"""Whisper-style encoder-decoder transformer [arXiv:2212.04356].

The mel-spectrogram + conv feature extractor is a STUB per the brief:
``input_specs()`` provides precomputed frame embeddings (B, enc_seq, D).
Sinusoidal positions (no RoPE — rope_theta=0 for this arch). Decoder layers
have self-attention (causal, cached) + cross-attention to the encoder
output (cross-KV computed once at prefill) + MLP.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding.partition import DistContext

PyTree = Any


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def init_enc_layer(rng, cfg: ModelConfig) -> PyTree:
    ks = jax.random.split(rng, 2)
    return {
        "attn_norm": jnp.ones((cfg.d_model,), _dtype(cfg)),
        "attn": L.init_attention(ks[0], cfg, _dtype(cfg)),
        "mlp_norm": jnp.ones((cfg.d_model,), _dtype(cfg)),
        "mlp": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, _dtype(cfg)),
    }


def init_dec_layer(rng, cfg: ModelConfig) -> PyTree:
    ks = jax.random.split(rng, 3)
    return {
        "self_norm": jnp.ones((cfg.d_model,), _dtype(cfg)),
        "self_attn": L.init_attention(ks[0], cfg, _dtype(cfg)),
        "cross_norm": jnp.ones((cfg.d_model,), _dtype(cfg)),
        "cross_attn": L.init_attention(ks[1], cfg, _dtype(cfg)),
        "mlp_norm": jnp.ones((cfg.d_model,), _dtype(cfg)),
        "mlp": L.init_mlp(ks[2], cfg.d_model, cfg.d_ff, _dtype(cfg)),
    }


def init_params(rng, cfg: ModelConfig) -> PyTree:
    k_embed, k_enc, k_dec, k_in = jax.random.split(rng, 4)
    enc_keys = jax.random.split(k_enc, cfg.enc_layers)
    dec_keys = jax.random.split(k_dec, cfg.n_layers)
    return {
        **L.init_embed(k_embed, cfg, _dtype(cfg)),
        # stub frontend: learned projection of precomputed frame features
        "frame_proj": {"proj": L.dense_init(k_in, (cfg.d_model, cfg.d_model),
                                            cfg.d_model, _dtype(cfg))},
        "enc_layers": jax.vmap(lambda k: init_enc_layer(k, cfg))(enc_keys),
        "enc_norm": jnp.ones((cfg.d_model,), _dtype(cfg)),
        "dec_layers": jax.vmap(lambda k: init_dec_layer(k, cfg))(dec_keys),
        "final_norm": jnp.ones((cfg.d_model,), _dtype(cfg)),
    }


def encode(params, frames, cfg: ModelConfig, ctx: DistContext):
    """frames: (B, T, D) stub embeddings -> encoder output (B, T, D)."""
    B, T, _ = frames.shape
    h = jnp.einsum("btd,de->bte", frames.astype(_dtype(cfg)),
                   params["frame_proj"]["proj"])
    h = h + L.sinusoidal_positions(T, cfg.d_model).astype(h.dtype)
    h = ctx.shard(h, "dp", None, None)
    positions = jnp.arange(T)

    def body(x, lp):
        a = L.attention_block(L.rms_norm(x, lp["attn_norm"]), lp["attn"],
                              cfg, ctx, positions=positions, causal=False,
                              q_chunk=min(512, T), kv_chunk=min(512, T))
        x = x + a
        x = x + L.mlp_block(L.rms_norm(x, lp["mlp_norm"]), lp["mlp"], ctx)
        return ctx.shard(x, "dp", ctx.tp, None), None

    h, _ = jax.lax.scan(body, h, params["enc_layers"],
                        unroll=L.UNROLL_FOR_COSTING)
    return L.rms_norm(h, params["enc_norm"])


def _dec_layer(x, lp, cfg, ctx, positions, enc_kv=None, enc_out=None,
               enc_pos=None, q_chunk=512):
    """One decoder layer (training path: enc_out given; cross-KV recomputed)."""
    a = L.attention_block(L.rms_norm(x, lp["self_norm"]), lp["self_attn"],
                          cfg, ctx, positions=positions, causal=True,
                          q_chunk=q_chunk, kv_chunk=q_chunk)
    x = x + a
    xn = L.rms_norm(x, lp["cross_norm"])
    p = lp["cross_attn"]
    q = jnp.einsum("bsd,dhk->bshk", xn, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", enc_out, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", enc_out, p["wv"])
    o = L.flash_attention(q, k, v, positions, enc_pos, causal=False,
                          q_chunk=q_chunk, kv_chunk=min(512, k.shape[1]),
                          ctx=ctx)
    c = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    x = x + ctx.shard(c, "dp", None, None)
    return x + L.mlp_block(L.rms_norm(x, lp["mlp_norm"]), lp["mlp"], ctx)


def train_loss(params, batch, cfg: ModelConfig, ctx: DistContext, **_):
    enc_out = encode(params, batch["frames"], cfg, ctx)
    tokens = batch["tokens"]
    B, Sq = tokens.shape
    h = L.embed_tokens(tokens, params, ctx)
    h = h + L.sinusoidal_positions(Sq, cfg.d_model).astype(h.dtype)
    h = ctx.shard(h, "dp", None, None)
    positions = jnp.arange(Sq)
    enc_pos = jnp.arange(enc_out.shape[1])

    def body(x, lp):
        fn = _dec_layer
        if cfg.remat:
            fn = jax.checkpoint(_dec_layer, static_argnums=(2, 3),
                                policy=jax.checkpoint_policies.nothing_saveable)
        x = fn(x, lp, cfg, ctx, positions, enc_out=enc_out, enc_pos=enc_pos)
        return ctx.shard(x, "dp", ctx.tp, None), None

    h, _ = jax.lax.scan(body, h, params["dec_layers"],
                        unroll=L.UNROLL_FOR_COSTING)
    h = L.rms_norm(h, params["final_norm"])
    mask = batch.get("mask", jnp.ones_like(batch["labels"], jnp.float32))
    return L.lm_loss_chunked(h, params, batch["labels"], mask, cfg, ctx)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               ctx: DistContext) -> PyTree:
    Hk, Dh, Ln, T = cfg.n_kv_heads, cfg.head_dim, cfg.n_layers, cfg.enc_seq
    dt = _dtype(cfg)
    return {
        "k": ctx.shard(jnp.zeros((Ln, batch, cache_len, Hk, Dh), dt),
                       None, "dp", None, ctx.tp, None),
        "v": ctx.shard(jnp.zeros((Ln, batch, cache_len, Hk, Dh), dt),
                       None, "dp", None, ctx.tp, None),
        "cross_k": ctx.shard(jnp.zeros((Ln, batch, T, Hk, Dh), dt),
                             None, "dp", None, ctx.tp, None),
        "cross_v": ctx.shard(jnp.zeros((Ln, batch, T, Hk, Dh), dt),
                             None, "dp", None, ctx.tp, None),
        "kpos": jnp.full((cache_len,), -1, jnp.int32),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(params, batch, cfg: ModelConfig, ctx: DistContext, spec=None):
    """Encode + teacher-forced decoder pass, building self+cross caches."""
    enc_out = encode(params, batch["frames"], cfg, ctx)
    tokens = batch["tokens"]
    B, Sq = tokens.shape
    h = L.embed_tokens(tokens, params, ctx)
    h = h + L.sinusoidal_positions(Sq, cfg.d_model).astype(h.dtype)
    h = ctx.shard(h, "dp", None, None)
    positions = jnp.arange(Sq)
    enc_pos = jnp.arange(enc_out.shape[1])

    def body(x, lp):
        p = lp["self_attn"]
        xn = L.rms_norm(x, lp["self_norm"])
        q = jnp.einsum("bsd,dhk->bshk", xn, p["wq"])
        k = jnp.einsum("bsd,dhk->bshk", xn, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", xn, p["wv"])
        o = L.flash_attention(q, k, v, positions, positions, causal=True,
                              q_chunk=min(512, Sq), kv_chunk=min(512, Sq),
                              ctx=ctx)
        x = x + ctx.shard(jnp.einsum("bshk,hkd->bsd", o, p["wo"]),
                          "dp", None, None)
        pc = lp["cross_attn"]
        xn = L.rms_norm(x, lp["cross_norm"])
        qc = jnp.einsum("bsd,dhk->bshk", xn, pc["wq"])
        ck = jnp.einsum("btd,dhk->bthk", enc_out, pc["wk"])
        cv = jnp.einsum("btd,dhk->bthk", enc_out, pc["wv"])
        oc = L.flash_attention(qc, ck, cv, positions, enc_pos, causal=False,
                               q_chunk=min(512, Sq),
                               kv_chunk=min(512, ck.shape[1]), ctx=ctx)
        x = x + ctx.shard(jnp.einsum("bshk,hkd->bsd", oc, pc["wo"]),
                          "dp", None, None)
        x = x + L.mlp_block(L.rms_norm(x, lp["mlp_norm"]), lp["mlp"], ctx)
        return x, (k.astype(_dtype(cfg)), v.astype(_dtype(cfg)),
                   ck.astype(_dtype(cfg)), cv.astype(_dtype(cfg)))

    h, (ks, vs, cks, cvs) = jax.lax.scan(body, h, params["dec_layers"],
                                         unroll=L.UNROLL_FOR_COSTING)
    h = L.rms_norm(h, params["final_norm"])
    logits = L.lm_logits(h[:, -1:], params, ctx)
    slack = 64                 # room for subsequently generated tokens
    zk = jnp.zeros(ks.shape[:2] + (slack,) + ks.shape[3:], ks.dtype)
    ks = jnp.concatenate([ks, zk], axis=2)
    vs = jnp.concatenate([vs, zk], axis=2)
    kpos = jnp.concatenate([jnp.arange(Sq, dtype=jnp.int32),
                            jnp.full((slack,), -1, jnp.int32)])
    cache = {"k": ks, "v": vs, "cross_k": cks, "cross_v": cvs,
             "kpos": kpos,
             "pos": jnp.asarray(Sq, jnp.int32)}
    return logits, cache


def decode_step(params, cache, tokens, cfg: ModelConfig, ctx: DistContext,
                spec=None):
    x = L.embed_tokens(tokens, params, ctx)
    pos = cache["pos"]
    x = x + L.sinusoidal_positions(1, cfg.d_model, offset=pos).astype(x.dtype)
    x = ctx.shard(x, "dp", None, None)
    positions = pos[None] + jnp.zeros((1,), jnp.int32)
    cache_len = cache["k"].shape[2]
    kpos = cache["kpos"].at[pos].set(pos)
    enc_pos = jnp.arange(cfg.enc_seq)

    def body(x, xs):
        lp, kc, vc, ck, cv = xs
        p = lp["self_attn"]
        xn = L.rms_norm(x, lp["self_norm"])
        q = jnp.einsum("bsd,dhk->bshk", xn, p["wq"])
        k = jnp.einsum("bsd,dhk->bshk", xn, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", xn, p["wv"])
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), pos, axis=1)
        o = L.flash_attention(q, kc, vc, positions, kpos, causal=True,
                              q_chunk=1, kv_chunk=min(1024, cache_len), ctx=ctx)
        x = x + ctx.shard(jnp.einsum("bshk,hkd->bsd", o, p["wo"]),
                          "dp", None, None)
        pc = lp["cross_attn"]
        xn = L.rms_norm(x, lp["cross_norm"])
        qc = jnp.einsum("bsd,dhk->bshk", xn, pc["wq"])
        oc = L.flash_attention(qc, ck, cv, positions, enc_pos, causal=False,
                               q_chunk=1, kv_chunk=min(512, cfg.enc_seq),
                               ctx=ctx)
        x = x + ctx.shard(jnp.einsum("bshk,hkd->bsd", oc, pc["wo"]),
                          "dp", None, None)
        x = x + L.mlp_block(L.rms_norm(x, lp["mlp_norm"]), lp["mlp"], ctx)
        return x, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]),
        unroll=L.UNROLL_FOR_COSTING)
    h = L.rms_norm(x, params["final_norm"])
    logits = L.lm_logits(h, params, ctx)
    new_cache = dict(cache, k=k_new, v=v_new, kpos=kpos, pos=pos + 1)
    return logits, new_cache
