"""Pallas TPU kernels: fused single-pass redundancy maintenance.

The checkpoint fabric's hot loop previously made three-plus independent
full passes over the live parameters every maintained step: a full-tree
replica copy, a pack-into-frames + gather + XOR parity encode (two
materialized full-model intermediates), and a third full read for PRIORITY
block scoring. Both kernels here collapse that to the memory-roofline
floor:

``fused_maintain`` — one sweep per parameter leaf that reads each element
of the live leaf (and its running-checkpoint counterpart) from HBM exactly
once and, in that single pass,

  (a) writes the replica snapshot (plain copy, original dtype),
  (b) XOR-accumulates the leaf's float32 bit-pattern rows directly into
      compact per-group parity frames — no ``(total_blocks, frame_width)``
      packed intermediate and no ``(n_groups, g, E)`` gather buffer ever
      exists, and
  (c) emits per-block squared-L2 distance partials for PRIORITY selection.

Layout: the grid is ``(E_tiles, S)`` — element tiles *outer*, blocks
*inner* — and the block axis is driven by three scalar-prefetched arrays:
``perm`` visits the leaf's blocks sorted by parity group, so all members
of one group arrive on consecutive grid steps and the parity output block
can be revisit-accumulated in VMEM (init on ``first``, XOR otherwise)
exactly like ``block_dist``'s running sum; ``outrow`` maps each sorted
position to its compact parity row. Replica rows and score partials are
written back through the inverse map so they land in natural block order.

``scatter_save`` — donation-based in-place partial-checkpoint write: the
running checkpoint buffer is aliased as the output and the grid walks only
the ``k`` selected blocks (scalar-prefetched row ids), so saving ``k``
blocks moves ``O(k · block_bytes)`` — never the full leaf. Unvisited rows
are never DMA'd and keep their previous contents (the §4.3 running
checkpoint is a mutable mix of iterations by construction).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BE = 512    # elements per tile (lanes; multiple of 128)


# ---------------------------------------------------------------------------
# fused_maintain: replica copy + parity XOR + priority scores, one read
# ---------------------------------------------------------------------------

def _fused_maintain_kernel(perm_ref, outrow_ref, first_ref, x_ref, z_ref,
                           rep_ref, sc_ref, par_ref):
    s = pl.program_id(1)
    x = x_ref[...]                               # (1, BE), leaf dtype
    rep_ref[...] = x                             # (a) replica snapshot
    x32 = x.astype(jnp.float32)
    d = x32 - z_ref[...].astype(jnp.float32)
    sc_ref[0, 0] = jnp.sum(d * d)                # (c) score partial
    bits = jax.lax.bitcast_convert_type(x32, jnp.int32)

    @pl.when(first_ref[s] == 1)
    def _init():                                 # (b) first member: seed
        par_ref[...] = bits

    @pl.when(first_ref[s] == 0)
    def _fold():                                 # (b) later member: fold
        par_ref[...] ^= bits


def fused_maintain_pallas(x: jnp.ndarray, z: jnp.ndarray,
                          perm: jnp.ndarray, outrow: jnp.ndarray,
                          first: jnp.ndarray, n_out_rows: int,
                          interpret: bool = False,
                          ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One fused maintenance sweep over a leaf's block view.

    x, z:    (S, E) live leaf view / running-checkpoint view (same shapes).
    perm:    (S,) int32 — block ids sorted by parity group (group members
             consecutive; within a group any order).
    outrow:  (S,) int32 — compact parity row of sorted position s.
    first:   (S,) int32 — 1 where s is the first sorted position of its row.
    n_out_rows — number of distinct parity rows (static).

    Returns (replica (S, E) x.dtype, scores (S,) f32,
    parity_contrib (n_out_rows, E) int32 — XOR of the f32 bit patterns of
    each row's member blocks).
    """
    s_dim, e = x.shape
    e_pad = -e % BE
    if e_pad:
        x = jnp.pad(x, ((0, 0), (0, e_pad)))
        z = jnp.pad(z, ((0, 0), (0, e_pad)))
    ep = x.shape[1]
    jt = ep // BE
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(jt, s_dim),                        # E tiles OUTER: parity row
        in_specs=[                               # revisits stay consecutive
            pl.BlockSpec((1, BE), lambda j, s, p, o, f: (p[s], j)),
            pl.BlockSpec((1, BE), lambda j, s, p, o, f: (p[s], j)),
        ],
        out_specs=[
            pl.BlockSpec((1, BE), lambda j, s, p, o, f: (p[s], j)),
            pl.BlockSpec((1, 1), lambda j, s, p, o, f: (p[s], j)),
            pl.BlockSpec((1, BE), lambda j, s, p, o, f: (o[s], j)),
        ],
    )
    rep, sc, par = pl.pallas_call(
        _fused_maintain_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((s_dim, ep), x.dtype),
            jax.ShapeDtypeStruct((s_dim, jt), jnp.float32),
            jax.ShapeDtypeStruct((n_out_rows, ep), jnp.int32),
        ],
        interpret=interpret,
    )(perm, outrow, first, x, z)
    return rep[:, :e], jnp.sum(sc, axis=1), par[:, :e]


# ---------------------------------------------------------------------------
# scatter_save: donation-based in-place partial checkpoint write
# ---------------------------------------------------------------------------

# ---------------------------------------------------------------------------
# arena_maintain: parity XOR + priority scores over the flat arena,
# ONE dispatch for the whole model (not one per leaf)
# ---------------------------------------------------------------------------

# (8, 128) f32 sublane tile of the 2D-retiled arena — the single source
# of truth is the arena layout module; desyncing block shapes from the
# block table would corrupt routing silently
from repro.core.arena import ARENA_LANES, ARENA_SUBLANES  # noqa: E402


def _arena_maintain_kernel(perm_ref, dest_ref, first_ref, x_ref, z_ref,
                           sc_ref, par_ref):
    s = pl.program_id(0)
    x = x_ref[...]                               # (8, 128) f32 arena tile
    d = x - z_ref[...]
    sc_ref[0, 0] = jnp.sum(d * d)                # per-tile score partial
    bits = jax.lax.bitcast_convert_type(x, jnp.int32)

    @pl.when(first_ref[s] == 1)
    def _init():                                 # first member tile: seed
        par_ref[...] = bits

    @pl.when(first_ref[s] == 0)
    def _fold():                                 # later member tile: fold
        par_ref[...] ^= bits


def arena_maintain_pallas(x2d: jnp.ndarray, z2d: jnp.ndarray,
                          perm: jnp.ndarray, dest: jnp.ndarray,
                          first: jnp.ndarray, n_dest_tiles: int,
                          interpret: bool = False,
                          ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One maintenance sweep over the whole 2D-retiled arena.

    x2d, z2d: ``(R, 128)`` float32 — live (replica) arena and running-
    checkpoint arena, ``R`` a multiple of 8. The grid walks ``(8, 128)``
    sublane-aligned tiles in an order sorted by parity destination:

    perm:  (T,) int32 — arena tile visited at grid step ``s`` (all tiles
           XOR-ing into one parity tile arrive consecutively).
    dest:  (T,) int32 — compact parity output tile per sorted step.
    first: (T,) int32 — 1 at the first step of its destination (seed vs
           fold, exactly the per-leaf kernel's revisit accumulation).

    Returns ``(sc (T, 1) f32 per-step score partials, par
    (n_dest_tiles·8, 128) int32 compact parity tiles)``. The caller
    segment-sums ``sc`` by block id and scatters ``par`` into the
    ``(n_groups, frame_elems)`` codec layout (both O(output) epilogues —
    the O(model) sweep is this single dispatch).
    """
    t = perm.shape[0]
    br = ARENA_SUBLANES
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(t,),
        in_specs=[
            pl.BlockSpec((br, ARENA_LANES), lambda s, p, d, f: (p[s], 0)),
            pl.BlockSpec((br, ARENA_LANES), lambda s, p, d, f: (p[s], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda s, p, d, f: (s, 0)),
            pl.BlockSpec((br, ARENA_LANES), lambda s, p, d, f: (d[s], 0)),
        ],
    )
    sc, par = pl.pallas_call(
        _arena_maintain_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((t, 1), jnp.float32),
            jax.ShapeDtypeStruct((n_dest_tiles * br, ARENA_LANES), jnp.int32),
        ],
        interpret=interpret,
    )(perm, dest, first, x2d, z2d)
    return sc, par


# ---------------------------------------------------------------------------
# arena_scatter: in-place partial save over the flat arena, ONE dispatch
# ---------------------------------------------------------------------------

def _arena_scatter_kernel(tiles_ref, src_ref, dst_ref, out_ref):
    del tiles_ref, dst_ref                       # routing/alias only
    out_ref[...] = src_ref[...]


def arena_scatter_pallas(dst2d: jnp.ndarray, src2d: jnp.ndarray,
                         tiles: jnp.ndarray,
                         interpret: bool = False) -> jnp.ndarray:
    """Copy the selected ``(8, 128)`` tiles of ``src2d`` into ``dst2d``
    in place (``dst2d`` donated/aliased — unselected tiles are never
    DMA'd). ``tiles``: (k,) int32 tile indices, duplicates idempotent
    (bucket padding). The whole-model partial save is this one dispatch —
    the per-leaf ``scatter_save`` launched one program per touched leaf.
    """
    k = tiles.shape[0]
    br = ARENA_SUBLANES
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k,),
        in_specs=[
            pl.BlockSpec((br, ARENA_LANES), lambda i, t: (t[i], 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),     # aliased, untouched
        ],
        out_specs=pl.BlockSpec((br, ARENA_LANES), lambda i, t: (t[i], 0)),
    )
    return pl.pallas_call(
        _arena_scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst2d.shape, dst2d.dtype),
        input_output_aliases={2: 0},             # dst (after scalars) -> out
        interpret=interpret,
    )(tiles, src2d, dst2d)


def _scatter_save_kernel(rows_ref, src_ref, dst_ref, out_ref):
    del rows_ref, dst_ref                        # routing/alias only
    out_ref[...] = src_ref[...]


def scatter_save_pallas(dst: jnp.ndarray, src: jnp.ndarray,
                        rows: jnp.ndarray, block_rows: int,
                        interpret: bool = False) -> jnp.ndarray:
    """In-place block scatter over a leaf's raw row matrix.

    dst, src: (R, W) — the leaf reshaped to (rows, row_width), NOT the
    zero-padded block view (padding would materialize a full copy and
    defeat the O(k) goal). rows: (k,) int32 selected *block* ids
    (duplicates are idempotent — callers pad short selections with
    repeats). Block ``b`` covers dst rows ``[b·block_rows, (b+1)·block_rows)``;
    the ragged tail block is handled by Pallas's partial-block masking.

    ``dst`` is donated and aliased to the output, so unselected rows are
    never read or written — saving ``k`` blocks moves ``O(k·block_bytes)``.
    """
    r, w = dst.shape
    k = rows.shape[0]
    br = min(block_rows, r)
    bw = min(BE, w)
    jt = -(-w // bw)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k, jt),
        in_specs=[
            pl.BlockSpec((br, bw), lambda i, j, rows: (rows[i], j)),
            pl.BlockSpec(memory_space=pltpu.ANY),     # aliased, untouched
        ],
        out_specs=pl.BlockSpec((br, bw), lambda i, j, rows: (rows[i], j)),
    )
    return pl.pallas_call(
        _scatter_save_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r, w), dst.dtype),
        input_output_aliases={2: 0},             # dst (after scalars) -> out
        interpret=interpret,
    )(rows, src, dst)
