"""Pure-jnp oracle for the masked_restore kernel."""
import jax.numpy as jnp


def masked_restore_ref(dst: jnp.ndarray, src: jnp.ndarray,
                       mask: jnp.ndarray) -> jnp.ndarray:
    """out[b] = src[b] if mask[b] else dst[b]."""
    return jnp.where(mask[:, None], src, dst)
