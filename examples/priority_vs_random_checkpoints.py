"""Reproduce the paper's Figure 8 story on one model, end to end.

Compares priority / round-robin / random partial-checkpoint strategies at
matched write budget, under the same failure, and prints the resulting
rework iterations — the core SCAR claim in one script.

Run:  PYTHONPATH=src python examples/priority_vs_random_checkpoints.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.policy import CheckpointPolicy, RecoveryMode, SelectionStrategy
from repro.models.classic import make_model
from repro.training import run_clean, run_with_failure


def main():
    model = make_model("mlr", n=600, dim=64, n_classes=5, batch=200)
    clean = run_clean(model, 150)["losses"]
    print("== Figure-8-style comparison on MLR (fail 50% of blocks @ iter 25)")
    print(f"{'strategy':12s} {'r':>6s} {'rework iters (mean of 5 seeds)':>32s}")

    trad = CheckpointPolicy(fraction=1.0, full_interval=8,
                            strategy=SelectionStrategy.ROUND_ROBIN,
                            recovery=RecoveryMode.FULL,
                            block_rows=model.block_rows)
    costs = [run_with_failure(model, trad, fail_iter=25, fail_fraction=0.5,
                              max_iters=150, seed=s,
                              clean_losses=clean)["iteration_cost"]
             for s in range(5)]
    print(f"{'traditional':12s} {'1':>6s} {np.mean(costs):>32.1f}")

    for strat in (SelectionStrategy.PRIORITY, SelectionStrategy.ROUND_ROBIN,
                  SelectionStrategy.RANDOM):
        for r in (0.25, 0.125):
            pol = CheckpointPolicy(fraction=r, full_interval=8,
                                   strategy=strat,
                                   recovery=RecoveryMode.PARTIAL,
                                   block_rows=model.block_rows)
            costs = [run_with_failure(model, pol, fail_iter=25,
                                      fail_fraction=0.5, max_iters=150,
                                      seed=s, clean_losses=clean)
                     ["iteration_cost"] for s in range(5)]
            print(f"{strat.value:12s} {r:>6} {np.mean(costs):>32.1f}")


if __name__ == "__main__":
    main()
