"""Tiered recovery planning: resolve each lost block to the cheapest
surviving redundancy tier and account perturbations per tier.

Tier order (cheapest perturbation first, see DESIGN.md):

  SURVIVOR      — block not lost; live value kept (SCAR partial recovery).
  PEER_REPLICA  — anti-affine replica survived; restores the replica
                  snapshot (live value when fresh → zero perturbation).
  PARITY        — single-erasure XOR reconstruction from surviving group
                  members + parity block (bit-exact live value when fresh).
  RUNNING_CKPT  — the paper's in-memory running checkpoint. Device-resident
                  like the params, so it gets its own ring-shifted homing;
                  blocks whose checkpoint copy also died fall through.
  DISK          — the persistent store mirror (always reachable, slowest).
  SILENT_ERROR  — not a loss tier: the integrity scrub's classification
                  for blocks whose coded state was silently corrupted
                  (detected via RS syndromes, corrected in place when
                  localizable — ‖δ′‖² ≈ 0, priced in the ledger).

This extends the Thm 4.1/4.2 accounting per tier: the applied perturbation
``E‖δ′‖²`` decomposes over tiers, and the replica/parity terms vanish when
those tiers are fresh — so the measured iteration cost drops accordingly.
"""
from __future__ import annotations

import dataclasses
import enum
import inspect
from typing import Any, Callable, Optional

import numpy as np

from repro.core.blocks import BlockPartition, masked_sq_norm, select_blocks
from repro.fabric.parity import (ParityCodec, _leaf_frame_width,
                                 unpack_frames_into)
from repro.fabric.placement import ClusterView, checkpoint_cache_homes
from repro.fabric.replica import ReplicaSet

PyTree = Any


class RecoveryTier(enum.IntEnum):
    SURVIVOR = 0
    PEER_REPLICA = 1
    PARITY = 2
    RUNNING_CKPT = 3
    DISK = 4
    SILENT_ERROR = 5


# nominal read bandwidth per tier, bytes/second — ICI peer copy, on-device
# XOR at HBM bandwidth, HBM-local checkpoint copy, and a shared disk/NFS
# mirror. Used for the recovery-latency estimates in benchmarks/reports.
TIER_BANDWIDTH = {
    RecoveryTier.SURVIVOR: float("inf"),
    RecoveryTier.PEER_REPLICA: 50e9,
    RecoveryTier.PARITY: 200e9,
    RecoveryTier.RUNNING_CKPT: 400e9,
    RecoveryTier.DISK: 1e9,
    # syndrome scrub + in-place XOR correction run at the parity tier's
    # on-device fold bandwidth
    RecoveryTier.SILENT_ERROR: 200e9,
}


@dataclasses.dataclass
class TierPlan:
    tiers: np.ndarray                  # (total_blocks,) int8 RecoveryTier
    failed_devices: np.ndarray
    step: int
    # never-silent fallback accounting: one dict per parity group whose
    # losses exceeded the code's surviving strength (the fabric emits a
    # ``tier_fallback`` event for each — see ParityCodec.exceeded_groups)
    fallbacks: list = dataclasses.field(default_factory=list)

    def mask(self, tier: RecoveryTier) -> np.ndarray:
        return self.tiers == int(tier)

    @property
    def counts(self) -> dict[str, int]:
        return {t.name: int(np.sum(self.tiers == int(t)))
                for t in RecoveryTier}


class TieredRecovery:
    """Planner + executor over the fabric's redundancy tiers."""

    def __init__(self, partition: BlockPartition, view: ClusterView,
                 replicas: Optional[ReplicaSet] = None,
                 parity: Optional[ParityCodec] = None):
        self.partition = partition
        self.view = view
        self.domains = view.domains
        self.replicas = replicas
        self.parity = parity
        # running-checkpoint cache homed on a host holding neither the
        # primary nor the replica, so one domain loss cannot take a block,
        # its replica, and its checkpoint copy all at once
        self.rehome()
        self._block_bytes = self._frame_bytes()

    @property
    def homes(self) -> np.ndarray:
        """Current primary placement (shared mutable view)."""
        return self.view.homes

    def rehome(self) -> None:
        """Recompute the running-checkpoint cache placement from the view's
        current topology (called after elastic re-homing / healing)."""
        self.ckpt_homes = checkpoint_cache_homes(
            self.view, self.replicas.replica_homes
            if self.replicas is not None else None)

    def _frame_bytes(self) -> np.ndarray:
        """Approximate payload bytes per block (for latency estimates)."""
        out = np.zeros((self.partition.total_blocks,), np.int64)
        br = self.partition.block_rows
        for leaf in self.partition.leaves:
            per = _leaf_frame_width(leaf, br) * 4
            out[leaf.offset:leaf.offset + leaf.n_blocks] += per
        return out

    # -- planning ------------------------------------------------------------

    def plan(self, lost_mask, failed_devices, step: int) -> TierPlan:
        """Resolve every block to its recovery tier for this failure."""
        lost = np.asarray(lost_mask, bool)
        failed = np.asarray(failed_devices, np.int32)
        total = self.partition.total_blocks
        tiers = np.full((total,), int(RecoveryTier.SURVIVOR), np.int8)

        replica_ok = np.zeros((total,), bool)
        replica_fresh = False
        if self.replicas is not None:
            replica_ok = lost & self.replicas.surviving(failed)
            replica_fresh = self.replicas.is_fresh(step)
        tiers[replica_ok] = int(RecoveryTier.PEER_REPLICA)

        parity_ok = np.zeros((total,), bool)
        fallbacks: list = []
        if self.parity is not None:
            # a member's frame is available if its home is still alive and
            # it isn't lost in this event — a block homed on a device dead
            # since an earlier (persisted) failure is physically gone even
            # though the simulation still holds its value. A fresh-replica-
            # restored block's frame equals its live value, so it can serve
            # as a survivor in its parity group (cascade).
            home_alive = self.view.alive[self.view.homes]
            available = (~lost & home_alive) | (replica_ok if replica_fresh
                                                else False)
            parity_ok = self.parity.reconstructable(
                lost & ~replica_ok, available, failed, step)
            fallbacks = self.parity.exceeded_groups(
                lost & ~replica_ok, available, failed, step)
        tiers[parity_ok & ~replica_ok] = int(RecoveryTier.PARITY)

        remaining = lost & ~replica_ok & ~parity_ok
        ckpt_alive = (self.view.alive[self.ckpt_homes]
                      & ~np.isin(self.ckpt_homes, failed))
        tiers[remaining & ckpt_alive] = int(RecoveryTier.RUNNING_CKPT)
        tiers[remaining & ~ckpt_alive] = int(RecoveryTier.DISK)
        return TierPlan(tiers=tiers, failed_devices=failed, step=int(step),
                        fallbacks=fallbacks)

    # -- execution -----------------------------------------------------------

    def recover(self, params: PyTree, ckpt_values: PyTree, plan: TierPlan,
                disk_values: Optional[PyTree] = None,
                disk_reader: Optional[Callable[[], PyTree]] = None,
                ) -> tuple[PyTree, dict]:
        """Apply the plan. Returns (recovered params, per-tier stats).

        ``params`` are the pre-failure live values (the simulation keeps
        them to *measure* the perturbation each tier applies — on a real
        failure the lost blocks' live values are simply gone).
        ``disk_reader`` is called only when the plan actually contains
        DISK blocks (a persistent-store read is expensive); without either
        disk source the running-checkpoint values stand in — a simulation
        fallback that is exact only while the disk mirror is in sync.
        """
        part = self.partition
        out = params

        m_rep = plan.mask(RecoveryTier.PEER_REPLICA)
        if m_rep.any():
            if self.replicas.arena is not None:
                # arena-form snapshot: each touched leaf decodes one
                # contiguous arena slice — no full-tree materialization
                # (arena_local: on a mesh the replica sits on the rotated
                # anti-affine device order; re-place before mixing with
                # the flat-sharded live values in one computation)
                from repro.kernels.masked_restore.ops import \
                    arena_masked_restore
                out = arena_masked_restore(out, self.replicas.arena_local(),
                                           np.asarray(m_rep),
                                           self.replicas.arena_layout)
            else:
                out = select_blocks(out, self.replicas.values,
                                    np.asarray(m_rep), part)

        m_par = plan.mask(RecoveryTier.PARITY)
        if m_par.any():
            # survivors + replica-restored blocks in ``out`` carry the live
            # frames parity reconstruction folds against — matching plan():
            # survivors must also be home-alive, replica restores count
            # regardless (their frame came off an alive replica device)
            home_alive = self.view.alive[self.view.homes]
            available = (plan.tiers < int(RecoveryTier.PARITY)) & (
                home_alive | (plan.tiers == int(RecoveryTier.PEER_REPLICA)))
            if (self.replicas is not None
                    and self.replicas.arena is not None
                    and self.replicas.refreshed_step
                    == self.parity.encoded_step):
                # the sweep that encoded this parity also packed the
                # snapshot arena, so the arena IS the encode-time frame
                # source — one gather, no full-tree pack_frames pass
                frames = self.parity.reconstruct_from_arena(
                    self.replicas.arena_local(), self.replicas.arena_layout,
                    m_par, available)
            else:
                frames = self.parity.reconstruct(out, m_par, available)
            out = unpack_frames_into(out, frames, m_par, part,
                                     self.parity.layout)

        m_ck = plan.mask(RecoveryTier.RUNNING_CKPT)
        if m_ck.any():
            out = select_blocks(out, ckpt_values, np.asarray(m_ck), part)

        m_dk = plan.mask(RecoveryTier.DISK)
        if m_dk.any():
            if disk_values is None and disk_reader is not None:
                # domain-keyed stores accept the block mask so the read
                # touches only the needed blocks' files; legacy readers
                # take no arguments and return the full mirror. Dispatch on
                # the signature — catching TypeError would swallow a
                # reader's own bugs.
                try:
                    takes_mask = len(inspect.signature(
                        disk_reader).parameters) >= 1
                except (TypeError, ValueError):
                    takes_mask = True
                disk_values = (disk_reader(np.asarray(m_dk)) if takes_mask
                               else disk_reader())
            src = disk_values if disk_values is not None else ckpt_values
            out = select_blocks(out, src, np.asarray(m_dk), part)

        tier_sq, tier_latency = {}, {}
        for tier in RecoveryTier:
            if tier == RecoveryTier.SURVIVOR:
                continue
            m = plan.mask(tier)
            tier_sq[tier.name] = (
                float(masked_sq_norm(out, params, np.asarray(m), part))
                if m.any() else 0.0)
            tier_latency[tier.name] = float(
                self._block_bytes[m].sum() / TIER_BANDWIDTH[tier])
        stats = {
            "tier_counts": plan.counts,
            "tier_sq": tier_sq,
            "est_recovery_seconds": tier_latency,
        }
        return out, stats
