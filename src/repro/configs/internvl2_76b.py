"""internvl2-76b [vlm] — InternViT + InternLM2 backbone [arXiv:2404.16821].

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256. The vision frontend
(InternViT-6B) is a STUB per the brief: input_specs() provides precomputed
patch embeddings (vit_dim=3200) which the learned projector maps to d_model.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    n_patches=1024,
    vit_dim=3200,
    sliding_window=4096,   # long_500k variant opt-in (noted in DESIGN.md)
    microbatch=4,
    source="arXiv:2404.16821",
))
