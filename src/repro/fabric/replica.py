"""Anti-affine peer replication of running-state blocks (tier 1).

Each block's replica is placed ring-shifted into a different failure domain
(the next rack when racks exist, else the next host), so a whole-domain
failure never takes a block *and* its replica together. Replicas hold live
parameter values as of the last refresh — refreshing is a device-to-device
copy (no host trip, no disk), cheap enough to run every iteration, so a
replica-recovered block is restored to its *live* value: zero perturbation
in the Thm 4.1 accounting (see DESIGN.md).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocks import BlockPartition
from repro.fabric.domains import (FailureDomainMap, anti_affine_shift,
                                  ring_shift_homes)

PyTree = Any


class ReplicaSet:
    """One replica per block, anti-affine to the block's primary home."""

    def __init__(self, partition: BlockPartition, homes: np.ndarray,
                 domains: FailureDomainMap, shift: Optional[int] = None):
        self.partition = partition
        self.domains = domains
        self.homes = np.asarray(homes, np.int32)
        if shift is None:
            shift = anti_affine_shift(domains)
        self.shift = shift
        self.replica_homes = ring_shift_homes(self.homes, shift,
                                              domains.n_devices)
        self.values: Optional[PyTree] = None
        self.refreshed_step = -1

    # -- maintenance ---------------------------------------------------------

    def refresh(self, step: int, params: PyTree) -> None:
        """Snapshot live params into the replicas (device copy)."""
        self.values = jax.tree_util.tree_map(jnp.array, params)
        self.refreshed_step = int(step)

    def is_fresh(self, step: int) -> bool:
        """True when replicas hold the *current* live values (no parameter
        update has happened since the refresh)."""
        return self.values is not None and self.refreshed_step == int(step)

    # -- survivorship --------------------------------------------------------

    def surviving(self, failed_devices) -> np.ndarray:
        """(total_blocks,) bool — replicas whose home device is alive."""
        if self.values is None:
            return np.zeros((self.partition.total_blocks,), bool)
        failed = np.asarray(failed_devices, np.int32)
        return ~np.isin(self.replica_homes, failed)

    def nbytes(self) -> int:
        if self.values is None:
            return 0
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree_util.tree_leaves(self.values))
