"""Tree-level drivers for the fused_maintain kernel family.

``make_fused_maintain_fn`` builds the fabric's hot-loop program: one jitted
function ``(params, ckpt_values) -> (replica_tree, scores, parity)`` that
reads each live leaf once and produces all three maintenance outputs. The
host-side group metadata (sorted block order, compact parity rows, member
matrices) is precomputed per parity striping and baked into the program —
rebuilt by the fabric whenever the placement engine re-stripes.

``tree_scatter_save`` is the checkpoint-side counterpart: a donation-based
in-place partial save that moves only the selected blocks' bytes into the
running checkpoint instead of rewriting every leaf through ``jnp.where``.

Backend contract matches the other kernel packages: compiled Pallas on
TPU, the jnp path elsewhere (interpret-mode Pallas is for validation
only).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocks import (BlockPartition, leaf_block_view,
                               leaf_block_words)
from repro.fabric.parity import FrameLayout
from repro.kernels.fused_maintain.kernel import (fused_maintain_pallas,
                                                 scatter_save_pallas)

PyTree = Any


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Host-side group metadata (static per parity striping)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LeafGroupMeta:
    """Per-leaf routing tables for the fused sweep (numpy, host-resident)."""
    perm: np.ndarray        # (S,) block ids sorted by parity group
    outrow: np.ndarray      # (S,) compact parity row per sorted position
    first: np.ndarray       # (S,) 1 at the first sorted position of its row
    touched: np.ndarray     # (n_out,) global group ids, ascending
    members: np.ndarray     # (n_out, m_hat) local block ids, -1 padded
    col: int                # column of this leaf's payload in the frame
    width: int              # payload width (int32 words)


def leaf_group_metas(partition: BlockPartition, layout: FrameLayout,
                     group_of: np.ndarray) -> list[LeafGroupMeta]:
    """Build each leaf's routing tables from the codec's group assignment."""
    group_of = np.asarray(group_of, np.int32)
    metas = []
    for leaf, col, width in zip(partition.leaves, layout.cols, layout.widths):
        gids = group_of[leaf.offset:leaf.offset + leaf.n_blocks]
        assert (gids >= 0).all(), \
            f"leaf {leaf.name}: blocks outside any parity group"
        order = np.argsort(gids, kind="stable").astype(np.int32)
        touched, inverse = np.unique(gids, return_inverse=True)
        outrow = inverse.astype(np.int32)[order]
        first = np.ones_like(outrow)
        first[1:] = (outrow[1:] != outrow[:-1]).astype(np.int32)
        m_hat = int(np.bincount(outrow).max())
        members = np.full((touched.size, m_hat), -1, np.int32)
        fill = np.zeros((touched.size,), np.int64)
        for pos, row in zip(order, outrow):
            members[row, fill[row]] = pos
            fill[row] += 1
        metas.append(LeafGroupMeta(perm=order, outrow=outrow, first=first,
                                   touched=touched.astype(np.int32),
                                   members=members, col=int(col),
                                   width=int(width)))
    return metas


# ---------------------------------------------------------------------------
# Fused maintenance program
# ---------------------------------------------------------------------------

def _leaf_sweep_pallas(x, z, meta: LeafGroupMeta, block_rows: int,
                       interpret: bool):
    xv = leaf_block_view(x, block_rows)
    zv = leaf_block_view(z.astype(x.dtype), block_rows)
    return fused_maintain_pallas(xv, zv, jnp.asarray(meta.perm),
                                 jnp.asarray(meta.outrow),
                                 jnp.asarray(meta.first),
                                 n_out_rows=int(meta.touched.size),
                                 interpret=interpret)


def _leaf_sweep_jnp(x, z, meta: LeafGroupMeta, block_rows: int):
    """jnp fast path: same outputs, one compact gather+fold per leaf —
    never the (total_blocks, frame_width) packed buffer of the seed path.
    Scores diff f32 views of the values (what ``block_scores`` does);
    the parity contribution is the leaf's raw bit-packed words."""
    xv = leaf_block_view(x.astype(jnp.float32), block_rows)
    zv = leaf_block_view(z.astype(jnp.float32), block_rows)
    scores = jnp.sum((xv - zv) ** 2, axis=1)
    bits = leaf_block_words(x, block_rows)
    idx = jnp.asarray(meta.members)
    valid = idx >= 0
    gathered = bits[jnp.where(valid, idx, 0)]        # (n_out, m_hat, E)
    contrib = jax.lax.reduce(jnp.where(valid[..., None], gathered, 0),
                             jnp.int32(0), jax.lax.bitwise_xor, (1,))
    replica = jax.tree_util.tree_map(jnp.array, x)
    return replica, scores, contrib


def make_fused_maintain_fn(partition: BlockPartition, layout: FrameLayout,
                           group_of: np.ndarray, n_groups: int,
                           use_pallas: Optional[bool] = None,
                           interpret: Optional[bool] = None,
                           ) -> Callable[[PyTree, PyTree], tuple]:
    """Build the jitted single-sweep maintenance program.

    Returns ``fn(params, ckpt_values) -> (replica_tree, scores, parity)``
    where ``scores`` is the (total_blocks,) squared-L2 drift vs the
    running checkpoint (colocated leaves accumulate, like
    :func:`repro.core.blocks.block_scores`) and ``parity`` is the
    (n_groups, frame_elems) int32 XOR parity — bit-identical to
    :meth:`ParityCodec.encode`'s result under the same striping.
    """
    if use_pallas is None:
        use_pallas = _is_tpu()
    if interpret is None:
        interpret = not _is_tpu()
    metas = leaf_group_metas(partition, layout, group_of)
    br = partition.block_rows

    def _maintain(params: PyTree, ckpt_values: PyTree):
        flat = jax.tree_util.tree_leaves(params)
        zflat = jax.tree_util.tree_leaves(ckpt_values)
        scores = jnp.zeros((partition.total_blocks,), jnp.float32)
        parity = jnp.zeros((n_groups, layout.frame_elems), jnp.int32)
        replicas = []
        for x, z, leaf, meta in zip(flat, zflat, partition.leaves, metas):
            # the Pallas leaf kernel is an element-width f32 program; for
            # word-packed dtypes (bf16/fp8/int8 — element count != word
            # count) the jnp word path computes the same outputs
            if use_pallas and np.dtype(leaf.dtype) == np.dtype(np.float32):
                rep_v, sc, contrib = _leaf_sweep_pallas(x, z, meta, br,
                                                        interpret)
                rows = max(leaf.rows, 1)
                rep = rep_v.reshape(-1, max(leaf.row_width, 1))[:rows]
                rep = rep.reshape(leaf.shape)
            else:
                rep, sc, contrib = _leaf_sweep_jnp(x, z, meta, br)
            replicas.append(rep)
            scores = jax.lax.dynamic_update_slice(
                scores, jax.lax.dynamic_slice(
                    scores, (leaf.offset,), (leaf.n_blocks,)) + sc,
                (leaf.offset,))
            rows = jnp.asarray(meta.touched)
            cols = slice(meta.col, meta.col + meta.width)
            parity = parity.at[rows, cols].set(parity[rows, cols] ^ contrib)
        replica_tree = jax.tree_util.tree_unflatten(partition.treedef,
                                                    replicas)
        return replica_tree, scores, parity

    return jax.jit(_maintain)


# ---------------------------------------------------------------------------
# Arena maintenance: ONE dispatch over the flat parameter arena
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ArenaRouting:
    """Host-side tile routing for the arena sweep (static per striping)."""
    perm: np.ndarray          # (T,) arena tile visited at sorted step s
    dest: np.ndarray          # (T,) compact parity tile per sorted step
    first: np.ndarray         # (T,) 1 at the first step of its dest
    touched: np.ndarray       # (n_dest,) full parity tile index, ascending
    members: np.ndarray       # (n_dest, m_hat) arena tile ids, -1 padded
    tile_gid: np.ndarray      # (T,) global block id per arena tile
    frame_tiles: int          # parity frame width in arena tiles


def arena_routing(arena_layout, frame_layout: FrameLayout,
                  group_of: np.ndarray) -> ArenaRouting:
    """Map every (8, 128) arena tile to its parity destination tile.

    Tile ``k`` of block ``gid`` (leaf ``l``) lands in parity frame row
    ``group_of[gid]`` at columns ``cols[l] + k·ARENA_TILE`` — whole tiles
    because the frame layout is arena-tile aligned. Sorting tiles by
    destination makes every parity output tile's contributors consecutive
    grid steps (seed on ``first``, XOR-fold after), exactly the per-leaf
    kernel's revisit accumulation but across the entire model at once.

    Tail-packed blocks (word-granular, tile-sharing) are *not* routed
    here — :class:`ArenaMaintainProgram` XOR-folds their payload words
    into the parity with a word-granular epilogue."""
    from repro.core.arena import ARENA_TILE
    group_of = np.asarray(group_of, np.int32)
    n_tiles = arena_layout.n_tiles
    ftiles = frame_layout.frame_elems // ARENA_TILE
    tail_start = getattr(arena_layout, "tail_start", -1)
    if tail_start < 0:
        tail_start = arena_layout.total_words
    # shard-pad tail tiles (sharded layouts only) carry no payload: they
    # route to no parity destination (dest -1, dropped from the perm) and
    # report gid 0 — zero words diffed against zero words add an exact
    # +0.0 to gid 0's score, so the score path can stay full-length.
    # Tail-region tiles are likewise unrouted (word epilogue).
    dest_full = np.full((n_tiles,), -1, np.int64)
    tile_gid = np.zeros((n_tiles,), np.int32)
    for ab in arena_layout.blocks:
        g = group_of[ab.gid]
        assert g >= 0, f"arena block gid={ab.gid} outside any parity group"
        if ab.offset >= tail_start:
            continue
        t0 = ab.offset // ARENA_TILE
        nt = ab.words // ARENA_TILE
        col_t = frame_layout.cols[ab.leaf] // ARENA_TILE
        dest_full[t0:t0 + nt] = g * ftiles + col_t + np.arange(nt)
        tile_gid[t0:t0 + nt] = ab.gid
    data_tiles = np.nonzero(dest_full >= 0)[0]
    perm = data_tiles[np.argsort(dest_full[data_tiles],
                                 kind="stable")].astype(np.int32)
    dest_sorted = dest_full[perm]
    touched, inverse = np.unique(dest_sorted, return_inverse=True)
    dest = inverse.astype(np.int32)
    first = np.ones_like(dest)
    first[1:] = (dest[1:] != dest[:-1]).astype(np.int32)
    m_hat = int(np.bincount(dest).max()) if dest.size else 0
    members = np.full((touched.size, m_hat), -1, np.int32)
    fill = np.zeros((touched.size,), np.int64)
    for pos, row in zip(perm, dest):
        members[row, fill[row]] = pos
        fill[row] += 1
    return ArenaRouting(perm=perm, dest=dest, first=first,
                       touched=touched.astype(np.int32), members=members,
                       tile_gid=tile_gid, frame_tiles=int(ftiles))


class ArenaMaintainProgram:
    """The jitted single-sweep maintenance program over the flat arena.

    ``program(params, ckpt_arena)`` packs the live tree into arena form
    (the pack IS the replica refresh — one read of every leaf, one write
    of the snapshot) and runs ONE kernel dispatch over the 2D-retiled
    arena emitting the group-sorted XOR parity and per-tile PRIORITY
    score partials; tiny O(output) epilogues fold partials into
    per-block scores and scatter the compact parity tiles into the
    codec's ``(n_groups, frame_elems)`` layout.

    Returns ``(replica_arena, scores, parity)`` — parity bit-identical
    to :meth:`ParityCodec.encode` under the same striping, scores
    allclose to :func:`repro.core.blocks.block_scores` (different
    association order; per-dtype word decode for quantized leaves).
    With ``ckpt_arena=None`` the sweep still refreshes replica +
    parity; scores are zeros (nothing to diff).

    Tail-packed blocks are swept by a word-granular epilogue: their
    payload words gather by flat parity position and XOR *into* the
    tile-scattered parity (a position can receive both a main tile and
    tail words — different gids of one group own different leaves'
    overlapping columns). The compiled Pallas arena kernel is an
    aligned-tile f32 program, so it only engages on uniform-f32 layouts
    without a tail region; everything else runs the (identical-output)
    jnp sweep.

    ``params`` may also be the live flat arena itself (arena-resident
    training state): the pack disappears entirely and the sweep is the
    pure 2-read/1-write pass — read live + checkpoint arenas, write the
    replica copy + compact outputs. Outputs are bit-identical to the
    pack path on the same values (``pack ∘ unpack`` is the identity)."""

    def __init__(self, partition: BlockPartition, arena_layout,
                 frame_layout: FrameLayout, group_of: np.ndarray,
                 n_groups: int, use_pallas: Optional[bool] = None,
                 interpret: Optional[bool] = None, out_sharding=None):
        from repro.core.arena import (ARENA_TILE, arena_drift_scores,
                                      pack_arena)
        if use_pallas is None:
            use_pallas = _is_tpu()
        if interpret is None:
            interpret = not _is_tpu()
        # the compiled arena kernel assumes words == f32 values on
        # exclusively owned aligned tiles; quantized or tail-packed
        # layouts run the jnp word sweep (same outputs) instead
        pallas_eligible = (arena_layout.uniform_f32
                           and not arena_layout.has_tail)
        use_pallas = bool(use_pallas and pallas_eligible)
        self.layout = arena_layout
        self.routing = arena_routing(arena_layout, frame_layout, group_of)
        r = self.routing
        total = partition.total_blocks
        n_dest = int(r.touched.size)
        full_tiles = n_groups * r.frame_tiles
        frame_elems = frame_layout.frame_elems
        perm = jnp.asarray(r.perm)
        dest = jnp.asarray(r.dest)
        first = jnp.asarray(r.first)
        touched = jnp.asarray(r.touched)
        members = jnp.asarray(np.where(r.members >= 0, r.members, 0))
        valid = jnp.asarray(r.members >= 0)
        gid_sorted = jnp.asarray(r.tile_gid[r.perm])

        # tail-packed blocks: word-granular parity routing. Every tail
        # payload word has one flat parity position group·frame_elems +
        # col + j; positions shared across gids (overlapping columns of
        # different leaves in one group) gather all their contributor
        # words and XOR-fold.
        tail_pos = tail_members = tail_valid = None
        if arena_layout.has_tail:
            gof = np.asarray(group_of, np.int64)
            pos_l, wid_l = [], []
            for ab in arena_layout.blocks:
                if ab.offset < arena_layout.tail_start:
                    continue
                base = (gof[ab.gid] * frame_elems
                        + frame_layout.cols[ab.leaf])
                pos_l.append(base + np.arange(ab.payload))
                wid_l.append(np.arange(ab.offset, ab.offset + ab.payload))
            pos = np.concatenate(pos_l)
            wid = np.concatenate(wid_l)
            upos, inv = np.unique(pos, return_inverse=True)
            m_hat = int(np.bincount(inv).max())
            tmem = np.zeros((upos.size, m_hat), np.int64)
            tval = np.zeros((upos.size, m_hat), bool)
            fill = np.zeros((upos.size,), np.int64)
            for w, row in zip(wid, inv):
                tmem[row, fill[row]] = w
                tval[row, fill[row]] = True
                fill[row] += 1
            tail_pos = jnp.asarray(upos)
            tail_members = jnp.asarray(tmem)
            tail_valid = jnp.asarray(tval)

        def _sweep(rep, z_arena):
            if use_pallas:
                from repro.kernels.fused_maintain.kernel import \
                    arena_maintain_pallas
                sc, par = arena_maintain_pallas(
                    rep.reshape(-1, 128), z_arena.reshape(-1, 128),
                    perm, dest, first, n_dest, interpret=interpret)
                scores = jax.ops.segment_sum(sc[:, 0], gid_sorted,
                                             num_segments=total)
                par_c = par.reshape(n_dest, ARENA_TILE)
            else:
                # per-dtype word scorer: bit-identical to the historical
                # tile scorer on all-f32 main regions, word-gid reduction
                # over the (shared-tile) tail region
                scores = arena_drift_scores(rep, z_arena, arena_layout)
                bits = jax.lax.bitcast_convert_type(
                    rep.reshape(-1, ARENA_TILE), jnp.int32)
                gathered = bits[members]          # (n_dest, m_hat, TILE)
                par_c = jax.lax.reduce(
                    jnp.where(valid[..., None], gathered, 0),
                    jnp.int32(0), jax.lax.bitwise_xor, (1,))
            full = jnp.zeros((full_tiles, ARENA_TILE), jnp.int32)
            parity = full.at[touched].set(par_c).reshape(n_groups * r.frame_tiles * ARENA_TILE)
            if tail_pos is not None:
                wbits = jax.lax.bitcast_convert_type(rep, jnp.int32)
                fold = jax.lax.reduce(
                    jnp.where(tail_valid, wbits[tail_members], 0),
                    jnp.int32(0), jax.lax.bitwise_xor, (1,))
                # XOR into (not over) the tile parity: a flat position
                # can hold a main tile's words AND tail contributions
                parity = parity.at[tail_pos].set(parity[tail_pos] ^ fold)
            return scores, parity.reshape(n_groups, frame_elems)

        # ``out_sharding`` (SPMD meshes) pins the internal pack to the
        # flat arena sharding — both the layout the sweep wants and the
        # workaround for jax 0.4.37's sharded-concatenate miscompile
        # (see core/arena.py)
        def _scored(params, z_arena):
            rep = pack_arena(params, arena_layout, out_sharding=out_sharding)
            scores, parity = _sweep(rep, z_arena)
            return rep, scores, parity

        def _unscored(params):
            rep = pack_arena(params, arena_layout, out_sharding=out_sharding)
            _, parity = _sweep(rep, rep)
            return rep, jnp.zeros((total,), jnp.float32), parity

        # arena-resident live state: the live params ARE already an
        # arena, so there is nothing to pack — the sweep reads the live
        # buffer and the replica snapshot is a plain copy of it, emitted
        # from the same read (2 reads + 1 write + compact outputs). The
        # optimization_barrier keeps the copy an op (not an identity the
        # runtime could forward as an alias of the input): the replica
        # must own its buffer because the live arena is donated into the
        # very next train step.
        def _scored_live(live, z_arena):
            scores, parity = _sweep(live, z_arena)
            return jax.lax.optimization_barrier(live), scores, parity

        def _unscored_live(live):
            _, parity = _sweep(live, live)
            return (jax.lax.optimization_barrier(live),
                    jnp.zeros((total,), jnp.float32), parity)

        # owned live arena (``own_live=True``): a tree-stepping caller
        # hands over the pack it just made — the buffer itself becomes
        # the replica, so the sweep emits no copy at all (the caller
        # guarantees the arena is never donated or mutated afterwards);
        # total cost matches the internal-pack path exactly
        def _scored_owned(live, z_arena):
            return _sweep(live, z_arena)

        def _unscored_owned(live):
            _, parity = _sweep(live, live)
            return jnp.zeros((total,), jnp.float32), parity

        self._scored = jax.jit(_scored)
        self._unscored = jax.jit(_unscored)
        self._scored_live = jax.jit(_scored_live)
        self._unscored_live = jax.jit(_unscored_live)
        self._scored_owned = jax.jit(_scored_owned)
        self._unscored_owned = jax.jit(_unscored_owned)

    def __call__(self, params: PyTree,
                 ckpt_arena: Optional[jnp.ndarray] = None,
                 own_live: bool = False):
        from repro.core.arena import as_live_arena
        live = as_live_arena(params, self.layout)
        if live is not None and own_live:
            if ckpt_arena is None:
                scores, parity = self._unscored_owned(live)
            else:
                scores, parity = self._scored_owned(live, ckpt_arena)
            return live, scores, parity
        if live is not None:
            return (self._unscored_live(live) if ckpt_arena is None
                    else self._scored_live(live, ckpt_arena))
        if ckpt_arena is None:
            return self._unscored(params)
        return self._scored(params, ckpt_arena)


# ---------------------------------------------------------------------------
# Arena in-place partial save: ONE donated scatter for the whole model
# ---------------------------------------------------------------------------

_ARENA_SCATTER_CACHE: dict = {}


def _arena_scatter_fn(total_words: int, k_hat: int, w_hat: int,
                      use_pallas: bool, interpret: bool):
    from repro.core.arena import ARENA_TILE
    key = (total_words, k_hat, w_hat, use_pallas, interpret)
    fn = _ARENA_SCATTER_CACHE.get(key)
    if fn is not None:
        return fn

    def _scatter(dst, src, tiles, widx):
        out = dst
        if k_hat:
            if use_pallas:
                from repro.kernels.fused_maintain.kernel import \
                    arena_scatter_pallas
                out = arena_scatter_pallas(out.reshape(-1, 128),
                                           src.reshape(-1, 128), tiles,
                                           interpret=interpret)
            else:
                d = out.reshape(-1, ARENA_TILE)
                out = d.at[tiles].set(src.reshape(-1, ARENA_TILE)[tiles])
            out = out.reshape(total_words)
        if w_hat:
            # tail-packed blocks share tiles, so their save granularity
            # is the payload word (duplicate pad indices are idempotent)
            out = out.at[widx].set(src[widx])
        return out

    fn = jax.jit(_scatter, donate_argnums=(0,))
    _ARENA_SCATTER_CACHE[key] = fn
    return fn


def arena_scatter_save(dst_arena: jnp.ndarray, src_arena: jnp.ndarray,
                       arena_layout, global_idx: np.ndarray,
                       use_pallas: Optional[bool] = None,
                       interpret: Optional[bool] = None,
                       ) -> tuple[jnp.ndarray, int]:
    """Overwrite the selected blocks' arena segments of ``dst_arena``
    from ``src_arena`` in place — one donated dispatch total, O(k·seg)
    bytes, vs ``tree_scatter_save``'s one dispatch per touched leaf.

    Main-region blocks move as whole tiles (the Pallas/jnp tile
    scatter); tail-packed blocks move their payload words only — a tile
    copy would clobber unselected tile-mates. Bytes moved therefore
    match :meth:`ArenaLayout.seg_bytes_for_blocks` exactly.

    ``global_idx``: host-resident selected global block ids (colocated
    leaves' segments ride along — they share gids). Returns
    ``(updated_arena, bytes_moved)``; ``dst_arena`` is donated."""
    if use_pallas is None:
        use_pallas = _is_tpu()
    if interpret is None:
        interpret = not _is_tpu()
    main, tail = arena_layout.split_tail_blocks(global_idx)
    tiles = np.empty((0,), np.int32)
    if main.size:
        t0, nt = arena_layout.ab_t0[main], arena_layout.ab_nt[main]
        starts = np.cumsum(nt) - nt
        tiles = np.unique(np.repeat(t0, nt) + (np.arange(int(nt.sum()))
                          - np.repeat(starts, nt))).astype(np.int32)
    widx = (np.concatenate(
        [np.arange(arena_layout.blocks[i].offset,
                   arena_layout.blocks[i].offset
                   + arena_layout.blocks[i].payload) for i in tail])
        if tail.size else np.empty((0,), np.int64))
    if tiles.size == 0 and widx.size == 0:
        return dst_arena, 0
    k_hat = _bucket(tiles.size, arena_layout.n_tiles) if tiles.size else 0
    tiles_p = np.full((max(k_hat, 1),), tiles[0] if tiles.size else 0,
                      np.int32)
    tiles_p[:tiles.size] = tiles
    # w_hat is a *layout constant* — the whole tail region, bucketed —
    # not the selection's tail word count: a per-save w_hat crosses with
    # k_hat into a fresh jit key almost every save (ROUND_ROBIN windows
    # shift across rotations) and recompiles in the save hot loop. Pad
    # slots repeat a word this save writes anyway (first selected
    # block's first payload word), so the duplicates are idempotent;
    # the tail region is sub-tile-scale by construction, so the extra
    # scatter lanes are noise.
    tail_words = (arena_layout.tail_end - arena_layout.tail_start
                  if arena_layout.has_tail else 0)
    w_hat = (_bucket(tail_words, arena_layout.total_words)
             if tail_words else 0)
    pad_src = tail if tail.size else main
    pad_word = int(arena_layout.blocks[int(pad_src[0])].offset)
    widx_p = np.full((max(w_hat, 1),), pad_word, np.int64)
    widx_p[:widx.size] = widx
    fn = _arena_scatter_fn(int(arena_layout.total_words), k_hat, w_hat,
                           use_pallas, interpret)
    out = fn(dst_arena, src_arena, jnp.asarray(tiles_p),
             jnp.asarray(widx_p))
    from repro.core.arena import ARENA_TILE
    return out, int(tiles.size) * ARENA_TILE * 4 + int(widx.size) * 4


# ---------------------------------------------------------------------------
# In-place partial save
# ---------------------------------------------------------------------------

_SCATTER_CACHE: dict = {}


def _bucket(n: int, cap: int) -> int:
    """Next power of two ≥ n, clipped to cap — bounds jit recompiles to
    O(log cap) distinct selection sizes per leaf signature."""
    return min(1 << max(0, math.ceil(math.log2(max(n, 1)))), cap)


def _scatter_leaf_fn(shape: tuple, dtype, k_hat: int, block_rows: int,
                     use_pallas: bool, interpret: bool):
    key = (shape, str(dtype), k_hat, block_rows, use_pallas, interpret)
    fn = _SCATTER_CACHE.get(key)
    if fn is not None:
        return fn
    rows_total = shape[0] if len(shape) >= 1 else 1
    width = int(np.prod(shape[1:])) if len(shape) >= 1 else 1

    def _scatter(dst, src, sel):
        d2 = dst.reshape(max(rows_total, 1), max(width, 1))
        s2 = src.astype(dst.dtype).reshape(max(rows_total, 1), max(width, 1))
        if use_pallas:
            out = scatter_save_pallas(d2, s2, sel, block_rows,
                                      interpret=interpret)
        else:
            # row-expanded gather/scatter: duplicates from the clip and the
            # bucket padding rewrite identical values (idempotent)
            row_idx = (sel[:, None] * block_rows
                       + jnp.arange(block_rows)[None, :]).reshape(-1)
            row_idx = jnp.minimum(row_idx, max(rows_total, 1) - 1)
            out = d2.at[row_idx].set(s2[row_idx])
        return out.reshape(shape)

    fn = jax.jit(_scatter, donate_argnums=(0,))
    _SCATTER_CACHE[key] = fn
    return fn


def tree_scatter_save(dst: PyTree, src: PyTree, global_idx: np.ndarray,
                      partition: BlockPartition,
                      use_pallas: Optional[bool] = None,
                      interpret: Optional[bool] = None,
                      ) -> tuple[PyTree, int]:
    """Overwrite the selected blocks of ``dst`` from ``src`` in place.

    ``global_idx`` — host-resident selected global block ids. Leaves with
    no selected block pass through untouched (zero traffic); each touched
    leaf moves only its selected blocks' rows. Returns
    ``(updated_tree, bytes_moved)``. ``dst`` leaves are donated — callers
    must not reuse the input buffers of touched leaves.
    """
    if use_pallas is None:
        use_pallas = _is_tpu()
    if interpret is None:
        interpret = not _is_tpu()
    idx = np.unique(np.asarray(global_idx, np.int64))
    dst_flat = jax.tree_util.tree_leaves(dst)
    src_flat = jax.tree_util.tree_leaves(src)
    br = partition.block_rows
    out = []
    moved = 0
    # colocated leaves share block-id ranges; each leaf still scatters its
    # own payload for the shared ids
    for d, s, leaf in zip(dst_flat, src_flat, partition.leaves):
        lo = np.searchsorted(idx, leaf.offset)
        hi = np.searchsorted(idx, leaf.offset + leaf.n_blocks)
        sel = (idx[lo:hi] - leaf.offset).astype(np.int32)
        if sel.size == 0:
            out.append(d)
            continue
        k_hat = _bucket(sel.size, leaf.n_blocks)
        padded = np.full((k_hat,), sel[0], np.int32)
        padded[:sel.size] = sel
        fn = _scatter_leaf_fn(tuple(leaf.shape), leaf.dtype, k_hat, br,
                              use_pallas, interpret)
        out.append(fn(d, s, jnp.asarray(padded)))
        rows_per = np.minimum((sel + 1) * br, max(leaf.rows, 1)) - sel * br
        moved += int(rows_per.clip(min=0).sum()) * leaf.row_width \
            * np.dtype(leaf.dtype).itemsize
    return jax.tree_util.tree_unflatten(partition.treedef, out), moved


# ---------------------------------------------------------------------------
# Analytic traffic model (bytes per maintain step / per partial save)
# ---------------------------------------------------------------------------

def _tree_nbytes(partition: BlockPartition) -> int:
    return sum(int(np.prod(l.shape) or 1) * np.dtype(l.dtype).itemsize
               for l in partition.leaves)


def maintain_traffic(partition: BlockPartition, layout: FrameLayout,
                     group_of: np.ndarray, n_groups: int,
                     group_width: int, arena_layout=None) -> dict[str, int]:
    """Analytic HBM bytes moved by one full maintenance step (replica
    refresh + parity encode + priority scoring), seed path vs fused path.

    The seed path reads the live tree once per pass (replica copy, frame
    pack, score) plus writes/reads two full-model staging buffers (the
    packed ``(total_blocks, frame_elems)`` frames and the
    ``(n_groups, g, E)`` gather); the fused path reads the live tree and
    the checkpoint once, writes the replica, and touches only the compact
    per-leaf parity contributions.
    """
    model = _tree_nbytes(partition)
    frames = partition.total_blocks * layout.frame_elems * 4
    gathered = n_groups * group_width * layout.frame_elems * 4
    parity = n_groups * layout.frame_elems * 4
    metas = leaf_group_metas(partition, layout, group_of)
    contrib = sum(m.touched.size * m.width * 4 for m in metas)
    seed = (
        model + model            # replica: read live + write replica
        + model + frames         # pack_frames: read live + write frames
        + frames + gathered      # gather: read frames + write grouped
        + gathered + parity      # encode: read grouped + write parity
        + model + model          # block_scores: read live + read ckpt
    )
    fused = (
        model + model            # one sweep: read live + read ckpt
        + model                  # write replica
        + contrib                # write compact parity contributions
        + 2 * contrib + parity   # combine: read contribs, rmw parity cols
    )
    out = {"seed": int(seed), "fused": int(fused), "model": int(model),
           "parity": int(parity), "staging_seed": int(frames + gathered),
           "staging_fused": int(contrib)}
    if arena_layout is not None:
        # arena path: the pack (read live + write the arena snapshot) IS
        # the replica refresh; the single-dispatch sweep then reads the
        # snapshot and the checkpoint arena once and writes compact
        # parity tiles + per-tile score partials; a tiny epilogue
        # scatters the compact tiles into the codec parity layout
        from repro.core.arena import ARENA_TILE
        a = arena_layout.nbytes
        r = arena_routing(arena_layout, layout, group_of)
        tail_words = sum(ab.payload for ab in arena_layout.blocks
                         if ab.offset >= arena_layout.tail_start) \
            if arena_layout.has_tail else 0
        compact = int(r.touched.size) * ARENA_TILE * 4 + tail_words * 4
        partials = arena_layout.n_tiles * 4
        out["arena_bytes"] = int(a)
        # pad words / live payload words: the alignment overhead tail
        # packing removes — a gauge, not a byte count
        out["padding_ratio"] = float(arena_layout.padding_ratio)
        out["staging_arena"] = int(compact + partials)
        out["arena"] = int(
            model + a                # pack: read live, write snapshot
            + a + a                  # sweep: read snapshot + ckpt arena
            + compact + partials     # sweep outputs
            + compact + parity)      # epilogue: compact -> codec layout
        # arena-resident live state: no pack — the sweep reads the live
        # arena and the checkpoint arena once each and writes the replica
        # copy from the same read (pure 2-read/1-write plus the compact
        # outputs); the per-step saving vs the pack path is exactly the
        # live tree's `model` bytes
        out["arena_resident"] = int(
            a + a                    # sweep: read live + ckpt arena
            + a                      # write the replica copy
            + compact + partials     # sweep outputs
            + compact + parity)      # epilogue: compact -> codec layout
        # owned live arena (tree-stepping callers hand their pack over
        # as the replica): no copy — the caller's pack (model + a,
        # booked by pack_live(account=True)) plus this equals the
        # internal-pack "arena" total exactly
        out["arena_owned"] = int(out["arena_resident"] - a)
        # async double-buffer: one extra snapshot copy (read live + write
        # the inactive slot, 2a) in front of the *owned* sweep over the
        # published slot (the snapshot IS the replica — no second copy),
        # so the total is the resident sweep plus one arena read. That +a
        # is the price of decoupling the sweep from the donated live
        # buffer; the wall-clock it buys back is the whole sweep.
        out["arena_async"] = int(out["arena_resident"] + a)
        # SPMD sharded arena: the sweep byte count is unchanged in total
        # (same 2-read/1-write pass, now executed shard-locally — each of
        # the `shards` devices touches 1/shards of every term), but the
        # replica copy crosses the interconnect: an anti-affine placement
        # moves the whole arena device-to-device once per sweep. Per-
        # device HBM traffic is arena_sharded / shards.
        out["arena_sharded"] = int(out["arena_resident"])
        out["arena_sharded_xfer"] = int(a)
        out["arena_shards"] = int(getattr(arena_layout, "shards", 1))
    return out
