"""ModelConfig dataclass + registry for the assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Dict

_REGISTRY: Dict[str, "ModelConfig"] = {}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int = 0            # 0 for attention-free
    n_kv_heads: int = 0
    d_head: int = 0             # 0 -> d_model // n_heads
    d_ff: int = 0
    vocab: int = 32000
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 1_000_000.0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    shared_expert: bool = False
    moe_every: int = 1          # 2 = alternate dense/MoE layers (llama4)
    d_ff_dense: int = 0         # FFN width of the dense layers when interleaved
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    ssm_chunk: int = 128
    # hybrid (zamba2): shared attention block applied every N ssm layers
    attn_every: int = 0
    # enc-dec (whisper)
    enc_layers: int = 0
    enc_seq: int = 0
    # vlm (internvl2): stub vision frontend emits n_patches embeddings of
    # vit_dim which a learned projector maps to d_model
    n_patches: int = 0
    vit_dim: int = 0
    # attention variant: 0 = full causal; >0 = sliding window (sub-quadratic)
    sliding_window: int = 0
    # numerics / training
    dtype: str = "bfloat16"
    remat: bool = True
    loss_chunk: int = 4096      # tokens per logits chunk (vocab-sharded xent)
    microbatch: int = 1         # grad-accumulation splits per train step
    opt_moment_dtype: str = "float32"  # bf16 halves optimizer HBM (400B-class)
    attn_chunk: int = 1024      # flash q/kv tile (drop when heads can't shard)
    # beyond-paper performance variants (the three §Perf hillclimbs;
    # default False = paper-faithful baseline)
    triangle_prefill: bool = False    # causal prefill skips masked-out tiles
    moe_reduce_scatter: bool = False  # MoE combine via reduce-scatter not AR
    kv_quant: bool = False            # int8 KV cache, per-token-head scales
    moe_no_fsdp: bool = False         # expert weights expert-parallel only (re-homed)
    source: str = ""            # citation

    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def has_attention(self) -> bool:
        return self.n_heads > 0

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: 2 layers, d_model<=512, <=4 experts."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4) if self.n_heads else 0
        n_kv = min(self.n_kv_heads, max(1, n_heads // 2)) if self.n_kv_heads else 0
        return dataclasses.replace(
            self,
            n_layers=2,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            d_head=(64 if self.d_head else 0),
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 1024),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            d_ff_dense=min(self.d_ff_dense, 512) if self.d_ff_dense else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=32 if self.ssm_state else self.ssm_headdim,
            ssm_chunk=32 if self.ssm_state else self.ssm_chunk,
            attn_every=min(self.attn_every, 2) if self.attn_every else 0,
            enc_layers=min(self.enc_layers, 2) if self.enc_layers else 0,
            enc_seq=min(self.enc_seq, 64) if self.enc_seq else 0,
            n_patches=min(self.n_patches, 16) if self.n_patches else 0,
            vit_dim=min(self.vit_dim, 128) if self.vit_dim else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            dtype="float32",
            loss_chunk=512,
            microbatch=1,
        )


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    cfg = _REGISTRY[name]
    return cfg.reduced() if reduced else cfg


def list_configs() -> list[str]:
    return sorted(_REGISTRY)
