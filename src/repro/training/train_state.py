"""Train state container for the SPMD LM trainer."""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.optim.optimizers import OptState

PyTree = Any


@partial(jax.tree_util.register_dataclass,
         data_fields=["params", "opt_state", "step"], meta_fields=[])
@dataclasses.dataclass
class TrainState:
    params: PyTree
    opt_state: OptState
    step: jnp.ndarray

    @classmethod
    def create(cls, params: PyTree, optimizer) -> "TrainState":
        return cls(params=params, opt_state=optimizer.init(params),
                   step=jnp.zeros((), jnp.int32))
