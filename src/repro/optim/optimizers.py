"""Minimal functional optimizers (optax-free: the container is offline and
the framework owns its substrate per the brief).

Each optimizer is ``init(params) -> state`` + ``update(grads, state, params)
-> (new_params, new_state)``. Optimizer state tensors mirror the parameter
pytree so SCAR block partitioning / sharding specs apply unchanged. Adam
moments are fp32 regardless of param dtype (TPU practice).

**Arena-native apply**: every optimizer here is elementwise, so the same
``update`` applies unchanged to the flat parameter arena
(:mod:`repro.core.arena`) — the arena is a one-leaf pytree and the moment
buffers become flat mirrors of it in the f32 *value* domain
(``(total_values,)``, master moments stay f32 whatever the stored
precision). :func:`arena_apply` wraps that call with the one step the
flat form can't express on its own: the dtype round trip. The word
arena stores raw leaf-dtype bit patterns, so the step is decode → f32
update → re-encode, one slice/bitcast per *coalesced same-dtype run*
(``layout.value_runs()``), never per segment. For an all-f32 layout the
decode/encode are the identity and the whole thing collapses to a bare
``optimizer.update`` on the arena — bit-identical to the historical f32
value-arena apply and to the per-leaf tree apply. Mixed-precision
layouts match the tree path's ``.astype(p.dtype)`` rounding exactly on
stored params; moments differ from the tree path only where the tree
path would also have quantized them (we keep them f32 — strictly less
perturbation, covered by the paper's Thm 3.2 self-correction class).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: PyTree        # first moment (or momentum buffer); None-like zeros for sgd
    nu: PyTree        # second moment; zeros for sgd/momentum


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], OptState]
    update: Callable[[PyTree, OptState, PyTree], tuple[PyTree, OptState]]
    name: str = "opt"


def _zeros_like_f32(params):
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), params)


def sgd(lr: float) -> Optimizer:
    def init(params):
        return OptState(jnp.zeros((), jnp.int32), (), ())

    def update(grads, state, params):
        new = jax.tree_util.tree_map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new, OptState(state.step + 1, (), ())
    return Optimizer(init, update, "sgd")


def momentum(lr: float, beta: float = 0.9) -> Optimizer:
    def init(params):
        return OptState(jnp.zeros((), jnp.int32), _zeros_like_f32(params), ())

    def update(grads, state, params):
        mu = jax.tree_util.tree_map(
            lambda m, g: beta * m + g.astype(jnp.float32), state.mu, grads)
        new = jax.tree_util.tree_map(
            lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
            params, mu)
        return new, OptState(state.step + 1, mu, ())
    return Optimizer(init, update, "momentum")


def adam(lr: float, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, moment_dtype=jnp.float32) -> Optimizer:
    return _adam_like(lr, b1, b2, eps, wd=0.0, name="adam",
                      moment_dtype=moment_dtype)


def adamw(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          wd: float = 0.01, moment_dtype=jnp.float32) -> Optimizer:
    # moment_dtype=jnp.bfloat16 halves optimizer-state HBM -- the
    # production lever for the largest (400B-class) architectures.
    return _adam_like(lr, b1, b2, eps, wd=wd, name="adamw",
                      moment_dtype=moment_dtype)


def _adam_like(lr, b1, b2, eps, wd, name, moment_dtype=jnp.float32) -> Optimizer:
    def _zeros_like_m(params):
        return jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, moment_dtype), params)

    def init(params):
        return OptState(jnp.zeros((), jnp.int32),
                        _zeros_like_m(params), _zeros_like_m(params))

    def update(grads, state, params):
        t = state.step + 1
        tf = t.astype(jnp.float32)
        mu = jax.tree_util.tree_map(
            lambda m, g: (b1 * m.astype(jnp.float32)
                          + (1 - b1) * g.astype(jnp.float32)
                          ).astype(moment_dtype), state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: (b2 * v.astype(jnp.float32)
                          + (1 - b2) * jnp.square(g.astype(jnp.float32))
                          ).astype(moment_dtype), state.nu, grads)
        bc1 = 1 - b1 ** tf
        bc2 = 1 - b2 ** tf

        def upd(p, m, v):
            m, v = m.astype(jnp.float32), v.astype(jnp.float32)
            step = lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            out = p.astype(jnp.float32) - step
            if wd:
                out = out - lr * wd * p.astype(jnp.float32)
            return out.astype(p.dtype)

        new = jax.tree_util.tree_map(upd, params, mu, nu)
        return new, OptState(t, mu, nu)
    return Optimizer(init, update, name)


# ---------------------------------------------------------------------------
# Arena-native apply (flat parameter arena as the live representation)
# ---------------------------------------------------------------------------

def arena_apply(optimizer: Optimizer, grads: jnp.ndarray, state: OptState,
                arena: jnp.ndarray, layout) -> tuple[jnp.ndarray, OptState]:
    """One optimizer step over the flat word arena.

    ``arena`` is the ``(total_words,)`` word buffer laid out by ``layout``
    (:class:`repro.core.arena.ArenaLayout`); ``grads`` and ``state``'s
    moment buffers live in the f32 value domain (``(total_values,)``,
    ``optimizer.init`` on a value-shaped zeros buffer). The step decodes
    the arena to values — one slice + bitcast per coalesced same-dtype
    run, not per segment — runs the optimizer's own elementwise math
    (bit-identical to the per-leaf tree apply), and re-encodes through
    each run's stored dtype (the same ``.astype(p.dtype)`` rounding the
    tree path applies). For all-f32 layouts values *are* words, both
    casts vanish, and the update runs directly on the arena. Pad words
    stay zero either way: zero grads give zero moments and a zero step,
    weight decay of 0 is 0 (invariant I4), and sub-word element pads
    decode to 0.0 and re-encode to zero bits, so no masking pass is
    needed.
    """
    from repro.core.arena import decode_values, encode_values

    if layout.uniform_f32:
        return optimizer.update(grads, state, arena)
    values = decode_values(arena, layout)
    new_values, new_state = optimizer.update(grads, state, values)
    return encode_values(new_values, layout), new_state
