"""Beyond-paper §Perf variants: correctness vs the paper-faithful baseline."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import lm_batch
from repro.models import get_model
from repro.models.layers import quantize_kv
from repro.sharding import single_device_ctx

CTX = single_device_ctx()


@pytest.mark.parametrize("name", ["yi-9b", "qwen3-moe-235b-a22b",
                                  "llama4-maverick-400b-a17b"])
def test_triangle_prefill_matches_baseline(name):
    base = get_config(name, reduced=True)
    ops = get_model(base)
    params = ops.init_params(jax.random.PRNGKey(0), base)
    batch = lm_batch(jax.random.PRNGKey(1), base, 2, 64)
    cfgt = dataclasses.replace(base, triangle_prefill=True)
    lp_b, _ = ops.prefill(params, batch, base, CTX)
    lp_t, _ = get_model(cfgt).prefill(params, batch, cfgt, CTX)
    np.testing.assert_allclose(np.asarray(lp_b, np.float32),
                               np.asarray(lp_t, np.float32),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("name", ["yi-9b", "llama4-maverick-400b-a17b"])
def test_kv_quant_decode_close_to_baseline(name):
    base = get_config(name, reduced=True)
    ops = get_model(base)
    params = ops.init_params(jax.random.PRNGKey(0), base)
    tok = jnp.zeros((2, 1), jnp.int32)
    c_b = ops.init_cache(base, 2, 64, CTX)
    l_b, c_b = ops.decode_step(params, c_b, tok, base, CTX)
    l_b2, _ = ops.decode_step(params, c_b, tok + 1, base, CTX)

    cfgq = dataclasses.replace(base, kv_quant=True)
    opsq = get_model(cfgq)
    c_q = opsq.init_cache(cfgq, 2, 64, CTX)
    assert c_q["k"].dtype == jnp.int8
    l_q, c_q = opsq.decode_step(params, c_q, tok, cfgq, CTX)
    l_q2, _ = opsq.decode_step(params, c_q, tok + 1, cfgq, CTX)
    p_b = jax.nn.softmax(l_b2[:, -1].astype(jnp.float32))
    p_q = jax.nn.softmax(l_q2[:, -1].astype(jnp.float32))
    assert float(jnp.max(jnp.abs(p_b - p_q))) < 0.05


def test_kv_quant_prefill_then_decode():
    cfgq = dataclasses.replace(get_config("granite-8b", reduced=True),
                               kv_quant=True)
    ops = get_model(cfgq)
    params = ops.init_params(jax.random.PRNGKey(0), cfgq)
    batch = lm_batch(jax.random.PRNGKey(1), cfgq, 2, 32)
    logits, cache = ops.prefill(params, batch, cfgq, CTX)
    assert cache["k"].dtype == jnp.int8
    l2, _ = ops.decode_step(params, cache, jnp.zeros((2, 1), jnp.int32),
                            cfgq, CTX)
    assert np.isfinite(np.asarray(l2, np.float32)).all()


def test_quantize_kv_roundtrip_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 16, 4, 32)), jnp.float32)
    q, scale = quantize_kv(x)
    assert q.dtype == jnp.int8
    back = q.astype(jnp.float32) * scale[..., None]
    # int8 with per-(token, head) scales: ~1% relative error
    rel = float(jnp.max(jnp.abs(back - x)) / jnp.max(jnp.abs(x)))
    assert rel < 0.01


def test_moe_reduce_scatter_single_device_noop():
    """Without a mesh the flag must not change results."""
    base = get_config("qwen3-moe-235b-a22b", reduced=True)
    cfgr = dataclasses.replace(base, moe_reduce_scatter=True)
    ops = get_model(base)
    params = ops.init_params(jax.random.PRNGKey(0), base)
    batch = lm_batch(jax.random.PRNGKey(1), base, 2, 64)
    l1 = ops.train_loss(params, batch, base, CTX)
    l2 = get_model(cfgr).train_loss(params, batch, cfgr, CTX)
    assert float(l1) == pytest.approx(float(l2), rel=1e-6)
