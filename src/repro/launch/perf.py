import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""§Perf hillclimb driver: baseline vs optimized roofline terms for the
three chosen (arch × shape) pairs.

  A. command-r-plus-104b × prefill_32k  — compute term
     hypothesis: causal prefill visits every kv tile and masks half away;
     triangle skip should cut attention FLOPs ≈ 2× (attention is ~50% of
     prefill compute at 32k, so ~25–30% on the compute term).
  B. qwen3-moe-235b-a22b × train_4k     — collective term
     hypothesis: the MoE combine all-reduces a full (tokens, d_model) f32
     per layer; reduce-scatter onto the S-sharded residual halves moved
     bytes (and 16× by the result-shape accounting we use).
  C. command-r-plus-104b × decode_32k   — memory term
     hypothesis: decode streams the whole KV cache per token; int8 cache
     halves those bytes, and the cache dominates decode HBM traffic.

Usage: PYTHONPATH=src python -m repro.launch.perf [--pair A|B|C|all]
Writes results/perf/<pair>.json
"""
import argparse
import json

from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze

PAIRS = {
    "A": dict(arch="command-r-plus-104b", shape="prefill_32k",
              overrides={"triangle_prefill": True},
              term="compute_s"),
    "B": dict(arch="qwen3-moe-235b-a22b", shape="train_4k",
              overrides={"moe_reduce_scatter": True},
              term="collective_s"),
    "C": dict(arch="command-r-plus-104b", shape="decode_32k",
              overrides={"kv_quant": True},
              term="memory_s"),
    # §Perf B iteration 2: the B measurement showed the collective term is
    # dominated by FSDP expert-weight all-gathers, not the combine AR.
    # Hypothesis: re-homing experts (expert-parallel only) removes those
    # gathers entirely -> large collective cut, +~2.9GB/device residency.
    "B2": dict(arch="qwen3-moe-235b-a22b", shape="train_4k",
               overrides={"moe_reduce_scatter": True, "moe_no_fsdp": True},
               term="collective_s"),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", default="all", choices=list(PAIRS) + ["all"])
    ap.add_argument("--outdir", default="results/perf")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)
    mesh = make_production_mesh(multi_pod=False)
    pairs = PAIRS if args.pair == "all" else {args.pair: PAIRS[args.pair]}
    for name, p in pairs.items():
        base = analyze(p["arch"], p["shape"], mesh, "results/dryrun")
        opt = analyze(p["arch"], p["shape"], mesh, "results/dryrun",
                      overrides=p["overrides"])
        term = p["term"]
        delta = 100.0 * (base[term] - opt[term]) / max(base[term], 1e-30)
        rec = {"pair": name, **{k: p[k] for k in ("arch", "shape", "term")},
               "overrides": p["overrides"],
               "baseline": {k: base[k] for k in
                            ("compute_s", "memory_s", "collective_s",
                             "dominant")},
               "optimized": {k: opt[k] for k in
                             ("compute_s", "memory_s", "collective_s",
                              "dominant")},
               "dominant_term_improvement_pct": delta}
        with open(os.path.join(args.outdir, f"{name}.json"), "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[perf {name}] {p['arch']} {p['shape']} {term}: "
              f"{base[term]:.3e}s -> {opt[term]:.3e}s "
              f"({delta:+.1f}% improvement)", flush=True)
        print(f"         baseline terms: comp={base['compute_s']:.2e} "
              f"mem={base['memory_s']:.2e} coll={base['collective_s']:.2e} "
              f"dom={base['dominant']}", flush=True)
        print(f"         optimized terms: comp={opt['compute_s']:.2e} "
              f"mem={opt['memory_s']:.2e} coll={opt['collective_s']:.2e} "
              f"dom={opt['dominant']}", flush=True)


if __name__ == "__main__":
    main()
