"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
SCAR fault tolerance, injecting partial failures along the way.

This is the deliverable-(b) end-to-end example: a real (small) transformer,
the sharded data pipeline, AdamW, the fault-tolerance controller with a
persistent on-disk store, and failure injection sampled from a geometric
distribution exactly as in the paper's §5.3.

The trainer runs **arena-resident** by default: the live training state is
the flat parameter arena (donated through the jitted step), the per-step
maintenance sweep reads it pack-free, and the partial save scatters
straight from it. ``--pytree`` forces the classic PyTree path for
comparison; both print the per-step maintenance overhead they observe.

Run:  PYTHONPATH=src python examples/train_lm_with_failures.py \
          [--steps 300] [--fail-prob 0.02] [--arch qwen2-1.5b] [--pytree]
(CPU: ~100M params; pass --tiny for a quick smoke run.)
"""
import argparse
import dataclasses
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.checkpoint_io import ShardedCheckpointStore
from repro.configs import get_config
from repro.core.policy import CheckpointPolicy
from repro.data.pipeline import ShardedLMDataset
from repro.fabric import FabricConfig
from repro.optim.optimizers import adamw
from repro.sharding import single_device_ctx
from repro.training import TrainLoop, TrainLoopConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--fail-prob", type=float, default=0.02)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--pytree", action="store_true",
                    help="force the classic PyTree training state")
    args = ap.parse_args()

    base = get_config(args.arch, reduced=True)
    if args.tiny:
        cfg, batch, seq = base, 2, 64
        args.steps = min(args.steps, 20)
    else:
        # ~100M params: scale the reduced config up
        cfg = dataclasses.replace(
            base, n_layers=8, d_model=768, n_heads=12, n_kv_heads=4,
            d_ff=2048, vocab=32000, d_head=64)
        batch, seq = 8, 256

    ctx = single_device_ctx()
    policy = CheckpointPolicy.scar(fraction=0.125, interval=8)
    store = ShardedCheckpointStore(tempfile.mkdtemp(prefix="scar_ckpt_"))
    loop = TrainLoop(cfg, ctx, optimizer=adamw(3e-4),
                     loop_cfg=TrainLoopConfig(policy=policy,
                                              fail_prob=args.fail_prob,
                                              fail_fraction=0.5,
                                              fabric=FabricConfig(),
                                              arena_state=not args.pytree),
                     store=store)
    state = loop.init_state()
    n = sum(x.size for x in jax.tree_util.tree_leaves(state.params))
    print(f"== training {args.arch}-derived LM: {n/1e6:.1f}M params, "
          f"{args.steps} steps, SCAR(r=1/8, partial recovery), "
          f"p_fail={args.fail_prob}/step, "
          f"state={'arena-resident' if loop.arena_layout is not None else 'pytree'}")

    ds = ShardedLMDataset(cfg, batch=batch, seq=seq, ctx=ctx)

    def on_step(i, loss):
        if i % 20 == 0 or i == 1:
            print(f"   step {i:4d}  loss {loss:.4f}")

    state = loop.run(state, iter(ds), args.steps, on_step=on_step)

    failures = [m for m in loop.metrics if "failure" in m]
    ckpts = sum(1 for m in loop.metrics if m.get("checkpointed"))
    print(f"== done. {ckpts} partial checkpoints, {len(failures)} failures")
    for m in failures:
        f = m["failure"]
        print(f"   failure @step {m['step']}: lost {f['lost_blocks']:.0f} "
              f"blocks, ||δ'||²={f['partial_sq']:.4f} "
              f"(full recovery would be {f['full_sq']:.4f})")
    losses = [m["loss"] for m in loop.metrics]
    print(f"   loss {losses[0]:.3f} -> {np.mean(losses[-10:]):.3f} "
          f"(finite: {np.isfinite(losses).all()})")
    stats = loop.controller.stats
    print(f"   controller: {stats['saves']} saves, "
          f"{stats['bytes_mirrored']/1e6:.1f}MB mirrored, "
          f"{stats['save_seconds']:.2f}s total dump time")
    over = loop.overhead_summary()
    print(f"   per-step maintenance overhead: "
          f"{over['overhead_seconds_mean']*1e3:.1f} ms "
          f"({over.get('maintain_bytes_per_step', 0)/1e6:.1f} MB/step "
          f"accounted) next to {over['step_seconds_mean']*1e3:.1f} ms/step "
          f"compute; arena-resident={over['arena_state']}, "
          f"{over.get('arena_resident_maintains', 0)} pack-free sweeps")


if __name__ == "__main__":
    main()
