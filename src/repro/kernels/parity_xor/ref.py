"""Pure-jnp oracle for the parity_xor kernel."""
import jax
import jax.numpy as jnp


def parity_xor_ref(frames: jnp.ndarray, base: jnp.ndarray,
                   keep: jnp.ndarray) -> jnp.ndarray:
    """out[j] = base[j] ^ XOR_{i: keep[j,i]} frames[j,i]."""
    contrib = jnp.where(keep[..., None] > 0, frames, 0)
    folded = jax.lax.reduce(contrib, jnp.int32(0),
                            jax.lax.bitwise_xor, (1,))
    return base ^ folded
