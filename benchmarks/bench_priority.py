"""Figure 8 + §5.4 headline: prioritized partial checkpoints.

Fixed failure of 1/2 of parameter blocks; checkpoint budget held constant
(fraction r saved every rC iterations). Strategies compared: priority
(largest drift since last save), round-robin, random.

Paper claims: priority improves as r shrinks (more frequent, smaller
checkpoints); random nearly always hurts; priority-1/8 + partial recovery
cuts iteration cost 78–95% vs traditional full checkpoint-restore.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import MODEL_KW, csv_row, summarize
from repro.core.policy import CheckpointPolicy, RecoveryMode, SelectionStrategy
from repro.models.classic import make_model
from repro.training import run_clean, run_with_failure

MODELS = ("mlr", "mf", "lda", "cnn")
FRACS = (1.0, 0.25, 0.125)       # full, 1/4 @ 4x, 1/8 @ 8x
STRATEGIES = {
    "priority": SelectionStrategy.PRIORITY,
    "round": SelectionStrategy.ROUND_ROBIN,
    "random": SelectionStrategy.RANDOM,
}


def run(trials: int = 5, quick: bool = False) -> list[str]:
    if quick:
        trials = 3
    rows = []
    headline = []
    for name in MODELS:
        model = make_model(name, **MODEL_KW[name])
        max_iters = 180
        clean = run_clean(model, max_iters, seed=0)["losses"]

        def measure(policy):
            cs = []
            for seed in range(trials):
                fail_iter = 10 + int(np.random.default_rng(seed).geometric(0.08))
                fail_iter = min(fail_iter, 60)
                r = run_with_failure(model, policy, fail_iter=fail_iter,
                                     fail_fraction=0.5, max_iters=max_iters,
                                     seed=seed, clean_losses=clean)
                cs.append(max(r["iteration_cost"], 0))
            return summarize(cs)

        # traditional baseline: full ckpt every 8 iters + FULL recovery
        trad, _ = measure(CheckpointPolicy(
            fraction=1.0, full_interval=8,
            strategy=SelectionStrategy.ROUND_ROBIN,
            recovery=RecoveryMode.FULL, block_rows=model.block_rows))

        for sname, strat in STRATEGIES.items():
            means = []
            for r_frac in FRACS:
                mean, sem = measure(CheckpointPolicy(
                    fraction=r_frac, full_interval=8, strategy=strat,
                    recovery=RecoveryMode.PARTIAL, norm=("scaled_tv"
                    if name == "lda" and strat == SelectionStrategy.PRIORITY
                    else "l2"), block_rows=model.block_rows))
                means.append(mean)
                rows.append(csv_row(
                    f"fig8_{name}_{sname}_r{r_frac}", 0.0,
                    f"cost={mean:.1f}±{sem:.1f}"))
            if sname == "priority":
                red = 100.0 * (trad - means[-1]) / max(trad, 1e-9)
                headline.append(red)
                rows.append(csv_row(
                    f"fig8_{name}_headline", 0.0,
                    f"traditional={trad:.1f};scar_1_8={means[-1]:.1f};"
                    f"reduction={red:.0f}%"))
    rows.append(csv_row(
        "fig8_scar_headline_range", 0.0,
        f"reductions={['%.0f%%' % h for h in headline]};paper=78-95%"))
    return rows
