"""llama4-maverick-400b-a17b [moe] — MoE top-1 + shared expert, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 (per expert) vocab=202048,
128 routed experts top-1 + shared expert; dense and MoE layers interleaved (moe_every=2, total ~400B, active ~17B). Early-fusion multimodal embeds
arrive via the stub frontend (text-only input specs exercise the backbone).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    n_experts=128,
    top_k=1,
    shared_expert=True,
    moe_every=2,          # llama4 interleaves dense and MoE layers
    d_ff_dense=16384,
    sliding_window=4096,
    microbatch=4,
    attn_chunk=512,
    opt_moment_dtype="bfloat16",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
))
