"""SPMD LM trainer with SCAR fault tolerance as a first-class feature.

``TrainLoop`` owns:

- the jitted ``train_step`` (value_and_grad + optimizer update), with
  params/opt-state sharded per :mod:`repro.sharding.partition` when a mesh
  is present;
- an :class:`repro.core.controller.FTController` over the *parameter*
  PyTree (optimizer moments are recoverable state too — SCAR checkpoints
  params; Adam moments after a partial restore are simply kept, which is
  itself a perturbation the theory covers; see DESIGN.md);
- optional fault injection (iteration sampled from a geometric
  distribution, as in the paper's §5.3), either the paper's uniform
  block-loss model or correlated whole-domain loss
  (``fail_domain="host"``) routed through the checkpoint fabric's tier
  planner (:mod:`repro.fabric`);
- trace-driven soak mode (``mtbf=``): an MTBF-sampled multi-event failure
  schedule where failed domains stay dead in the fabric's cluster view
  (elastic fabrics re-home/re-seed across the survivors) and optionally
  heal ``heal_after`` steps later — long-horizon degraded-mode training
  with per-event tier/perturbation accounting in ``metrics`` and
  ``controller.stats["events"]``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.controller import FTController
from repro.core.policy import CheckpointPolicy
from repro.models import get_model
from repro.optim.optimizers import Optimizer, adamw
from repro.sharding.partition import DistContext, named_shardings
from repro.training.train_state import TrainState

PyTree = Any


@dataclasses.dataclass
class TrainLoopConfig:
    policy: Optional[CheckpointPolicy] = None
    fail_prob: float = 0.0          # per-iteration geometric failure prob
    fail_fraction: float = 0.5      # fraction of blocks lost per failure
    fail_domain: str = "uniform"    # "uniform" | "device" | "host" | "rack"
    fabric: Optional[Any] = None    # FabricConfig → tiered recovery fabric
    # trace-driven soak mode: per-domain-kind MTBF means (in steps) sampled
    # into a multi-event failure schedule each run(); failed domains stay
    # dead in the cluster view, and optionally heal ``heal_after`` steps
    # later (re-admitting their devices to the placement engine)
    mtbf: Optional[dict] = None     # e.g. {"host": 200.0, "device": 80.0}
    heal_after: Optional[int] = None
    log_every: int = 10
    seed: int = 0

    def __post_init__(self):
        if self.fail_domain != "uniform" and self.fabric is None:
            raise ValueError("correlated fail_domain injection needs a "
                             "fabric (set TrainLoopConfig.fabric)")
        if self.mtbf is not None and self.fabric is None:
            raise ValueError("trace-driven soak mode needs a fabric "
                             "(set TrainLoopConfig.fabric)")


class TrainLoop:
    def __init__(self, cfg: ModelConfig, ctx: DistContext,
                 optimizer: Optional[Optimizer] = None,
                 loop_cfg: Optional[TrainLoopConfig] = None,
                 store=None):
        self.cfg = cfg
        self.ctx = ctx
        self.ops = get_model(cfg)
        self.optimizer = optimizer or adamw(3e-4)
        self.loop_cfg = loop_cfg or TrainLoopConfig()
        self._store = store
        self._rng = np.random.default_rng(self.loop_cfg.seed)
        self.controller: Optional[FTController] = None
        self.metrics: list[dict] = []
        self._redundancy_flags: list[bool] = []

        from repro.training.step import make_train_step
        self._train_step = jax.jit(
            make_train_step(self.ops, cfg, ctx, self.optimizer),
            donate_argnums=(0,))

    # -- initialization ------------------------------------------------------

    def init_state(self, rng: Optional[jax.Array] = None) -> TrainState:
        rng = rng if rng is not None else jax.random.PRNGKey(self.loop_cfg.seed)
        if self.ctx.mesh is not None:
            p_shape = jax.eval_shape(self.ops.init_params, rng, self.cfg)
            shardings = named_shardings(p_shape, self.ctx)
            params = jax.jit(self.ops.init_params, static_argnums=(1,),
                             out_shardings=shardings)(rng, self.cfg)
        else:
            params = self.ops.init_params(rng, self.cfg)
        state = TrainState.create(params, self.optimizer)
        if self.loop_cfg.policy is not None:
            self.controller = FTController(params, self.loop_cfg.policy,
                                           store=self._store,
                                           fabric=self.loop_cfg.fabric)
        return state

    # -- run loop -------------------------------------------------------------

    def run(self, state: TrainState, batches, n_steps: int,
            on_step: Optional[Callable[[int, float], None]] = None,
            ) -> TrainState:
        it = iter(batches)
        events_at = self._sample_trace(n_steps)
        heal_at: dict[int, list] = {}
        for i in range(1, n_steps + 1):
            t0 = time.perf_counter()
            state, loss = self._train_step(state, next(it))
            loss = float(loss)
            dt = time.perf_counter() - t0
            rec = {"step": int(state.step), "loss": loss, "seconds": dt}

            if self.controller is not None:
                # maintain first: the fused maintenance sweep scores the
                # blocks against the running checkpoint in the same read,
                # and a same-step partial save below reuses those scores
                self.controller.maintain(int(state.step), state.params)
                if self.controller.maybe_checkpoint(int(state.step),
                                                    state.params):
                    rec["checkpointed"] = True
                for ev in events_at.pop(i, []):
                    new_params, info = self.controller.on_domain_event(
                        state.params, ev.kind, ev.index,
                        step=int(state.step))
                    state = TrainState(new_params, state.opt_state,
                                       state.step)
                    rec.setdefault("failures", []).append(info)
                    if (self.loop_cfg.heal_after is not None
                            and not info.get("skipped")):
                        heal_at.setdefault(i + self.loop_cfg.heal_after,
                                           []).append(ev)
                for ev in heal_at.pop(i, []):
                    heal = self.controller.heal_domain(
                        ev.kind, ev.index, state.params,
                        step=int(state.step))
                    rec.setdefault("heals", []).append(heal)
                if (self.loop_cfg.fail_prob > 0
                        and self._rng.random() < self.loop_cfg.fail_prob):
                    new_params, info = self._inject(state)
                    state = TrainState(new_params, state.opt_state, state.step)
                    rec["failure"] = info
                if self.controller.fabric is not None:
                    # per-step placement health — availability_summary()
                    # folds these into the soak goodput report
                    full = self.controller.fabric.redundancy_state()["full"]
                    rec["redundancy_full"] = full
                    self._redundancy_flags.append(full)
            self.metrics.append(rec)
            if on_step is not None:
                on_step(i, loss)
        return state

    def availability_summary(self) -> dict:
        """Aggregate this loop's soak accounting (per-event tier counts +
        per-step redundancy flags) into the availability/goodput report —
        see :func:`repro.fabric.availability.summarize_availability`."""
        from repro.fabric.availability import summarize_availability
        events = (self.controller.stats["events"]
                  if self.controller is not None else [])
        return summarize_availability(events, self._redundancy_flags)

    def _sample_trace(self, n_steps: int) -> dict[int, list]:
        """MTBF-driven soak schedule for one run(): loop-iteration → events.
        Empty without ``mtbf`` (or without a controller to recover)."""
        if self.loop_cfg.mtbf is None or self.controller is None \
                or self.controller.fabric is None:
            return {}
        trace = self.controller.fabric.domains.sample_failure_trace(
            self._rng, n_steps, self.loop_cfg.mtbf)
        events_at: dict[int, list] = {}
        for ev in trace:
            events_at.setdefault(max(1, min(ev.step, n_steps)),
                                 []).append(ev)
        return events_at

    def _inject(self, state: TrainState) -> tuple[PyTree, dict]:
        """One failure event per the configured model (uniform/correlated)."""
        if self.loop_cfg.fail_domain == "uniform":
            lost = self.controller.sample_failure(self.loop_cfg.fail_fraction)
            return self.controller.on_failure(state.params, lost,
                                              step=int(state.step))
        lost, failed = self.controller.sample_domain_failure(
            self.loop_cfg.fail_domain)
        return self.controller.on_failure(state.params, lost,
                                          failed_devices=failed,
                                          step=int(state.step))

    def inject_failure(self, state: TrainState,
                       fraction: Optional[float] = None,
                       ) -> tuple[TrainState, dict]:
        """Explicit failure injection (for experiments/examples)."""
        assert self.controller is not None, "enable a CheckpointPolicy first"
        if fraction is not None:
            lost = self.controller.sample_failure(fraction)
            new_params, info = self.controller.on_failure(
                state.params, lost, step=int(state.step))
        else:
            new_params, info = self._inject(state)
        return TrainState(new_params, state.opt_state, state.step), info
