"""Shared transformer layers: RMSNorm, RoPE, chunked (flash-style) GQA
attention, SwiGLU MLP, expert-parallel MoE, chunked vocab-sharded LM loss.

Everything is functional JAX. Attention and the LM loss are *chunked* so
that activation memory stays bounded at 32k–512k sequence lengths: logits /
score matrices are never materialized beyond a (q_chunk × kv_chunk) tile —
the pure-JAX analogue of the flash-attention tiling the Pallas kernels
(kernels/sw_attention) implement for TPU.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.sharding.partition import DistContext

PyTree = Any

NEG_INF = -1e30

# When True (set by launch/roofline.py cost probes), layer-stack and
# loss/embedding chunk scans are UNROLLED so XLA's cost_analysis counts
# every iteration (a rolled `while` body is counted once regardless of
# trip count). Never enabled for real execution.
UNROLL_FOR_COSTING = False


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(rng, shape, in_axis_size: Optional[int] = None, dtype=jnp.float32):
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(rng, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms / rope
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    return out.astype(x.dtype)


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    if theta <= 0:
        return x
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                        # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., None, :]                  # (..., S, 1, Dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d_model: int, offset=0) -> jnp.ndarray:
    pos = (jnp.arange(seq) + offset)[:, None].astype(jnp.float32)
    div = jnp.exp(jnp.arange(0, d_model, 2, dtype=jnp.float32)
                  * (-math.log(10000.0) / d_model))
    pe = jnp.zeros((seq, d_model), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ---------------------------------------------------------------------------
# chunked flash-style attention (pure JAX; TPU kernel in kernels/sw_attention)
# ---------------------------------------------------------------------------

def _attend_chunk(q, k, v, qpos, kpos, *, causal, window, scale,
                  k_scale=None, v_scale=None):
    """One (q_chunk × kv_chunk) tile. q: (B,qc,Hk,G,Dh); k/v: (B,kc,Hk,Dh).
    Optional per-(token, head) dequant scales for int8 KV (§Perf C).
    Returns unnormalized (acc, m, l) online-softmax contributions."""
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if k_scale is not None:
        kf = kf * k_scale.astype(jnp.float32)[..., None]
    if v_scale is not None:
        vf = vf * v_scale.astype(jnp.float32)[..., None]
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32), kf) * scale
    mask = kpos[None, :] <= qpos[:, None] if causal else \
        jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if window:
        mask = mask & (qpos[:, None] - kpos[None, :] < window)
    mask = mask & (kpos >= 0)[None, :]            # ring-buffer validity
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1)                                   # (B,Hk,G,qc)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(mask[None, None, None], p, 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return acc, m, l


def _fwd_chunks(qg, kc, vc, qposc, kposc, *, causal, window, scale,
                q_chunk, kv_chunk, nk, Skv):
    """Forward over all (q-chunk × kv-chunk) tiles with online softmax.

    qg: (B, nq, qc, Hk, G, Dh); kc/vc: (B, nk, kc, Hk, Dh).
    Returns (o (B, nq, qc, Hk, G, Dh) f32, lse (B, nq, Hk, G, qc) f32).
    """
    B = qg.shape[0]

    def one_q_chunk(qck, qpck):
        if window > 0 and Skv > window + q_chunk:
            # sliding window: only a static-size kv span can be visible
            span = window + q_chunk
            nspan = min(-(-span // kv_chunk) + 1, nk)
            lo_chunk = jnp.clip((jnp.min(qpck) - window) // kv_chunk,
                                0, max(nk - nspan, 0)).astype(jnp.int32)
            idx = lo_chunk + jnp.arange(nspan)
            ks, vs, kps = kc[:, idx], vc[:, idx], kposc[idx]
        else:
            ks, vs, kps = kc, vc, kposc

        def body(carry, xs):
            acc, m, l = carry
            kt, vt, kpt = xs
            a, mt, lt = _attend_chunk(qck, kt, vt, qpck, kpt,
                                      causal=causal, window=window, scale=scale)
            m_new = jnp.maximum(m, mt)
            r_old = jnp.exp(m - m_new)
            r_new = jnp.exp(mt - m_new)
            acc = acc * r_old.transpose(0, 3, 1, 2)[..., None] \
                + a * r_new.transpose(0, 3, 1, 2)[..., None]
            l = l * r_old + lt * r_new
            return (acc, m_new, l), None

        qc, Hk, G, Dh = qck.shape[1], qck.shape[2], qck.shape[3], qck.shape[4]
        init = (jnp.zeros((B, qc, Hk, G, Dh), jnp.float32),
                jnp.full((B, Hk, G, qc), NEG_INF, jnp.float32),
                jnp.zeros((B, Hk, G, qc), jnp.float32))
        (acc, m, l), _ = jax.lax.scan(
            body, init,
            (jnp.moveaxis(ks, 1, 0), jnp.moveaxis(vs, 1, 0), kps))
        l = jnp.maximum(l, 1e-30)
        # output in input dtype: halves the custom-vjp residual and keeps
        # the backward cotangent chain in bf16 (D is recomputed in f32)
        o = (acc / l.transpose(0, 3, 1, 2)[..., None]).astype(qck.dtype)
        lse = m + jnp.log(l)
        return o, lse

    nq = qg.shape[1]
    if nq == 1:
        o, lse = one_q_chunk(qg[:, 0], qposc[0])
        return o[:, None], lse[:, None]
    o, lse = jax.lax.map(lambda i: one_q_chunk(qg[:, i], qposc[i]),
                         jnp.arange(nq))
    return jnp.moveaxis(o, 0, 1), jnp.moveaxis(lse, 0, 1)


def _pad_chunks(q, k, v, qpos, kpos, q_chunk, kv_chunk):
    B, Sq, Hk, G, Dh = q.shape
    Skv = k.shape[1]
    nq = -(-Sq // q_chunk)
    nk = -(-Skv // kv_chunk)
    qpad, kpad = nq * q_chunk - Sq, nk * kv_chunk - Skv
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0), (0, 0)))
        qpos = jnp.pad(qpos, (0, qpad), constant_values=qpos[-1])
    if kpad:
        k = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, (0, kpad), constant_values=-1)
    qg = q.reshape(B, nq, q_chunk, Hk, G, Dh)
    kc = k.reshape(B, nk, kv_chunk, Hk, Dh)
    vc = v.reshape(B, nk, kv_chunk, Hk, Dh)
    return qg, kc, vc, qpos.reshape(nq, q_chunk), kpos.reshape(nk, kv_chunk), nq, nk


def _fwd_chunks_triangle(qg, kc, vc, qposc, kposc, *, scale, q_chunk,
                         kv_chunk):
    """Causal prefill with triangle skip: q-chunk i visits ONLY kv-chunks
    j ≤ i (a python loop over q chunks — static per-i scan lengths), so no
    masked-out tiles are ever computed. ~2× fewer attention FLOPs than the
    visit-all-and-mask baseline at Sq == Skv (§Perf iteration A).
    Forward-only (prefill); training keeps the scannable baseline.
    """
    B, nq = qg.shape[0], qg.shape[1]
    outs = []
    for i in range(nq):
        qck, qpck = qg[:, i], qposc[i]
        ks, vs, kps = kc[:, :i + 1], vc[:, :i + 1], kposc[:i + 1]

        def body(carry, xs):
            acc, m, l = carry
            kt, vt, kpt = xs
            a, mt, lt = _attend_chunk(qck, kt, vt, qpck, kpt,
                                      causal=True, window=0, scale=scale)
            m_new = jnp.maximum(m, mt)
            r_old = jnp.exp(m - m_new)
            r_new = jnp.exp(mt - m_new)
            acc = acc * r_old.transpose(0, 3, 1, 2)[..., None] \
                + a * r_new.transpose(0, 3, 1, 2)[..., None]
            l = l * r_old + lt * r_new
            return (acc, m_new, l), None

        qc, Hk, G, Dh = qck.shape[1], qck.shape[2], qck.shape[3], qck.shape[4]
        init = (jnp.zeros((B, qc, Hk, G, Dh), jnp.float32),
                jnp.full((B, Hk, G, qc), NEG_INF, jnp.float32),
                jnp.zeros((B, Hk, G, qc), jnp.float32))
        (acc, m, l), _ = jax.lax.scan(
            body, init,
            (jnp.moveaxis(ks, 1, 0), jnp.moveaxis(vs, 1, 0), kps))
        l = jnp.maximum(l, 1e-30)
        outs.append(((acc / l.transpose(0, 3, 1, 2)[..., None])
                     .astype(qck.dtype)))
    return jnp.stack(outs, axis=1)


def _flash_core_fwd(q, k, v, qpos, kpos, causal, window, q_chunk, kv_chunk):
    B, Sq, Hk, G, Dh = q.shape
    Skv = k.shape[1]
    scale = 1.0 / math.sqrt(Dh)
    qg, kc, vc, qposc, kposc, nq, nk = _pad_chunks(
        q, k, v, qpos, kpos, q_chunk, kv_chunk)
    o, lse = _fwd_chunks(qg, kc, vc, qposc, kposc, causal=causal,
                         window=window, scale=scale, q_chunk=q_chunk,
                         kv_chunk=kv_chunk, nk=nk, Skv=Skv)
    o_full = jnp.moveaxis(o, 1, 1).reshape(B, nq * q_chunk, Hk, G, Dh)[:, :Sq]
    return o_full, (q, k, v, qpos, kpos, o_full, lse)


def _flash_core_bwd(causal, window, q_chunk, kv_chunk, res, do):
    """Flash-attention backward: recompute tiles, never materialize S×S."""
    q, k, v, qpos, kpos, o, lse = res
    B, Sq, Hk, G, Dh = q.shape
    Skv = k.shape[1]
    scale = 1.0 / math.sqrt(Dh)
    qg, kc, vc, qposc, kposc, nq, nk = _pad_chunks(
        q, k, v, qpos, kpos, q_chunk, kv_chunk)
    dpad = nq * q_chunk - Sq
    dog = jnp.pad(do.astype(jnp.float32),
                  ((0, 0), (0, dpad), (0, 0), (0, 0), (0, 0))
                  ).reshape(B, nq, q_chunk, Hk, G, Dh)
    og = jnp.pad(o.astype(jnp.float32),
                 ((0, 0), (0, dpad), (0, 0), (0, 0), (0, 0))
                 ).reshape(B, nq, q_chunk, Hk, G, Dh)
    # lse from fwd is per (B, nq, Hk, G, qc)
    lseg = res[6]
    # D_i = rowsum(do * o): (B, nq, Hk, G, qc)
    Drow = jnp.einsum("bnqhgd,bnqhgd->bnhgq", dog, og)

    def one_q_chunk(i):
        qck = qg[:, i]                                  # (B,qc,Hk,G,Dh)
        qpck = qposc[i]
        dock = dog[:, i]
        lsek = lseg[:, i]                               # (B,Hk,G,qc)
        Dk = Drow[:, i]

        def body(carry, xs):
            dq = carry
            kt, vt, kpt = xs                            # (B,kc,Hk,Dh), (kc,)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qck.astype(jnp.float32),
                           kt.astype(jnp.float32)) * scale
            mask = kpt[None, :] <= qpck[:, None] if causal else \
                jnp.ones((qpck.shape[0], kpt.shape[0]), bool)
            if window:
                mask = mask & (qpck[:, None] - kpt[None, :] < window)
            mask = mask & (kpt >= 0)[None, :]
            p = jnp.where(mask[None, None, None],
                          jnp.exp(s - lsek[..., None]), 0.0)
            dv_c = jnp.einsum("bhgqk,bqhgd->bkhd", p, dock)
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", dock,
                            vt.astype(jnp.float32))
            ds = p * (dp - Dk[..., None]) * scale
            dq = dq + jnp.einsum("bhgqk,bkhd->bqhgd", ds,
                                 kt.astype(jnp.float32))
            dk_c = jnp.einsum("bhgqk,bqhgd->bkhd", ds,
                              qck.astype(jnp.float32))
            return dq, (dk_c, dv_c)

        dq0 = jnp.zeros_like(qck, jnp.float32)
        dq, (dk_parts, dv_parts) = jax.lax.scan(
            body, dq0, (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), kposc))
        # dk_parts: (nk, B, kc, Hk, Dh) for this q chunk
        return dq, dk_parts, dv_parts

    if nq == 1:
        dq, dkp, dvp = one_q_chunk(0)
        dq = dq[:, None]
        dk = jnp.moveaxis(dkp, 0, 1).reshape(B, nk * kv_chunk, Hk, Dh)
        dv = jnp.moveaxis(dvp, 0, 1).reshape(B, nk * kv_chunk, Hk, Dh)
    else:
        dq, dkp, dvp = jax.lax.map(one_q_chunk, jnp.arange(nq))
        dq = jnp.moveaxis(dq, 0, 1)                      # (B,nq,qc,...)
        dk = jnp.moveaxis(jnp.sum(dkp, axis=0), 0, 1).reshape(
            B, nk * kv_chunk, Hk, Dh)
        dv = jnp.moveaxis(jnp.sum(dvp, axis=0), 0, 1).reshape(
            B, nk * kv_chunk, Hk, Dh)
    dq = dq.reshape(B, nq * q_chunk, Hk, G, Dh)[:, :Sq]
    dk = dk[:, :Skv]
    dv = dv[:, :Skv]
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None, None)


@partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _flash(q, k, v, qpos, kpos, causal, window, q_chunk, kv_chunk):
    o, _ = _flash_core_fwd(q, k, v, qpos, kpos, causal, window,
                           q_chunk, kv_chunk)
    return o


def _flash_fwd_rule(q, k, v, qpos, kpos, causal, window, q_chunk, kv_chunk):
    return _flash_core_fwd(q, k, v, qpos, kpos, causal, window,
                           q_chunk, kv_chunk)


_flash.defvjp(_flash_fwd_rule, _flash_core_bwd)


def quantize_kv(x):
    """(..., Hk, Dh) -> (int8 values, per-(..., Hk) f32 scales). §Perf C."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def flash_attention_kvq(q, k8, v8, k_scale, v_scale, qpos, kpos, *,
                        window=0, kv_chunk=1024, ctx: DistContext = None):
    """Single-query-chunk decode attention over an int8 KV cache.

    q: (B,Sq,Hq,Dh) — Sq small (decode); k8/v8: (B,Skv,Hk) int8;
    k_scale/v_scale: (B,Skv,Hk) f32. The cache is streamed chunk-by-chunk
    and dequantized in-register — HBM traffic is the int8 bytes (§Perf C:
    halves the decode memory term vs bf16).
    """
    B, Sq, Hq, Dh = q.shape
    _, Skv, Hk, _ = k8.shape
    G = Hq // Hk
    if G > 1:
        k8 = jnp.repeat(k8, G, axis=2)
        v8 = jnp.repeat(v8, G, axis=2)
        k_scale = jnp.repeat(k_scale, G, axis=2)
        v_scale = jnp.repeat(v_scale, G, axis=2)
    qg = q.reshape(B, Sq, Hq, 1, Dh)
    scale = 1.0 / math.sqrt(Dh)
    kv_chunk = min(kv_chunk, Skv)
    nk = -(-Skv // kv_chunk)
    kpad = nk * kv_chunk - Skv
    if kpad:
        k8 = jnp.pad(k8, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        v8 = jnp.pad(v8, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        k_scale = jnp.pad(k_scale, ((0, 0), (0, kpad), (0, 0)))
        v_scale = jnp.pad(v_scale, ((0, 0), (0, kpad), (0, 0)))
        kpos = jnp.pad(kpos, (0, kpad), constant_values=-1)
    kc = k8.reshape(B, nk, kv_chunk, Hq, Dh)
    vc = v8.reshape(B, nk, kv_chunk, Hq, Dh)
    ksc = k_scale.reshape(B, nk, kv_chunk, Hq)
    vsc = v_scale.reshape(B, nk, kv_chunk, Hq)
    kposc = kpos.reshape(nk, kv_chunk)

    def body(carry, xs):
        acc, m, l = carry
        kt, vt, kst, vst, kpt = xs
        a, mt, lt = _attend_chunk(qg, kt, vt, qpos, kpt, causal=True,
                                  window=window, scale=scale,
                                  k_scale=kst, v_scale=vst)
        m_new = jnp.maximum(m, mt)
        r_old = jnp.exp(m - m_new)
        r_new = jnp.exp(mt - m_new)
        acc = acc * r_old.transpose(0, 3, 1, 2)[..., None] \
            + a * r_new.transpose(0, 3, 1, 2)[..., None]
        l = l * r_old + lt * r_new
        return (acc, m_new, l), None

    init = (jnp.zeros((B, Sq, Hq, 1, Dh), jnp.float32),
            jnp.full((B, Hq, 1, Sq), NEG_INF, jnp.float32),
            jnp.zeros((B, Hq, 1, Sq), jnp.float32))
    (acc, m, l), _ = jax.lax.scan(
        body, init, (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
                     jnp.moveaxis(ksc, 1, 0), jnp.moveaxis(vsc, 1, 0), kposc))
    l = jnp.maximum(l, 1e-30)
    o = acc / l.transpose(0, 3, 1, 2)[..., None]
    return o.reshape(B, Sq, Hq, Dh).astype(q.dtype)


def flash_attention_triangle(q, k, v, qpos, kpos, *, q_chunk=1024,
                             kv_chunk=1024, ctx: DistContext = None):
    """Forward-only causal attention with triangle skip (§Perf A).

    Same contract as flash_attention(causal=True, window=0); used by the
    optimized prefill path (cfg.triangle_prefill)."""
    B, Sq, Hq, Dh = q.shape
    _, Skv, Hk, _ = k.shape
    G = Hq // Hk
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    qg = q.reshape(B, Sq, Hq, 1, Dh)
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    scale = 1.0 / math.sqrt(Dh)
    qgc, kc, vc, qposc, kposc, nq, nk = _pad_chunks(
        qg, k, v, qpos, kpos, q_chunk, kv_chunk)
    o = _fwd_chunks_triangle(qgc, kc, vc, qposc, kposc, scale=scale,
                             q_chunk=q_chunk, kv_chunk=kv_chunk)
    o = o.reshape(B, nq * q_chunk, Hq, 1, Dh)[:, :Sq]
    return o.reshape(B, Sq, Hq, Dh).astype(q.dtype)


def flash_attention(q, k, v, qpos, kpos, *, causal=True, window=0,
                    q_chunk=1024, kv_chunk=1024, ctx: DistContext = None):
    """Chunked attention with online softmax and a flash-style custom VJP
    (backward recomputes tiles — activation memory stays O(S), not O(S²)).

    q: (B, Sq, Hq, Dh);  k, v: (B, Skv, Hk, Dh);  Hq = G·Hk (GQA).
    qpos: (Sq,) absolute positions; kpos: (Skv,) positions (−1 = invalid).
    ``window > 0`` restricts to a sliding window (sub-quadratic: only kv
    chunks overlapping [qpos−window, qpos] are visited).
    """
    B, Sq, Hq, Dh = q.shape
    _, Skv, Hk, _ = k.shape
    G = Hq // Hk
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    if G > 1:
        # GQA: repeat KV heads to Hq so the head dim shards evenly over the
        # model axis (a 5-D (Hk, G) grouping breaks XLA's tiling when
        # Hq % tp == 0 but Hk % tp != 0 — e.g. 96 q-heads, 8 kv-heads, tp=16).
        # The repeat is outside the custom VJP, so dk/dv group-sums happen
        # via autodiff; XLA shards the repeated operand with the einsum.
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    qg = q.reshape(B, Sq, Hq, 1, Dh)
    o = _flash(qg, k, v, qpos, kpos, causal, window, q_chunk, kv_chunk)
    return o.reshape(B, Sq, Hq, Dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------

def init_attention(rng, cfg: ModelConfig, dtype) -> PyTree:
    D, Hq, Hk, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(ks[0], (D, Hq, Dh), D, dtype),
        "wk": dense_init(ks[1], (D, Hk, Dh), D, dtype),
        "wv": dense_init(ks[2], (D, Hk, Dh), D, dtype),
        "wo": dense_init(ks[3], (Hq, Dh, D), Hq * Dh, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((Hq, Dh), dtype)
        p["bk"] = jnp.zeros((Hk, Dh), dtype)
        p["bv"] = jnp.zeros((Hk, Dh), dtype)
    return p


def qkv_project(x, p, cfg: ModelConfig, ctx: DistContext, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    # heads on tp when the count divides; else shard head_dim (ctx.shard
    # drops non-divisible entries, so listing tp on both dims is safe for
    # exactly one of them to stick)
    if ctx.mesh is not None and ctx.tp is not None \
            and q.shape[2] % ctx.tp_size != 0:
        q = ctx.shard(q, "dp", None, None, ctx.tp)
        k = ctx.shard(k, "dp", None, None, ctx.tp)
        v = ctx.shard(v, "dp", None, None, ctx.tp)
    else:
        q = ctx.shard(q, "dp", None, ctx.tp, None)
        k = ctx.shard(k, "dp", None, ctx.tp, None)
        v = ctx.shard(v, "dp", None, ctx.tp, None)
    return q, k, v


def attention_block(x, p, cfg: ModelConfig, ctx: DistContext, *,
                    positions, causal=True, window=0,
                    q_chunk=1024, kv_chunk=1024):
    """Self-attention over x: (B,S,D) -> (B,S,D)."""
    q, k, v = qkv_project(x, p, cfg, ctx, positions)
    o = flash_attention(q, k, v, positions, positions, causal=causal,
                        window=window, q_chunk=q_chunk, kv_chunk=kv_chunk,
                        ctx=ctx)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return ctx.shard(out, "dp", None, None)


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------

def init_mlp(rng, d_model: int, d_ff: int, dtype) -> PyTree:
    ks = jax.random.split(rng, 3)
    return {
        "w_gate": dense_init(ks[0], (d_model, d_ff), d_model, dtype),
        "w_up": dense_init(ks[1], (d_model, d_ff), d_model, dtype),
        "w_down": dense_init(ks[2], (d_ff, d_model), d_ff, dtype),
    }


def mlp_block(x, p, ctx: DistContext):
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["w_gate"])) \
        * jnp.einsum("bsd,df->bsf", x, p["w_up"])
    h = ctx.shard(h, "dp", None, ctx.tp)
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    return ctx.shard(out, "dp", None, None)


# ---------------------------------------------------------------------------
# Mixture of Experts (expert-parallel over the model axis)
# ---------------------------------------------------------------------------

def init_moe(rng, cfg: ModelConfig, dtype) -> PyTree:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(rng, 5)
    p = {
        "router": dense_init(ks[0], (D, E), D, jnp.float32),
        "w_gate_experts": dense_init(ks[1], (E, D, F), D, dtype),
        "w_up_experts": dense_init(ks[2], (E, D, F), D, dtype),
        "w_down_experts": dense_init(ks[3], (E, F, D), F, dtype),
    }
    if cfg.shared_expert:
        p["shared"] = init_mlp(ks[4], D, F, dtype)
    return p


def _moe_body(x, router, wg, wu, wd, *, cfg: ModelConfig, E_local: int,
              e_offset, capacity: int):
    """Token-choice top-k routing, per-expert top-capacity gather.

    x: (N, D) local tokens; wg/wu/wd: (E_local, ...) local expert weights.
    Every device sees all local tokens (activations replicated over the
    model axis) and computes only its experts; outputs are summed over the
    model axis by the caller. Returns (out (N,D) fp32, aux losses).
    """
    N, D = x.shape
    logits = x.astype(jnp.float32) @ router               # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, sel = jax.lax.top_k(probs, cfg.top_k)       # (N, k)
    # normalized combine weights
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    # dense (N, E) sparse-weight matrix, then slice local experts
    w_full = jnp.zeros((N, cfg.n_experts), jnp.float32)
    w_full = w_full.at[jnp.arange(N)[:, None], sel].set(gate_vals)
    w_local = jax.lax.dynamic_slice(w_full, (0, e_offset), (N, E_local))

    def expert_one(we, wg_e, wu_e, wd_e):
        vals, idx = jax.lax.top_k(we, capacity)            # top-C tokens
        xe = x[idx]                                        # (C, D)
        h = jax.nn.silu(xe @ wg_e) * (xe @ wu_e)
        he = (h @ wd_e).astype(jnp.float32) * vals[:, None]
        return idx, he

    idxs, hes = jax.vmap(expert_one)(w_local.T, wg, wu, wd)  # (E_l,C),(E_l,C,D)
    out = jnp.zeros((N, D), jnp.float32)
    out = out.at[idxs.reshape(-1)].add(hes.reshape(-1, D))
    # router aux losses (load balance + z-loss), standard formulation
    me = jnp.mean(probs, axis=0)                            # (E,)
    ce = jnp.mean(w_full > 0, axis=0)
    lb_loss = cfg.n_experts * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return out, lb_loss, z_loss


def moe_block(x, p, cfg: ModelConfig, ctx: DistContext):
    """x: (B,S,D) -> (B,S,D). Expert-parallel under shard_map when a mesh
    with a model axis is present; plain local compute otherwise."""
    from jax.sharding import PartitionSpec as P
    B, S, D = x.shape
    E = cfg.n_experts

    if ctx.mesh is not None and ctx.tp is not None:
        tp_size = ctx.tp_size
        E_local = E // tp_size
        dp_total = 1
        for a in ctx.dp:
            dp_total *= ctx.mesh.shape[a]
        N_local = (B // dp_total if ctx.batch_shardable else B) * S
        capacity = max(1, int(math.ceil(
            N_local * cfg.top_k / E * cfg.capacity_factor)))
        dps = ctx.dp_spec

        # §Perf B: combine expert outputs with reduce-scatter over the token
        # dim instead of all-reduce — the next consumer (the residual
        # stream) is S-sharded over the model axis anyway, so the all-gather
        # half of the all-reduce is pure waste. 2× less ICI traffic.
        S_local = x.shape[1]
        use_rs = (cfg.moe_reduce_scatter and S_local % tp_size == 0
                  and S_local > 1)

        def body(xl, router, wg, wu, wd):
            n = xl.shape[0] * xl.shape[1]
            e_off = jax.lax.axis_index(ctx.tp) * E_local
            out, lb, zl = _moe_body(xl.reshape(n, D), router, wg, wu, wd,
                                    cfg=cfg, E_local=E_local, e_offset=e_off,
                                    capacity=min(capacity, n))
            if use_rs:
                out = out.reshape(xl.shape[0], S_local, D)
                out = jax.lax.psum_scatter(out, ctx.tp, scatter_dimension=1,
                                           tiled=True)
                return out.astype(xl.dtype), lb, zl
            out = jax.lax.psum(out, ctx.tp)
            return out.reshape(xl.shape).astype(xl.dtype), lb, zl

        out_spec = P(dps, ctx.tp, None) if use_rs else P(dps, None, None)
        out, lb, zl = jax.shard_map(
            body, mesh=ctx.mesh,
            in_specs=(P(dps, None, None), P(), P(ctx.tp), P(ctx.tp), P(ctx.tp)),
            out_specs=(out_spec, P(), P()),
            check_vma=False,
        )(x, p["router"], p["w_gate_experts"], p["w_up_experts"],
          p["w_down_experts"])
    else:
        n = B * S
        capacity = max(1, int(math.ceil(n * cfg.top_k / E * cfg.capacity_factor)))
        out, lb, zl = _moe_body(x.reshape(n, D), p["router"],
                                p["w_gate_experts"], p["w_up_experts"],
                                p["w_down_experts"], cfg=cfg, E_local=E,
                                e_offset=0, capacity=min(capacity, n))
        out = out.reshape(B, S, D).astype(x.dtype)

    if cfg.shared_expert:
        out = out + mlp_block(x, p["shared"], ctx)
    return out, (lb, zl)


# ---------------------------------------------------------------------------
# Embedding + chunked vocab-sharded LM loss
# ---------------------------------------------------------------------------

def init_embed(rng, cfg: ModelConfig, dtype) -> PyTree:
    ks = jax.random.split(rng, 2)
    p = {"embed": dense_init(ks[0], (cfg.vocab, cfg.d_model), cfg.d_model, dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[1], (cfg.vocab, cfg.d_model), cfg.d_model, dtype)
    return p


def embed_tokens(tokens, p, ctx: DistContext, chunk: int = 8192):
    """Token embedding lookup.

    Single device: plain gather. Under tensor parallelism the embedding
    table is vocab-sharded — a gather would make XLA all-gather the whole
    table (GBs for 256k vocab). Instead: chunked one-hot matmul, which the
    partitioner turns into a local partial matmul + all-reduce, never
    materializing the full table or the full one-hot. The chunk body is
    rematted so no (chunk, V) one-hot is saved for backward.
    """
    embed = p["embed"]
    if ctx.mesh is None or ctx.tp is None:
        return jnp.take(embed, tokens, axis=0)
    B, S = tokens.shape
    V, D = embed.shape
    # chunk along S, preserving the batch dim: reshapes that flatten (B, S)
    # globally lose the dp sharding and force XLA into involuntary full
    # replication of (tokens, D)-sized buffers
    C = min(max(chunk // max(B // 8, 1), 128), S)
    while S % C:
        C //= 2
    C = max(C, 1)
    ncs = S // C
    tok = tokens.reshape(B, ncs, C)
    tok = jnp.moveaxis(tok, 1, 0)                     # (ncs, B, C)

    def body(_, tx):
        onehot = (tx[..., None] == jnp.arange(V)).astype(embed.dtype)
        onehot = ctx.shard(onehot, "dp", None, ctx.tp)
        return None, jnp.einsum("bcv,vd->bcd", onehot, embed)

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    _, out = jax.lax.scan(body, None, tok,
                          unroll=UNROLL_FOR_COSTING)  # (ncs, B, C, D)
    out = jnp.moveaxis(out, 0, 1).reshape(B, S, D)
    return ctx.shard(out, "dp", None, None)


def lm_logits(h, p, ctx: DistContext):
    head = p.get("lm_head", p["embed"])
    logits = jnp.einsum("bsd,vd->bsv", h.astype(jnp.float32),
                        head.astype(jnp.float32))
    return ctx.shard(logits, "dp", None, ctx.tp)


def lm_loss_chunked(h, p, labels, mask, cfg: ModelConfig, ctx: DistContext):
    """Next-token cross-entropy without materializing (N, V) logits.

    h: (B,S,D); labels/mask: (B,S). Scans over S-chunks *preserving the
    batch dim* (a global (B·S) flatten would break the dp sharding and
    force involuntary replication); within a chunk the (B, chunk, V)
    logits are vocab-sharded over the model axis.
    """
    B, S, D = h.shape
    head = p.get("lm_head", p["embed"])
    C = min(max(cfg.loss_chunk // max(B // 8, 1), 128), S)
    while S % C:
        C //= 2
    C = max(C, 1)
    nc = S // C
    hc = jnp.moveaxis(h.reshape(B, nc, C, D), 1, 0)           # (nc,B,C,D)
    yc = jnp.moveaxis(labels.reshape(B, nc, C), 1, 0)
    mc = jnp.moveaxis(mask.astype(jnp.float32).reshape(B, nc, C), 1, 0)

    def chunk_loss(hx, yx, mx):
        logits = jnp.einsum("bcd,vd->bcv", hx.astype(jnp.float32),
                            head.astype(jnp.float32))
        logits = ctx.shard(logits, "dp", None, ctx.tp)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, yx[..., None], axis=2)[..., 0]
        return jnp.sum((lse - ll) * mx), jnp.sum(mx)

    # remat: the (chunk, V) logits are recomputed in backward, never saved
    chunk_loss = jax.checkpoint(
        chunk_loss, policy=jax.checkpoint_policies.nothing_saveable)

    def body(carry, xs):
        loss, cnt = chunk_loss(*xs)
        return (carry[0] + loss, carry[1] + cnt), None

    (total, count), _ = jax.lax.scan(
        body, (jnp.float32(0), jnp.float32(0)), (hc, yc, mc),
        unroll=UNROLL_FOR_COSTING)
    return total / jnp.maximum(count, 1.0)
