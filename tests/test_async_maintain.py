"""Async maintenance pipeline: double-buffered epoch/publish protocol.

The tentpole invariants of ``FabricConfig(async_maintain=True)``:

- **bit-identity** — every-step async maintenance produces losses,
  running checkpoints, and recovered params bit-identical to the
  synchronous path (the snapshot holds exactly the live values; only
  *when* the sweep's device work completes changes);
- **published-epoch recovery** — a failure injected while a sweep is in
  flight settles the pending epoch first and recovers from the last
  *published* slot, never a torn one; a failure a step past the
  published epoch recovers the stale-but-bounded replica values and the
  staleness is accounted explicitly (recovered_epoch/staleness in the
  recovery stats and the perturbation ledger);
- **deferred fence ordering** — the fence moves off the per-step hot
  path and is taken only at consume points (``maybe_checkpoint``,
  failure/elastic replan, ``block_until_maintained``, end of run);
- **overlap** — the Chrome trace's deferred ``maintain`` spans cover
  [dispatch, fence] and genuinely overlap the next ``train_step`` span.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint_io.store import ShardedCheckpointStore
from repro.configs import get_config
from repro.core.blocks import partition_pytree
from repro.core.controller import FTController
from repro.core.policy import (CheckpointPolicy, RecoveryMode,
                               SelectionStrategy)
from repro.data.pipeline import ShardedLMDataset
from repro.fabric import FabricConfig
from repro.models.classic import make_model
from repro.sharding import single_device_ctx
from repro.telemetry.recorder import Recorder
from repro.training import TrainLoop, TrainLoopConfig, run_with_failure


def _keys(seed: int):
    base = jax.random.PRNGKey(seed)

    def key(i: int):
        return jax.random.fold_in(base, i)
    return key


def _tree_equal(a, b) -> bool:
    return all(bool((np.asarray(x) == np.asarray(y)).all())
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


def _controller(model, async_maintain: bool, elastic: bool = False,
                recorder=None, seed: int = 0):
    p = model.init(jax.random.PRNGKey(1))
    pol = CheckpointPolicy(fraction=0.25, full_interval=8,
                           strategy=SelectionStrategy.PRIORITY,
                           recovery=RecoveryMode.PARTIAL,
                           block_rows=model.block_rows)
    ctl = FTController(p, pol, norm_aux=model.norm_aux,
                       rng=jax.random.PRNGKey(seed + 13),
                       colocate=model.colocate,
                       fabric=FabricConfig(n_devices=8, use_pallas=False,
                                           async_maintain=async_maintain,
                                           elastic=elastic),
                       recorder=recorder)
    assert ctl.arena_ready
    return p, ctl


# ---------------------------------------------------------------------------
# config gate + traffic model
# ---------------------------------------------------------------------------

def test_async_config_requires_fused_arena():
    with pytest.raises(ValueError, match="async_maintain"):
        FabricConfig(async_maintain=True, fused=False)
    with pytest.raises(ValueError, match="async_maintain"):
        FabricConfig(async_maintain=True, arena=False)
    FabricConfig(async_maintain=True)   # default pipeline is eligible


def test_async_traffic_is_resident_plus_snapshot():
    """arena_async = resident sweep + one extra arena read/write pair net
    of the adopted copy: symmetric around the resident cost with
    arena_owned (async - resident == resident - owned == arena bytes)."""
    model = make_model("mf", m=60, n=80, rank=3)
    _, ctl = _controller(model, True)
    t = ctl.fabric._traffic_model()
    assert t["arena_async"] - t["arena_resident"] \
        == t["arena_resident"] - t["arena_owned"] > 0


# ---------------------------------------------------------------------------
# bit-identity (classic path, every-step saves — the consume-heavy case)
# ---------------------------------------------------------------------------

def test_async_classic_every_step_bit_identical():
    """scar policy (partial save every iteration): even with a consume
    point every step, async losses and recovery match sync exactly."""
    model = make_model("mf", m=80, n=120, rank=4)
    pol = CheckpointPolicy.scar(fraction=0.25, interval=4)
    pol = CheckpointPolicy(fraction=pol.fraction,
                           full_interval=pol.full_interval,
                           strategy=pol.strategy, recovery=pol.recovery,
                           block_rows=model.block_rows)
    kw = dict(fail_iter=10, fail_fraction=0.4, max_iters=20, seed=0,
              fail_domain="host")
    sync = run_with_failure(model, pol, fabric=FabricConfig(
        n_devices=8, use_pallas=False), **kw)
    asy = run_with_failure(model, pol, fabric=FabricConfig(
        n_devices=8, use_pallas=False, async_maintain=True), **kw)
    assert sync["losses"] == asy["losses"]
    assert asy["fabric_stats"]["async_maintains"] == 20
    assert asy["fabric_stats"]["fence_count"] >= 1
    # same tiers served the recovery, priced against a fresh epoch
    assert asy["recovery"]["tier_counts"] == sync["recovery"]["tier_counts"]
    assert asy["recovery"]["recovered_epoch"] == 10
    assert asy["recovery"]["staleness"] == 0


# ---------------------------------------------------------------------------
# published-epoch recovery
# ---------------------------------------------------------------------------

def test_mid_sweep_failure_recovers_from_published_epoch():
    """Failure injected while the sweep is still in flight: the pending
    epoch settles (never a torn slot) and every lost block restores
    bit-exactly from the published replica."""
    model = make_model("mf", m=60, n=80, rank=3)
    key = _keys(0)
    p, ctl = _controller(model, True)
    fab = ctl.fabric
    for i in range(1, 4):
        p = model.step(p, key(i), i)
        live = ctl.pack_live(p, account=True)
        ctl.maintain(i, live, own_live=True)
    # epoch 3 is dispatched but not settled — mid-sweep by construction
    assert fab.has_pending_maintenance
    assert fab.published_epoch == 3
    lost = ctl.sample_failure(0.5)
    p2, info = ctl.on_failure(p, lost, step=3)
    assert not fab.has_pending_maintenance   # settled at the consume point
    assert info["recovered_epoch"] == 3 and info["staleness"] == 0
    assert info["tier_counts"]["PEER_REPLICA"] == int(np.asarray(lost).sum())
    assert float(info["applied_sq"]) == 0.0
    assert _tree_equal(p2, p)                # bit-exact, zero perturbation


def test_stale_published_epoch_priced_explicitly():
    """Failure one step past the published epoch: the replica tier still
    serves (bounded staleness), and recovered_epoch/staleness land in the
    recovery stats AND the perturbation ledger entry."""
    model = make_model("mf", m=60, n=80, rank=3)
    key = _keys(0)
    rec = Recorder()
    p, ctl = _controller(model, True, recorder=rec)
    fab = ctl.fabric
    for i in range(1, 4):
        p = model.step(p, key(i), i)
        live = ctl.pack_live(p, account=True)
        ctl.maintain(i, live, own_live=True)
    # one more update WITHOUT a maintain: live is at step 4, published at 3
    p = model.step(p, key(4), 4)
    lost = ctl.sample_failure(0.5)
    p2, info = ctl.on_failure(p, lost, step=4)
    assert info["recovered_epoch"] == 3 and info["staleness"] == 1
    # the stale replica served — the sync planner would have fallen back
    # to the running checkpoint here (replicas not fresh at step 4)
    assert info["tier_counts"]["PEER_REPLICA"] == int(np.asarray(lost).sum())
    # stale-by-one values are a real (bounded) perturbation, not zero
    assert float(info["applied_sq"]) > 0.0
    entry = rec.ledger.entries[-1]
    assert entry.extra["recovered_epoch"] == 3
    assert entry.extra["staleness"] == 1


# ---------------------------------------------------------------------------
# deferred fence ordering
# ---------------------------------------------------------------------------

def test_deferred_fence_ordering_under_checkpoint_and_replan():
    model = make_model("mf", m=60, n=80, rank=3)
    key = _keys(0)
    p, ctl = _controller(model, True, elastic=True)
    fab = ctl.fabric
    p = model.step(p, key(1), 1)
    live = ctl.pack_live(p, account=True)
    ctl.maintain(1, live, own_live=True)
    assert fab.has_pending_maintenance       # dispatch left the fence open
    # consume point 1: a checkpoint settles before sourcing the save
    ctl.checkpoint_now(1, live)
    assert not fab.has_pending_maintenance
    p = model.step(p, key(2), 2)
    live = ctl.pack_live(p, account=True)
    ctl.maintain(2, live, own_live=True)
    assert fab.has_pending_maintenance
    # consume point 2: elastic replan fences, recovers, re-publishes
    lost, failed = ctl.sample_domain_failure("host")
    p2, info = ctl.on_failure(p, lost, failed_devices=failed, step=2)
    assert not fab.has_pending_maintenance
    assert info["placement"]["rehomed_blocks"] >= 0
    assert fab.published_epoch == 2          # the replan's sweep published
    p2 = model.step(p2, key(3), 3)
    live = ctl.pack_live(p2, account=True)
    ctl.maintain(3, live, own_live=True)
    assert fab.has_pending_maintenance
    # consume point 3: the explicit deferred fence
    fab.block_until_maintained()
    assert not fab.has_pending_maintenance
    assert fab.stats["fence_count"] == 3


# ---------------------------------------------------------------------------
# LM loop: bit-identity + span overlap (the acceptance-criterion test)
# ---------------------------------------------------------------------------

def _lm_loop(async_maintain: bool):
    ctx = single_device_ctx()
    cfg = get_config("qwen2-1.5b", reduced=True)
    # every-step maintenance, partial save every 4 steps (fraction ×
    # full_interval) — maintain-only steps are where the overlap lives
    pol = CheckpointPolicy(fraction=0.25, full_interval=16,
                           strategy=SelectionStrategy.PRIORITY,
                           recovery=RecoveryMode.PARTIAL)
    rec = Recorder()
    loop = TrainLoop(cfg, ctx, loop_cfg=TrainLoopConfig(
        policy=pol, fabric=FabricConfig(async_maintain=async_maintain),
        arena_state=True, recorder=rec))
    state = loop.init_state()
    ds = ShardedLMDataset(cfg, batch=2, seq=32, ctx=ctx)
    return loop, state, ds, rec


def test_async_lm_bit_identical_and_spans_overlap():
    ls, ss, dss, _ = _lm_loop(False)
    la, sa, dsa, rec = _lm_loop(True)
    ss = ls.run(ss, iter(dss), 10)
    sa = la.run(sa, iter(dsa), 10)
    # bit-identical losses, checkpoint arena, saved_iter, final params
    assert [m["loss"] for m in ls.metrics] == [m["loss"] for m in la.metrics]
    assert (np.asarray(ls.controller._ckpt_arena)
            == np.asarray(la.controller._ckpt_arena)).all()
    assert (np.asarray(ls.controller.ckpt.saved_iter)
            == np.asarray(la.controller.ckpt.saved_iter)).all()
    assert (np.asarray(ss.arena) == np.asarray(sa.arena)).all()
    fab = la.controller.fabric
    assert fab.stats["async_maintains"] == 10
    assert not fab.has_pending_maintenance   # end-of-run fence ran
    # the Chrome trace shows maintain spans genuinely overlapping
    # train_step spans — the deferred [dispatch, fence] intervals
    trains = rec.tracer.intervals("train_step")
    maints = rec.tracer.intervals("maintain")
    assert len(maints) == 10
    overlapping = sum(
        any(m0 < t1 and t0 < m1 for (t0, t1) in trains)
        for (m0, m1) in maints)
    assert overlapping >= 1
    deferred = [s for s in rec.tracer.spans
                if s.name == "maintain" and s.args.get("deferred")]
    assert len(deferred) == 10
    assert all(s.args["mode"] == "arena_async" for s in deferred)
    # phase split + overlap gauge are wired through overhead_summary
    out = la.overhead_summary()
    assert set(out["phases"]) == {"sweep", "save", "fence"}
    assert out["phases"]["fence"]["count"] >= 1
    assert 0.0 < out["overlap_efficiency"] <= 1.0
    assert rec.gauges["fabric/overlap_efficiency"].value \
        == out["overlap_efficiency"]
    # sync mode reports zero overlap (nothing is hidden)
    assert ls.overhead_summary()["overlap_efficiency"] == 0.0


# ---------------------------------------------------------------------------
# store flush error context (satellite)
# ---------------------------------------------------------------------------

def test_store_flush_chains_failed_job_context(tmp_path):
    params = {"w": jnp.arange(24.0, dtype=jnp.float32).reshape(8, 3)}
    part = partition_pytree(params, block_rows=4)
    rec = Recorder()
    store = ShardedCheckpointStore(str(tmp_path))
    store.attach_recorder(rec)
    store.init(params, part)

    def boom(jobs, step):
        raise OSError("disk full")

    store._do_write = boom
    mask = np.ones((part.total_blocks,), bool)
    store.write_blocks(mask, params, step=7, background=True)
    with pytest.raises(RuntimeError) as ei:
        store.flush()
    msg = str(ei.value)
    assert "step 7" in msg and "segment" in msg and "shard" in msg
    # chain: flush context -> retry-budget RuntimeError -> original OSError
    assert isinstance(ei.value.__cause__, RuntimeError)
    assert "attempts" in str(ei.value.__cause__)
    assert isinstance(ei.value.__cause__.__cause__, OSError)
    ev = [e for e in rec.events if e["kind"] == "store_write_failed"]
    assert len(ev) == 1
    retried = [e for e in rec.events if e["kind"] == "store_write_retried"]
    assert len(retried) == store._retry_limit
    assert ev[0]["step"] == 7 and "disk full" in ev[0]["error"]
    assert ev[0]["segment"] is not None and ev[0]["path"] is not None
    # the error is one-shot: a second flush succeeds
    store.flush()
