"""Pallas TPU kernel: fused XOR parity encode + masked single-erasure
reconstruct (checkpoint-fabric parity tier).

One kernel body serves both directions of the code, because both are the
same fold ``out[j] = base[j] ^ XOR_{i : keep[j,i]} frames[j,i]``:

- encode      — base = 0, keep = the group's valid members: the parity
                block of each group.
- reconstruct — base = parity, keep = the surviving members: the single
                lost member of each group, bit-exact.

Fusing the member mask into the fold avoids materializing the masked
(n_groups, g, E) intermediate the jnp path builds, and reads each member
frame from HBM exactly once — memory-roofline optimal, like masked_restore.

Grid/layout follows masked_restore: (n_groups, E) tiles of (BG, BE); the
small group axis ``g`` (≤ ~16 members) rides whole inside each tile, and the
(BG, g) keep block rides along the i axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BG = 8
BE = 512


def _parity_xor_kernel(frames_ref, base_ref, keep_ref, out_ref, *, g: int):
    k = keep_ref[...]                        # (BG, g) int32
    acc = base_ref[...]                      # (BG, BE) int32
    for i in range(g):                       # g is static and small
        member = frames_ref[:, i, :]         # (BG, BE) int32
        acc = acc ^ jnp.where((k[:, i] > 0)[:, None], member, 0)
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def parity_xor_pallas(frames: jnp.ndarray, base: jnp.ndarray,
                      keep: jnp.ndarray,
                      interpret: bool = False) -> jnp.ndarray:
    """frames: (n_groups, g, E) int32; base: (n_groups, E) int32;
    keep: (n_groups, g) bool/int32 → (n_groups, E) int32.

    out[j] = base[j] ^ XOR over members i with keep[j, i] of frames[j, i].
    """
    n, g, e = frames.shape
    n_pad = -n % BG
    e_pad = -e % BE
    keep_i = keep.astype(jnp.int32)
    if n_pad or e_pad:
        frames = jnp.pad(frames, ((0, n_pad), (0, 0), (0, e_pad)))
        base = jnp.pad(base, ((0, n_pad), (0, e_pad)))
        keep_i = jnp.pad(keep_i, ((0, n_pad), (0, 0)))
    np_, _, ep_ = frames.shape
    grid = (np_ // BG, ep_ // BE)
    out = pl.pallas_call(
        functools.partial(_parity_xor_kernel, g=g),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BG, g, BE), lambda i, j: (i, 0, j)),
            pl.BlockSpec((BG, BE), lambda i, j: (i, j)),
            pl.BlockSpec((BG, g), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BG, BE), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, ep_), jnp.int32),
        interpret=interpret,
    )(frames, base, keep_i)
    return out[:n, :e]
