"""GF(256) field arithmetic tables + host-side linear algebra.

The Reed-Solomon tier works over GF(2^8) with the primitive polynomial
0x11D (x^8 + x^4 + x^3 + x^2 + 1, generator alpha = 2 — the RAID-6 /
CCSDS convention). Everything here is host-side numpy: the log/antilog
tables the jnp oracle gathers from, scalar field ops, the Cauchy
coefficient matrix the codec encodes with, and the Gauss-Jordan solve
that turns an erasure pattern into per-survivor decode weights.

Why Cauchy and not Vandermonde: the erasure decode inverts the e x e
submatrix selecting e parity rows and e erased member columns. Every
square submatrix of a Cauchy matrix is nonsingular, so *any* combination
of <= m erasures against any m surviving parity rows is solvable;
Vandermonde submatrices over GF(2^8) can be singular. Columns are scaled
so row 0 is all-ones — parity row 0 of the RS code is then bit-identical
to the XOR tier's parity block (RS(k, 1) degenerates to `parity_xor`),
and scaling preserves the every-submatrix-nonsingular property.
"""
from __future__ import annotations

import numpy as np

GF_POLY = 0x11D

# EXP is doubled so EXP[log a + log b] needs no modular reduction on the
# host path; LOG[0] is a sentinel (0) masked out by every consumer.
GF_EXP = np.zeros((512,), np.int32)
GF_LOG = np.zeros((256,), np.int32)
_x = 1
for _i in range(255):
    GF_EXP[_i] = _x
    GF_LOG[_x] = _i
    _x <<= 1
    if _x & 0x100:
        _x ^= GF_POLY
GF_EXP[255:510] = GF_EXP[:255]
del _x, _i


def gf_mul(a, b):
    """Elementwise GF(256) product of arrays/scalars in [0, 256)."""
    a = np.asarray(a, np.int32)
    b = np.asarray(b, np.int32)
    out = GF_EXP[GF_LOG[a] + GF_LOG[b]]
    return np.where((a == 0) | (b == 0), 0, out)


def gf_inv(a):
    """Multiplicative inverse; 0 has none (asserted)."""
    a = np.asarray(a, np.int32)
    assert np.all(a != 0), "gf_inv(0) is undefined"
    return GF_EXP[255 - GF_LOG[a]]


def gf_scale_words_np(words, c) -> np.ndarray:
    """Scale each byte of packed int32 words by the scalar byte ``c``
    (host-side mirror of the kernel's SWAR multiply; used by syndrome
    localization)."""
    words = np.asarray(words, np.int64) & 0xFFFFFFFF
    out = np.zeros_like(words)
    for plane in range(4):
        b = (words >> (8 * plane)) & 0xFF
        out |= gf_mul(b, c).astype(np.int64) << (8 * plane)
    return (out & 0xFFFFFFFF).astype(np.uint32).view(np.int32)


def gf_mat_inv(a: np.ndarray) -> np.ndarray:
    """Invert a square matrix over GF(256) by Gauss-Jordan elimination.

    Raises ``np.linalg.LinAlgError`` on a singular input — with Cauchy
    coefficients that never happens for a legal erasure pattern, so a
    raise here means the caller selected a malformed submatrix.
    """
    a = np.array(a, np.int32, copy=True)
    n = a.shape[0]
    out = np.eye(n, dtype=np.int32)
    for col in range(n):
        piv = col + int(np.argmax(a[col:, col] != 0))
        if a[piv, col] == 0:
            raise np.linalg.LinAlgError("singular GF(256) matrix")
        if piv != col:
            a[[col, piv]] = a[[piv, col]]
            out[[col, piv]] = out[[piv, col]]
        inv = gf_inv(a[col, col])
        a[col] = gf_mul(a[col], inv)
        out[col] = gf_mul(out[col], inv)
        for r in range(n):
            if r != col and a[r, col]:
                f = a[r, col]
                a[r] ^= gf_mul(f, a[col])
                out[r] ^= gf_mul(f, out[col])
    return out


def rs_coefficients(width: int, n_parity: int) -> np.ndarray:
    """(n_parity, width) Cauchy encode matrix, row 0 normalized to ones.

    Parity row r of a group is ``P_r = XOR_i gf_mul(C[r, i], D_i)`` over
    the group's valid members. ``width + n_parity <= 256`` bounds the
    code (one field element per codeword position).
    """
    if width + n_parity > 256:
        raise ValueError(
            f"RS({width}, {n_parity}) exceeds GF(256): width + parity "
            "count must be <= 256")
    x = np.arange(n_parity, dtype=np.int32)            # parity positions
    y = np.arange(width, dtype=np.int32) + n_parity    # member positions
    c = gf_inv(x[:, None] ^ y[None, :])                # Cauchy: 1/(x ^ y)
    return gf_mul(c, gf_inv(c[0])[None, :])            # row 0 -> all ones


def rs_decode_weights(coeff: np.ndarray, erased: np.ndarray,
                      survivors: np.ndarray,
                      parity_rows: np.ndarray) -> np.ndarray:
    """Decode weights for one group's erasure pattern.

    ``coeff`` is the (m, width) encode matrix, ``erased`` the member
    slots to solve for (e <= len(parity_rows)), ``survivors`` the member
    slots with trusted live frames, ``parity_rows`` the parity row
    indices to fold (the first e are used). Returns ``(e, width + m)``
    weights W such that erased member q's frame is

        XOR_i gf_mul(W[q, i], member_frame_i)
        XOR_r gf_mul(W[q, width + r], parity_frame_r)

    — i.e. the syndrome fold and the inverse application collapsed into
    one multiply-accumulate over [member frames, parity frames].
    """
    m, width = coeff.shape
    erased = np.asarray(erased, np.int64)
    rows = np.asarray(parity_rows, np.int64)[:erased.size]
    a = coeff[np.ix_(rows, erased)]
    a_inv = gf_mat_inv(a)
    w = np.zeros((erased.size, width + m), np.int32)
    for q in range(erased.size):
        for ri, r in enumerate(rows):
            w[q, width + int(r)] ^= a_inv[q, ri]
            for i in np.asarray(survivors, np.int64):
                w[q, int(i)] ^= gf_mul(a_inv[q, ri], coeff[int(r), int(i)])
    return w
