"""Perturbation generators (paper §5.2 experiment types).

Three families, matching Figures 3/5/6:

- ``random``      — isotropic Gaussian of a target norm (Fig. 3a, 5a).
- ``adversarial`` — opposite the direction of convergence, i.e. pointing
                    away from x* (Fig. 5b): δ = s · (x − x*)/||x − x*||.
- ``reset``       — reset a uniformly-random fraction of parameters back to
                    their initial values (Fig. 6) — the realistic analogue
                    of partial checkpoint recovery.

Each generator maps a parameter PyTree to a *perturbed* PyTree and also
returns ||δ|| so experiments can plug it directly into the Theorem 3.2
bound.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.blocks import BlockPartition, select_blocks, tree_sq_norm

PyTree = Any


def _tree_random_like(rng: jax.Array, tree: PyTree) -> PyTree:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(rng, len(leaves))
    out = [jax.random.normal(k, x.shape, jnp.float32).astype(x.dtype)
           for k, x in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)


def _tree_scale(tree: PyTree, s) -> PyTree:
    return jax.tree_util.tree_map(lambda x: (x.astype(jnp.float32) * s).astype(x.dtype), tree)


def _tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda x, y: x + y.astype(x.dtype), a, b)


def _tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda x, y: x - y.astype(x.dtype), a, b)


def random_perturbation(rng: jax.Array, params: PyTree, norm: float,
                        ) -> tuple[PyTree, jnp.ndarray]:
    """Gaussian direction scaled to ``norm``. Returns (perturbed, ||δ||)."""
    noise = _tree_random_like(rng, params)
    nsq = tree_sq_norm(noise, _tree_scale(noise, 0.0))
    scale = norm / jnp.sqrt(nsq + 1e-30)
    delta = _tree_scale(noise, scale)
    return _tree_add(params, delta), jnp.asarray(norm, jnp.float32)


def adversarial_perturbation(params: PyTree, x_star: PyTree, norm: float,
                             ) -> tuple[PyTree, jnp.ndarray]:
    """δ points away from the optimum: δ = s·(x − x*)/||x − x*|| (Fig. 5b)."""
    direction = _tree_sub(params, x_star)
    dsq = tree_sq_norm(params, x_star)
    scale = norm / jnp.sqrt(dsq + 1e-30)
    delta = _tree_scale(direction, scale)
    return _tree_add(params, delta), jnp.asarray(norm, jnp.float32)


def reset_perturbation(rng: jax.Array, params: PyTree, x0: PyTree,
                       fraction: float, partition: BlockPartition,
                       ) -> tuple[PyTree, jnp.ndarray]:
    """Reset a random fraction of parameter blocks to initial values (Fig. 6).

    Returns (perturbed, ||δ||).
    """
    total = partition.total_blocks
    k = max(1, round(fraction * total))
    idx = jax.random.choice(rng, total, (min(k, total),), replace=False)
    mask = jnp.zeros((total,), bool).at[idx].set(True)
    perturbed = select_blocks(params, x0, mask, partition)
    dn = jnp.sqrt(tree_sq_norm(perturbed, params))
    return perturbed, dn
