"""Kernel micro-benchmarks: us_per_call for the 4 Pallas kernels vs their
pure-jnp oracles (interpret mode on CPU — relative numbers demonstrate the
harness; absolute perf is a TPU question answered by §Roofline)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, timed
from repro.kernels.block_dist.kernel import block_dist_pallas
from repro.kernels.block_dist.ref import block_dist_ref
from repro.kernels.masked_restore.kernel import masked_restore_pallas
from repro.kernels.masked_restore.ref import masked_restore_ref
from repro.kernels.ssd_scan.kernel import ssd_intra_pallas
from repro.kernels.ssd_scan.ref import ssd_intra_ref
from repro.kernels.sw_attention.kernel import sw_attention_pallas
from repro.kernels.sw_attention.ref import sw_attention_ref


def run(trials: int = 3, quick: bool = False) -> list[str]:
    rng = np.random.default_rng(0)
    rows = []

    a = jnp.asarray(rng.normal(size=(64, 1024)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(64, 1024)), jnp.float32)
    ref = jax.jit(block_dist_ref)
    _, us_ref = timed(lambda: ref(a, b).block_until_ready(), repeats=trials)
    _, us_krn = timed(lambda: block_dist_pallas(a, b, interpret=True
                                                ).block_until_ready(),
                      repeats=trials)
    rows.append(csv_row("kernel_block_dist_ref", us_ref, "shape=64x1024"))
    rows.append(csv_row("kernel_block_dist_pallas_interp", us_krn,
                        "shape=64x1024"))

    m = jnp.asarray(rng.random(64) < 0.5)
    refm = jax.jit(masked_restore_ref)
    _, us_ref = timed(lambda: refm(a, b, m).block_until_ready(), repeats=trials)
    _, us_krn = timed(lambda: masked_restore_pallas(a, b, m, interpret=True
                                                    ).block_until_ready(),
                      repeats=trials)
    rows.append(csv_row("kernel_masked_restore_ref", us_ref, "shape=64x1024"))
    rows.append(csv_row("kernel_masked_restore_pallas_interp", us_krn,
                        "shape=64x1024"))

    B, nc, Q, H, P, N = 1, 4, 32, 4, 16, 32
    la = -jnp.abs(jnp.asarray(rng.normal(size=(B, nc, Q, H)), jnp.float32)) * .1
    dt = jnp.abs(jnp.asarray(rng.normal(size=(B, nc, Q, H)), jnp.float32))
    x = jnp.asarray(rng.normal(size=(B, nc, Q, H, P)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, nc, Q, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, nc, Q, N)), jnp.float32)
    refs = jax.jit(ssd_intra_ref)
    _, us_ref = timed(lambda: jax.block_until_ready(refs(la, dt, x, Bm, Cm)),
                      repeats=trials)
    _, us_krn = timed(lambda: jax.block_until_ready(
        ssd_intra_pallas(la, dt, x, Bm, Cm, interpret=True)), repeats=trials)
    rows.append(csv_row("kernel_ssd_intra_ref", us_ref,
                        f"B{B}nc{nc}Q{Q}H{H}P{P}N{N}"))
    rows.append(csv_row("kernel_ssd_intra_pallas_interp", us_krn,
                        f"B{B}nc{nc}Q{Q}H{H}P{P}N{N}"))

    BH, G, S, Dh, W = 2, 2, 128, 32, 32
    q = jnp.asarray(rng.normal(size=(BH, G, S, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(BH, S, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(BH, S, Dh)), jnp.float32)
    refa = jax.jit(lambda q, k, v: sw_attention_ref(q, k, v, window=W))
    _, us_ref = timed(lambda: refa(q, k, v).block_until_ready(), repeats=trials)
    _, us_krn = timed(lambda: sw_attention_pallas(
        q, k, v, window=W, q_chunk=32, kv_chunk=32,
        interpret=True).block_until_ready(), repeats=trials)
    rows.append(csv_row("kernel_sw_attention_ref", us_ref,
                        f"BH{BH}G{G}S{S}Dh{Dh}W{W}"))
    rows.append(csv_row("kernel_sw_attention_pallas_interp", us_krn,
                        f"BH{BH}G{G}S{S}Dh{Dh}W{W}"))
    return rows
