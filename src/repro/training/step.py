"""Shared train-step builders (used by TrainLoop and launch/dryrun).

``make_train_step`` is the classic PyTree step. ``make_arena_train_step``
is its arena-native twin: the live parameters enter and leave the step as
the flat arena (:mod:`repro.core.arena`) — decoded to the leaf-shaped
tree view at the top of the program for the forward pass, loss/grad taken
w.r.t. that tree (NOT through the decode — see the function docstring for
why), the gradient packed back to arena form in the same program, and the
optimizer run as the flat elementwise apply
(:func:`repro.optim.optimizers.arena_apply`). Jitted with donation, the
arena buffer is reused across steps and never round-trips through a
host-visible pack; the per-step fault-tolerance sweep then reads
``state.arena`` directly.

Both steps implement microbatched gradient accumulation
(``cfg.microbatch > 1``): the global batch is split into MB microbatches
processed by a ``lax.scan`` with an fp32-accumulated gradient buffer.
This is the standard memory lever for the largest dense architectures —
per-step transient activation memory scales 1/MB while keeping the same
global batch semantics.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.api import ModelOps
from repro.optim.optimizers import Optimizer, arena_apply
from repro.sharding.partition import DistContext
from repro.training.train_state import ArenaTrainState, TrainState

PyTree = Any


def make_train_step(ops: ModelOps, cfg: ModelConfig, ctx: DistContext,
                    optimizer: Optimizer):
    loss_and_grad = jax.value_and_grad(ops.train_loss)

    def train_step(state: TrainState, batch: PyTree):
        mb = max(cfg.microbatch, 1)
        if mb == 1:
            loss, grads = loss_and_grad(state.params, batch, cfg, ctx)
        else:
            def split(x):
                return x.reshape((mb, x.shape[0] // mb) + tuple(x.shape[1:]))

            mbatch = jax.tree_util.tree_map(split, batch)
            acc_dtype = jnp.dtype(cfg.opt_moment_dtype)
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dtype), state.params)

            def body(carry, bx):
                loss_sum, gacc = carry
                l, g = loss_and_grad(state.params, bx, cfg, ctx)
                gacc = jax.tree_util.tree_map(
                    lambda a, x: (a.astype(jnp.float32)
                                  + x.astype(jnp.float32)).astype(a.dtype),
                    gacc, g)
                return (loss_sum + l, gacc), None

            (loss, grads), _ = jax.lax.scan(
                body, (jnp.float32(0.0), g0), mbatch)
            loss = loss / mb
            grads = jax.tree_util.tree_map(lambda g: g / mb, grads)
        params, opt_state = optimizer.update(grads, state.opt_state,
                                             state.params)
        return TrainState(params, opt_state, state.step + 1), loss

    return train_step


def make_arena_train_step(ops: ModelOps, cfg: ModelConfig, ctx: DistContext,
                          optimizer: Optimizer, layout):
    """Arena-native train step: ``(ArenaTrainState, batch) -> (state', loss)``.

    The arena is decoded to the leaf-shaped tree view once at the top of
    the program (the model's forward pass needs shapes), the loss/grad is
    the same tree computation as :func:`make_train_step`, and the
    gradient is packed back to arena form in the same program before the
    flat elementwise optimizer apply — the whole step is one jitted
    function of ``(arena, moments) -> (arena', moments')``, meant to be
    jitted with ``donate_argnums=(0,)`` so those buffers are reused in
    place and never round-trip through a host-visible pack.

    (The grad is deliberately taken w.r.t. the *tree*, not the arena:
    differentiating through the decode would transpose each leaf's slice
    into its own full-arena scatter — ~n_leaves arena-sized buffers —
    where the explicit ``pack_arena`` of the grads is one model-sized
    pass.)

    Bit-equivalent to the PyTree step on an all-f32 model: the decode is
    a bitcast view of the stored words, ``pack_values`` of the grads is
    the f32 image of the same values the tree optimizer reads, and the
    flat apply is the same elementwise math. On mixed-precision models
    the grads/moments live in the f32 *value* domain
    (``layout.total_values`` ≥ ``total_words``) and :func:`arena_apply`
    does the decode → update → re-encode round trip one coalesced
    same-dtype run at a time; stored params round through exactly the
    tree path's ``.astype(p.dtype)``, while master moments stay f32
    (allclose to the tree path, documented in DESIGN.md).

    On a mesh (``ctx.mesh is not None``) the step is SPMD: the arena and
    adam moments carry the flat :func:`~repro.sharding.partition
    .arena_sharding` (each device owns a contiguous tile-aligned span),
    decoded leaves are constrained to the model's FSDP+TP partition
    specs, and the grads pack pins every part to the flat sharding
    (both the layout we want and the workaround for jax 0.4.37's
    sharded-``concatenate`` miscompile — see ``core/arena.py``). The
    elementwise apply partitions exactly along the flat shards, so the
    sharded step stays bit-equal to the PyTree step *on the same mesh*
    (asserted in ``tests/test_sharded_arena.py``; across topologies,
    reduction order differs at ULP level as with any SPMD change).
    """
    from repro.core.arena import pack_values, unpack_arena
    from repro.sharding.partition import (arena_sharding,
                                          param_partition_specs)
    from jax.sharding import NamedSharding

    loss_and_grad = jax.value_and_grad(ops.train_loss)
    if ctx.mesh is not None:
        flat_sh = arena_sharding(ctx.mesh)

        def constrain_tree(p):
            p_shape = jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), p)
            specs = param_partition_specs(p_shape, ctx)
            return jax.tree_util.tree_map(
                lambda x, s: jax.lax.with_sharding_constraint(
                    x, NamedSharding(ctx.mesh, s)), p, specs)

        def pack_grads(g):
            return pack_values(g, layout, out_sharding=flat_sh)

        def constrain_arena(a):
            # Value buffers only share the flat arena sharding when the
            # two domains coincide (all-f32 layout; mixed-dtype + mesh is
            # gated off upstream in the fabric).
            if a.size != layout.total_words:
                return a
            return jax.lax.with_sharding_constraint(a, flat_sh)
    else:
        def constrain_tree(p):
            return p

        def pack_grads(g):
            return pack_values(g, layout)

        def constrain_arena(a):
            return a

    def train_step(state: ArenaTrainState, batch: PyTree):
        params = constrain_tree(unpack_arena(state.arena, layout))
        mb = max(cfg.microbatch, 1)
        if mb == 1:
            loss, g = loss_and_grad(params, batch, cfg, ctx)
            grads = pack_grads(g)
        else:
            def split(x):
                return x.reshape((mb, x.shape[0] // mb) + tuple(x.shape[1:]))

            mbatch = jax.tree_util.tree_map(split, batch)
            acc_dtype = jnp.dtype(cfg.opt_moment_dtype)
            g0 = constrain_arena(jnp.zeros((layout.total_values,),
                                           acc_dtype))

            def body(carry, bx):
                loss_sum, gacc = carry
                l, g = loss_and_grad(params, bx, cfg, ctx)
                gacc = (gacc.astype(jnp.float32)
                        + pack_grads(g)).astype(acc_dtype)
                return (loss_sum + l, gacc), None

            (loss, gacc), _ = jax.lax.scan(
                body, (jnp.float32(0.0), g0), mbatch)
            loss = loss / mb
            grads = gacc / mb     # acc_dtype division, like the tree path
        new_arena, opt_state = arena_apply(optimizer, grads,
                                           state.opt_state, state.arena,
                                           layout)
        return ArenaTrainState(constrain_arena(new_arena), opt_state,
                               state.step + 1, state.layout), loss

    return train_step
