"""Persistent checkpoint storage (the paper's CephFS/NFS role)."""
from repro.checkpoint_io.store import ShardedCheckpointStore

__all__ = ["ShardedCheckpointStore"]
