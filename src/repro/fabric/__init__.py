"""Tiered checkpoint fabric: failure domains, peer replication, parity.

The paper's SCAR recovers every lost block from one redundancy tier — the
in-memory running checkpoint (with a disk mirror behind it). Production
failures are *correlated* (a host or rack dies, taking every block homed
there), and cheaper redundancy tiers exist: anti-affine peer replicas and
XOR parity groups recover *live* block values at zero perturbation. This
package layers those tiers above the running checkpoint and resolves each
lost block to the cheapest surviving one. See DESIGN.md.
"""
from repro.fabric.domains import FailureDomainMap, FailureEvent
from repro.fabric.fabric import CheckpointFabric, FabricConfig
from repro.fabric.parity import ParityCodec
from repro.fabric.replica import ReplicaSet
from repro.fabric.tiers import RecoveryTier, TieredRecovery, TierPlan

__all__ = ["FailureDomainMap", "FailureEvent", "CheckpointFabric",
           "FabricConfig", "ParityCodec", "ReplicaSet", "RecoveryTier",
           "TieredRecovery", "TierPlan"]
