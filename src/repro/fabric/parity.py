"""XOR parity groups over blocks with single-erasure reconstruction (tier 2).

Storage-cheap redundancy: blocks are striped into groups of ``g`` members
whose homes sit on *distinct hosts*, and one parity block (the XOR of the
members' bit patterns) is kept per group — 1/g of the replica tier's
memory. A whole-host failure then loses at most one member per group, and
the lost member is reconstructed bit-exactly as
``parity ^ XOR(surviving members)`` by the fused Pallas ``parity_xor``
kernel.

Reconstruction needs the survivors' frames *as of encode time*; re-encoding
runs at memory bandwidth (one XOR pass), so the codec is refreshed every
maintenance call and reconstruction recovers the *live* value — zero
perturbation, same accounting as the replica tier. A stale parity (any
parameter update since encode) is unusable — the XOR would mix bit patterns
from different iterations into garbage — so the tier planner gates on
freshness.

Block frames: each block's payload is bit-packed into 32-bit words
(``dtype_word_ratio`` elements per word — raw bf16/fp8/int8 bits, not f32
images, so frame bytes scale with the stored precision), one fixed-width
int32 row per global block id (zero-padded — zeros are XOR-neutral).
Colocated leaves (shared block ids) concatenate side by side within the
frame. Non-word-packable dtypes (f64/int64/…) keep the historical
one-f32-image-per-element convention.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocks import (BlockPartition, LeafMeta,  # noqa: F401
                               decode_block_words, expand_block_mask,
                               leaf_block_words, leaf_word_width)
from repro.fabric.placement import (ClusterView, effective_parity_group,
                                    parity_group_homes, stripe_parity_groups)
from repro.kernels.parity_xor.ops import parity_encode, parity_reconstruct

PyTree = Any


# ---------------------------------------------------------------------------
# Block frames: fixed-width bit-pattern rows, one per global block id
# ---------------------------------------------------------------------------

# canonical definition lives with the block partition (the arena shares
# it); kept under the old name for in-package callers. Since the
# word-level arena this is the payload *word* count per block (elements
# bit-packed ``dtype_word_ratio`` per word), not the element count.
_leaf_frame_width = leaf_word_width


@dataclasses.dataclass(frozen=True)
class FrameLayout:
    """Column placement of each leaf's payload inside its blocks' frames.

    Column starts (and the total frame width) are aligned to the arena
    tile (``repro.core.arena.ARENA_TILE`` words) so a group-sorted XOR
    over ``(8, 128)`` arena tiles lands on whole-tile frame columns —
    the padding columns are zero on every path (XOR-neutral)."""
    cols: tuple[int, ...]      # per-leaf start column (tile-aligned)
    widths: tuple[int, ...]    # per-leaf payload width
    frame_elems: int           # int32 words per frame (tile-aligned)


def frame_layout(partition: BlockPartition) -> FrameLayout:
    from repro.core.arena import _align
    cols, widths = [], []
    used: dict[int, int] = {}  # block-id offset -> columns consumed so far
    for leaf in partition.leaves:
        w = _leaf_frame_width(leaf, partition.block_rows)
        start = used.get(leaf.offset, 0)   # colocated leaves share offsets
        cols.append(start)
        widths.append(w)
        used[leaf.offset] = start + _align(w)
    return FrameLayout(tuple(cols), tuple(widths),
                       _align(max(used.values())))


def pack_frames(values: PyTree, partition: BlockPartition,
                layout: FrameLayout) -> jnp.ndarray:
    """(total_blocks, frame_elems) int32 — raw bit-packed words, 0-padded."""
    out = jnp.zeros((partition.total_blocks, layout.frame_elems), jnp.int32)
    flat = jax.tree_util.tree_leaves(values)
    for x, leaf, col, w in zip(flat, partition.leaves, layout.cols,
                               layout.widths):
        bits = leaf_block_words(x, partition.block_rows)
        out = out.at[leaf.offset:leaf.offset + leaf.n_blocks,
                     col:col + w].set(bits)
    return out


def unpack_frames_into(dst: PyTree, frames_by_block: jnp.ndarray,
                       block_mask: np.ndarray, partition: BlockPartition,
                       layout: FrameLayout) -> PyTree:
    """Overwrite the masked blocks of ``dst`` with values decoded from
    ``frames_by_block``; all other blocks pass through untouched."""
    mask = np.asarray(block_mask, bool)
    flat = jax.tree_util.tree_leaves(dst)
    out = []
    for x, leaf, col, w in zip(flat, partition.leaves, layout.cols,
                               layout.widths):
        seg = mask[leaf.offset:leaf.offset + leaf.n_blocks]
        if not seg.any():
            out.append(x)
            continue
        bits = frames_by_block[leaf.offset:leaf.offset + leaf.n_blocks,
                               col:col + w]
        decoded = decode_block_words(bits, leaf,
                                     partition.block_rows).astype(x.dtype)
        em = expand_block_mask(jnp.asarray(seg), leaf, partition.block_rows)
        out.append(jnp.where(em, decoded, x))
    return jax.tree_util.tree_unflatten(partition.treedef, out)


# ---------------------------------------------------------------------------
# Codec
# ---------------------------------------------------------------------------

class ParityCodec:
    """XOR parity over anti-affine block groups, Pallas-kernel backed.

    Group striping and parity homing are read from the fabric's mutable
    :class:`~repro.fabric.placement.ClusterView` — after a domain loss,
    :meth:`restripe` re-cuts the groups over the surviving hosts (the RAID
    width clamp follows the *alive* host count) and invalidates the parity
    until the next :meth:`encode`.
    """

    # single parity row per group; the RS subclass raises both. The
    # fused arena sweep emits XOR parity directly (needs_arena_encode
    # False); codecs that must re-encode from the snapshot arena set it.
    n_parity = 1
    needs_arena_encode = False
    supports_integrity = False

    def __init__(self, partition: BlockPartition, view: ClusterView,
                 group_size: int = 4, use_pallas: bool | None = None):
        if group_size < 2:
            raise ValueError("parity group_size must be >= 2")
        self.partition = partition
        self.view = view
        self.domains = view.domains
        self.requested_group_size = group_size
        self.use_pallas = use_pallas
        self.layout = frame_layout(partition)
        self.parity: Optional[jnp.ndarray] = None
        self.encoded_step = -1
        # arena → frame gather index, built lazily per arena layout (the
        # arena-path reconstruction sources member frames straight from
        # the maintenance sweep's snapshot arena)
        self._arena_gather: Optional[np.ndarray] = None
        self._arena_gather_layout = None
        self._build()

    def _build(self) -> None:
        """(Re)derive groups, parity homes, and the fused encode program
        from the view's current placement."""
        self._stripe()
        self.parity_homes = parity_group_homes(self.members, self.view)
        self._build_encode()

    def _stripe(self) -> None:
        """Cut member groups over the view's current placement (shared by
        the XOR and RS codecs — only homes and the fold differ)."""
        self.group_size = effective_parity_group(self.view,
                                                 self.requested_group_size,
                                                 reserve=self.n_parity)
        self.members = stripe_parity_groups(self.view, self.group_size,
                                            fold_tail=self.n_parity < 2)
        self.n_groups = self.members.shape[0]
        self.group_of = np.full((self.partition.total_blocks,), -1, np.int32)
        for j, row in enumerate(self.members):
            for b in row[row >= 0]:
                self.group_of[b] = j
        self.valid = (self.members >= 0)
        # -1 members gather row 0 but are masked out by ``valid``
        self._gather_ids = np.where(self.valid, self.members, 0)

    def _build_encode(self) -> None:
        # encode runs every maintenance interval (the hot loop): fuse
        # pack + gather + XOR fold into one cached jitted program so the
        # per-step cost is one dispatch, not a per-leaf eager op chain
        gather = jnp.asarray(self._gather_ids)
        valid = jnp.asarray(self.valid)

        def _encode(values):
            frames = pack_frames(values, self.partition, self.layout)
            return parity_encode(frames[gather], valid,
                                 use_pallas=self.use_pallas)
        self._encode_fn = jax.jit(_encode)

    # -- maintenance ---------------------------------------------------------

    def encode(self, step: int, values: PyTree) -> None:
        """Re-encode all parity blocks from live values (one XOR pass)."""
        self.parity = self._encode_fn(values)
        self.encoded_step = int(step)

    def ingest(self, step: int, parity: jnp.ndarray) -> None:
        """Adopt a parity buffer encoded elsewhere (the fused maintenance
        sweep XOR-folds leaf bit patterns straight into group frames —
        bit-identical to :meth:`encode` under the same striping)."""
        self.parity = parity
        self.encoded_step = int(step)

    def restripe(self) -> None:
        """Re-cut the parity groups over the view's current topology.

        The old parity buffers XOR frames of the old groups — meaningless
        under the new striping — so the codec is invalidated until the next
        :meth:`encode` (the fabric re-encodes immediately after a
        post-failure restripe)."""
        self._build()
        self.parity = None
        self.encoded_step = -1

    def is_fresh(self, step: int) -> bool:
        return self.parity is not None and self.encoded_step == int(step)

    def nbytes(self) -> int:
        return 0 if self.parity is None else int(self.parity.nbytes)

    def staging_nbytes(self) -> int:
        """Peak staging footprint of one seed-path :meth:`encode`: the
        packed ``(total_blocks, frame_elems)`` bit-pattern buffer plus the
        ``(n_groups, width, frame_elems)`` member gather the XOR fold
        consumes. The fused maintenance path replaces both with compact
        per-leaf contributions (see ``kernels/fused_maintain``); callers
        accounting real memory overhead must include whichever applies."""
        frames = self.partition.total_blocks * self.layout.frame_elems * 4
        gathered = int(self.members.size) * self.layout.frame_elems * 4
        return frames + gathered

    # -- recovery ------------------------------------------------------------

    def code_strength(self, failed_devices) -> np.ndarray:
        """(n_groups,) erasures each group can absorb right now: its
        parity rows homed on devices alive and outside the failing set.
        0 or 1 for the XOR codec, up to m for RS."""
        failed = np.asarray(failed_devices, np.int32)
        homes = np.asarray(self.parity_homes).reshape(self.n_groups, -1)
        ok = self.view.alive[homes] & ~np.isin(homes, failed)
        return ok.sum(axis=1).astype(np.int64)

    def reconstructable(self, lost_mask: np.ndarray,
                        available_mask: np.ndarray,
                        failed_devices, step: int) -> np.ndarray:
        """(total_blocks,) bool — lost blocks recoverable from parity.

        A lost block is parity-recoverable iff the parity is fresh and
        its group's erasure count (members without an available live
        frame) is within the group's surviving code strength — exactly
        one erasure against one live parity home for the XOR codec, up
        to m erasures against m surviving parity rows for RS.
        """
        total = self.partition.total_blocks
        if not self.is_fresh(step):
            return np.zeros((total,), bool)
        lost = np.asarray(lost_mask, bool)
        available = np.asarray(available_mask, bool)
        failed = np.asarray(failed_devices, np.int32)
        member_unavail = self.valid & ~available[self._gather_ids]
        erased = member_unavail.sum(axis=1)
        strength = self.code_strength(failed)
        ok_group = (erased >= 1) & (erased <= strength)
        out = np.zeros((total,), bool)
        grouped_ok = ok_group[:, None] & member_unavail
        out[self._gather_ids[grouped_ok]] = True
        return out & lost

    def exceeded_groups(self, lost_mask: np.ndarray,
                        available_mask: np.ndarray,
                        failed_devices, step: int) -> list[dict]:
        """Never-silent fallback accounting: groups that hold lost blocks
        the code cannot recover (erasures exceed surviving strength, or
        the parity is stale). One dict per exceeded group — the fabric
        turns each into a ``tier_fallback`` event so a RUNNING_CKPT
        fallback always says *why* the cheaper tier declined."""
        lost = np.asarray(lost_mask, bool)
        available = np.asarray(available_mask, bool)
        failed = np.asarray(failed_devices, np.int32)
        member_lost = self.valid & lost[self._gather_ids]
        erased = (self.valid & ~available[self._gather_ids]).sum(axis=1)
        fresh = self.is_fresh(step)
        strength = self.code_strength(failed) if fresh \
            else np.zeros((self.n_groups,), np.int64)
        bad = member_lost.any(axis=1) & (erased > strength)
        return [dict(group=int(j), lost_members=int(member_lost[j].sum()),
                     unavailable=int(erased[j]), strength=int(strength[j]),
                     fresh=bool(fresh))
                for j in np.nonzero(bad)[0]]

    def reconstruct(self, values: PyTree, recover_mask: np.ndarray,
                    available_mask: np.ndarray) -> jnp.ndarray:
        """Reconstruct the masked blocks' frames; returns a
        (total_blocks, frame_elems) int32 buffer (zeros off-mask).

        ``values`` must hold live frames for every available member
        (survivors and fresh-replica-restored blocks).
        """
        frames = pack_frames(values, self.partition, self.layout)
        return self._reconstruct_frames(frames, recover_mask,
                                        available_mask)

    def _ensure_arena_gather(self, arena_layout) -> np.ndarray:
        """Cache the arena-word → frame-column gather for this layout."""
        from repro.core.arena import frames_gather_index
        if self._arena_gather is None \
                or self._arena_gather_layout is not arena_layout:
            self._arena_gather = frames_gather_index(arena_layout,
                                                     self.layout)
            self._arena_gather_layout = arena_layout
        return self._arena_gather

    def reconstruct_from_arena(self, arena: jnp.ndarray, arena_layout,
                               recover_mask: np.ndarray,
                               available_mask: np.ndarray) -> jnp.ndarray:
        """Arena-path reconstruction: member frames come from the flat
        snapshot arena via one gather (``frames_from_arena``) instead of
        a full-tree ``pack_frames`` pass. Valid when the arena is the
        encode-time snapshot — in arena maintenance mode the replica
        arena and the parity are emitted by the same sweep, so the tier
        planner checks ``refreshed_step == encoded_step`` and routes
        here."""
        from repro.core.arena import frames_from_arena
        frames = frames_from_arena(arena,
                                   self._ensure_arena_gather(arena_layout))
        return self._reconstruct_frames(frames, recover_mask,
                                        available_mask)

    def _reconstruct_frames(self, frames: jnp.ndarray,
                            recover_mask: np.ndarray,
                            available_mask: np.ndarray) -> jnp.ndarray:
        assert self.parity is not None
        grouped = frames[jnp.asarray(self._gather_ids)]
        survivors = self.valid & np.asarray(available_mask, bool)[
            self._gather_ids]
        rec = parity_reconstruct(grouped, self.parity,
                                 jnp.asarray(survivors),
                                 use_pallas=self.use_pallas)
        ids = np.nonzero(np.asarray(recover_mask, bool))[0]
        out = jnp.zeros_like(frames)
        if ids.size:
            out = out.at[jnp.asarray(ids)].set(rec[jnp.asarray(
                self.group_of[ids])])
        return out
