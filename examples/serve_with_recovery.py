"""Serving example: batched generation + SCAR-style weight recovery.

Serves a reduced model (batched greedy decode with a KV cache), then
simulates a partial weight-loss event on the serving replica (e.g. a host
dropping out of the inference pod) and restores the lost blocks from the
running checkpoint — generation continues without reloading the full model.

Run:  PYTHONPATH=src python examples/serve_with_recovery.py [--arch yi-9b]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.controller import FTController
from repro.core.policy import CheckpointPolicy
from repro.data import lm_batch
from repro.models import get_model
from repro.sharding import single_device_ctx
from repro.training.serve import Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args()

    ctx = single_device_ctx()
    cfg = get_config(args.arch, reduced=True)
    ops = get_model(cfg)
    params = ops.init_params(jax.random.PRNGKey(0), cfg)
    srv = Server(cfg, ctx, params)
    batch = lm_batch(jax.random.PRNGKey(1), cfg, args.batch, args.prompt_len)

    print(f"== serving {args.arch} (reduced): batch={args.batch}, "
          f"prompt={args.prompt_len}, +{args.new_tokens} tokens")
    toks0 = srv.generate(batch, args.new_tokens)
    print("   tokens (before failure):", np.asarray(toks0)[0])

    # checkpoint the serving weights, lose 30% of blocks, partially restore
    ctl = FTController(params, CheckpointPolicy.scar(fraction=1.0, interval=1))
    ctl.checkpoint_now(1, params)
    lost = ctl.sample_failure(0.3)
    recovered, info = ctl.on_failure(params, lost)
    print(f"   failure: lost {info['lost_blocks']:.0f} blocks; "
          f"restored from running checkpoint (||δ||²={info['applied_sq']:.2e})")

    srv2 = Server(cfg, ctx, recovered)
    toks1 = srv2.generate(batch, args.new_tokens)
    print("   tokens (after recovery): ", np.asarray(toks1)[0])
    same = bool(jnp.all(toks0 == toks1))
    print(f"== generations identical after lossless recovery: {same}")
    assert same, "checkpoint was fresh — recovery must be exact"


if __name__ == "__main__":
    main()
