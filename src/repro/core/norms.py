"""Pluggable block norms for priority scoring (paper §4.2 + Appendix C).

A norm function has signature ``(a_view, b_view, leaf) -> (n_blocks,)`` where
the views are ``(n_blocks, block_rows * row_width)`` float32 arrays produced
by :func:`repro.core.blocks.leaf_block_view`.

- ``sq_l2``       — squared L2 distance per block (default; what Theorems
                    4.1/4.2 measure).
- ``scaled_tv``   — scaled total-variation for distribution-valued rows
                    (paper Appendix C, LDA): per-row TV = ½ Σ|p − q| scaled
                    by a per-row weight (document length), summed per block.
                    Falls back to uniform weights when none registered.

Norms are registered by name so ``CheckpointPolicy.norm`` stays a plain
string (config-system friendly). Per-leaf auxiliary data (e.g. document
lengths) is attached via ``register_aux``.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax.numpy as jnp

from repro.core.blocks import LeafMeta

NormFn = Callable[[jnp.ndarray, jnp.ndarray, LeafMeta], jnp.ndarray]

_REGISTRY: Dict[str, Callable[..., NormFn]] = {}


def register_norm(name: str):
    def deco(factory):
        _REGISTRY[name] = factory
        return factory
    return deco


def get_norm(name: str, aux=None, block_rows: int = 128) -> NormFn:
    if name not in _REGISTRY:
        raise KeyError(f"unknown norm {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](aux=aux, block_rows=block_rows)


@register_norm("l2")
def _sq_l2_factory(aux=None, block_rows: int = 128) -> NormFn:
    def sq_l2(a, b, leaf):
        return jnp.sum((a - b) ** 2, axis=-1)
    return sq_l2


@register_norm("l1")
def _l1_factory(aux=None, block_rows: int = 128) -> NormFn:
    def l1(a, b, leaf):
        return jnp.sum(jnp.abs(a - b), axis=-1)
    return l1


@register_norm("linf")
def _linf_factory(aux=None, block_rows: int = 128) -> NormFn:
    def linf(a, b, leaf):
        return jnp.max(jnp.abs(a - b), axis=-1)
    return linf


@register_norm("scaled_tv")
def _scaled_tv_factory(aux=None, block_rows: int = 128) -> NormFn:
    """aux: dict leaf-name -> (rows,) weight vector (document lengths).

    Rows of the leaf are probability distributions; TV distance per row is
    ½ Σ_t |p_t − q_t|, weighted and summed within each block. The weighting
    keeps long documents from being under-prioritized (paper Appendix C).
    """
    aux = aux or {}

    def scaled_tv(a, b, leaf):
        n_blocks, block_elems = a.shape
        width = leaf.row_width
        ar = a.reshape(n_blocks, -1, width)
        br = b.reshape(n_blocks, -1, width)
        tv = 0.5 * jnp.sum(jnp.abs(ar - br), axis=-1)   # (n_blocks, block_rows)
        w = aux.get(leaf.name)
        if w is not None:
            w = jnp.asarray(w, jnp.float32)
            pad = n_blocks * tv.shape[1] - leaf.rows
            if pad:
                w = jnp.pad(w, (0, pad))
            tv = tv * w.reshape(n_blocks, tv.shape[1])
        return jnp.sum(tv, axis=-1)
    return scaled_tv
