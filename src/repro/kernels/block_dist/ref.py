"""Pure-jnp oracle for the block_dist kernel."""
import jax.numpy as jnp


def block_dist_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """a, b: (n_blocks, E) → (n_blocks,) f32 squared L2 distances."""
    d = a.astype(jnp.float32) - b.astype(jnp.float32)
    return jnp.sum(d * d, axis=1)
