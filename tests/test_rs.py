"""RS(k, m) erasure tier + silent-error integrity checks.

Covers the multi-erasure subsystem end to end:
- GF(256) table arithmetic (field axioms; property tests when hypothesis
  is available — import-guarded, never a hard dependency),
- the Cauchy coefficient matrix is MDS (every square submatrix inverts)
  and its normalized row 0 makes RS(k, 1) bit-identical to the XOR tier,
- the three gf256 MAC paths (jnp tables, numpy mirror, Pallas SWAR
  kernel in interpret mode) agree bit-for-bit,
- encode ∘ decode is the identity for any ≤ m erasures per group,
- an RS(k, 2) fabric recovers a simultaneous two-host loss bit-exactly
  through the PARITY tier (the acceptance gate `rs_recovery_bit_equal`),
  while the XOR fabric's pinned baseline falls back to RUNNING_CKPT/DISK
  with never-silent ``tier_fallback`` records,
- the integrity scrub detects an injected arena bit flip, localizes it
  to the corrupted block, corrects it in place, and prices it in the
  ledger at ‖δ′‖² ≈ 0,
- the background store writer retries transient failures with backoff
  (``store_write_retried`` events) before surfacing a chained error.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.blocks import partition_pytree
from repro.fabric import CheckpointFabric, FabricConfig
from repro.kernels.gf256_mac.ops import gf256_mac, rs_decode, rs_encode
from repro.kernels.gf256_mac.ref import gf256_mac_np, gf256_mac_ref
from repro.kernels.gf256_mac.tables import (GF_EXP, GF_LOG, gf_inv,
                                            gf_mat_inv, gf_mul,
                                            gf_scale_words_np,
                                            rs_coefficients,
                                            rs_decode_weights)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # no pip install in this environment: the
    HAVE_HYPOTHESIS = False  # property tests below are skipped, not failed

    def given(*a, **k):      # decorator stubs so the module still imports
        return lambda f: f

    def settings(*a, **k):
        return lambda f: f

    class _St:
        @staticmethod
        def integers(lo, hi):
            return None
    st = _St()

RNG = np.random.default_rng(11)


def _params(rows=256, width=6):
    return {"w": jnp.asarray(RNG.normal(size=(rows, width)), jnp.float32),
            "b": jnp.asarray(RNG.normal(size=(8,)), jnp.float32)}


def _fabric(part, **kw):
    cfg = FabricConfig(n_devices=8, devices_per_host=2, hosts_per_rack=2,
                       use_pallas=False, **kw)
    return CheckpointFabric(part, cfg)


def _ckpt_like(params):
    return {k: jnp.zeros_like(v) for k, v in params.items()}


# ---------------------------------------------------------------------------
# GF(256) arithmetic
# ---------------------------------------------------------------------------

def test_gf_field_axioms_sampled():
    a = RNG.integers(0, 256, 200)
    b = RNG.integers(0, 256, 200)
    c = RNG.integers(0, 256, 200)
    np.testing.assert_array_equal(gf_mul(a, b), gf_mul(b, a))
    np.testing.assert_array_equal(gf_mul(gf_mul(a, b), c),
                                  gf_mul(a, gf_mul(b, c)))
    # distributivity over the field's addition (XOR)
    np.testing.assert_array_equal(gf_mul(a, b ^ c),
                                  gf_mul(a, b) ^ gf_mul(a, c))
    np.testing.assert_array_equal(gf_mul(a, np.ones_like(a)), a)
    np.testing.assert_array_equal(gf_mul(a, np.zeros_like(a)), 0)


def test_gf_inverse_all_elements():
    nz = np.arange(1, 256)
    np.testing.assert_array_equal(gf_mul(nz, gf_inv(nz)), 1)


def test_gf_tables_consistent():
    # EXP/LOG round-trip over the multiplicative group
    assert GF_EXP[0] == 1 and len(set(GF_EXP[:255].tolist())) == 255
    nz = np.arange(1, 256)
    np.testing.assert_array_equal(GF_EXP[GF_LOG[nz]], nz)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=200, deadline=None)
@given(st.integers(0, 255), st.integers(0, 255), st.integers(0, 255))
def test_gf_mul_properties(a, b, c):
    assert gf_mul(a, b) == gf_mul(b, a)
    assert gf_mul(gf_mul(a, b), c) == gf_mul(a, gf_mul(b, c))
    assert gf_mul(a, b ^ c) == (gf_mul(a, b) ^ gf_mul(a, c))
    if b:
        assert gf_mul(gf_mul(a, b), gf_inv(b)) == a


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=100, deadline=None)
@given(st.integers(1, 255))
def test_gf_inv_involution(a):
    assert gf_inv(gf_inv(a)) == a
    assert gf_mul(a, gf_inv(a)) == 1


def test_rs_coefficients_mds():
    # Cauchy construction: every square submatrix is nonsingular, so any
    # erasure pattern decodes against any surviving parity rows
    coeff = rs_coefficients(6, 3)
    assert coeff.shape == (3, 6)
    np.testing.assert_array_equal(coeff[0], 1)  # normalized row 0 = XOR
    for _ in range(50):
        e = RNG.integers(1, 4)
        rows = RNG.choice(3, e, replace=False)
        cols = RNG.choice(6, e, replace=False)
        sub = coeff[np.ix_(rows, cols)]
        inv = gf_mat_inv(sub)  # raises LinAlgError if singular
        prod = np.zeros((e, e), np.int64)
        for i in range(e):
            for j in range(e):
                for k in range(e):
                    prod[i, j] ^= gf_mul(int(sub[i, k]), int(inv[k, j]))
        np.testing.assert_array_equal(prod, np.eye(e, dtype=np.int64))


# ---------------------------------------------------------------------------
# MAC kernel paths
# ---------------------------------------------------------------------------

def test_mac_paths_bit_equal():
    n, g, e = 5, 4, 70
    frames = RNG.integers(-2**31, 2**31, (n, g, e)).astype(np.int32)
    base = RNG.integers(-2**31, 2**31, (n, e)).astype(np.int32)
    coeff = RNG.integers(0, 256, (n, g)).astype(np.int32)
    ref = np.asarray(gf256_mac_ref(jnp.asarray(frames), jnp.asarray(base),
                                   jnp.asarray(coeff)))
    np.testing.assert_array_equal(ref, gf256_mac_np(frames, base, coeff))
    pal = np.asarray(gf256_mac(jnp.asarray(frames), jnp.asarray(base),
                               jnp.asarray(coeff), use_pallas=True,
                               interpret=True))
    np.testing.assert_array_equal(ref, pal)


def test_mac_xor_special_case():
    # coefficients in {0, 1} degrade the MAC to a masked XOR fold
    n, g, e = 3, 4, 33
    frames = RNG.integers(-2**31, 2**31, (n, g, e)).astype(np.int32)
    coeff = RNG.integers(0, 2, (n, g)).astype(np.int32)
    out = gf256_mac_np(frames, np.zeros((n, e), np.int32), coeff)
    expect = np.zeros((n, e), np.int32)
    for j in range(n):
        for s in range(g):
            if coeff[j, s]:
                expect[j] ^= frames[j, s]
    np.testing.assert_array_equal(out, expect)


def test_encode_decode_identity():
    width, m, e = 5, 2, 48
    n = 4
    coeff = rs_coefficients(width, m)
    frames = RNG.integers(-2**31, 2**31, (n, width, e)).astype(np.int32)
    valid = np.ones((n, width), bool)
    valid[-1, -1] = False  # one padded slot
    frames[-1, -1] = 0
    coeff_rows = np.where(valid[None], coeff[:, None, :], 0).astype(np.int32)
    parity = np.asarray(rs_encode(jnp.asarray(frames),
                                  jnp.asarray(coeff_rows)))
    assert parity.shape == (n, m, e)
    for j in range(n):
        slots = np.nonzero(valid[j])[0]
        erased = RNG.choice(slots, min(m, slots.size), replace=False)
        survivors = np.array([s for s in slots if s not in erased])
        w = rs_decode_weights(coeff, np.sort(erased), survivors,
                              np.arange(m))
        ext = np.concatenate([frames[j], parity[j]], 0)[None]
        for q, slot in enumerate(np.sort(erased)):
            rec = np.asarray(rs_decode(jnp.asarray(ext),
                                       jnp.asarray(w[q][None])))
            np.testing.assert_array_equal(rec[0], frames[j, slot])


def test_rs1_parity_matches_xor():
    part = partition_pytree(_params(), 16)
    params = _params()
    xor = _fabric(part, replicate=False)
    rs1 = _fabric(part, replicate=False, rs_parity=1)
    xor.maintain(2, params)
    rs1.maintain(2, params)
    np.testing.assert_array_equal(np.asarray(xor.parity.members),
                                  np.asarray(rs1.parity.members))
    np.testing.assert_array_equal(np.asarray(xor.parity.parity),
                                  np.asarray(rs1.parity.parity[:, 0]))


# ---------------------------------------------------------------------------
# multi-erasure recovery (the acceptance gate)
# ---------------------------------------------------------------------------

def test_rs_two_host_simultaneous_loss_bit_exact():
    """Any simultaneous two-host loss recovers through PARITY alone —
    bit-exact, zero perturbation, no RUNNING_CKPT fallback (the CI flag
    ``rs_recovery_bit_equal`` asserts the same invariant in the soak)."""
    params = _params()
    part = partition_pytree(params, 16)
    fab = _fabric(part, replicate=False, rs_parity=2)
    ckpt = _ckpt_like(params)
    fab.maintain(3, params)
    for h0 in range(4):
        for h1 in range(h0 + 1, 4):
            l0, f0 = fab.domain_failure("host", h0)
            l1, f1 = fab.domain_failure("host", h1)
            lost = l0 | l1
            failed = np.unique(np.concatenate([f0, f1]))
            rec, stats = fab.on_failure(params, ckpt, lost,
                                        failed_devices=failed, step=3,
                                        persist_failure=False)
            assert stats["tier_counts"]["PARITY"] == int(lost.sum())
            assert stats["tier_counts"]["RUNNING_CKPT"] == 0
            assert stats["tier_sq"]["PARITY"] == 0.0
            assert stats["tier_fallbacks"] == []
            for k in params:
                np.testing.assert_array_equal(np.asarray(rec[k]),
                                              np.asarray(params[k]))


def test_rs_controller_two_domain_events_zero_perturbation():
    """The controller's combined-event path: host + host in the same step
    resolve against the pre-failure view and recover in one pass."""
    from repro.core.controller import FTController
    from repro.core.policy import (CheckpointPolicy, RecoveryMode,
                                   SelectionStrategy)
    params = _params()
    pol = CheckpointPolicy(fraction=0.5, full_interval=4,
                           strategy=SelectionStrategy.ROUND_ROBIN,
                           recovery=RecoveryMode.PARTIAL)
    cfg = FabricConfig(n_devices=8, devices_per_host=2, hosts_per_rack=2,
                       use_pallas=False, rs_parity=2, replicate=False)
    ctl = FTController(params, pol, fabric=cfg)
    ctl.fabric.maintain(3, params)
    ctl.checkpoint_now(3, params)
    rec, info = ctl.on_domain_events(params, [("host", 0), ("host", 1)],
                                     step=3)
    assert info["applied_sq"] == 0.0
    assert info["tier_counts"]["RUNNING_CKPT"] == 0
    assert [e["kind"] for e in info["events"]] == ["host", "host"]
    for k in params:
        np.testing.assert_array_equal(np.asarray(rec[k]),
                                      np.asarray(params[k]))


def test_xor_two_host_fallback_pinned_baseline():
    """The XOR tier's pinned baseline for the same double loss: strength-1
    groups with two erasures fall back to RUNNING_CKPT/DISK, every one
    announced by a ``tier_fallback`` record (never silent), and the
    checkpoint staleness is priced honestly (‖δ′‖² > 0 vs a stale ckpt)."""
    from repro.telemetry.recorder import Recorder
    params = _params()
    part = partition_pytree(params, 16)
    rec = Recorder()
    cfg = FabricConfig(n_devices=8, devices_per_host=2, hosts_per_rack=2,
                       use_pallas=False, replicate=False)
    fab = CheckpointFabric(part, cfg, recorder=rec)
    ckpt = _ckpt_like(params)  # deliberately stale (zeros)
    fab.maintain(3, params)
    l0, f0 = fab.domain_failure("host", 0)
    l1, f1 = fab.domain_failure("host", 1)
    lost = l0 | l1
    failed = np.unique(np.concatenate([f0, f1]))
    out, stats = fab.on_failure(params, ckpt, lost, failed_devices=failed,
                                step=3, persist_failure=False)
    counts = stats["tier_counts"]
    # pinned: no group survives two erasures on the XOR code — every lost
    # block lands on the checkpoint tiers and pays staleness
    assert counts["PARITY"] == 0
    assert counts["RUNNING_CKPT"] + counts["DISK"] == int(lost.sum())
    assert stats["tier_sq"]["RUNNING_CKPT"] > 0.0
    assert len(stats["tier_fallbacks"]) > 0
    for fb in stats["tier_fallbacks"]:
        assert fb["lost_members"] > fb["strength"]
        assert set(fb) >= {"group", "lost_members", "unavailable",
                           "strength", "fresh"}
    kinds = [e["kind"] for e in rec.events]
    assert kinds.count("tier_fallback") == len(stats["tier_fallbacks"])
    assert fab.stats["tier_fallbacks"] == len(stats["tier_fallbacks"])


# ---------------------------------------------------------------------------
# silent-error integrity
# ---------------------------------------------------------------------------

def test_scrub_detects_localizes_corrects_member_flip():
    params = _params()
    part = partition_pytree(params, 16)
    fab = _fabric(part, rs_parity=2)
    ckpt = _ckpt_like(params)
    fab.maintain(4, params)
    where = fab.inject_arena_bit_flip(block=7, word=3, bit=19)
    out = fab.scrub(step=4)
    assert out["checked"] and out["detected"] == 1 and out["corrected"] == 1
    r = out["reports"][0]
    assert r["kind"] == "member" and r["block"] == where["block"]
    assert r["localized"] and r["corrected"]
    # corrected in place: a second pass is clean and a host loss recovers
    # the corrected snapshot bit-exactly
    assert fab.scrub(step=4)["detected"] == 0
    l0, f0 = fab.domain_failure("host", 0)
    rec, stats = fab.on_failure(params, ckpt, l0, failed_devices=f0,
                                step=4, persist_failure=False)
    for k in params:
        np.testing.assert_array_equal(np.asarray(rec[k]),
                                      np.asarray(params[k]))
    assert fab.stats["silent_errors_detected"] == 1
    assert fab.stats["silent_errors_corrected"] == 1


def test_scrub_detects_corrupted_parity_row():
    params = _params()
    part = partition_pytree(params, 16)
    fab = _fabric(part, rs_parity=2)
    fab.maintain(4, params)
    codec = fab.parity
    cur = int(np.asarray(codec.parity[2, 1, 5]))
    codec.parity = codec.parity.at[2, 1, 5].set(jnp.int32(cur ^ (1 << 9)))
    out = fab.scrub(step=4)
    assert out["detected"] == 1 and out["corrected"] == 1
    r = out["reports"][0]
    assert r["kind"] == "parity" and r["row"] == 1 and r["group"] == 2
    assert fab.scrub(step=4)["detected"] == 0


def test_scrub_m1_detects_without_localizing():
    params = _params()
    part = partition_pytree(params, 16)
    fab = _fabric(part, rs_parity=1)
    fab.maintain(4, params)
    fab.inject_arena_bit_flip(block=3, word=1, bit=4)
    out = fab.scrub(step=4)
    assert out["checked"] and out["detected"] == 1
    assert out["corrected"] == 0 and not out["reports"][0]["localized"]


def test_controller_scrub_prices_ledger():
    from repro.core.controller import FTController
    from repro.core.policy import (CheckpointPolicy, RecoveryMode,
                                   SelectionStrategy)
    from repro.telemetry.recorder import Recorder
    params = _params()
    rec = Recorder()
    pol = CheckpointPolicy(fraction=0.5, full_interval=4,
                           strategy=SelectionStrategy.ROUND_ROBIN,
                           recovery=RecoveryMode.PARTIAL)
    cfg = FabricConfig(n_devices=8, devices_per_host=2, hosts_per_rack=2,
                       use_pallas=False, rs_parity=2)
    ctl = FTController(params, pol, fabric=cfg, recorder=rec)
    ctl.fabric.maintain(4, params)
    ctl.fabric.inject_arena_bit_flip(block=1)
    out = ctl.scrub(step=4)
    assert out["detected"] == 1 and out["corrected"] == 1
    led = rec.ledger.summary()
    assert led["n_events"] == 1
    entry = rec.ledger.entries[-1]
    assert entry.applied_sq == 0.0
    assert entry.tier_counts == {"SILENT_ERROR": 1}
    assert any(e["kind"] == "silent_error_detected" for e in rec.events)


# ---------------------------------------------------------------------------
# train-loop soak plumbing (flip schedule + scrub cadence)
# ---------------------------------------------------------------------------

def test_train_loop_flip_schedule_and_scrub():
    from repro.configs import get_config
    from repro.core.policy import CheckpointPolicy
    from repro.data.pipeline import ShardedLMDataset
    from repro.sharding import single_device_ctx
    from repro.training import TrainLoop, TrainLoopConfig
    ctx = single_device_ctx()
    cfg = get_config("qwen2-1.5b", reduced=True)
    pol = CheckpointPolicy.scar(fraction=0.25, interval=2)
    loop = TrainLoop(cfg, ctx, loop_cfg=TrainLoopConfig(
        policy=pol, fabric=FabricConfig(rs_parity=2),
        # scrub every step: a corruption only survives until the next
        # maintenance sweep re-snapshots the arena over it, so the scrub
        # must land inside the same maintenance window as the flip
        flip_schedule=[3, (5, 2)], scrub_interval=1, seed=0))
    state = loop.init_state()
    ds = ShardedLMDataset(cfg, batch=2, seq=32, ctx=ctx)
    loop.run(state, iter(ds), 6)
    flips = [m for m in loop.metrics if "bit_flips" in m]
    scrubs = [m["scrub"] for m in loop.metrics if "scrub" in m]
    assert len(flips) == 2
    assert flips[1]["bit_flips"][0]["block"] == 2
    assert sum(s["detected"] for s in scrubs) == 2
    assert sum(s["corrected"] for s in scrubs) == 2


# ---------------------------------------------------------------------------
# store: parity mirror with 2-D homes + bounded background-write retry
# ---------------------------------------------------------------------------

def test_write_parity_rs_homes_roundtrip(tmp_path):
    from repro.checkpoint_io.store import ShardedCheckpointStore
    params = _params()
    part = partition_pytree(params, 16)
    fab = _fabric(part, rs_parity=2)
    fab.maintain(2, params)
    store = ShardedCheckpointStore(str(tmp_path / "mirror"))
    store.init(params, part, homes=fab.view.homes, domains=fab.domains)
    n = store.write_parity(2, np.asarray(fab.parity.parity),
                           fab.parity.parity_homes, domains=fab.domains,
                           members=fab.parity.members)
    assert n == np.asarray(fab.parity.parity).nbytes
    parity, meta = store.read_parity()
    np.testing.assert_array_equal(parity, np.asarray(fab.parity.parity))
    assert meta["n_parity"] == 2
    assert np.asarray(meta["parity_homes"]).shape == \
        fab.parity.parity_homes.shape


def test_store_background_write_retries_then_succeeds(tmp_path):
    from repro.checkpoint_io.store import ShardedCheckpointStore
    from repro.telemetry.recorder import Recorder
    params = _params(rows=64, width=4)
    part = partition_pytree(params, 16)
    store = ShardedCheckpointStore(str(tmp_path / "s"))
    store._retry_base_delay = 1e-4
    rec = Recorder()
    store.attach_recorder(rec)
    store.init(params, part)
    real = store._do_write
    fails = {"left": 2}

    def flaky(jobs, step):
        if fails["left"]:
            fails["left"] -= 1
            raise OSError("transient shared-fs blip")
        return real(jobs, step)

    store._do_write = flaky
    mask = jnp.ones((part.total_blocks,), bool)
    store.write_blocks(mask, params, step=1, background=True)
    store.flush()  # transient failures retried away — must not raise
    retried = [e for e in rec.events if e["kind"] == "store_write_retried"]
    assert len(retried) == 2
    assert [e["attempt"] for e in retried] == [1, 2]
    assert all(e["delay_seconds"] > 0 for e in retried)
    assert not [e for e in rec.events if e["kind"] == "store_write_failed"]
    store._do_write = real
    got = store.read_all()
    for k in params:
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(params[k]))


def test_store_background_write_fails_after_retry_budget(tmp_path):
    from repro.checkpoint_io.store import ShardedCheckpointStore
    from repro.telemetry.recorder import Recorder
    params = _params(rows=64, width=4)
    part = partition_pytree(params, 16)
    store = ShardedCheckpointStore(str(tmp_path / "s"))
    store._retry_base_delay = 1e-4
    rec = Recorder()
    store.attach_recorder(rec)
    store.init(params, part)

    def broken(jobs, step):
        raise OSError("disk truly gone")

    store._do_write = broken
    mask = jnp.ones((part.total_blocks,), bool)
    store.write_blocks(mask, params, step=1, background=True)
    with pytest.raises(RuntimeError,
                       match="background checkpoint write") as ei:
        store.flush()
    # the chained cause names the exhausted retry budget, then the root
    assert "attempts" in str(ei.value.__cause__)
    assert isinstance(ei.value.__cause__.__cause__, OSError)
    retried = [e for e in rec.events if e["kind"] == "store_write_retried"]
    assert len(retried) == store._retry_limit
    assert [e for e in rec.events if e["kind"] == "store_write_failed"]


# ---------------------------------------------------------------------------
# code advisor
# ---------------------------------------------------------------------------

def test_advise_code_prefers_cheapest_meeting_risk():
    from repro.core.advisor import advise_code
    (k, m), rep = advise_code({"host": 500.0}, window=4,
                              model_bytes=10_000_000, n_hosts=8,
                              target_risk=1e-4)
    assert rep["met_risk"]
    # rare failures: the cheapest feasible redundancy fraction wins
    assert m / k == min(mm / kk for kk in (2, 3, 4, 6)
                        for mm in (1, 2, 3) if kk + mm <= 8
                        and rep["table"][f"k={kk},m={mm}"]["risk"] <= 1e-4)


def test_advise_code_flags_unmet_risk_under_budget():
    from repro.core.advisor import advise_code
    (k, m), rep = advise_code({"host": 3.0}, window=6,
                              model_bytes=1_000_000,
                              budget_bytes=200_000, n_hosts=16)
    assert not rep["met_risk"]  # budget too tight for the failure rate —
    assert rep["risk"] > 1e-4   # reported, never silent
