"""On-disk mirror of the running checkpoint (paper §4.3 persistent storage).

Layout: one ``.npy`` file per parameter *block* (the unit of partial save /
restore), plus a JSON manifest recording the leaf geometry and which
iteration each block was last persisted. Writing only the selected blocks
gives the paper's property that a fraction-r checkpoint writes the same
bytes per C iterations as a full checkpoint.

Writes can be deferred to a background thread (``background=True``),
matching §4.3: "the training algorithm can be resumed as soon as the
in-memory caches have been updated, while output to the shared persistent
storage happens asynchronously".
"""
from __future__ import annotations

import json
import math
import os
import queue
import threading
from typing import Any, Optional

import jax
import numpy as np

from repro.core.blocks import BlockPartition

PyTree = Any


class ShardedCheckpointStore:
    def __init__(self, root: str):
        self.root = root
        self.partition: Optional[BlockPartition] = None
        self.must_reload = False
        self._q: "queue.Queue" = queue.Queue()
        self._worker: Optional[threading.Thread] = None
        self._worker_error: Optional[BaseException] = None
        os.makedirs(root, exist_ok=True)

    # -- lifecycle ----------------------------------------------------------

    def init(self, params: PyTree, partition: BlockPartition) -> None:
        self.partition = partition
        manifest = {
            "block_rows": partition.block_rows,
            "leaves": [
                {"name": l.name, "shape": list(l.shape), "dtype": str(np.dtype(l.dtype)),
                 "rows": l.rows, "row_width": l.row_width,
                 "n_blocks": l.n_blocks, "offset": l.offset}
                for l in partition.leaves
            ],
            "saved_iter": [0] * partition.total_blocks,
        }
        self._write_manifest(manifest)
        # initial full mirror (x^(0)) — the running checkpoint's base
        full_mask = np.ones((partition.total_blocks,), bool)
        self.write_blocks(full_mask, params, step=0, background=False)

    def _manifest_path(self) -> str:
        return os.path.join(self.root, "MANIFEST.json")

    def _write_manifest(self, manifest: dict) -> None:
        """Atomic replace: a crash mid-write can never leave a torn manifest
        (readers either see the old complete file or the new one)."""
        tmp = self._manifest_path() + ".tmp"
        with open(tmp, "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, self._manifest_path())

    def _block_path(self, gid: int) -> str:
        return os.path.join(self.root, f"block_{gid:08d}.npy")

    # -- write path ---------------------------------------------------------

    def write_blocks(self, mask, values: PyTree, step: int,
                     background: bool = True) -> int:
        """Persist the masked blocks. Returns bytes written (scheduled)."""
        assert self.partition is not None, "call init() first"
        mask_np = np.asarray(mask)
        # materialize only the selected blocks on host
        leaves = jax.tree_util.tree_leaves(values)
        jobs: list[tuple[int, np.ndarray]] = []
        nbytes = 0
        br = self.partition.block_rows
        for leaf_meta, x in zip(self.partition.leaves, leaves):
            seg = mask_np[leaf_meta.offset:leaf_meta.offset + leaf_meta.n_blocks]
            if not seg.any():
                continue
            arr = np.asarray(x).reshape(max(leaf_meta.rows, 1), -1)
            for b in np.nonzero(seg)[0]:
                lo, hi = b * br, min((b + 1) * br, leaf_meta.rows)
                blk = arr[lo:hi]
                jobs.append((leaf_meta.offset + int(b), blk))
                nbytes += blk.nbytes
        if background:
            self._ensure_worker()
            self._q.put(("write", jobs, step))
        else:
            self._do_write(jobs, step)
        return nbytes

    def _ensure_worker(self) -> None:
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    def _drain(self) -> None:
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                _, jobs, step = item
                self._do_write(jobs, step)
            except BaseException as e:  # keep draining; surface on flush()
                self._worker_error = e
            finally:
                # task_done even on failure — otherwise q.join() in flush()
                # deadlocks forever on the first bad write
                self._q.task_done()

    def _do_write(self, jobs, step: int) -> None:
        for gid, blk in jobs:
            # atomic like the manifest: a crash mid-overwrite must not tear
            # the previous good copy of the block
            path = self._block_path(gid)
            tmp = path + ".tmp"
            with open(tmp, "wb") as f:
                np.save(f, blk)
            os.replace(tmp, path)
        with open(self._manifest_path()) as f:
            manifest = json.load(f)
        for gid, _ in jobs:
            manifest["saved_iter"][gid] = int(step)
        self._write_manifest(manifest)

    def flush(self) -> None:
        """Block until all background writes have landed.

        Raises if any background write failed since the last flush — a
        silently-lost mirror write would otherwise surface only at recovery
        time, when the data is already gone.
        """
        if self._worker is not None and self._worker.is_alive():
            self._q.join()
        if self._worker_error is not None:
            err, self._worker_error = self._worker_error, None
            raise RuntimeError("background checkpoint write failed") from err

    # -- read path ----------------------------------------------------------

    def read_all(self) -> PyTree:
        """Reassemble the full running checkpoint from disk (total-failure
        recovery). Returns a flat list in leaf order; callers unflatten with
        the partition's treedef."""
        assert self.partition is not None
        self.flush()
        br = self.partition.block_rows
        out = []
        for leaf_meta in self.partition.leaves:
            rows = max(leaf_meta.rows, 1)
            arr = np.zeros((rows, leaf_meta.row_width), np.dtype(leaf_meta.dtype))
            for b in range(leaf_meta.n_blocks):
                p = self._block_path(leaf_meta.offset + b)
                if os.path.exists(p):
                    blk = np.load(p)
                    arr[b * br:b * br + blk.shape[0]] = blk
            out.append(arr.reshape(leaf_meta.shape))
        return jax.tree_util.tree_unflatten(self.partition.treedef, out)

    def saved_iters(self) -> np.ndarray:
        with open(self._manifest_path()) as f:
            return np.asarray(json.load(f)["saved_iter"], np.int32)
