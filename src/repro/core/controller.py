"""Fault-tolerance controller (paper §4.3, Figure 4).

Host-side orchestrator that owns the running checkpoint and drives:

1. *Checkpoint coordination* — every ``policy.partial_interval`` iterations,
   score blocks (priority), update the in-memory running checkpoint
   (jitted, device-resident), and mirror the saved blocks to persistent
   storage. Training resumes as soon as the in-memory cache is updated;
   the disk write is a background-able host callback (paper §4.3 step 4).
2. *Recovery coordination* — on a detected failure (a lost block mask),
   partially (or fully) restore from the running checkpoint. If the
   in-memory replica itself was lost (total failure), reload from the
   persistent store.
3. *Fabric coordination* (optional ``fabric=``) — maintain the tiered
   redundancy fabric (anti-affine peer replicas + XOR parity,
   :mod:`repro.fabric`) alongside the running checkpoint, and route
   ``on_failure`` through the tier planner so each lost block recovers
   from the cheapest surviving tier, with per-tier perturbation stats.

The controller is deliberately thin: all numerics are pure functions from
:mod:`repro.core.checkpoint` / :mod:`repro.core.recovery`, so it composes
with any training loop (including the big-model SPMD trainer).
"""
from __future__ import annotations

import time
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocks import (BlockPartition, block_scores,
                               partition_pytree, tree_sq_norm)
from repro.core.checkpoint import (RunningCheckpoint, full_save,
                                   init_running_checkpoint, save_step)
from repro.core.norms import get_norm
from repro.core.policy import CheckpointPolicy, RecoveryMode, SelectionStrategy
from repro.core.recovery import (apply_failure_and_recover,
                                 perturbation_norms, sample_failure_mask)

PyTree = Any


class FTController:
    """Checkpoint + recovery coordinator for one training job."""

    def __init__(self, params: PyTree, policy: CheckpointPolicy, *,
                 norm_aux: Optional[dict] = None,
                 store: Optional[Any] = None,
                 score_fn: Optional[Callable] = None,
                 rng: Optional[jax.Array] = None,
                 colocate: tuple = (),
                 fabric: Optional[Any] = None):
        self.policy = policy
        self.partition = partition_pytree(params, policy.block_rows,
                                          colocate=colocate)
        self.norm_fn = get_norm(policy.norm, aux=norm_aux,
                                block_rows=policy.block_rows)
        self.ckpt = init_running_checkpoint(params, self.partition)
        self.store = store
        self._score_fn = score_fn  # optional kernel-backed scorer
        self._rng = rng if rng is not None else jax.random.PRNGKey(0)
        # np generator for topology sampling, derived from the jax key
        # (key_data handles both legacy uint32 and typed key arrays)
        np_seed = int(np.asarray(
            jax.random.key_data(self._rng)).ravel()[-1])
        self._np_rng = np.random.default_rng(np_seed)
        # fabric: a CheckpointFabric, or a FabricConfig to build one over
        # this controller's partition (import deferred so fabric-less
        # controllers never pay the fabric/kernel import chain)
        if fabric is not None:
            from repro.fabric import CheckpointFabric, FabricConfig
            if isinstance(fabric, FabricConfig):
                fabric = CheckpointFabric(self.partition, fabric)
            if policy.recovery == RecoveryMode.FULL:
                # the tier planner is inherently partial (survivors keep
                # live values); a FULL-recovery baseline must not silently
                # degrade into it
                raise ValueError("fabric recovery is tiered/partial; use "
                                 "recovery=RecoveryMode.PARTIAL or drop "
                                 "the fabric for a FULL-recovery baseline")
        self.fabric = fabric
        self.stats = {"saves": 0, "recoveries": 0, "save_seconds": 0.0,
                      "blocks_saved": 0, "bytes_mirrored": 0}
        self._jit_save = jax.jit(partial(
            save_step, policy=self.policy, partition=self.partition,
            norm_fn=self.norm_fn))
        if store is not None:
            store.init(params, self.partition)

    # -- checkpoint path ----------------------------------------------------

    def should_checkpoint(self, step: int) -> bool:
        interval = (self.policy.full_interval
                    if self.policy.fraction >= 1.0
                    else self.policy.partial_interval)
        return step > 0 and step % interval == 0

    def maybe_checkpoint(self, step: int, params: PyTree) -> bool:
        if not self.should_checkpoint(step):
            return False
        self.checkpoint_now(step, params)
        return True

    def checkpoint_now(self, step: int, params: PyTree) -> jnp.ndarray:
        """Update the running checkpoint; returns the saved block mask."""
        t0 = time.perf_counter()
        if self.policy.fraction >= 1.0 and \
                self.policy.strategy != SelectionStrategy.PRIORITY:
            self.ckpt = full_save(self.ckpt, params, jnp.int32(step))
            mask = jnp.ones((self.partition.total_blocks,), bool)
        else:
            self._rng, sub = jax.random.split(self._rng)
            scores = None
            if self._score_fn is not None and \
                    self.policy.strategy == SelectionStrategy.PRIORITY:
                scores = self._score_fn(params, self.ckpt.values)
            self.ckpt, mask = self._jit_save(self.ckpt, params,
                                             jnp.int32(step), rng=sub,
                                             scores=scores)
        # block until the in-memory cache is consistent (paper: training may
        # resume now), then mirror to disk
        jax.block_until_ready(self.ckpt.values)
        self.stats["saves"] += 1
        self.stats["blocks_saved"] += int(jnp.sum(mask))
        self.stats["save_seconds"] += time.perf_counter() - t0
        if self.store is not None:
            self.stats["bytes_mirrored"] += self.store.write_blocks(
                mask, self.ckpt.values, step,
                background=self.policy.async_persist)
        if self.fabric is not None:
            # keep the redundancy tiers at least as fresh as the checkpoint
            self.fabric.maintain(int(step), params, force=True)
        return mask

    def maintain(self, step: int, params: PyTree) -> None:
        """Per-iteration fabric upkeep (replica refresh / parity re-encode
        on their configured intervals). No-op without a fabric."""
        if self.fabric is not None:
            self.fabric.maintain(int(step), params)

    # -- recovery path ------------------------------------------------------

    def sample_failure(self, fraction: float) -> jnp.ndarray:
        self._rng, sub = jax.random.split(self._rng)
        return sample_failure_mask(sub, self.partition, fraction)

    def sample_domain_failure(self, kind: str = "host",
                              ) -> tuple[np.ndarray, np.ndarray]:
        """Correlated whole-domain failure → (lost mask, failed devices).
        Requires a fabric (it owns the failure-domain topology)."""
        assert self.fabric is not None, "domain failures need a fabric"
        return self.fabric.sample_domain_failure(self._np_rng, kind)

    def on_failure(self, params: PyTree, lost_mask: jnp.ndarray,
                   failed_devices=None, step: Optional[int] = None,
                   ) -> tuple[PyTree, dict]:
        """Recover from a partial failure. Returns (params', diagnostics).

        With a fabric, recovery routes through the tier planner: each lost
        block resolves to the cheapest surviving redundancy tier, and the
        diagnostics gain per-tier block counts and perturbation norms.
        ``failed_devices`` names the dead devices of a correlated failure
        (None = the paper's uniform block-loss model).
        """
        ckpt = self.ckpt
        if self.store is not None and getattr(self.store, "must_reload", False):
            values = self.store.read_all()
            ckpt = RunningCheckpoint(values, ckpt.saved_iter, ckpt.rr_cursor)
        if self.fabric is not None:
            lost = np.asarray(lost_mask, bool)
            info = perturbation_norms(params, ckpt, jnp.asarray(lost),
                                      self.partition)
            recovered, tier_info = self.fabric.on_failure(
                params, ckpt.values, lost,
                failed_devices=failed_devices, step=step,
                disk_reader=(self.store.read_all if self.store is not None
                             else None))
            info["applied_sq"] = tree_sq_norm(recovered, params)
            info["lost_blocks"] = int(lost.sum())
            info.update(tier_info)
        else:
            recovered, info = apply_failure_and_recover(
                params, ckpt, lost_mask, self.policy.recovery, self.partition)
        self.stats["recoveries"] += 1
        return recovered, {k: (float(v) if hasattr(v, "item") else v)
                           for k, v in info.items()}

    # -- analysis helpers ---------------------------------------------------

    def block_drift(self, params: PyTree) -> jnp.ndarray:
        """Per-block distance between live params and the running ckpt."""
        return block_scores(params, self.ckpt.values, self.partition,
                            self.norm_fn)
