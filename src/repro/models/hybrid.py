"""Zamba2-style hybrid: Mamba2 backbone + a *shared* attention block
[arXiv:2411.15242].

The backbone is ``n_layers`` Mamba2 mixers; one transformer block (GQA
attention + MLP) with a single set of weights is applied every
``cfg.attn_every`` backbone layers (weight re-use is the Zamba2 trick that
keeps the attention parameter cost of a 1.2B model negligible).

Layer schedule (n_layers=38, attn_every=6): segments of 6 mamba layers
separated by applications of the shared block — the segment loop is an
unrolled python loop over ``lax.scan`` segments, keeping HLO size small.

State for serving = per-layer SSM states + ONE KV cache (the shared block
sees the sequence once per application; we cache per application slot).
For simplicity and memory-boundedness, the serve path applies the shared
attention block with a ring/linear cache per slot exactly like the dense
decode path.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import transformer as T
from repro.sharding.partition import DistContext

PyTree = Any


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def n_segments(cfg: ModelConfig) -> int:
    return -(-cfg.n_layers // cfg.attn_every)


def init_params(rng, cfg: ModelConfig) -> PyTree:
    k_embed, k_layers, k_shared = jax.random.split(rng, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    return {
        **L.init_embed(k_embed, cfg, _dtype(cfg)),
        "layers": jax.vmap(lambda k: S.init_layer(k, cfg))(layer_keys),
        "shared": T.init_layer(k_shared, cfg),   # attention + MLP block
        "final_norm": jnp.ones((cfg.d_model,), _dtype(cfg)),
    }


def _segments(cfg: ModelConfig):
    """Static (start, length) list of backbone segments."""
    segs, start = [], 0
    while start < cfg.n_layers:
        ln = min(cfg.attn_every, cfg.n_layers - start)
        segs.append((start, ln))
        start += ln
    return segs


def _slice_layers(layers: PyTree, start: int, length: int) -> PyTree:
    return jax.tree_util.tree_map(
        lambda x: jax.lax.slice_in_dim(x, start, start + length, axis=0), layers)


def train_loss(params, batch, cfg: ModelConfig, ctx: DistContext, **_):
    h = L.embed_tokens(batch["tokens"], params, ctx)
    h = ctx.shard(h, "dp", None, None)
    Bsz, Sq = batch["tokens"].shape
    positions = jnp.arange(Sq)

    def mamba_body(x, lp):
        fn = S.mixer_fwd
        if cfg.remat:
            fn = jax.checkpoint(S.mixer_fwd, static_argnums=(2, 3),
                                policy=jax.checkpoint_policies.nothing_saveable)
        x = x + fn(L.rms_norm(x, lp["norm"]), lp["mixer"], cfg, ctx)
        return ctx.shard(x, "dp", ctx.tp, None), None

    shared_call = lambda x: T._layer_fwd(x, params["shared"], cfg, ctx,
                                         positions, window=0, q_chunk=1024,
                                         kv_chunk=1024)
    if cfg.remat:
        shared_call = jax.checkpoint(
            shared_call, policy=jax.checkpoint_policies.nothing_saveable)
    for (start, length) in _segments(cfg):
        h, _ = jax.lax.scan(mamba_body, h,
                            _slice_layers(params["layers"], start, length),
                            unroll=L.UNROLL_FOR_COSTING)
        h, _ = shared_call(h)
        h = ctx.shard(h, "dp", ctx.tp, None)
    h = L.rms_norm(h, params["final_norm"])
    mask = batch.get("mask", jnp.ones_like(batch["labels"], jnp.float32))
    return L.lm_loss_chunked(h, params, batch["labels"], mask, cfg, ctx)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_state(cfg: ModelConfig, batch: int, cache_len: int,
               ctx: DistContext) -> PyTree:
    nseg = n_segments(cfg)
    Hk, Dh = cfg.n_kv_heads, cfg.head_dim
    # batch-shardable shapes shard the cache on batch; long-context B=1
    # decode shards the cache *length* over the data axes instead
    # (sequence-parallel KV, see DESIGN.md)
    if ctx.batch_shardable:
        kv_spec = (None, "dp", None, ctx.tp, None)
    else:
        kv_spec = (None, None, ctx.raw_dp_spec, ctx.tp, None)
    return {
        "ssm": S.init_state(cfg, batch, ctx),
        # one KV cache per shared-block application slot
        "k": ctx.shard(jnp.zeros((nseg, batch, cache_len, Hk, Dh), _dtype(cfg)),
                       *kv_spec),
        "v": ctx.shard(jnp.zeros((nseg, batch, cache_len, Hk, Dh), _dtype(cfg)),
                       *kv_spec),
        "kpos": jnp.full((cache_len,), -1, jnp.int32),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(params, state, tokens, cfg: ModelConfig, ctx: DistContext,
                spec=None):
    x = L.embed_tokens(tokens, params, ctx)
    x = ctx.shard(x, "dp", None, None)
    pos = state["pos"]
    positions = pos[None] + jnp.zeros((1,), jnp.int32)
    cache_len = state["k"].shape[2]
    slot = pos % cache_len
    kpos = state["kpos"].at[slot].set(pos)
    ssm = state["ssm"]

    def mamba_body(x, xs):
        lp, hs, cs = xs
        out, new = S.mixer_decode(L.rms_norm(x, lp["norm"]), lp["mixer"],
                                  {"h": hs, "conv": cs}, cfg, ctx)
        return x + out, (new["h"], new["conv"])

    new_h, new_conv, new_k, new_v = [], [], [], []
    lp_sh = params["shared"]
    for si, (start, length) in enumerate(_segments(cfg)):
        seg_layers = _slice_layers(params["layers"], start, length)
        seg_h = jax.lax.slice_in_dim(ssm["h"], start, start + length, axis=0)
        seg_c = jax.lax.slice_in_dim(ssm["conv"], start, start + length, axis=0)
        x, (hs, cs) = jax.lax.scan(mamba_body, x, (seg_layers, seg_h, seg_c),
                                   unroll=L.UNROLL_FOR_COSTING)
        new_h.append(hs)
        new_conv.append(cs)
        # shared attention block over this segment's cache slot
        xn = L.rms_norm(x, lp_sh["attn_norm"])
        q, k, v = L.qkv_project(xn, lp_sh["attn"], cfg, ctx, positions)
        kc = jax.lax.dynamic_update_slice_in_dim(state["k"][si], k, slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(state["v"][si], v, slot, axis=1)
        o = L.flash_attention(q, kc, vc, positions, kpos, causal=True,
                              window=0, q_chunk=1,
                              kv_chunk=min(1024, cache_len), ctx=ctx)
        a = jnp.einsum("bshk,hkd->bsd", o, lp_sh["attn"]["wo"])
        x = x + ctx.shard(a, "dp", None, None)
        x = x + L.mlp_block(L.rms_norm(x, lp_sh["mlp_norm"]), lp_sh["mlp"], ctx)
        new_k.append(kc)
        new_v.append(vc)

    h = L.rms_norm(x, params["final_norm"])
    logits = L.lm_logits(h, params, ctx)
    new_state = {
        "ssm": {"h": jnp.concatenate(new_h, axis=0),
                "conv": jnp.concatenate(new_conv, axis=0),
                "pos": ssm["pos"] + 1},
        "k": jnp.stack(new_k), "v": jnp.stack(new_v),
        "kpos": kpos, "pos": pos + 1,
    }
    return logits, new_state


def prefill(params, batch, cfg: ModelConfig, ctx: DistContext, spec=None):
    """Prefill: chunked SSD over the prompt + shared-block KV caches."""
    tokens = batch["tokens"]
    h = L.embed_tokens(tokens, params, ctx)
    h = ctx.shard(h, "dp", None, None)
    Bsz, Sq = tokens.shape
    positions = jnp.arange(Sq)

    def mamba_body(x, lp):
        xn = L.rms_norm(x, lp["norm"])
        p = lp["mixer"]
        zxbcdt = jnp.einsum("bsd,de->bse", xn, p["in_proj"])
        z, xi, Bm, Cm, dtr = S._split_proj(zxbcdt, cfg)
        xi, conv_state = S._causal_conv(xi, p["conv_w"])
        H, P = cfg.ssm_heads, cfg.ssm_headdim
        xh = xi.reshape(Bsz, Sq, H, P).astype(jnp.float32)
        dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])
        A = -jnp.exp(p["A_log"])
        y, h_fin = S.ssd_chunked(xh, dt, A, Bm.astype(jnp.float32),
                                 Cm.astype(jnp.float32), cfg, ctx)
        y = y + xh * p["D_skip"][:, None]
        y = y.reshape(Bsz, Sq, cfg.d_inner).astype(x.dtype) * jax.nn.silu(z)
        out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
        return x + ctx.shard(out, "dp", None, None), (h_fin, conv_state)

    lp_sh = params["shared"]
    hs_all, conv_all, k_all, v_all = [], [], [], []
    for (start, length) in _segments(cfg):
        h, (hs, cs) = jax.lax.scan(mamba_body, h,
                                   _slice_layers(params["layers"], start, length))
        hs_all.append(hs)
        conv_all.append(cs)
        xn = L.rms_norm(h, lp_sh["attn_norm"])
        q, k, v = L.qkv_project(xn, lp_sh["attn"], cfg, ctx, positions)
        o = L.flash_attention(q, k, v, positions, positions, causal=True,
                              window=0, q_chunk=min(1024, Sq),
                              kv_chunk=min(1024, Sq), ctx=ctx)
        a = jnp.einsum("bshk,hkd->bsd", o, lp_sh["attn"]["wo"])
        h = h + ctx.shard(a, "dp", None, None)
        h = h + L.mlp_block(L.rms_norm(h, lp_sh["mlp_norm"]), lp_sh["mlp"], ctx)
        k_all.append(k.astype(_dtype(cfg)))
        v_all.append(v.astype(_dtype(cfg)))

    hfin = L.rms_norm(h, params["final_norm"])
    logits = L.lm_logits(hfin[:, -1:], params, ctx)
    slack = 64                 # room for subsequently generated tokens
    ks = jnp.stack(k_all)
    vs = jnp.stack(v_all)
    zk = jnp.zeros(ks.shape[:2] + (slack,) + ks.shape[3:], ks.dtype)
    ks = jnp.concatenate([ks, zk], axis=2)
    vs = jnp.concatenate([vs, zk], axis=2)
    kpos = jnp.concatenate([jnp.arange(Sq, dtype=jnp.int32),
                            jnp.full((slack,), -1, jnp.int32)])
    state = {
        "ssm": {"h": jnp.concatenate(hs_all, 0),
                "conv": jnp.concatenate(conv_all, 0),
                "pos": jnp.asarray(Sq, jnp.int32)},
        "k": ks, "v": vs,
        "kpos": kpos, "pos": jnp.asarray(Sq, jnp.int32),
    }
    return logits, state
