"""Word-level quantized arena (bf16/fp8/int8) with tail packing.

Covers the quantized-arena subsystem end to end:
- ``pack_arena ∘ unpack_arena`` is bit-exact for every word-packable
  dtype (f32/bf16/f16/fp8/int8/int16/int32/uint8), any shape —
  invariant I3; property tests when hypothesis is available
  (import-guarded, never a hard dependency),
- the tail-packed layout satisfies the word-level invariants I1–I4
  (tile-aligned main region, word-contiguous tail, exact disjoint
  coverage, zero pad words *and* zero sub-word pad bits),
- the value domain: ``decode_values`` matches per-leaf ``astype(f32)``,
  ``encode ∘ decode`` is the arena identity, and ``pack_values`` agrees
  with decoding a packed arena,
- a mixed-dtype model (f32 + bf16 + f16 + int8 + fp8 when available)
  survives a correlated host loss bit-exactly through PEER_REPLICA and,
  on a parity-only fabric, through PARITY — zero perturbation, raw
  words restored, no ``.astype`` round trip anywhere in the path,
- the RS integrity scrub detects, localizes and corrects an injected
  bit flip on a quantized arena, and recovery afterwards is bit-exact,
- a bf16 model's redundancy bytes are ≤ 0.55× the f32 layout of the
  same shapes (the test twin of the ``quant_bytes_le_half_f32`` CI
  gate), and the ``arena_padding_ratio`` gauge surfaces through fabric
  stats and the telemetry run report.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.arena import (ARENA_TILE, arena_compatible,
                              build_arena_layout, decode_values,
                              encode_values, pack_arena, pack_values,
                              unpack_arena)
from repro.core.blocks import partition_pytree, word_packable
from repro.fabric import CheckpointFabric, FabricConfig

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # no pip install in this environment: the
    HAVE_HYPOTHESIS = False  # property tests below are skipped, not failed

    def given(*a, **k):      # decorator stubs so the module still imports
        return lambda f: f

    def settings(*a, **k):
        return lambda f: f

    class _St:
        @staticmethod
        def integers(lo, hi):
            return None
    st = _St()

RNG = np.random.default_rng(23)

FP8 = getattr(jnp, "float8_e4m3fn", None)

# every word-packable dtype the arena admits (fp8 only on jax builds
# that ship ml_dtypes' float8 family)
PACKABLE = [jnp.float32, jnp.bfloat16, jnp.float16,
            jnp.int8, jnp.int16, jnp.int32, jnp.uint8]
if FP8 is not None:
    PACKABLE.append(FP8)


def _leaf(shape, dtype, rng):
    """Random finite leaf with bit patterns representable in ``dtype``."""
    dt = np.dtype(dtype)
    if dt.kind in "iu":
        lo, hi = (0, 200) if dt.kind == "u" else (-100, 100)
        return jnp.asarray(rng.integers(lo, hi, shape), dtype)
    return jnp.asarray(rng.normal(size=shape), jnp.float32).astype(dtype)


def _mixed_params(rng=None, with_int=True):
    """Mixed-dtype model: multi-block 2D leaves, a tail 1-D leaf, a
    scalar — every region and width class of the layout."""
    rng = rng or np.random.default_rng(7)
    p = {"w32": _leaf((96, 6), jnp.float32, rng),
         "wbf": _leaf((64, 6), jnp.bfloat16, rng),
         "h16": _leaf((48, 6), jnp.float16, rng),
         "b": _leaf((7,), jnp.float32, rng),
         "s": _leaf((), jnp.bfloat16, rng)}
    if with_int:
        p["q8"] = _leaf((40, 6), jnp.int8, rng)
    if FP8 is not None:
        p["e4m3"] = _leaf((32, 6), FP8, rng)
    return p


def _fabric(part, **kw):
    cfg = FabricConfig(n_devices=8, devices_per_host=2, hosts_per_rack=2,
                       use_pallas=False, **kw)
    return CheckpointFabric(part, cfg)


def _bits_equal(a, b):
    a, b = np.asarray(a), np.asarray(b)
    assert a.shape == b.shape and a.dtype == b.dtype
    assert a.tobytes() == b.tobytes()


# ---------------------------------------------------------------------------
# I3: pack/unpack round trip per dtype
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", PACKABLE,
                         ids=[np.dtype(d).name for d in PACKABLE])
def test_pack_unpack_roundtrip_bit_exact(dtype):
    rng = np.random.default_rng(3)
    tree = {"w": _leaf((24, 6), dtype, rng),     # multi-block main leaf
            "v": _leaf((5,), dtype, rng),        # tail, sub-word ragged
            "s": _leaf((), dtype, rng)}          # scalar tail
    part = partition_pytree(tree, 8)
    assert arena_compatible(part) and word_packable(dtype)
    lay = build_arena_layout(part)
    out = unpack_arena(pack_arena(tree, lay), lay)
    for k in tree:
        _bits_equal(out[k], tree[k])


def test_roundtrip_extreme_bit_patterns():
    """Denormals, infs, NaNs, sign-zero, INT_MIN: the arena moves raw
    words, so even non-finite payloads round-trip bit-exactly."""
    f32 = np.array([0.0, -0.0, np.inf, -np.inf, np.nan, 1e-42, -1e-42,
                    np.finfo(np.float32).max], np.float32)
    bf = np.arange(8, dtype=np.uint16)
    bf = (bf * 8191 + 3).astype(np.uint16).view(jnp.bfloat16.dtype)
    i8 = np.array([-128, -1, 0, 1, 127], np.int8)
    tree = {"f": jnp.asarray(f32), "b": jnp.asarray(bf),
            "i": jnp.asarray(i8)}
    part = partition_pytree(tree, 8)
    lay = build_arena_layout(part)
    out = unpack_arena(pack_arena(tree, lay), lay)
    for k in tree:
        _bits_equal(out[k], tree[k])


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=25, deadline=None)
@given(st.integers(1, 40), st.integers(1, 9), st.integers(0, 2 ** 31 - 1))
def test_roundtrip_property_mixed_shapes(rows, width, seed):
    rng = np.random.default_rng(seed)
    dtypes = [PACKABLE[int(rng.integers(len(PACKABLE)))] for _ in range(3)]
    tree = {"a": _leaf((rows, width), dtypes[0], rng),
            "b": _leaf((max(1, rows // 3),), dtypes[1], rng),
            "c": _leaf((), dtypes[2], rng)}
    part = partition_pytree(tree, 8)
    lay = build_arena_layout(part)
    out = unpack_arena(pack_arena(tree, lay), lay)
    for k in tree:
        _bits_equal(out[k], tree[k])
    # and the unaligned layout agrees
    lay2 = build_arena_layout(part, tail_pack=False)
    out2 = unpack_arena(pack_arena(tree, lay2), lay2)
    for k in tree:
        _bits_equal(out2[k], tree[k])


# ---------------------------------------------------------------------------
# I1/I2/I4: tail-packed layout invariants
# ---------------------------------------------------------------------------

def test_tail_packed_layout_invariants():
    tree = _mixed_params()
    part = partition_pytree(tree, 16)
    lay = build_arena_layout(part)
    assert lay.has_tail and not lay.uniform_f32

    # I1 — alignment classes
    assert lay.tail_start % ARENA_TILE == 0
    assert lay.data_words % ARENA_TILE == 0
    assert lay.total_words % ARENA_TILE == 0
    for ab in lay.blocks:
        if ab.offset < lay.tail_start:
            assert ab.offset % ARENA_TILE == 0
            assert ab.words % ARENA_TILE == 0
            assert 0 < ab.payload <= ab.words
        else:
            assert ab.words == ab.payload > 0  # word-contiguous tail

    # I2 — disjoint segments covering [0, data_words) except the
    # tail-alignment gap [tail_end, data_words)
    cover = np.zeros(lay.data_words, np.int32)
    for ab in lay.blocks:
        cover[ab.offset:ab.offset + ab.words] += 1
    assert cover.max() == 1
    uncovered = np.nonzero(cover == 0)[0]
    np.testing.assert_array_equal(uncovered,
                                  np.arange(lay.tail_end, lay.data_words))

    # I4 — pad words are zero after pack, and sub-word element pads are
    # zero *bits* (check at byte granularity through an int8 view)
    arena = np.asarray(pack_arena(tree, lay)).view(np.int32)
    payload_bytes = np.zeros(lay.total_words * 4, bool)
    for ab in lay.blocks:
        esz = np.dtype(part.leaves[ab.leaf].dtype).itemsize
        live = int(lay.payload_elems[ab.leaf]) * esz
        b0 = ab.offset * 4
        payload_bytes[b0:b0 + live] = True
    abytes = arena.view(np.int8)
    assert abytes.size == payload_bytes.size
    np.testing.assert_array_equal(abytes[~payload_bytes], 0)
    # and whole pad words in particular
    word_live = payload_bytes.reshape(-1, 4).any(axis=1)
    np.testing.assert_array_equal(arena[~word_live], 0)


def test_tail_pack_shrinks_layout():
    """Tail packing strictly shrinks a small-leaf-heavy model and the
    padding_ratio gauge reflects it."""
    rng = np.random.default_rng(5)
    tree = {f"s{i}": _leaf((3 + i,), jnp.float32, rng) for i in range(6)}
    part = partition_pytree(tree, 16)
    packed = build_arena_layout(part)
    aligned = build_arena_layout(part, tail_pack=False)
    assert packed.total_words < aligned.total_words
    assert packed.padding_ratio < aligned.padding_ratio
    assert not aligned.has_tail and packed.has_tail


# ---------------------------------------------------------------------------
# value domain (optimizer seam)
# ---------------------------------------------------------------------------

def test_decode_encode_value_domain():
    tree = _mixed_params(with_int=False)  # float leaves: values meaningful
    part = partition_pytree(tree, 16)
    lay = build_arena_layout(part)
    arena = pack_arena(tree, lay)
    vals = decode_values(arena, lay)
    assert vals.shape == (lay.total_values,) and vals.dtype == jnp.float32
    v = np.asarray(vals)
    # encode ∘ decode is the identity on the arena (bit-exact)
    back = encode_values(vals, lay)
    _bits_equal(np.asarray(back), np.asarray(arena))
    # pack_values agrees with decoding a packed arena
    gv = pack_values(tree, lay)
    np.testing.assert_array_equal(np.asarray(gv), v)


def test_decode_values_matches_astype_f32():
    """Per-leaf semantics: the decoded f32 values of a bf16 leaf are
    exactly ``leaf.astype(float32)`` (widening, hence lossless)."""
    rng = np.random.default_rng(17)
    w = _leaf((16, 6), jnp.bfloat16, rng)
    part = partition_pytree({"w": w}, 16)
    lay = build_arena_layout(part)
    vals = np.asarray(decode_values(pack_arena({"w": w}, lay), lay))
    want = np.asarray(w).astype(np.float32).ravel()
    np.testing.assert_array_equal(vals[:want.size], want)
    np.testing.assert_array_equal(vals[want.size:], 0.0)


def test_value_domain_identity_for_f32():
    rng = np.random.default_rng(9)
    tree = {"w": _leaf((64, 6), jnp.float32, rng),
            "b": _leaf((7,), jnp.float32, rng)}
    part = partition_pytree(tree, 16)
    lay = build_arena_layout(part)
    assert lay.uniform_f32 and lay.total_values == lay.total_words
    arena = pack_arena(tree, lay)
    _bits_equal(np.asarray(decode_values(arena, lay)), np.asarray(arena))


# ---------------------------------------------------------------------------
# mixed-dtype recovery: PEER_REPLICA and PARITY, bit-exact
# ---------------------------------------------------------------------------

def test_mixed_dtype_host_loss_recovers_bit_exact_peer_replica():
    params = _mixed_params()
    part = partition_pytree(params, 16)
    fab = _fabric(part)
    ckpt = {k: jnp.zeros_like(v) for k, v in params.items()}
    fab.maintain(3, params)
    for h in range(4):
        lost, failed = fab.domain_failure("host", h)
        rec, stats = fab.on_failure(params, ckpt, lost,
                                    failed_devices=failed, step=3,
                                    persist_failure=False)
        assert stats["tier_counts"]["PEER_REPLICA"] == int(lost.sum()) > 0
        assert stats["tier_counts"]["RUNNING_CKPT"] == 0
        for k in params:
            _bits_equal(rec[k], params[k])


def test_mixed_dtype_singly_erased_recovers_bit_exact_parity():
    """XOR parity over raw words: one erased member per group XORs back
    bit-exactly — for bf16/fp8/int8 payloads just as for f32 (the words
    are opaque bit patterns to the codec)."""
    params = _mixed_params()
    part = partition_pytree(params, 16)
    fab = _fabric(part, replicate=False)
    ckpt = {k: jnp.zeros_like(v) for k, v in params.items()}
    fab.maintain(3, params)
    # deterministic singly-erased loss: the first member of each group
    members = np.asarray(fab.parity.members)
    lost = np.zeros((part.total_blocks,), bool)
    for row in members:
        ids = row[row >= 0]
        if ids.size:
            lost[ids[0]] = True
    rec, stats = fab.on_failure(params, ckpt, lost,
                                failed_devices=np.empty((0,), np.int32),
                                step=3, persist_failure=False)
    assert stats["tier_counts"]["PARITY"] == int(lost.sum()) > 0
    assert stats["tier_counts"]["RUNNING_CKPT"] == 0
    assert stats["tier_sq"]["PARITY"] == 0.0
    for k in params:
        _bits_equal(rec[k], params[k])


def test_mixed_dtype_rs_two_host_loss_bit_exact():
    """RS(k, 2) over a quantized arena: simultaneous two-host loss
    decodes through GF(256) on raw words — bit-exact for every dtype."""
    params = _mixed_params()
    part = partition_pytree(params, 16)
    fab = _fabric(part, replicate=False, rs_parity=2)
    ckpt = {k: jnp.zeros_like(v) for k, v in params.items()}
    fab.maintain(3, params)
    l0, f0 = fab.domain_failure("host", 0)
    l1, f1 = fab.domain_failure("host", 2)
    lost = l0 | l1
    failed = np.unique(np.concatenate([f0, f1]))
    rec, stats = fab.on_failure(params, ckpt, lost, failed_devices=failed,
                                step=3, persist_failure=False)
    assert stats["tier_counts"]["PARITY"] == int(lost.sum())
    assert stats["tier_fallbacks"] == []
    for k in params:
        _bits_equal(rec[k], params[k])


# ---------------------------------------------------------------------------
# integrity scrub on a quantized arena
# ---------------------------------------------------------------------------

def test_scrub_detects_and_corrects_on_quantized_arena():
    params = _mixed_params()
    part = partition_pytree(params, 16)
    fab = _fabric(part, rs_parity=2)
    ckpt = {k: jnp.zeros_like(v) for k, v in params.items()}
    fab.maintain(4, params)
    where = fab.inject_arena_bit_flip(block=3, word=2, bit=11)
    out = fab.scrub(step=4)
    assert out["checked"] and out["detected"] == 1 and out["corrected"] == 1
    r = out["reports"][0]
    assert r["kind"] == "member" and r["block"] == where["block"]
    assert r["localized"] and r["corrected"]
    assert fab.scrub(step=4)["detected"] == 0
    # corrected snapshot recovers a host loss bit-exactly afterwards
    lost, failed = fab.domain_failure("host", 1)
    rec, _ = fab.on_failure(params, ckpt, lost, failed_devices=failed,
                            step=4, persist_failure=False)
    for k in params:
        _bits_equal(rec[k], params[k])


# ---------------------------------------------------------------------------
# redundancy bytes + padding gauge (CI gate twins)
# ---------------------------------------------------------------------------

def test_bf16_redundancy_bytes_le_half_f32():
    """Layout-level twin of the ``quant_bytes_le_half_f32`` bench gate:
    the same shapes in bf16 need ≤ 0.55× the f32 arena bytes (the slack
    absorbs tile-alignment padding)."""
    rng = np.random.default_rng(13)
    # tile-width blocks (16·128 elems): the precision halving is not
    # swallowed by per-block tile alignment, as in a real model
    shapes = [("w1", (256, 128)), ("w2", (96, 128)), ("b", (9,))]
    t32 = {k: _leaf(s, jnp.float32, rng) for k, s in shapes}
    t16 = {k: _leaf(s, jnp.bfloat16, rng) for k, s in shapes}
    lay32 = build_arena_layout(partition_pytree(t32, 16))
    lay16 = build_arena_layout(partition_pytree(t16, 16))
    assert lay16.nbytes <= 0.55 * lay32.nbytes
    # and the fabric's per-sweep bytes shrink accordingly
    f32 = _fabric(partition_pytree(t32, 16))
    f16 = _fabric(partition_pytree(t16, 16))
    f32.maintain(1, t32)
    f16.maintain(1, t16)
    assert f16.stats["maintain_bytes_moved"] <= \
        0.55 * f32.stats["maintain_bytes_moved"] + 4 * ARENA_TILE


def test_padding_ratio_gauge_in_stats_and_report():
    from repro.telemetry.recorder import Recorder
    from repro.telemetry.report import format_report, run_report
    params = _mixed_params()
    part = partition_pytree(params, 16)
    rec = Recorder()
    cfg = FabricConfig(n_devices=8, devices_per_host=2, hosts_per_rack=2,
                       use_pallas=False)
    fab = CheckpointFabric(part, cfg, recorder=rec)
    assert fab.arena_layout is not None
    want = float(fab.arena_layout.padding_ratio)
    assert fab.stats["arena_padding_ratio"] == want > 0.0
    fab.maintain(1, params)
    report = run_report(rec)
    assert report["bytes"]["arena_padding_ratio"] == want
    assert "arena padding ratio" in format_report(report)
