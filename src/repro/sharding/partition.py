"""Distribution context + parameter partition specs.

``DistContext`` is threaded through every model forward. It carries the mesh
and the logical→mesh axis mapping, and degrades gracefully to a no-op on a
single device (smoke tests) so model code is written once:

- ``ctx.shard(x, *axes)``      — with_sharding_constraint, or identity.
- ``ctx.dp`` / ``ctx.tp``      — the batch (data-parallel) mesh axes and the
                                 tensor/model-parallel axis name.
- ``ctx.moe_shard_map(fn,...)``— helper to run the expert-parallel MoE body
                                 under shard_map over the model axis.

Parameter partition specs (FSDP + TP hybrid, MaxText-style):

- 2-D weights (d_in, d_out): TP on the "wide" axis, FSDP (data) on the other.
- embeddings (V, D): vocab on TP, D on data.
- expert weights (E, d_in, d_out): experts on TP, d_in on data (FSDP).
- biases / norms / small vectors: replicated.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


@dataclasses.dataclass(frozen=True)
class DistContext:
    mesh: Optional[Mesh] = None
    dp: tuple[str, ...] = ("data",)   # batch axes (("pod","data") multi-pod)
    tp: Optional[str] = "model"
    batch_shardable: bool = True      # False for global batch < |dp| (long_500k)
    expert_fsdp: bool = True          # False: expert weights expert-parallel only

    @property
    def dp_spec(self):
        """Batch-dim spec component (None when batch cannot be sharded)."""
        if not self.batch_shardable or not self.dp:
            return None
        return self.dp if len(self.dp) > 1 else self.dp[0]

    @property
    def raw_dp_spec(self):
        """Batch-axes spec regardless of batch_shardable (for sharding a
        long sequence/cache dim when the batch itself cannot be split)."""
        if not self.dp:
            return None
        return self.dp if len(self.dp) > 1 else self.dp[0]

    @property
    def tp_size(self) -> int:
        if self.mesh is None or self.tp is None:
            return 1
        return self.mesh.shape[self.tp]

    def shard(self, x: jnp.ndarray, *axes) -> jnp.ndarray:
        """with_sharding_constraint(x, P(*axes)); no-op without a mesh.

        ``axes`` entries: None, an axis name, a tuple of axis names, or the
        sentinel "dp" which expands to the batch axes (or None). Axis
        assignments that don't divide the dim are dropped (e.g. 12 heads
        over model=16)."""
        if self.mesh is None:
            return x
        sizes = dict(self.mesh.shape)
        resolved = []
        for dim, a in zip(x.shape, axes):
            a = self.dp_spec if a == "dp" else a
            if a is None:
                resolved.append(None)
                continue
            group = a if isinstance(a, tuple) else (a,)
            total = 1
            for ax in group:
                total *= sizes[ax]
            resolved.append(a if dim % total == 0 else None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*resolved)))

    def psum_tp(self, x: jnp.ndarray) -> jnp.ndarray:
        if self.mesh is None or self.tp is None:
            return x
        return jax.lax.psum(x, self.tp)


def single_device_ctx() -> DistContext:
    return DistContext(mesh=None, dp=(), tp=None)


def make_dist_ctx(mesh: Mesh, batch_shardable: bool = True) -> DistContext:
    names = mesh.axis_names
    dp = tuple(a for a in names if a in ("pod", "data"))
    tp = "model" if "model" in names else None
    return DistContext(mesh=mesh, dp=dp, tp=tp, batch_shardable=batch_shardable)


# ---------------------------------------------------------------------------
# Parameter partition specs
# ---------------------------------------------------------------------------

def _spec_for_leaf(name: str, shape: tuple[int, ...], ctx: DistContext) -> P:
    """FSDP+TP spec by leaf-name convention and rank.

    Conventions (see models/*): leaves are named through dict keys; the
    trailing key determines the role. Layer-stacked leaves have a leading L
    dim which is never sharded.
    """
    tp, dp = ctx.tp, ("data",) if ctx.mesh is not None and "data" in ctx.mesh.axis_names else ()
    d = dp[0] if dp else None
    # strip leading layer-stack dim from consideration
    key = name.rsplit("'", 2)[-2] if "'" in name else name

    def rank_tail(n):  # shape without the layer-stack leading dim
        return shape[-n:]

    if tp is None:
        return P()

    def divides(dim: int) -> bool:
        return dim % ctx.tp_size == 0

    if key in ("embed", "lm_head"):           # (V, D)
        return P(*([None] * (len(shape) - 2)), tp, d)
    if key in ("w_gate_experts", "w_up_experts"):   # (L, E, D, F)
        # §Perf B2: re-homed experts skip the FSDP shard (no per-layer
        # all-gather of expert weights) at the cost of E/tp experts
        # resident per device
        return P(None, tp, d if ctx.expert_fsdp else None, None)
    if key in ("w_down_experts",):                  # (L, E, F, D)
        return P(None, tp, None, d if ctx.expert_fsdp else None)
    if key in ("wq", "wk", "wv"):             # (L, D, H, Dh) — heads on tp,
        if divides(shape[-2]):                # falling back to head_dim when
            return P(None, d, tp, None)       # the head count doesn't divide
        return P(None, d, None, tp)
    if key in ("wo",):                        # (L, H, Dh, D)
        if divides(shape[-3]):
            return P(None, tp, None, d)
        return P(None, None, tp, d)
    if key in ("w_gate", "w_up"):             # (L, D, F)
        return P(None, d, tp)
    if key in ("w_down",):                    # (L, F, D)
        return P(None, tp, d)
    if key in ("in_proj", "out_proj", "proj", "router"):  # generic 2-D (+L)
        if len(shape) == 3:
            return P(None, d, tp)
        if len(shape) == 2:
            return P(d, tp)
        return P()
    # norms, biases, conv kernels, dt params, small tensors: replicated
    return P()


def _fit_spec(shape: tuple[int, ...], spec: P, ctx: DistContext) -> P:
    """Drop axis assignments that do not divide the corresponding dim.

    E.g. GQA with 2 KV heads cannot shard the head dim over model=16 —
    that dim falls back to replicated (FSDP still applies elsewhere).
    """
    if ctx.mesh is None:
        return P()
    sizes = dict(ctx.mesh.shape)
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        total = 1
        for a in axes:
            total *= sizes[a]
        out.append(entry if dim % total == 0 else None)
    return P(*out)


def param_partition_specs(params_shape: PyTree, ctx: DistContext) -> PyTree:
    """PartitionSpec pytree for a params pytree (or its eval_shape)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        spec = _spec_for_leaf(name, tuple(leaf.shape), ctx)
        specs.append(_fit_spec(tuple(leaf.shape), spec, ctx))
    return jax.tree_util.tree_unflatten(treedef, specs)


def named_shardings(params_shape: PyTree, ctx: DistContext) -> PyTree:
    specs = param_partition_specs(params_shape, ctx)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(ctx.mesh, s), specs,
        is_leaf=lambda s: isinstance(s, P))


def _state_spec_for_leaf(name: str, shape: tuple[int, ...], ctx: DistContext) -> P:
    """Serving-state (KV cache / SSM state) specs by leaf-name convention."""
    if ctx.mesh is None or ctx.tp is None:
        return P()
    tp = ctx.tp
    dp = ctx.dp_spec           # None when batch unshardable (long_500k B=1)
    seq_dp = None if ctx.batch_shardable else ctx.raw_dp_spec
    key = name.rsplit("'", 2)[-2] if "'" in name else name
    if key in ("k", "v", "cross_k", "cross_v") and len(shape) == 5:
        # (L|nseg, B, S, Hk, Dh): batch on dp, or cache length on dp for B=1;
        # kv heads on tp, falling back to head_dim when Hk doesn't divide
        if shape[-2] % ctx.tp_size == 0:
            return P(None, dp, seq_dp, tp, None)
        return P(None, dp, seq_dp, None, tp)
    if key in ("k_scale", "v_scale") and len(shape) == 4:
        # (L, B, S, Hk) int8-cache dequant scales
        if shape[-1] % ctx.tp_size == 0:
            return P(None, dp, seq_dp, tp)
        return P(None, dp, seq_dp, None)
    if key == "h" and len(shape) == 5:      # (L, B, H, P, N): SSM heads on tp
        return P(None, dp, tp, None, None)
    if key == "conv" and len(shape) == 4:   # (L, B, K, DI): channels on tp
        return P(None, dp, None, tp)
    return P()                              # kpos / pos / scalars: replicated


def state_partition_specs(state_shape: PyTree, ctx: DistContext) -> PyTree:
    flat, treedef = jax.tree_util.tree_flatten_with_path(state_shape)
    specs = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        spec = _state_spec_for_leaf(name, tuple(leaf.shape), ctx)
        specs.append(_fit_spec(tuple(leaf.shape), spec, ctx))
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_partition_specs(batch_shape: PyTree, ctx: DistContext) -> PyTree:
    """Input batch specs: leading batch dim over the dp axes."""
    return jax.tree_util.tree_map(
        lambda x: P(ctx.dp_spec, *([None] * (len(x.shape) - 1))), batch_shape)


# ---------------------------------------------------------------------------
# Flat arena sharding
# ---------------------------------------------------------------------------

def arena_sharding(mesh: Mesh) -> NamedSharding:
    """Flat 1-D sharding of the parameter arena over *every* mesh axis.

    Device ``i`` (row-major over the mesh) owns the contiguous word span
    ``[i·total/n, (i+1)·total/n)`` — a whole number of ``(8, 128)`` tiles
    when the layout was built with ``shards = mesh.devices.size``. The
    optimizer sweep (``arena_apply``), the maintain sweep, and the
    replica copy all become shard-local passes under this placement."""
    return NamedSharding(mesh, P(tuple(mesh.axis_names)))


def shard_arena_state(state, mesh: Mesh):
    """Place an ``ArenaTrainState``-shaped pytree on the mesh: every 1-D
    floating leaf (arena, adam moments) gets the flat arena sharding,
    scalars (step counts) replicate."""
    flat = arena_sharding(mesh)
    rep = NamedSharding(mesh, P())

    def put(x):
        if getattr(x, "ndim", None) == 1:
            return jax.device_put(x, flat)
        return jax.device_put(x, rep)
    return jax.tree_util.tree_map(put, state)


# ---------------------------------------------------------------------------
# Failure domains: mesh devices -> parameter blocks
# ---------------------------------------------------------------------------

def block_device_homes(partition, n_devices: int) -> np.ndarray:
    """(total_blocks,) int32 — the data-axis slice ("device") holding each
    block's rows under FSDP row-sharding.

    Each leaf's leading rows are split into ``n_devices`` equal spans; the
    block's first real row decides its home. This is the *initial* placement
    the checkpoint fabric seeds its mutable
    :class:`~repro.fabric.placement.ClusterView` with — not the permanent
    one: after a correlated domain loss the elastic placement engine
    re-homes displaced blocks across the surviving devices, so the current
    homing always lives in the view. A dead device takes every block
    *currently* homed on it.
    """
    homes = np.zeros((partition.total_blocks,), np.int32)
    for leaf in partition.leaves:
        span = max(1, leaf.rows // n_devices)
        for b in range(leaf.n_blocks):
            row = min(b * partition.block_rows, leaf.rows - 1)
            homes[leaf.offset + b] = min(row // span, n_devices - 1)
    return homes


def blocks_on_failed_devices(partition, params_shape: PyTree, ctx: DistContext,
                             failed_device_fraction: float,
                             rng: np.random.Generator) -> np.ndarray:
    """Topology-aware failure: choose a random contiguous slice of mesh
    devices (a "host"), mark every block whose rows are homed there.

    With FSDP sharding each leaf's leading rows are split over the data
    axis; a failed data-slice loses the corresponding row ranges. This is
    the beyond-paper topology-aware failure model; the uniform-random model
    of Thm 4.2 is in :func:`repro.core.recovery.sample_failure_mask`.
    """
    n_data = ctx.mesh.shape.get("data", 1) if ctx.mesh is not None else 1
    n_fail = max(1, round(failed_device_fraction * n_data))
    start = int(rng.integers(0, n_data))
    failed = [(start + i) % n_data for i in range(n_fail)]
    homes = block_device_homes(partition, n_data)
    return np.isin(homes, failed)
