"""Flat parameter arena: one contiguous per-host buffer for all leaves.

The fabric's hot loop (replica refresh + parity encode + PRIORITY scoring
+ in-place partial save) previously operated on a *forest* of leaves: one
kernel dispatch per touched leaf, `(1, BE)` row tiles that waste TPU
sublanes, and per-leaf eager dispatch overhead that dominates wall-clock
at small scale (see ``BENCH_maintain.json``).

The arena collapses the forest to a single contiguous buffer of 32-bit
**words** (carried as ``float32`` at the JAX level so every existing
consumer keeps its dtype expectations; the words of non-f32 leaves are
raw bit patterns, not values):

  - every leaf's payload is bit-packed ``dtype_word_ratio`` elements per
    word (f32/i32 → 1, bf16/f16/i16 → 2, fp8/i8 → 4; f32 leaves are
    therefore stored *bitwise as their values*, the historical layout),
    and the block table tags each segment with the leaf dtype — replica,
    parity, RS MAC, scatter saves and the integrity scrub all move raw
    words, so redundancy bytes scale with the stored precision;
  - **main region**: multi-block and >= tile leaves laid out block-major
    in flatten order, each block's payload zero-padded to a multiple of
    ``ARENA_TILE`` = 8·128 words so every block covers whole ``(8, 128)``
    sublane-aligned tiles of the 2D ``(rows, 128)`` retiling;
  - **tail region** (tail packing): single-block leaves narrower than a
    tile are packed back-to-back at *word* granularity after the main
    region — they share tiles, which removes the ~1.6× alignment cost
    small leaves used to pay on the reduced config. The region end is
    re-aligned so ``data_words`` stays a tile multiple; build with
    ``tail_pack=False`` to recover the fully aligned layout;
  - the **block table** maps ``(leaf, block) → (offset, words, payload)``
    — ``payload`` is the live words, the tail up to ``words`` is zero
    padding (XOR-neutral for parity, diff-neutral for scores);
  - colocated leaves (shared global block ids) get *separate* segments —
    the table is keyed by arena-block id, so a partial save or disk
    mirror of one gid moves every colocated payload for that gid;
  - per-leaf arena column starts equal the parity ``FrameLayout``
    (word-) columns, so an XOR over arena words lands bit-exactly in the
    codec's ``(n_groups, frame_elems)`` parity frames.

Alongside the word domain the layout describes a **value domain** for
the optimizer seam: per leaf, ``seg_elems = seg_words · ratio`` f32
values per block at ``value_offset`` — ``decode_values`` /
``encode_values`` move between the two with one slice + bitcast per
*run* of consecutive same-dtype leaves (coalesced; an all-bf16 model is
a single run). For an all-f32 model ``total_values == total_words`` and
both transforms are the identity, so gradients, moments and the
optimizer update are bit-identical to the historical f32 arena.

Invariants (relied on by kernels, the store, and the property tests):

  I1  main-region ``offset``/``words`` are multiples of ``ARENA_TILE``;
      tail-region blocks are word-contiguous (``words == payload``,
      offsets unaligned) and ``tail_start``/``data_words``/
      ``total_words`` are tile multiples.
  I2  segments are disjoint and cover ``[0, data_words)`` exactly except
      the tail-alignment gap ``[tail_end, data_words)``, which is zero;
      ``[data_words, total_words)`` is the arena-level shard pad (zero
      tiles appended so ``n_tiles`` divides ``shards`` evenly — empty
      when ``shards == 1``).
  I3  ``unpack(pack(tree)) == tree`` bit-exactly for every word-packable
      dtype (f32/bf16/f16/fp8/int8/…), any shape (including scalars and
      ragged tail blocks).
  I4  pad words are 0x00000000 after ``pack`` and are *kept* zero by
      every arena mutation (scatter saves copy whole segments, so pads
      are overwritten with source pads — also zero; the tail-alignment
      gap and the shard-pad tail are never scatter targets). Sub-word
      element pads are zero *bits*, which decode to value 0 for every
      packable dtype.

Sharded form: when the trainer runs on a mesh, the same 1-D buffer
carries a flat ``NamedSharding`` over every mesh axis — device ``d`` of
``n`` owns words ``[d·total/n, (d+1)·total/n)``, a whole number of
``(8, 128)`` tiles by I1/I2. ``arena_block_homes`` derives the
block→device map *from* that span ownership, so "each device owns the
tile-aligned segments of its home blocks" holds by construction.

.. warning:: jax 0.4.37's CPU SPMD partitioner miscompiles
   ``concatenate`` of 1-D operands that carry a minor-mesh-axis
   sharding (wrong *values*, not a perf hazard). ``pack_arena`` takes
   ``out_sharding`` and pins every part and the result to the flat
   arena sharding, which sidesteps the bug and is the layout we want
   anyway; sharded callers must pass it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocks import (BlockPartition, decode_block_words,
                               dtype_word_ratio, expand_block_mask,
                               leaf_block_view, leaf_block_words,
                               leaf_frame_width, leaf_word_width,
                               word_packable)

PyTree = Any

ARENA_LANES = 128          # lane width of the 2D retiling
ARENA_SUBLANES = 8         # f32 sublane tile height
ARENA_TILE = ARENA_LANES * ARENA_SUBLANES   # words per (8, 128) tile

# kept for reference/back-compat: the dtypes the pre-word-level arena
# admitted (f32 round-trippable). The live gate is ``arena_compatible``,
# which now admits every word-packable dtype.
ARENA_DTYPES = (jnp.float32, jnp.bfloat16, jnp.float16)


def _align(n: int, a: int = ARENA_TILE) -> int:
    return -(-max(int(n), 1) // a) * a


def leaf_payload_words(leaf, block_rows: int) -> int:
    """Live words per block of this leaf — the parity frame payload
    width (:func:`repro.core.blocks.leaf_word_width`)."""
    return leaf_word_width(leaf, block_rows)


def arena_compatible(partition: BlockPartition) -> bool:
    """True when every leaf dtype is word-packable (1/2/4-byte int or
    float: f32, bf16, f16, fp8, int8/16/32, uint8/16/32 — stored as raw
    bit patterns, so the round trip is bit-exact by construction).
    Truly unsupported dtypes (f64, int64, complex, bool) gate the model
    to the PyTree path with a loud ``fabric/arena_gated`` warn+event."""
    return all(word_packable(l.dtype) for l in partition.leaves)


@dataclasses.dataclass(frozen=True)
class ArenaBlock:
    """One block-table row: where block ``b`` of leaf ``li`` lives."""
    leaf: int          # leaf index in flatten order
    gid: int           # global block id (colocated leaves share gids)
    offset: int        # word offset of the segment (tile-aligned unless tail)
    words: int         # segment length (== payload for tail blocks)
    payload: int       # live words; [payload, words) is zero padding


@dataclasses.dataclass(frozen=True, eq=False)
class ArenaLayout:
    """Static block table + tile routing for one partition.

    ``ab_t0``/``ab_nt`` (first tile / touched-tile count per arena block)
    and the gid→arena-block CSR (``gid_ab``/``gid_ptr``) make the
    per-save lookups O(selected) — the save hot path never scans the
    full table.

    ``eq=False``: identity comparison/hash, so a layout can ride as a
    static (meta) field of a registered pytree (``ArenaTrainState``) —
    the numpy tables would make the generated ``__eq__`` ill-defined, and
    every consumer shares the one instance its fabric built anyway."""
    partition: BlockPartition
    blocks: tuple[ArenaBlock, ...]      # offset-ascending
    leaf_offset: tuple[int, ...]        # word offset of each leaf's segment
    seg_words: tuple[int, ...]          # segment words per block, per leaf
    payload_words: tuple[int, ...]      # live words per block, per leaf
    total_words: int                    # ARENA_TILE multiple (incl. shard pad)
    ab_t0: np.ndarray                   # (n_ab,) first tile per arena block
    ab_nt: np.ndarray                   # (n_ab,) touched tiles per arena block
    gid_ab: np.ndarray                  # arena blocks sorted by gid (CSR)
    gid_ptr: np.ndarray                 # (total_blocks + 1,) CSR pointers
    shards: int = 1                     # even flat-sharding divisor of n_tiles
    data_words: int = -1                # words before the shard-pad tail
    tail_start: int = -1                # word offset of the tail-packed region
    leaf_order: tuple[int, ...] = ()    # leaf indices in offset order
    payload_elems: tuple[int, ...] = () # live elements per block, per leaf
    seg_elems: tuple[int, ...] = ()     # value-domain elems per block, per leaf
    value_offset: tuple[int, ...] = ()  # value-domain start per leaf
    total_values: int = -1              # f32 value-domain length

    @property
    def n_tiles(self) -> int:
        return self.total_words // ARENA_TILE

    @property
    def pad_words(self) -> int:
        """Zero words of the shard-pad tail (0 when ``shards == 1``)."""
        return self.total_words - (self.total_words if self.data_words < 0
                                   else self.data_words)

    @property
    def shard_words(self) -> int:
        """Words each of the ``shards`` flat shards owns (tile multiple)."""
        return self.total_words // self.shards

    @property
    def rows_2d(self) -> int:
        return self.total_words // ARENA_LANES

    @property
    def nbytes(self) -> int:
        return self.total_words * 4

    @property
    def uniform_f32(self) -> bool:
        """True when every leaf is f32 — words *are* values and the value
        domain is the identity (``total_values == total_words``)."""
        return all(np.dtype(l.dtype) == np.dtype(np.float32)
                   for l in self.partition.leaves)

    @property
    def has_tail(self) -> bool:
        return 0 <= self.tail_start < self.data_words

    @property
    def tail_end(self) -> int:
        """End of the last tail payload (``data_words`` minus the
        tail-alignment gap; == ``tail_start`` when no tail region)."""
        end = self.tail_start
        for ab in self.blocks:
            if ab.offset >= self.tail_start:
                end = max(end, ab.offset + ab.payload)
        return end

    @property
    def padding_ratio(self) -> float:
        """Pad words / live payload words over the whole buffer — the
        number tail packing shrinks (reported in ``maintain_traffic`` and
        the ``maint_arena_padding`` bench row)."""
        data = sum(ab.payload for ab in self.blocks)
        return (self.total_words - data) / max(data, 1)

    # -- host-side routing (O(selected), not O(table)) -----------------------

    def tile_gids(self) -> np.ndarray:
        """(n_tiles,) global block id owning each (8, 128) tile.

        Tail-region tiles report -1: they may be shared by several
        blocks, so per-gid reductions must use :meth:`word_tables` there.
        Shard-pad tail tiles report gid 0: their words are zero in every
        arena (I4), so any per-gid reduction over tiles (scores, diffs)
        sees an exact ``+0.0`` contribution — bit-neutral."""
        gids = np.zeros((self.n_tiles,), np.int32)
        for ab in self.blocks:
            if ab.offset >= self.tail_start >= 0:
                continue
            t0 = ab.offset // ARENA_TILE
            gids[t0:t0 + ab.words // ARENA_TILE] = ab.gid
        if self.has_tail:
            gids[self.tail_start // ARENA_TILE:
                 self.data_words // ARENA_TILE] = -1
        return gids

    def word_tables(self) -> tuple[np.ndarray, np.ndarray, tuple]:
        """Cached ``(word_gid, word_code, code_dtypes)``.

        ``word_gid[w]`` is the gid owning word ``w`` (pads → 0, whose
        zero words contribute an exact +0.0 to any reduction);
        ``word_code[w]`` tags the stored dtype: 0 = f32 (including every
        pad), ``k >= 1`` = ``code_dtypes[k - 1]``. The per-word drift
        scorer and the tail parity epilogue are driven by these."""
        cached = getattr(self, "_word_tables", None)
        if cached is None:
            gid = np.zeros((self.total_words,), np.int32)
            code = np.zeros((self.total_words,), np.int8)
            codes: dict[str, int] = {}
            dts: list[np.dtype] = []
            for ab in self.blocks:
                dt = np.dtype(self.partition.leaves[ab.leaf].dtype)
                if dt == np.dtype(np.float32) or not word_packable(dt):
                    c = 0
                else:
                    if dt.name not in codes:
                        dts.append(dt)
                        codes[dt.name] = len(dts)
                    c = codes[dt.name]
                gid[ab.offset:ab.offset + ab.words] = ab.gid
                code[ab.offset:ab.offset + ab.words] = c
            cached = (gid, code, tuple(dts))
            object.__setattr__(self, "_word_tables", cached)
        return cached

    def value_runs(self) -> tuple[tuple[int, int, int, int, Any], ...]:
        """Cached coalesced decode/encode plan: ``(word_start, words,
        value_start, values, dtype)`` per run of consecutive same-dtype
        leaves in offset order (pads ride inside their leaf's run; the
        tail-alignment gap and shard pad close an f32 run). An all-f32
        model is one run; an all-bf16 model is one run."""
        cached = getattr(self, "_value_runs", None)
        if cached is None:
            runs: list[list] = []   # [w0, nw, v0, nv, dtype]
            w = v = 0

            def push(nw: int, nv: int, dt) -> None:
                nonlocal w, v
                if nw == 0:
                    return
                if runs and np.dtype(runs[-1][4]) == np.dtype(dt):
                    runs[-1][1] += nw
                    runs[-1][3] += nv
                else:
                    runs.append([w, nw, v, nv, np.dtype(dt)])
                w += nw
                v += nv

            for li in self.leaf_order:
                leaf = self.partition.leaves[li]
                dt = np.dtype(leaf.dtype) if word_packable(leaf.dtype) \
                    else np.dtype(np.float32)
                push(self.seg_words[li] * leaf.n_blocks,
                     self.seg_elems[li] * leaf.n_blocks, dt)
            push(self.total_words - w, self.total_values - v, np.float32)
            assert w == self.total_words and v == self.total_values
            cached = tuple(tuple(r) for r in runs)
            object.__setattr__(self, "_value_runs", cached)
        return cached

    def blocks_for_gids(self, global_ids) -> np.ndarray:
        """Ascending arena-block indices covering the given gids — every
        colocated leaf's segment rides along (they share gids)."""
        gids = np.unique(np.asarray(global_ids, np.int64).ravel())
        if gids.size == 0:
            return np.empty((0,), np.int64)
        parts = [self.gid_ab[self.gid_ptr[g]:self.gid_ptr[g + 1]]
                 for g in gids]
        return np.sort(np.concatenate(parts))

    def tiles_for_blocks(self, global_ids) -> np.ndarray:
        """Ascending unique (8-row-) tile indices touched by the given
        gids (tail blocks may share tiles, hence the dedup)."""
        abs_ = self.blocks_for_gids(global_ids)
        if abs_.size == 0:
            return np.empty((0,), np.int32)
        t0, nt = self.ab_t0[abs_], self.ab_nt[abs_]
        total = int(nt.sum())
        starts = np.cumsum(nt) - nt
        tiles = (np.repeat(t0, nt)
                 + (np.arange(total) - np.repeat(starts, nt)))
        return np.unique(tiles).astype(np.int32)

    def split_tail_blocks(self, global_ids) -> tuple[np.ndarray, np.ndarray]:
        """Arena-block indices of the given gids, split into
        (main-region, tail-region) — the two scatter granularities."""
        abs_ = self.blocks_for_gids(global_ids)
        if abs_.size == 0 or not self.has_tail:
            return abs_, np.empty((0,), np.int64)
        off = np.asarray([self.blocks[i].offset for i in abs_])
        tail = off >= self.tail_start
        return abs_[~tail], abs_[tail]

    def seg_bytes_for_blocks(self, global_ids) -> int:
        """Bytes a scatter of these gids actually moves: whole touched
        tiles for main-region blocks, payload words for tail blocks."""
        main, tail = self.split_tail_blocks(global_ids)
        tiles = 0
        if main.size:
            t0, nt = self.ab_t0[main], self.ab_nt[main]
            total = int(nt.sum())
            starts = np.cumsum(nt) - nt
            tiles = np.unique(np.repeat(t0, nt) + (np.arange(total)
                              - np.repeat(starts, nt))).size
        words = sum(self.blocks[i].payload for i in tail)
        return 4 * (ARENA_TILE * tiles + int(words))


def as_live_arena(x: Any, layout: Optional[ArenaLayout]):
    """Return ``x`` when it is a live flat arena for ``layout``, else None.

    The training stack's arena-native hot path passes the flat ``(N,)``
    f32 buffer where tree-form params used to flow; consumers
    (FTController, CheckpointFabric, ArenaMaintainProgram) use this one
    predicate so the two forms share every entry point. A 1-D leaf tree
    can only be mistaken for an arena if it is a single bare f32 array of
    exactly ``total_words`` (a tile-aligned size no real model hits) —
    and the arena path is only reachable with a fabric-built layout."""
    if layout is None:
        return None
    if getattr(x, "ndim", None) == 1 and getattr(x, "size", 0) \
            == layout.total_words and x.dtype == jnp.float32:
        return x
    return None


def build_arena_layout(partition: BlockPartition, shards: int = 1,
                       tail_pack: bool = True) -> ArenaLayout:
    """Lay out ``partition`` in the flat word arena.

    Main-region leaves go first in flatten order (tile-aligned
    segments); tail leaves (single-block, payload < ``ARENA_TILE``
    words) follow back-to-back at word granularity, then the region is
    re-aligned to a tile. ``tail_pack=False`` keeps every segment
    tile-aligned (the pre-tail-packing layout — the ``maint_arena_padding``
    bench compares the two).

    ``shards > 1`` appends zero tiles so ``n_tiles % shards == 0`` —
    every flat shard of the 1-D buffer then owns a whole number of
    ``(8, 128)`` tiles and the data region ``[0, data_words)`` is
    *identical* to the ``shards=1`` layout (relayout across shard counts
    is a slice + re-pad, bit-exact)."""
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    br = partition.block_rows
    n = len(partition.leaves)
    pw_leaf = [leaf_word_width(leaf, br) for leaf in partition.leaves]
    is_tail = [tail_pack and leaf.n_blocks == 1 and pw_leaf[li] < ARENA_TILE
               for li, leaf in enumerate(partition.leaves)]
    order = ([li for li in range(n) if not is_tail[li]]
             + [li for li in range(n) if is_tail[li]])
    blocks: list[ArenaBlock] = []
    leaf_offset = [0] * n
    seg_words = [0] * n
    payload_words = [0] * n
    payload_elems = [0] * n
    seg_elems = [0] * n
    value_offset = [0] * n
    off = voff = 0
    tail_start = None
    for li in order:
        leaf = partition.leaves[li]
        pw = pw_leaf[li]
        seg = pw if is_tail[li] else _align(pw)
        if is_tail[li] and tail_start is None:
            tail_start = off
        r = dtype_word_ratio(leaf.dtype)
        leaf_offset[li] = off
        seg_words[li] = seg
        payload_words[li] = pw
        payload_elems[li] = leaf_frame_width(leaf, br)
        seg_elems[li] = seg * r
        value_offset[li] = voff
        for b in range(leaf.n_blocks):
            blocks.append(ArenaBlock(leaf=li, gid=leaf.offset + b,
                                     offset=off, words=seg, payload=pw))
            off += seg
            voff += seg * r
    if tail_start is None:
        tail_start = off
    data_words = _align(off)
    voff += data_words - off          # tail-alignment gap, f32 values
    ab_gid = np.asarray([ab.gid for ab in blocks], np.int64)
    gid_order = np.argsort(ab_gid, kind="stable")
    gid_ptr = np.searchsorted(ab_gid[gid_order],
                              np.arange(partition.total_blocks + 1))
    pad_tiles = (-(data_words // ARENA_TILE)) % shards
    total_words = data_words + pad_tiles * ARENA_TILE
    total_values = voff + pad_tiles * ARENA_TILE
    ab_t0 = np.asarray([ab.offset // ARENA_TILE for ab in blocks], np.int64)
    ab_last = np.asarray([(ab.offset + max(ab.words, 1) - 1) // ARENA_TILE
                          for ab in blocks], np.int64)
    return ArenaLayout(partition=partition, blocks=tuple(blocks),
                       leaf_offset=tuple(leaf_offset),
                       seg_words=tuple(seg_words),
                       payload_words=tuple(payload_words),
                       total_words=total_words,
                       ab_t0=ab_t0, ab_nt=ab_last - ab_t0 + 1,
                       gid_ab=gid_order, gid_ptr=gid_ptr,
                       shards=shards, data_words=data_words,
                       tail_start=tail_start, leaf_order=tuple(order),
                       payload_elems=tuple(payload_elems),
                       seg_elems=tuple(seg_elems),
                       value_offset=tuple(value_offset),
                       total_values=total_values)


# ---------------------------------------------------------------------------
# pack / unpack / restore (pure, jittable; layout is static)
# ---------------------------------------------------------------------------

def _is_f32(leaf) -> bool:
    return np.dtype(leaf.dtype) == np.dtype(np.float32)


def pack_arena(values: PyTree, layout: ArenaLayout,
               out_sharding=None) -> jnp.ndarray:
    """Pack a tree into the flat (total_words,) word arena.

    One read of every leaf, one write of the arena — this *is* the replica
    refresh cost when the fabric snapshots into arena form. f32 leaves are
    value-stored (bitwise the historical layout); other word-packable
    dtypes are raw bit patterns via :func:`leaf_block_words`.

    ``out_sharding`` (a flat 1-D ``NamedSharding``) pins every part and
    the result; **required** when any input leaf is mesh-sharded — see
    the module warning on the jax 0.4.37 sharded-``concatenate``
    miscompile this constraint sidesteps."""
    part = layout.partition
    con = ((lambda v: jax.lax.with_sharding_constraint(v, out_sharding))
           if out_sharding is not None else (lambda v: v))
    leaves = jax.tree_util.tree_leaves(values)
    parts = []
    covered = 0
    for li in layout.leaf_order:
        x, leaf = leaves[li], part.leaves[li]
        seg = layout.seg_words[li]
        if _is_f32(leaf):
            view = leaf_block_view(x.astype(jnp.float32), part.block_rows)
        else:
            view = jax.lax.bitcast_convert_type(
                leaf_block_words(x, part.block_rows), jnp.float32)
        if view.shape[1] < seg:
            view = jnp.pad(view, ((0, 0), (0, seg - view.shape[1])))
        parts.append(con(view.reshape(-1)))
        covered += seg * leaf.n_blocks
    if layout.total_words > covered:
        parts.append(con(jnp.zeros((layout.total_words - covered,),
                                   jnp.float32)))
    out = jnp.concatenate(parts) if len(parts) > 1 else parts[0]
    return con(out)


def _decode_leaf(arena: jnp.ndarray, layout: ArenaLayout, li: int):
    """Contiguous slice of leaf ``li``'s segment, decoded to leaf shape."""
    leaf = layout.partition.leaves[li]
    seg, payload = layout.seg_words[li], layout.payload_words[li]
    off = layout.leaf_offset[li]
    flat = jax.lax.dynamic_slice(arena, (off,), (leaf.n_blocks * seg,))
    view = flat.reshape(leaf.n_blocks, seg)
    if _is_f32(leaf):
        vals = view[:, :payload]
        rows = max(leaf.rows, 1)
        vals = vals.reshape(-1, max(leaf.row_width, 1))[:rows]
        return vals.reshape(leaf.shape).astype(leaf.dtype)
    bits = jax.lax.bitcast_convert_type(view[:, :payload], jnp.int32)
    return decode_block_words(bits, leaf, layout.partition.block_rows)


def unpack_arena(arena: jnp.ndarray, layout: ArenaLayout) -> PyTree:
    """Inverse of :func:`pack_arena`, bit-exact (invariant I3)."""
    out = [_decode_leaf(arena, layout, li)
           for li in range(len(layout.partition.leaves))]
    return jax.tree_util.tree_unflatten(layout.partition.treedef, out)


# ---------------------------------------------------------------------------
# value domain (the optimizer seam)
# ---------------------------------------------------------------------------

def pack_values(values: PyTree, layout: ArenaLayout,
                out_sharding=None) -> jnp.ndarray:
    """Pack a tree into the flat ``(total_values,)`` f32 value buffer —
    the gradient/moment counterpart of :func:`pack_arena`. For an all-f32
    layout this emits the *same program* as ``pack_arena`` (words are
    values and ``seg_elems == seg_words``)."""
    part = layout.partition
    con = ((lambda v: jax.lax.with_sharding_constraint(v, out_sharding))
           if out_sharding is not None else (lambda v: v))
    leaves = jax.tree_util.tree_leaves(values)
    parts = []
    covered = 0
    for li in layout.leaf_order:
        x, leaf = leaves[li], part.leaves[li]
        se = layout.seg_elems[li]
        view = leaf_block_view(x.astype(jnp.float32), part.block_rows)
        if view.shape[1] < se:
            view = jnp.pad(view, ((0, 0), (0, se - view.shape[1])))
        parts.append(con(view.reshape(-1)))
        covered += se * leaf.n_blocks
    if layout.total_values > covered:
        parts.append(con(jnp.zeros((layout.total_values - covered,),
                                   jnp.float32)))
    out = jnp.concatenate(parts) if len(parts) > 1 else parts[0]
    return con(out)


def decode_values(arena: jnp.ndarray, layout: ArenaLayout) -> jnp.ndarray:
    """Word arena → ``(total_values,)`` f32 values, one slice + bitcast
    per coalesced same-dtype run (identity for all-f32 layouts)."""
    if layout.uniform_f32:
        return arena
    parts = []
    for w0, nw, _v0, _nv, dt in layout.value_runs():
        w = jax.lax.slice(arena, (w0,), (w0 + nw,))
        if dt == np.dtype(np.float32):
            parts.append(w)
            continue
        bits = jax.lax.bitcast_convert_type(w, jnp.int32)
        e = bits if dt == np.dtype(np.int32) \
            else jax.lax.bitcast_convert_type(bits, dt)
        parts.append(e.astype(jnp.float32).reshape(-1))
    return jnp.concatenate(parts) if len(parts) > 1 else parts[0]


def encode_values(values: jnp.ndarray, layout: ArenaLayout) -> jnp.ndarray:
    """Inverse of :func:`decode_values`: re-encode the f32 value buffer
    into raw arena words (``astype`` to the stored dtype — the same
    rounding the PyTree optimizer path applies — then bitcast)."""
    if layout.uniform_f32:
        return values
    parts = []
    for _w0, nw, v0, nv, dt in layout.value_runs():
        v = jax.lax.slice(values, (v0,), (v0 + nv,))
        if dt == np.dtype(np.float32):
            parts.append(v)
            continue
        r = dtype_word_ratio(dt)
        e = v.astype(dt)
        bits = e if dt == np.dtype(np.int32) else (
            jax.lax.bitcast_convert_type(e, jnp.int32) if r == 1
            else jax.lax.bitcast_convert_type(e.reshape(nw, r), jnp.int32))
        parts.append(jax.lax.bitcast_convert_type(bits, jnp.float32))
    return jnp.concatenate(parts) if len(parts) > 1 else parts[0]


def relayout_values(buf, old: ArenaLayout, new: ArenaLayout,
                    out_sharding=None):
    """Value-domain counterpart of :func:`relayout_arena` (optimizer
    moments across a shard-count change): the region before the shard
    pad is partition-determined, so this is a host slice + re-pad."""
    d_old = old.total_values - old.pad_words
    d_new = new.total_values - new.pad_words
    if d_old != d_new:
        raise ValueError("relayout_values: layouts disagree on the data "
                         f"region ({d_old} vs {d_new} values) — not the "
                         "same partition")
    host = np.asarray(buf)
    out = np.concatenate(
        [host[:d_new], np.zeros((new.pad_words,), np.float32)])
    return jax.device_put(out, out_sharding) if out_sharding is not None \
        else jnp.asarray(out)


def arena_drift_scores(live: jnp.ndarray, ref: jnp.ndarray,
                       layout: ArenaLayout) -> jnp.ndarray:
    """Per-gid squared drift ``||live_b − ref_b||²`` → (total_blocks,) f32,
    decoding each word by its stored dtype.

    Main-region tiles reduce per tile first (for an all-f32 layout this
    is bit-identical to the historical tile scorer); tail-region words
    reduce by ``word_gid`` directly, since tail tiles are shared. Pad
    words diff two zero words → exact +0.0 (I4)."""
    word_gid, word_code, dts = layout.word_tables()
    wc = (live - ref) ** 2
    for k, dt in enumerate(dts, start=1):
        r = dtype_word_ratio(dt)
        ex = jax.lax.bitcast_convert_type(
            jax.lax.bitcast_convert_type(live, jnp.int32), dt)
        er = jax.lax.bitcast_convert_type(
            jax.lax.bitcast_convert_type(ref, jnp.int32), dt)
        d = ex.astype(jnp.float32) - er.astype(jnp.float32)
        dk = jnp.sum(d * d, axis=-1) if r > 1 else d * d
        wc = jnp.where(jnp.asarray(word_code == k), dk, wc)
    total = layout.partition.total_blocks
    tile_gid = np.where(word_gid[::ARENA_TILE] >= 0,
                        word_gid[::ARENA_TILE], 0)
    partials = jnp.sum(wc.reshape(-1, ARENA_TILE), axis=1)
    if layout.has_tail:
        tt0 = layout.tail_start // ARENA_TILE
        tt1 = layout.data_words // ARENA_TILE
        mask = np.ones((layout.n_tiles,), bool)
        mask[tt0:tt1] = False
        partials = jnp.where(jnp.asarray(mask), partials, 0.0)
    scores = jax.ops.segment_sum(partials, jnp.asarray(tile_gid),
                                 num_segments=total)
    if layout.has_tail:
        lo, hi = layout.tail_start, layout.data_words
        scores = scores + jax.ops.segment_sum(
            wc[lo:hi], jnp.asarray(word_gid[lo:hi]), num_segments=total)
    return scores


def relayout_arena(arena, old: ArenaLayout, new: ArenaLayout,
                   out_sharding=None):
    """Re-pad an arena across a shard-count change, bit-exactly.

    The data region ``[0, data_words)`` is identical for every shard
    count of the same partition (``build_arena_layout`` only moves the
    zero tail), so relayout is a host-side slice + re-pad. Used on the
    elastic resize path (mesh shrink / re-grow), which is failure-rate —
    not per-step — so the device round trip is acceptable; the result is
    ``device_put`` onto ``out_sharding`` when given."""
    if old.data_words != new.data_words:
        raise ValueError("relayout_arena: layouts disagree on the data "
                         f"region ({old.data_words} vs {new.data_words} "
                         "words) — not the same partition")
    host = np.asarray(arena)
    data = host[:new.data_words]
    out = np.concatenate(
        [data, np.zeros((new.total_words - new.data_words,), np.float32)])
    return jax.device_put(out, out_sharding) if out_sharding is not None \
        else jnp.asarray(out)


def arena_block_homes(layout: ArenaLayout,
                      n_devices: Optional[int] = None) -> np.ndarray:
    """(total_blocks,) home device of each gid, derived from flat-shard
    span ownership: the device whose contiguous word span holds the
    first tile of the gid's first arena block. With ``shards ==
    n_devices`` every device's span is tile-aligned (I1/I2), so a
    device's home blocks are exactly the tile-aligned segments it
    already owns — the sharded maintain sweep and the partial save read
    only local (plus boundary-straddling) tiles."""
    n = layout.shards if n_devices is None else int(n_devices)
    if layout.n_tiles % n:
        raise ValueError(f"n_tiles {layout.n_tiles} not divisible by "
                         f"{n} devices — build the layout with shards={n}")
    tiles_per = layout.n_tiles // n
    first_ab = layout.gid_ab[layout.gid_ptr[:-1]]
    return (layout.ab_t0[first_ab] // tiles_per).astype(np.int64)


def arena_restore(dst: PyTree, arena: jnp.ndarray, global_mask,
                  layout: ArenaLayout) -> PyTree:
    """Overwrite the masked blocks of ``dst`` from the arena.

    The arena-source counterpart of ``select_blocks`` /
    ``tree_masked_restore``: each touched leaf decodes one contiguous
    arena slice; untouched leaves pass through as the same buffer."""
    part = layout.partition
    mask = np.asarray(global_mask, bool)
    out = []
    for li, (x, leaf) in enumerate(zip(jax.tree_util.tree_leaves(dst),
                                       part.leaves)):
        seg = mask[leaf.offset:leaf.offset + leaf.n_blocks]
        if not seg.any():
            out.append(x)
            continue
        decoded = _decode_leaf(arena, layout, li).astype(x.dtype)
        em = expand_block_mask(jnp.asarray(seg), leaf, part.block_rows)
        out.append(jnp.where(em, decoded, x))
    return jax.tree_util.tree_unflatten(part.treedef, out)


# ---------------------------------------------------------------------------
# parity frame bridge
# ---------------------------------------------------------------------------

def frames_gather_index(layout: ArenaLayout, frame_layout) -> np.ndarray:
    """(total_blocks, frame_elems) arena word index per frame position
    (-1 where the frame is zero padding) — ``frames_from_arena``'s map.

    Valid because the arena's per-leaf columns match the ``FrameLayout``
    word columns: frame row ``gid`` is the side-by-side concat of every
    colocated leaf's segment for that gid. Word-granular, so tail-packed
    (unaligned) blocks index straight in."""
    part = layout.partition
    idx = np.full((part.total_blocks, frame_layout.frame_elems), -1,
                  np.int64)
    for ab in layout.blocks:
        col = frame_layout.cols[ab.leaf]
        idx[ab.gid, col:col + ab.payload] = np.arange(
            ab.offset, ab.offset + ab.payload)
    return idx


def frames_from_arena(arena: jnp.ndarray, gather_idx: np.ndarray,
                      ) -> jnp.ndarray:
    """(total_blocks, frame_elems) int32 bit-pattern frames — bit-exact
    vs ``pack_frames`` of the unpacked tree (one gather, no per-leaf
    pass)."""
    idx = jnp.asarray(np.where(gather_idx >= 0, gather_idx, 0))
    vals = jnp.where(jnp.asarray(gather_idx >= 0), arena[idx],
                     jnp.float32(0.0))
    return jax.lax.bitcast_convert_type(vals, jnp.int32)
