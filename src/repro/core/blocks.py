"""Deterministic block partition of a parameter PyTree.

The paper partitions model parameters across PS nodes "uniformly at random"
at row granularity (§5.1: rows of the MLR matrix, rows of L / columns of R
for MF, document-topic rows for LDA, layer/shard tensors for the CNN).

In the SPMD adaptation, the unit of loss/checkpoint/priority is a **block**:
``block_rows`` consecutive leading-dim rows of each leaf (TPU-aligned, 128 by
default). A ``BlockPartition`` is the static (host-side) description of that
blocking; every runtime operation over blocks (distance scoring, masked
restore, failure injection) is a pure jittable function parameterized by it.

Layout per leaf ``x`` of shape ``(d0, d1, ..., dn)``:
  rows      = d0              (ndim ≥ 1; scalars are treated as 1 row)
  row_width = prod(d1..dn)
  n_blocks  = ceil(rows / block_rows)
Blocks of a leaf are contiguous row groups; global block ids concatenate
leaves in flatten order. Padding rows (to fill the last block) are zeros on
both sides of any distance computation, so they never affect scores.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass(frozen=True)
class LeafMeta:
    name: str
    shape: tuple[int, ...]
    dtype: Any
    rows: int
    row_width: int
    n_blocks: int
    offset: int            # global block-id offset of this leaf's first block


@dataclasses.dataclass(frozen=True)
class BlockPartition:
    block_rows: int
    leaves: tuple[LeafMeta, ...]
    treedef: Any

    @property
    def total_blocks(self) -> int:
        # colocated leaves share offsets, so count by extent not by sum
        return max(l.offset + l.n_blocks for l in self.leaves)

    @property
    def total_params(self) -> int:
        return sum(int(np.prod(l.shape)) if l.shape else 1 for l in self.leaves)

    def leaf_slices(self) -> list[tuple[int, int]]:
        """[(start, end)] global block-id ranges per leaf, in flatten order."""
        return [(l.offset, l.offset + l.n_blocks) for l in self.leaves]

    def blocks_for_k(self, fraction: float) -> int:
        """Number of blocks in a fraction-r checkpoint (ceil, >= 1)."""
        return max(1, math.ceil(fraction * self.total_blocks))


def _leaf_name(path) -> str:
    return jax.tree_util.keystr(path)


def partition_pytree(params: PyTree, block_rows: int = 128,
                     colocate: tuple = ()) -> BlockPartition:
    """Build the static block partition for ``params``.

    Works on concrete arrays or ShapeDtypeStructs (no data access).

    ``colocate``: top-level keys whose subtrees share block ids with each
    other (matching by the remaining path). This models the parameter-
    server reality that optimizer state lives WITH its parameters — a
    failed partition loses a weight block *and its Adam moments together*,
    and partial recovery restores them together. Without colocation, a
    partial restore could mix a new weight with stale moments (which makes
    adaptive optimizers diverge — measured in EXPERIMENTS.md §Repro).
    E.g. state = {"net": ..., "mu": ..., "nu": ...} with
    colocate=("net", "mu", "nu"): mu's and nu's leaves reuse net's blocks.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    leaves = []
    offset = 0
    canonical_offsets: dict = {}
    for path, x in flat:
        shape = tuple(x.shape)
        rows = shape[0] if len(shape) >= 1 else 1
        row_width = int(np.prod(shape[1:])) if len(shape) >= 1 else 1
        n_blocks = max(1, math.ceil(rows / block_rows))
        name = _leaf_name(path)
        leaf_offset = offset
        if colocate and path and getattr(path[0], "key", None) in colocate:
            canon = jax.tree_util.keystr(tuple(path[1:]))
            if canon in canonical_offsets:
                leaf_offset, prev_blocks = canonical_offsets[canon]
                assert prev_blocks == n_blocks, (
                    f"colocated leaf {name} has {n_blocks} blocks, "
                    f"group has {prev_blocks}")
            else:
                canonical_offsets[canon] = (offset, n_blocks)
                offset += n_blocks
        else:
            offset += n_blocks
        leaves.append(LeafMeta(
            name=name, shape=shape, dtype=x.dtype, rows=rows,
            row_width=row_width, n_blocks=n_blocks, offset=leaf_offset))
    return BlockPartition(block_rows=block_rows, leaves=tuple(leaves),
                          treedef=treedef)


# ---------------------------------------------------------------------------
# Runtime (jittable) block ops
# ---------------------------------------------------------------------------

def leaf_frame_width(leaf: LeafMeta, block_rows: int) -> int:
    """Payload elements per block of this leaf — the width of its
    :func:`leaf_block_view` rows (single-block leaves are unpadded), and
    therefore the per-block payload of both the parity frames and the
    flat parameter arena (which zero-pad it to their own alignments)."""
    if leaf.n_blocks == 1:
        return max(leaf.rows, 1) * max(leaf.row_width, 1)
    return block_rows * leaf.row_width


def word_packable(dtype) -> bool:
    """True when ``dtype`` values are stored in arena/frame words as raw
    bit patterns: 1/2/4-byte ints and floats (f32, bf16, f16, the fp8
    family, int8/16/32, uint8/16/32). Everything else (f64, int64,
    complex, bool) falls back to the legacy f32-image convention — one
    word per element, value cast through float32."""
    dt = np.dtype(dtype)
    # ml_dtypes types (bfloat16, the fp8 family) register as numpy kind
    # 'V' (void) but are plain fixed-width bit patterns like any other
    # int/float, so admit them alongside the native f/i/u kinds. True
    # void/structured dtypes never appear as pytree leaves here.
    return dt.kind in "fiuV" and dt.itemsize in (1, 2, 4)


def dtype_word_ratio(dtype) -> int:
    """Elements per 32-bit word: 1 (f32/i32), 2 (bf16/f16/i16), 4
    (fp8/i8). Non-word-packable dtypes use the f32-image convention, so
    one element per word."""
    dt = np.dtype(dtype)
    return 4 // dt.itemsize if word_packable(dt) else 1


def leaf_word_width(leaf: LeafMeta, block_rows: int) -> int:
    """Payload 32-bit *words* per block of this leaf: its
    :func:`leaf_frame_width` elements bit-packed ``dtype_word_ratio``
    per word (sub-word tail padded with zero bits)."""
    r = dtype_word_ratio(leaf.dtype)
    return -(-leaf_frame_width(leaf, block_rows) // r)


def leaf_block_words(x: jnp.ndarray, block_rows: int) -> jnp.ndarray:
    """(n_blocks, payload_words) int32 raw bit pattern of a leaf's blocks.

    Word-packable dtypes pack ``dtype_word_ratio`` consecutive elements
    per word, element 0 in the low-order bytes — the same packing as a
    numpy ``.view(int32)`` on little-endian hosts (property-tested in
    ``tests/test_quant_arena.py``). Other dtypes store one f32 image per
    word, the historical frames convention.
    """
    r = dtype_word_ratio(x.dtype)
    if not word_packable(x.dtype):
        x = x.astype(jnp.float32)
    view = leaf_block_view(x, block_rows)
    if r == 1:
        if view.dtype == jnp.int32:
            return view
        return jax.lax.bitcast_convert_type(view, jnp.int32)
    words = -(-view.shape[1] // r)
    tail = words * r - view.shape[1]
    if tail:
        view = jnp.pad(view, ((0, 0), (0, tail)))
    return jax.lax.bitcast_convert_type(
        view.reshape(view.shape[0], words, r), jnp.int32)


def decode_block_words(words: jnp.ndarray, leaf: LeafMeta,
                       block_rows: int) -> jnp.ndarray:
    """Inverse of :func:`leaf_block_words`: ``(n_blocks, >= payload_words)``
    int32 words back to the leaf-shaped array — bit-exact for
    word-packable dtypes, a value cast through f32 otherwise."""
    dt = np.dtype(leaf.dtype)
    elems = leaf_frame_width(leaf, block_rows)
    r = dtype_word_ratio(dt)
    pw = -(-elems // r)
    words = words[:, :pw]
    if not word_packable(dt):
        vals = jax.lax.bitcast_convert_type(words, jnp.float32)
    elif dt == np.dtype(np.int32):
        vals = words
    else:
        vals = jax.lax.bitcast_convert_type(words, dt)
        if r > 1:
            vals = vals.reshape(words.shape[0], pw * r)
    vals = vals[:, :elems]
    rows = max(leaf.rows, 1)
    vals = vals.reshape(-1, max(leaf.row_width, 1))[:rows]
    return vals.reshape(leaf.shape).astype(leaf.dtype)


def leaf_block_view(x: jnp.ndarray, block_rows: int) -> jnp.ndarray:
    """Reshape a leaf to (n_blocks, elems_per_block), zero-padded.

    Single-block leaves (rows <= block_rows) are returned unpadded as
    (1, rows·row_width) — padding a 2-row layer-stacked leaf out to 128
    rows would be a 64× memory/compute blowup for zero benefit. Consumers
    reduce within blocks, so per-leaf block widths may differ.
    """
    if x.ndim == 0:
        x = x[None]
    rows = x.shape[0]
    row_width = int(np.prod(x.shape[1:])) if x.ndim > 1 else 1
    flat = x.reshape(rows, row_width)
    n_blocks = max(1, math.ceil(rows / block_rows))
    if n_blocks == 1:
        return flat.reshape(1, rows * row_width)
    pad = n_blocks * block_rows - rows
    if pad:
        flat = jnp.pad(flat, ((0, pad), (0, 0)))
    return flat.reshape(n_blocks, block_rows * row_width)


def split_global_mask(mask: jnp.ndarray, partition: BlockPartition) -> list[jnp.ndarray]:
    """Split a (total_blocks,) vector into per-leaf (n_blocks,) segments."""
    return [mask[l.offset:l.offset + l.n_blocks] for l in partition.leaves]


def expand_block_mask(block_mask: jnp.ndarray, leaf: LeafMeta,
                      block_rows: int) -> jnp.ndarray:
    """(n_blocks,) bool -> bool array broadcastable to the leaf shape.

    Expands over rows then broadcasts across trailing dims.
    """
    row_mask = jnp.repeat(block_mask, block_rows)[:leaf.rows]
    if len(leaf.shape) == 0:
        return row_mask[0]
    return row_mask.reshape((leaf.rows,) + (1,) * (len(leaf.shape) - 1))


def select_blocks(dst: PyTree, src: PyTree, global_mask: jnp.ndarray,
                  partition: BlockPartition) -> PyTree:
    """Per-block select: where mask is True take ``src``'s block, else ``dst``.

    This is the primitive behind both partial recovery (dst=live params,
    src=checkpoint, mask=lost blocks) and partial checkpoint save
    (dst=checkpoint values, src=live params, mask=selected blocks).
    """
    dst_flat = jax.tree_util.tree_leaves(dst)
    src_flat = jax.tree_util.tree_leaves(src)
    masks = split_global_mask(global_mask, partition)
    out = []
    for d, s, m, leaf in zip(dst_flat, src_flat, masks, partition.leaves):
        em = expand_block_mask(m, leaf, partition.block_rows)
        out.append(jnp.where(em, s, d))
    return jax.tree_util.tree_unflatten(partition.treedef, out)


def block_scores(a: PyTree, b: PyTree, partition: BlockPartition,
                 norm_fn: Callable[[jnp.ndarray, jnp.ndarray, LeafMeta], jnp.ndarray],
                 ) -> jnp.ndarray:
    """Per-block distance scores between two pytrees -> (total_blocks,) f32.

    ``norm_fn(a_view, b_view, leaf)`` maps two (n_blocks, block_elems) views
    to per-block scores; see :mod:`repro.core.norms`. Colocated leaves
    (shared offsets) accumulate into the same slots.
    """
    a_flat = jax.tree_util.tree_leaves(a)
    b_flat = jax.tree_util.tree_leaves(b)
    out = jnp.zeros((partition.total_blocks,), jnp.float32)
    for xa, xb, leaf in zip(a_flat, b_flat, partition.leaves):
        va = leaf_block_view(xa.astype(jnp.float32), partition.block_rows)
        vb = leaf_block_view(xb.astype(jnp.float32), partition.block_rows)
        s = norm_fn(va, vb, leaf).astype(jnp.float32)
        out = jax.lax.dynamic_update_slice(
            out, jax.lax.dynamic_slice(out, (leaf.offset,),
                                       (leaf.n_blocks,)) + s,
            (leaf.offset,))
    return out


def masked_sq_norm(a: PyTree, b: PyTree, global_mask: jnp.ndarray,
                   partition: BlockPartition) -> jnp.ndarray:
    """||(a − b) restricted to masked blocks||² — the δ' of Theorem 4.1."""
    def sq(va, vb, leaf):
        return jnp.sum((va - vb) ** 2, axis=-1)
    per_block = block_scores(a, b, partition, sq)
    return jnp.sum(jnp.where(global_mask, per_block, 0.0))


def tree_sq_norm(a: PyTree, b: PyTree) -> jnp.ndarray:
    """||a − b||² over the whole tree — the δ of full recovery."""
    diffs = jax.tree_util.tree_map(
        lambda x, y: jnp.sum((x.astype(jnp.float32) - y.astype(jnp.float32)) ** 2), a, b)
    return jax.tree_util.tree_reduce(jnp.add, diffs, jnp.float32(0.0))
