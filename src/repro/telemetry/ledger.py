"""Perturbation-cost ledger: the paper's iteration-cost bound, per event.

Every recovery event applies a perturbation ``δ′`` (zero when the lost
blocks came back from a fresh live tier, the running checkpoint's
staleness otherwise). The paper's Theorem 3.2 (and its SCAR refinement,
Thm 4.1) prices that perturbation in *iterations*:

    ι ≤ log(1 + c^{-T}·‖δ′‖ / ‖x⁰−x*‖) / log(1/c)

The ledger records, for every recovery, the lost blocks, the recovery
tiers used, the measured ‖δ′‖², and that bound — computed by calling
:func:`repro.core.iteration_cost.single_perturbation_bound` (per event)
and :func:`repro.core.iteration_cost.iteration_cost_bound` (jointly over
the whole fault history), so ledger numbers are bit-identical to the
theory module's. The running cumulative series is the run's
"iterations owed to faults" — the quantity behind the paper's headline
78–95% iteration-cost reduction, now a first-class observable.

The contraction rate ``c`` and initial distance ``‖x⁰−x*‖`` are usually
only known after a clean reference run; :meth:`set_rates` back-fills every
entry's bound, so the ledger can record online and price at the end.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Sequence

from repro.core.iteration_cost import (iteration_cost_bound,
                                       single_perturbation_bound)


@dataclasses.dataclass
class LedgerEntry:
    step: Optional[int]            # iteration the failure hit (T)
    lost_blocks: int
    tier_counts: Optional[dict]    # blocks recovered per tier name
    applied_sq: float              # measured ‖δ′‖²
    delta_norm: float              # ‖δ′‖ = sqrt(applied_sq)
    bound: Optional[float] = None  # Thm-3.2/4.1 iteration-cost bound
    extra: dict = dataclasses.field(default_factory=dict)

    @property
    def source_tiers(self) -> dict:
        """Tiers that actually supplied blocks (nonzero counts only)."""
        return {k: v for k, v in (self.tier_counts or {}).items() if v}


class PerturbationLedger:
    """Append-only per-recovery cost accounting.

    ``c``/``x0_err`` may be passed up front (bounds computed as events
    arrive) or via :meth:`set_rates` afterwards (bounds back-filled).
    """

    def __init__(self, c: Optional[float] = None,
                 x0_err: Optional[float] = None) -> None:
        self.c = c
        self.x0_err = x0_err
        self.entries: list[LedgerEntry] = []

    # -- recording ----------------------------------------------------------

    def record(self, step: Optional[int], lost_blocks: int,
               tier_counts: Optional[dict], applied_sq: float,
               **extra: Any) -> LedgerEntry:
        applied_sq = float(applied_sq)
        entry = LedgerEntry(step=None if step is None else int(step),
                            lost_blocks=int(lost_blocks),
                            tier_counts=(dict(tier_counts)
                                         if tier_counts else None),
                            applied_sq=applied_sq,
                            delta_norm=math.sqrt(max(applied_sq, 0.0)),
                            extra=dict(extra))
        entry.bound = self._bound(entry)
        self.entries.append(entry)
        return entry

    def set_rates(self, c: float, x0_err: float) -> None:
        """Fix the contraction rate + initial distance and (re)price every
        recorded entry with them."""
        self.c = float(c)
        self.x0_err = float(x0_err)
        for e in self.entries:
            e.bound = self._bound(e)

    def _bound(self, e: LedgerEntry) -> Optional[float]:
        """Exactly ``single_perturbation_bound`` — never re-derived here."""
        if self.c is None or self.x0_err is None or e.step is None:
            return None
        return single_perturbation_bound(e.delta_norm, self.c,
                                         T=e.step, x0_err=self.x0_err)

    # -- series + aggregates ------------------------------------------------

    def iterations_owed(self) -> list[Optional[float]]:
        """Running cumulative sum of per-event bounds — the "iterations
        owed to faults" series (None while unpriced)."""
        out: list[Optional[float]] = []
        total = 0.0
        for e in self.entries:
            if e.bound is None:
                out.append(None)
            else:
                total += e.bound
                out.append(total)
        return out

    def delta_series(self, horizon: Optional[int] = None) -> Sequence[float]:
        """Dense ‖δ_ℓ‖ vector (length ``max(step)+1`` or ``horizon``) —
        the input shape Theorem 3.2's joint bound expects. Events at the
        same step accumulate (norms add as an upper bound)."""
        steps = [e.step for e in self.entries if e.step is not None]
        T = max(steps, default=0)
        n = (int(horizon) if horizon is not None else T) + 1
        dense = [0.0] * n
        for e in self.entries:
            if e.step is not None and e.step < n:
                dense[e.step] += e.delta_norm
        return dense

    def cumulative_bound(self, horizon: Optional[int] = None,
                         ) -> Optional[float]:
        """The joint Theorem-3.2 bound over the whole fault history —
        exactly ``iteration_cost_bound`` on the dense delta series."""
        if self.c is None or self.x0_err is None or not self.entries:
            return None
        return float(iteration_cost_bound(self.delta_series(horizon),
                                          self.c, self.x0_err))

    def summary(self) -> dict:
        """Ledger roll-up for reports: totals, the per-event table, and
        both cost aggregates (per-event sum + joint bound)."""
        owed = self.iterations_owed()
        priced = [b for b in owed if b is not None]
        per_tier: dict[str, int] = {}
        for e in self.entries:
            for t, n in (e.tier_counts or {}).items():
                per_tier[t] = per_tier.get(t, 0) + int(n)
        return {
            "n_events": len(self.entries),
            "lost_blocks": sum(e.lost_blocks for e in self.entries),
            "applied_sq_total": sum(e.applied_sq for e in self.entries),
            "tier_blocks": per_tier,
            "c": self.c,
            "x0_err": self.x0_err,
            "entries": [{
                "step": e.step, "lost_blocks": e.lost_blocks,
                "source_tiers": e.source_tiers,
                "applied_sq": e.applied_sq, "delta_norm": e.delta_norm,
                "bound": e.bound,
            } for e in self.entries],
            "iterations_owed": owed,
            "iterations_owed_total": (priced[-1] if priced else None),
            "cumulative_bound": self.cumulative_bound(),
        }
