"""Mamba2 (SSD — state-space duality) language model [arXiv:2405.21060].

TPU adaptation of the SSD algorithm: the sequence is processed in chunks of
``cfg.ssm_chunk`` tokens. Within a chunk the recurrence is computed in its
*dual* quadratic (attention-like) matmul form — MXU-friendly, 128-aligned —
and chunk-to-chunk state is carried by a short ``lax.scan``. This is the
structure the paper's authors target at GPU tensor cores; it maps directly
onto the TPU MXU (see kernels/ssd_scan for the Pallas tile).

Simplifications vs. the reference CUDA implementation (noted in DESIGN.md):
single B/C group (n_groups=1), depthwise short conv applied to x only.

Decode is the O(1) recurrent form: h ← a·h + dt·B⊗x per layer.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding.partition import DistContext

PyTree = Any


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def init_mixer(rng, cfg: ModelConfig) -> PyTree:
    dt = _dtype(cfg)
    D, DI, N, H, P = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                      cfg.ssm_heads, cfg.ssm_headdim)
    ks = jax.random.split(rng, 4)
    return {
        # in_proj -> [z (DI), x (DI), B (N), C (N), dt (H)]
        "in_proj": L.dense_init(ks[0], (D, 2 * DI + 2 * N + H), D, dt),
        "conv_w": L.dense_init(ks[1], (cfg.conv_width, DI), cfg.conv_width, dt),
        "A_log": jnp.zeros((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D_skip": jnp.ones((H,), jnp.float32),
        "out_proj": L.dense_init(ks[2], (DI, D), DI, dt),
    }


def init_layer(rng, cfg: ModelConfig) -> PyTree:
    return {"norm": jnp.ones((cfg.d_model,), _dtype(cfg)),
            "mixer": init_mixer(rng, cfg)}


def init_params(rng, cfg: ModelConfig) -> PyTree:
    k_embed, k_layers = jax.random.split(rng)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    return {
        **L.init_embed(k_embed, cfg, _dtype(cfg)),
        "layers": jax.vmap(lambda k: init_layer(k, cfg))(layer_keys),
        "final_norm": jnp.ones((cfg.d_model,), _dtype(cfg)),
    }


# ---------------------------------------------------------------------------
# mixer forward pieces
# ---------------------------------------------------------------------------

def _split_proj(zxbcdt, cfg: ModelConfig):
    DI, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :DI]
    x = zxbcdt[..., DI:2 * DI]
    Bm = zxbcdt[..., 2 * DI:2 * DI + N]
    Cm = zxbcdt[..., 2 * DI + N:2 * DI + 2 * N]
    dt = zxbcdt[..., 2 * DI + 2 * N:]
    return z, x, Bm, Cm, dt


def _causal_conv(x, w, state=None):
    """Depthwise causal conv. x: (B,S,DI); w: (K,DI). state: (B,K-1,DI)."""
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else None
    return jax.nn.silu(out), new_state


def ssd_chunked(x, dt, A, Bm, Cm, cfg: ModelConfig, ctx: DistContext,
                h0=None):
    """Chunked SSD scan (pure-JAX oracle for kernels/ssd_scan).

    x: (B,S,H,P); dt: (B,S,H) (post-softplus); A: (H,) negative;
    Bm, Cm: (B,S,N). Returns (y (B,S,H,P), h_final (B,H,P,N)).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(cfg.ssm_chunk, S)
    nc = S // Q
    assert nc * Q == S, f"seq {S} must be divisible by chunk {Q}"

    la = (dt * A).reshape(Bsz, nc, Q, H)                  # log a_t (negative)
    xc = x.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)

    cum = jnp.cumsum(la, axis=2)                           # (B,nc,Q,H)
    seg_total = cum[:, :, -1]                              # (B,nc,H)

    # intra-chunk (dual quadratic form): M[i,j] = exp(cum_i - cum_j)·dt_j·(C_i·B_j)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)         # (B,nc,Q,Q)
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    M = jnp.where(causal[None, None, :, :, None],
                  jnp.exp(decay), 0.0) * scores[..., None] \
        * dtc[:, :, None, :, :]                            # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M, xc)

    # chunk summaries: S_c = Σ_j exp(cum_Q - cum_j)·dt_j·(B_j ⊗ x_j)
    w = jnp.exp(seg_total[:, :, None, :] - cum) * dtc      # (B,nc,Q,H)
    chunk_state = jnp.einsum("bcjh,bcjn,bcjhp->bchpn", w, Bc, xc)

    # inter-chunk recurrence over nc chunks
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def body(h, xs):
        seg, st = xs                                       # (B,H), (B,H,P,N)
        h_out = h                                          # state BEFORE chunk
        h = h * jnp.exp(seg)[:, :, None, None] + st
        return h, h_out

    hs_final, h_prev = jax.lax.scan(
        body, h0, (jnp.moveaxis(seg_total, 1, 0), jnp.moveaxis(chunk_state, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                    # (B,nc,H,P,N)

    # inter-chunk contribution: y_inter[i] = exp(cum_i)·(C_i · h_prev)
    y_inter = jnp.einsum("bcin,bchpn->bcihp", Cc, h_prev) \
        * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y, hs_final


def mixer_fwd(x, p, cfg: ModelConfig, ctx: DistContext):
    """x: (B,S,D) -> (B,S,D). Training/prefill path."""
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xi, Bm, Cm, dtr = _split_proj(zxbcdt, cfg)
    xi, _ = _causal_conv(xi, p["conv_w"])
    H, P = cfg.ssm_heads, cfg.ssm_headdim
    Bsz, S, _ = x.shape
    # SSD heads are independent -> shard H over the model axis so the
    # O(Q²)·H intra-chunk intermediates divide across TP
    xh = xi.reshape(Bsz, S, H, P).astype(jnp.float32)
    xh = ctx.shard(xh, "dp", None, ctx.tp, None)
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])
    dt = ctx.shard(dt, "dp", None, ctx.tp)
    A = -jnp.exp(p["A_log"])
    y, _ = ssd_chunked(xh, dt, A, Bm.astype(jnp.float32),
                       Cm.astype(jnp.float32), cfg, ctx)
    y = ctx.shard(y, "dp", None, ctx.tp, None)
    y = y + xh * p["D_skip"][:, None]
    y = y.reshape(Bsz, S, cfg.d_inner).astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return ctx.shard(out, "dp", None, None)


def mixer_decode(x, p, state, cfg: ModelConfig, ctx: DistContext):
    """Single-token recurrent step. x: (B,1,D); state: dict(h, conv)."""
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xi, Bm, Cm, dtr = _split_proj(zxbcdt, cfg)
    xi, conv_state = _causal_conv(xi, p["conv_w"], state["conv"])
    H, P = cfg.ssm_heads, cfg.ssm_headdim
    Bsz = x.shape[0]
    xh = xi.reshape(Bsz, H, P).astype(jnp.float32)
    dt = jax.nn.softplus(dtr[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A)                                     # (B,H)
    h = state["h"] * a[:, :, None, None] \
        + jnp.einsum("bh,bn,bhp->bhpn", dt, Bm[:, 0].astype(jnp.float32), xh)
    y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), h)
    y = y + xh * p["D_skip"][:, None]
    y = y.reshape(Bsz, 1, cfg.d_inner).astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return ctx.shard(out, "dp", None, None), {"h": h, "conv": conv_state}


# ---------------------------------------------------------------------------
# model-level API
# ---------------------------------------------------------------------------

def train_loss(params, batch, cfg: ModelConfig, ctx: DistContext, **_):
    h = L.embed_tokens(batch["tokens"], params, ctx)
    h = ctx.shard(h, "dp", None, None)

    def body(x, lp):
        fn = mixer_fwd
        if cfg.remat:
            fn = jax.checkpoint(mixer_fwd, static_argnums=(2, 3),
                                policy=jax.checkpoint_policies.nothing_saveable)
        x = x + fn(L.rms_norm(x, lp["norm"]), lp["mixer"], cfg, ctx)
        # sequence-parallel residual stream (saved activations S-sharded)
        return ctx.shard(x, "dp", ctx.tp, None), None

    h, _ = jax.lax.scan(body, h, params["layers"],
                        unroll=L.UNROLL_FOR_COSTING)
    h = L.rms_norm(h, params["final_norm"])
    mask = batch.get("mask", jnp.ones_like(batch["labels"], jnp.float32))
    return L.lm_loss_chunked(h, params, batch["labels"], mask, cfg, ctx)


def init_state(cfg: ModelConfig, batch: int, ctx: DistContext) -> PyTree:
    H, P, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    return {
        "h": jnp.zeros((cfg.n_layers, batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.conv_width - 1,
                           cfg.d_inner), jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(params, batch, cfg: ModelConfig, ctx: DistContext, spec=None):
    """Run the chunked scan over the prompt, carrying final SSM states."""
    tokens = batch["tokens"]
    h = L.embed_tokens(tokens, params, ctx)
    h = ctx.shard(h, "dp", None, None)
    Bsz, S = tokens.shape

    def body(x, lp):
        xn = L.rms_norm(x, lp["norm"])
        p = lp["mixer"]
        zxbcdt = jnp.einsum("bsd,de->bse", xn, p["in_proj"])
        z, xi, Bm, Cm, dtr = _split_proj(zxbcdt, cfg)
        xi, conv_state = _causal_conv(xi, p["conv_w"])
        H, P = cfg.ssm_heads, cfg.ssm_headdim
        xh = xi.reshape(Bsz, S, H, P).astype(jnp.float32)
        dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])
        A = -jnp.exp(p["A_log"])
        y, h_fin = ssd_chunked(xh, dt, A, Bm.astype(jnp.float32),
                               Cm.astype(jnp.float32), cfg, ctx)
        y = y + xh * p["D_skip"][:, None]
        y = y.reshape(Bsz, S, cfg.d_inner).astype(x.dtype) * jax.nn.silu(z)
        out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
        return x + ctx.shard(out, "dp", None, None), (h_fin, conv_state)

    h, (hs, convs) = jax.lax.scan(body, h, params["layers"],
                                  unroll=L.UNROLL_FOR_COSTING)
    hfin = L.rms_norm(h, params["final_norm"])
    logits = L.lm_logits(hfin[:, -1:], params, ctx)
    state = {"h": hs, "conv": convs, "pos": jnp.asarray(S, jnp.int32)}
    return logits, state


def decode_step(params, state, tokens, cfg: ModelConfig, ctx: DistContext,
                spec=None):
    x = L.embed_tokens(tokens, params, ctx)
    x = ctx.shard(x, "dp", None, None)

    def body(x, xs):
        lp, hs, cs = xs
        out, new = mixer_decode(L.rms_norm(x, lp["norm"]), lp["mixer"],
                                {"h": hs, "conv": cs}, cfg, ctx)
        return x + out, (new["h"], new["conv"])

    x, (hs, convs) = jax.lax.scan(body, x,
                                  (params["layers"], state["h"], state["conv"]),
                                  unroll=L.UNROLL_FOR_COSTING)
    h = L.rms_norm(x, params["final_norm"])
    logits = L.lm_logits(h, params, ctx)
    return logits, {"h": hs, "conv": convs, "pos": state["pos"] + 1}
