"""Iteration-cost theory (paper §3 and Appendix B).

Implements, as plain JAX-compatible functions:

- ``delta_T``                    — the time-discounted perturbation aggregate
                                   ``Δ_T = Σ_{ℓ=0}^T c^{-ℓ} E||δ_ℓ||``.
- ``iteration_cost_bound``       — Theorem 3.2:
                                   ``ι ≤ log(1 + Δ_T/||x⁰−x*||) / log(1/c)``.
- ``infinite_perturbation_bound``— Appendix B.1 (perturbation every step,
                                   bounded by Δ): irreducible error
                                   ``(c/(1−c))Δ`` and the adjusted cost bound.
- ``estimate_contraction``       — empirical fit of the linear rate ``c``
                                   from an observed error trajectory
                                   (paper: "the value of c is determined
                                   empirically").
- ``iterations_to_eps``          — κ(·, ε) for a measured error trajectory:
                                   first iteration index whose error is < ε
                                   (used to *measure* iteration cost
                                   empirically, ι = κ(y) − κ(x)).
- ``sgd_iteration_bound``        — Appendix B.2 sublinear analogue with
                                   a_k = Π(1−α_i): implicit-k bound solved
                                   numerically.

All functions are pure and operate on scalars / 1-D arrays so they can be
used both inside jit (for on-the-fly predictive decisions, paper §7) and on
the host for analysis.
"""
from __future__ import annotations

import math
from typing import Sequence

import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


def delta_T(delta_norms: Array, c: float) -> Array:
    """Δ_T = Σ_{ℓ=0}^{T} c^{-ℓ} E||δ_ℓ|| (Theorem 3.2).

    ``delta_norms[ℓ]`` is E||δ_ℓ|| for ℓ = 0..T. Perturbation-free steps
    contribute 0, so sparse fault histories can be passed as dense vectors.

    Computed in log-space-free stable form by factoring out c^{-T}:
    Δ_T = c^{-T} Σ c^{T-ℓ}||δ_ℓ|| — avoids overflow of c^{-ℓ} for long
    horizons when combined with the bound (which only needs Δ_T relative
    to ||x⁰−x*||; callers comparing at iteration k should prefer
    :func:`discounted_delta` below).
    """
    delta_norms = jnp.asarray(delta_norms)
    T = delta_norms.shape[0] - 1
    ell = jnp.arange(T + 1)
    # c^{T-ℓ} is <= 1, then one overall factor c^{-T}.
    weights = jnp.power(c, T - ell)
    return jnp.power(c, -T) * jnp.sum(weights * delta_norms)


def discounted_delta(delta_norms: Array, c: float, k: int) -> Array:
    """c^k · Δ_T — the *absolute* residual contribution of perturbations at
    iteration k ≥ T (numerically stable form of the Lemma A.1 second term)."""
    delta_norms = jnp.asarray(delta_norms)
    T = delta_norms.shape[0] - 1
    ell = jnp.arange(T + 1)
    return jnp.sum(jnp.power(c, k - ell) * delta_norms)


def iteration_cost_bound(delta_norms: Array, c: float, x0_err: float) -> Array:
    """Theorem 3.2: ι(δ, ε) ≤ log(1 + Δ_T/||x⁰−x*||) / log(1/c).

    Note the bound is independent of ε (it cancels). ``x0_err`` is
    ||x^{(0)} − x*||.
    """
    dT = delta_T(delta_norms, c)
    return jnp.log1p(dT / x0_err) / jnp.log(1.0 / c)


def single_perturbation_bound(delta_norm: float, c: float, T: int, x0_err: float) -> float:
    """Specialization for one perturbation of size ||δ|| at iteration T
    (the checkpoint-recovery case, Example 2.3): Δ_T = c^{-T}||δ||."""
    dT = (c ** (-T)) * delta_norm
    return float(math.log1p(dT / x0_err) / math.log(1.0 / c))


def infinite_perturbation_bound(delta_bound: float, c: float, x0_err: float, eps: float) -> float:
    """Appendix B.1: perturbations of size ≤ Δ in *every* iteration.

    Returns the iteration-cost bound (14); ``float('inf')`` when ε is
    below the irreducible error (c/(1−c))Δ or the bound is uninformative.
    """
    irreducible = (c / (1.0 - c)) * delta_bound
    if eps <= irreducible or x0_err <= irreducible:
        return float("inf")
    num = 1.0 - irreducible / x0_err
    den = 1.0 - irreducible / eps
    return math.log(num / den) / math.log(1.0 / c)


def irreducible_error(delta_bound: float, c: float) -> float:
    """Appendix B.1 irreducible error (c/(1−c))·Δ."""
    return (c / (1.0 - c)) * delta_bound


def estimate_contraction(errors: Sequence[float], burn_in: int = 0) -> float:
    """Fit the linear rate c from an error trajectory ||x^{(k)} − x*||.

    Least-squares slope of log(err) vs k (geometric fit), ignoring the
    first ``burn_in`` iterations and any non-positive/zero errors.
    Clipped into (0, 1) exclusive — callers need log(1/c) > 0.
    """
    errs = np.asarray(errors, dtype=np.float64)[burn_in:]
    mask = errs > 0
    ks = np.arange(errs.shape[0], dtype=np.float64)[mask]
    logs = np.log(errs[mask])
    if ks.shape[0] < 2:
        raise ValueError("need at least two positive error observations")
    slope = np.polyfit(ks, logs, 1)[0]
    c = float(np.exp(slope))
    return min(max(c, 1e-9), 1.0 - 1e-9)


def iterations_to_eps(errors: Sequence[float], eps: float) -> int:
    """κ(a, ε): first iteration with error < ε, else len(errors) (∞-proxy)."""
    errs = np.asarray(errors)
    hits = np.nonzero(errs < eps)[0]
    return int(hits[0]) if hits.size else int(errs.shape[0])


def empirical_iteration_cost(perturbed_errors: Sequence[float],
                             clean_errors: Sequence[float],
                             eps: float) -> int:
    """Measured ι = κ(y, ε) − κ(x, ε) from two error trajectories."""
    return iterations_to_eps(perturbed_errors, eps) - iterations_to_eps(clean_errors, eps)


def sgd_iteration_bound(delta_norms: Array,
                        alpha0: float,
                        G: float,
                        x0_err: float,
                        eps: float,
                        max_k: int = 1_000_000) -> int:
    """Appendix B.2: sublinear (SGD, α_k = α₀/k) analogue of Theorem 3.2.

    Uses a_k = Π_{i=1..k}(1 − α_i) and the recursion
    E||y^{(k)} − x*|| ≤ a_k [ ||x⁰−x*|| + Σ_ℓ a_ℓ^{-1}(E||δ_ℓ|| + α_ℓ² G²) ],
    solving for the smallest k meeting ε numerically. Returns ``max_k`` if
    unreachable within the horizon.
    """
    deltas = np.asarray(delta_norms, dtype=np.float64)
    T = deltas.shape[0]
    a = 1.0
    # accumulate the bracketed constant over the perturbation horizon
    bracket = float(x0_err)
    a_hist = []
    for k in range(1, T + 1):
        alpha = min(alpha0 / k, 0.999)
        a *= (1.0 - alpha)
        a_hist.append(a)
        bracket += (deltas[k - 1] + alpha * alpha * G * G) / a
    # after T: no more perturbations; error ≤ a_k * bracket
    k = T
    while k < max_k:
        if a * bracket < eps:
            return k
        k += 1
        alpha = min(alpha0 / k, 0.999)
        a *= (1.0 - alpha)
    return max_k
