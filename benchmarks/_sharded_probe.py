"""Forced-8-device CPU driver behind the sharded-arena bench rows.

``bench_maintain`` runs in the normal single-device process (the committed
byte baselines depend on that), so the SPMD measurements live here: the
parent spawns this module as a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and parses the JSON
this prints on stdout. Standalone use works too::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m benchmarks._sharded_probe --quick

Two measurements:

  sharded      — arena-resident vs PyTree-pack TrainLoop on the SAME
                 (4, 2) mesh: accounted maintenance bytes/step for both,
                 loss bit-equality (identical shardings → identical
                 reduction orders; see DESIGN.md for why this only holds
                 same-mesh), pack-free-ness, and the ICI/DCN split of the
                 anti-affine replica transfer.
  elastic_soak — host loss at step 4 shrinks the mesh to the survivors
                 (8 → 4 shards under batch divisibility), the heal at
                 step 9 re-grows to the full mesh; training must stay
                 finite and arena-resident throughout.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")

import time

import jax
import numpy as np


def _bench(quick: bool) -> dict:
    from repro.configs import get_config
    from repro.core.policy import CheckpointPolicy
    from repro.data.pipeline import ShardedLMDataset
    from repro.fabric import FabricConfig
    from repro.launch.mesh import make_mesh_compat
    from repro.sharding.partition import make_dist_ctx
    from repro.training import ArenaTrainState, TrainLoop, TrainLoopConfig

    cfg = get_config("qwen2-1.5b", reduced=True)
    mesh = make_mesh_compat((4, 2), ("data", "model"))
    warm = 2
    steps = 5 if quick else 10

    out = {}
    for name, arena_state in (("arena", True), ("pytree", False)):
        ctx = make_dist_ctx(mesh)
        loop = TrainLoop(cfg, ctx, loop_cfg=TrainLoopConfig(
            policy=CheckpointPolicy.scar(fraction=0.25, interval=2),
            fabric=FabricConfig(), arena_state=arena_state))
        state = loop.init_state()
        if arena_state:
            assert isinstance(state, ArenaTrainState)
        ds = ShardedLMDataset(cfg, batch=8, seq=32, ctx=ctx)
        it = iter(ds)
        state = loop.run(state, it, warm)          # compile everything
        ctl = loop.controller
        fab = ctl.fabric
        b0 = fab.stats["maintain_bytes_moved"] + ctl.stats["save_bytes_moved"]
        m0 = max(fab.stats["arena_maintains"] + fab.stats["fused_maintains"],
                 1)
        i0, d0 = fab.stats["ici_bytes_moved"], fab.stats["dcn_bytes_moved"]
        t0 = time.perf_counter()
        state = loop.run(state, it, steps)
        total_us = (time.perf_counter() - t0) / steps * 1e6
        ms = loop.metrics[warm:]
        overhead_us = float(np.median(
            [m["overhead_seconds"] for m in ms])) * 1e6
        n_maint = max(fab.stats["arena_maintains"]
                      + fab.stats["fused_maintains"] - m0, 1)
        out[name] = {
            "bytes_per_step":
                (fab.stats["maintain_bytes_moved"]
                 + ctl.stats["save_bytes_moved"] - b0) / steps,
            "overhead_us": overhead_us,
            "total_us": total_us,
            "losses": [m["loss"] for m in loop.metrics],
            "live_packs": fab.stats["live_packs"],
            "resident_maintains": fab.stats["arena_resident_maintains"],
            "ici_per_maintain":
                (fab.stats["ici_bytes_moved"] - i0) / n_maint,
            "dcn_per_maintain":
                (fab.stats["dcn_bytes_moved"] - d0) / n_maint,
            "shards": fab.arena_layout.shards,
        }
    return {
        "shards": out["arena"]["shards"],
        "arena": out["arena"], "pytree": out["pytree"],
        "loss_bit_equal":
            out["arena"]["losses"] == out["pytree"]["losses"],
        "bytes_le_pack": bool(out["arena"]["bytes_per_step"]
                              <= out["pytree"]["bytes_per_step"]),
    }


def _elastic_soak(quick: bool) -> dict:
    from repro.configs import get_config
    from repro.core.policy import CheckpointPolicy
    from repro.data.pipeline import ShardedLMDataset
    from repro.fabric import FabricConfig
    from repro.launch.mesh import make_mesh_compat
    from repro.sharding.partition import make_dist_ctx
    from repro.training import ArenaTrainState, TrainLoop, TrainLoopConfig

    cfg = get_config("qwen2-1.5b", reduced=True)
    mesh = make_mesh_compat((4, 2), ("data", "model"))
    ctx = make_dist_ctx(mesh)
    steps = 12 if quick else 20
    loop = TrainLoop(cfg, ctx, loop_cfg=TrainLoopConfig(
        policy=CheckpointPolicy.scar(fraction=0.25, interval=2),
        fabric=FabricConfig(elastic=True),
        fail_schedule=[(4, "host", 1)], heal_after=5))
    state = loop.init_state()
    assert isinstance(state, ArenaTrainState)
    ds = ShardedLMDataset(cfg, batch=8, seq=32, ctx=ctx)
    t0 = time.perf_counter()
    state = loop.run(state, iter(ds), steps)
    us_per_step = (time.perf_counter() - t0) / steps * 1e6
    fab = loop.controller.fabric
    resizes = [m["mesh_resize"]["shards"] for m in loop.metrics
               if "mesh_resize" in m]
    finite = all(np.isfinite(m["loss"]) for m in loop.metrics)
    params_finite = all(np.isfinite(np.asarray(l)).all()
                        for l in jax.tree_util.tree_leaves(state.params))
    return {
        "us_per_step": us_per_step,
        "steps": steps,
        "mesh_resizes": fab.stats["mesh_resizes"],
        "resize_shards": resizes,
        "min_shards": min(resizes) if resizes else fab.arena_layout.shards,
        "final_shards": fab.arena_layout.shards,
        "live_packs": fab.stats["live_packs"],
        "losses_finite": bool(finite),
        "cycle_ok": bool(finite and params_finite
                         and resizes == [4, 8]
                         and fab.stats["live_packs"] == 0
                         and fab.arena_layout.shards == 8),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    assert jax.device_count() == 8, (
        f"need 8 forced host devices, got {jax.device_count()} — set "
        "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    result = {"sharded": _bench(args.quick),
              "elastic": _elastic_soak(args.quick)}
    json.dump(result, sys.stdout)
    print()


if __name__ == "__main__":
    main()
