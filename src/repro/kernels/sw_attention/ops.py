"""jit'd wrapper: model-layout sliding-window attention.

Accepts the model's (B, S, Hq, Dh) / (B, S, Hk, Dh) layout, regroups for
GQA, and dispatches to the Pallas kernel (TPU) or the jnp oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.sw_attention.kernel import sw_attention_pallas
from repro.kernels.sw_attention.ref import sw_attention_ref


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


def sw_attention(q, k, v, *, window: int, q_chunk: int = 128,
                 kv_chunk: int = 128, use_pallas: bool = True,
                 interpret: bool | None = None) -> jnp.ndarray:
    """q: (B, S, Hq, Dh); k, v: (B, S, Hk, Dh) -> (B, S, Hq, Dh)."""
    B, S, Hq, Dh = q.shape
    Hk = k.shape[2]
    G = Hq // Hk
    qg = q.transpose(0, 2, 1, 3).reshape(B * Hk, G, S, Dh)
    kg = k.transpose(0, 2, 1, 3).reshape(B * Hk, S, Dh)
    vg = v.transpose(0, 2, 1, 3).reshape(B * Hk, S, Dh)
    if use_pallas:
        if interpret is None:
            interpret = not _is_tpu()
        o = sw_attention_pallas(qg, kg, vg, window=window, q_chunk=q_chunk,
                                kv_chunk=kv_chunk, interpret=interpret)
    else:
        o = sw_attention_ref(qg, kg, vg, window=window)
    o = o.reshape(B, Hk, G, S, Dh).transpose(0, 3, 1, 2, 4)
    return o.reshape(B, S, Hq, Dh).astype(q.dtype)
