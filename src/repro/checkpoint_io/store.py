"""On-disk mirror of the running checkpoint (paper §4.3 persistent storage).

Layout: **packed per-shard block files**. Block payloads are appended to a
log-structured shard file (``blocks.shard``, or ``host_NNNN/blocks.shard``
under the fabric-aware domain keying) and MANIFEST.json carries an offset
index — ``segments[gid] = [offset, nbytes]`` points at each block's *latest*
copy. Earlier layouts wrote one ``.npy`` file per block, which costs a
file create + rename + metadata flush per saved block; a fraction-r partial
save of k blocks now appends k contiguous payloads to (at most) a handful
of shard files and publishes one manifest. Reads go through ``np.memmap``
slices of the shard, so a partial DISK-tier read touches only the needed
blocks' byte ranges.

Crash consistency is log-structured: appends land before the manifest is
atomically replaced, so a crash mid-write leaves dangling bytes at the tail
of a shard (unreferenced garbage) but never a torn block — readers follow
the old index until the new one is published. ``compact()`` rewrites each
shard keeping only live segments (the log otherwise grows by the write
volume of overwritten blocks; ``disk_nbytes`` reports both). Compaction
writes a *new generation* file (``blocks.gNNNN.shard``), publishes the
manifest pointing into it, and only then removes older generations — a
crash at any point leaves either the old index over the old file or the
new index over the new file, never live offsets into a rewritten file.

Writes can be deferred to a background thread (``background=True``),
matching §4.3: "the training algorithm can be resumed as soon as the
in-memory caches have been updated, while output to the shared persistent
storage happens asynchronously".

**Fabric-aware sharding** (optional ``homes``/``domains`` at ``init``):
shards are keyed by failure domain — ``host_NNNN/blocks.shard`` per the
block's home host — and the manifest records ``host_of_block``. A DISK-tier
read after a domain loss then touches only the surviving domains' shards
(:meth:`read_blocks`), and :meth:`read_surviving` models a host-local
deployment where a dead domain's shard is unreachable. :meth:`write_parity`
mirrors the fabric's XOR parity blocks to disk so blocks whose domain shard
died remain reconstructable offline from the surviving members + parity.
"""
from __future__ import annotations

import json
import os
import queue
import random
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

from repro.core.blocks import BlockPartition
from repro.telemetry.recorder import NULL_RECORDER

PyTree = Any


def _shard_name(gen: int) -> str:
    return f"blocks.g{gen:04d}.shard"


def _is_shard_name(name: str) -> bool:
    return name.startswith("blocks.") and name.endswith(".shard")


class ShardedCheckpointStore:
    def __init__(self, root: str):
        self.root = root
        self.partition: Optional[BlockPartition] = None
        self.must_reload = False
        self.host_of_block: Optional[np.ndarray] = None
        # flat-arena layout (optional): segments are keyed by arena-block
        # id — one row per (leaf, block), so colocated leaves (which share
        # global block ids) each persist their own payload
        self.arena_layout = None
        self._leaf_first_seg: Optional[np.ndarray] = None
        # per shard-directory compaction generation (segments index offsets
        # are only valid within their generation's file)
        self._gen: dict = {}
        self._q: "queue.Queue" = queue.Queue()
        self._worker: Optional[threading.Thread] = None
        self._worker_error: Optional[BaseException] = None
        self._worker_error_ctx: Optional[dict] = None
        self.recorder = NULL_RECORDER
        os.makedirs(root, exist_ok=True)

    def attach_recorder(self, recorder: Any) -> None:
        """Late-bind a recorder (events only — the store keeps no stats
        dict). No-op if ``recorder`` is null or one is already attached."""
        if recorder is None or not getattr(recorder, "enabled", False) \
                or self.recorder.enabled:
            return
        self.recorder = recorder

    # -- lifecycle ----------------------------------------------------------

    def init(self, params: PyTree, partition: BlockPartition,
             homes: Optional[np.ndarray] = None,
             domains: Optional[Any] = None,
             arena_layout=None,
             arena_values: Optional[np.ndarray] = None) -> None:
        """``homes``/``domains`` (a block→device map + ``FailureDomainMap``)
        switch on the domain-keyed layout. The keying snapshots the homes at
        init — the *initial* placement; elastic re-homing moves the in-memory
        tiers, while the disk mirror keeps its stable layout until a
        re-keying :meth:`compact` migrates segments to their current homes.

        ``arena_layout`` (+ ``arena_values``, the packed word arena of
        ``params``) switches on the **arena segment layout**: segments are
        the arena block table's rows (word payloads — raw leaf-dtype bytes
        for word-packable dtypes, the f32 image otherwise; one per
        (leaf, block)), a save appends one contiguous buffer per host
        shard, and partial reads memmap exactly the needed byte ranges."""
        self.partition = partition
        self.arena_layout = arena_layout
        self._gen = {}
        if arena_layout is not None:
            # arena-block index of each leaf's first block. The block
            # table is offset-ordered (tail-packed leaves after the main
            # region), NOT flatten-ordered — derive from the table, where
            # each leaf's blocks are contiguous and in b order.
            first = np.full((len(partition.leaves),), -1, np.int64)
            for idx, ab in enumerate(arena_layout.blocks):
                if first[ab.leaf] < 0:
                    first[ab.leaf] = idx
            self._leaf_first_seg = first
        if homes is not None and domains is not None:
            self.host_of_block = np.asarray(
                domains.host_of(np.asarray(homes)), np.int32)
            for h in np.unique(self.host_of_block):
                os.makedirs(os.path.join(self.root, f"host_{int(h):04d}"),
                            exist_ok=True)
        n_segments = (len(arena_layout.blocks) if arena_layout is not None
                      else partition.total_blocks)
        manifest = {
            "block_rows": partition.block_rows,
            "leaves": [
                {"name": l.name, "shape": list(l.shape), "dtype": str(np.dtype(l.dtype)),
                 "rows": l.rows, "row_width": l.row_width,
                 "n_blocks": l.n_blocks, "offset": l.offset}
                for l in partition.leaves
            ],
            "saved_iter": [0] * partition.total_blocks,
            "segments": [None] * n_segments,
        }
        if arena_layout is not None:
            # per-segment stored dtype: word-packable leaves persist raw
            # element bytes in that dtype, everything else the f32 image —
            # an offline reader needs no partition object to decode
            from repro.core.blocks import word_packable
            seg_dtype = [
                str(np.dtype(partition.leaves[ab.leaf].dtype))
                if word_packable(partition.leaves[ab.leaf].dtype)
                else "float32"
                for ab in arena_layout.blocks]
            manifest["arena"] = {"n_segments": n_segments,
                                 "segment_dtype": seg_dtype}
        if self.host_of_block is not None:
            manifest["host_of_block"] = [int(h) for h in self.host_of_block]
        self._write_manifest(manifest)
        # initial full mirror (x^(0)) — the running checkpoint's base
        full_mask = np.ones((partition.total_blocks,), bool)
        if arena_layout is not None:
            assert arena_values is not None, \
                "arena-layout init needs the packed arena values"
            from repro.core.arena import ARENA_TILE
            tiles = arena_layout.tiles_for_blocks(
                np.arange(partition.total_blocks))
            data = np.asarray(arena_values, np.float32).reshape(
                -1, ARENA_TILE)[tiles]
            self.write_arena(full_mask, tiles, data, step=0,
                             background=False)
        else:
            self.write_blocks(full_mask, params, step=0, background=False)

    # -- arena segment helpers ----------------------------------------------

    def _seg_gid(self, seg: int) -> int:
        """Global block id owning segment ``seg`` (identity without an
        arena layout)."""
        if self.arena_layout is None:
            return int(seg)
        return int(self.arena_layout.blocks[seg].gid)

    def _manifest_path(self) -> str:
        return os.path.join(self.root, "MANIFEST.json")

    def _write_manifest(self, manifest: dict) -> None:
        """Atomic replace: a crash mid-write can never leave a torn manifest
        (readers either see the old complete file or the new one)."""
        tmp = self._manifest_path() + ".tmp"
        with open(tmp, "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, self._manifest_path())

    def _shard_dir(self, seg: int) -> str:
        """Shard directory of a segment (arena-block id in arena mode,
        global block id otherwise)."""
        if self.host_of_block is not None:
            gid = self._seg_gid(seg)
            host_dir = f"host_{int(self.host_of_block[gid]):04d}"
            return os.path.join(self.root, host_dir)
        return self.root

    def _shard_path(self, seg: int) -> str:
        d = self._shard_dir(seg)
        return os.path.join(d, _shard_name(self._gen.get(d, 0)))

    # -- write path ---------------------------------------------------------

    def write_blocks(self, mask, values: PyTree, step: int,
                     background: bool = True) -> int:
        """Persist the masked blocks. Returns bytes written (scheduled)."""
        assert self.partition is not None, "call init() first"
        mask_np = np.asarray(mask)
        # materialize only the selected blocks on host
        leaves = jax.tree_util.tree_leaves(values)
        jobs: list[tuple[int, np.ndarray]] = []
        nbytes = 0
        br = self.partition.block_rows
        if self.arena_layout is not None:
            # arena-layout store fed from a PyTree: convert each selected
            # (leaf, block) to its word arena payload so the on-disk
            # format stays uniform (and colocated leaves each keep their
            # own segment instead of overwriting a shared gid key).
            # Word-packable dtypes store raw little-endian element bytes
            # zero-padded to whole words; legacy dtypes (f64/int64/bool)
            # keep the f32-image convention, one word per element.
            from repro.core.blocks import word_packable
            for li, (leaf_meta, x) in enumerate(
                    zip(self.partition.leaves, leaves)):
                seg = mask_np[leaf_meta.offset:
                              leaf_meta.offset + leaf_meta.n_blocks]
                if not seg.any():
                    continue
                packable = word_packable(leaf_meta.dtype)
                arr = (np.asarray(x) if packable
                       else np.asarray(x, np.float32)).reshape(
                    max(leaf_meta.rows, 1), -1)
                payload = self.arena_layout.payload_words[li]
                for b in np.nonzero(seg)[0]:
                    lo = int(b) * br
                    hi = min(lo + br, max(leaf_meta.rows, 1))
                    blk = np.ascontiguousarray(arr[lo:hi]).reshape(-1)
                    full = np.zeros((payload,), np.float32)
                    if packable:
                        full.view(np.dtype(leaf_meta.dtype))[:blk.size] = blk
                    else:
                        full[:blk.size] = blk
                    ab = int(self._leaf_first_seg[li]) + int(b)
                    jobs.append((ab, full))
                    nbytes += full.nbytes
        else:
            for leaf_meta, x in zip(self.partition.leaves, leaves):
                seg = mask_np[leaf_meta.offset:leaf_meta.offset + leaf_meta.n_blocks]
                if not seg.any():
                    continue
                arr = np.asarray(x).reshape(max(leaf_meta.rows, 1), -1)
                for b in np.nonzero(seg)[0]:
                    lo, hi = b * br, min((b + 1) * br, leaf_meta.rows)
                    blk = arr[lo:hi] if hi > lo else arr[:1]
                    jobs.append((leaf_meta.offset + int(b), blk))
                    nbytes += blk.nbytes
        if background:
            self._ensure_worker()
            self._q.put(("write", jobs, step))
        else:
            self._do_write(jobs, step)
        if self.recorder.enabled:
            self.recorder.event("mirror", step=int(step), bytes=nbytes,
                                segments=len(jobs), background=background)
        return nbytes

    def write_arena(self, mask, tiles: np.ndarray, data: np.ndarray,
                    step: int, background: bool = True) -> int:
        """Persist arena segments straight from gathered arena tiles.

        ``tiles``/``data``: the ascending tile indices covering the
        selected blocks and their ``(len(tiles), ARENA_TILE)`` float32
        payloads (the controller gathers them off-device in one O(k)
        transfer). Each selected arena block's payload is sliced out
        contiguously; the write path batches all of a host's payloads
        into **one** append write per shard file."""
        assert self.arena_layout is not None, "store not in arena mode"
        mask_np = np.asarray(mask, bool)
        tiles = np.asarray(tiles, np.int64)
        from repro.core.arena import ARENA_TILE
        flat = np.asarray(data, np.float32).reshape(-1)
        jobs: list[tuple[int, np.ndarray]] = []
        nbytes = 0
        # O(selected): only the masked gids' arena blocks are visited
        for ab_index in self.arena_layout.blocks_for_gids(
                np.nonzero(mask_np)[0]):
            ab = self.arena_layout.blocks[ab_index]
            t0 = ab.offset // ARENA_TILE
            # tail-packed blocks start mid-tile and may straddle two tiles;
            # their (consecutive-integer) tiles sit at adjacent positions
            # of the unique ascending gather, so one flat slice from the
            # intra-tile start still covers the payload
            last = (ab.offset + max(ab.words, 1) - 1) // ARENA_TILE
            nt = int(last - t0 + 1)
            pos = int(np.searchsorted(tiles, t0))
            assert pos + nt <= tiles.size and tiles[pos] == t0, \
                "gathered tiles do not cover the selected blocks"
            start = pos * ARENA_TILE + (ab.offset - t0 * ARENA_TILE)
            payload = flat[start:start + ab.payload]
            jobs.append((int(ab_index), payload))
            nbytes += payload.nbytes
        if background:
            self._ensure_worker()
            self._q.put(("write", jobs, step))
        else:
            self._do_write(jobs, step)
        if self.recorder.enabled:
            self.recorder.event("mirror", step=int(step), bytes=nbytes,
                                segments=len(jobs), background=background)
        return nbytes

    def write_parity(self, step: int, parity: np.ndarray,
                     parity_homes: np.ndarray,
                     domains: Optional[Any] = None,
                     members: Optional[np.ndarray] = None) -> int:
        """Mirror the fabric's parity blocks to disk for offline
        reconstruction. One file per group, keyed by the parity home's host
        when the store is domain-keyed, plus a small ``PARITY.json``
        manifest (step, frame width, per-group paths, and — essential for
        reconstruction after a restart — each group's member block ids as
        of encode time, which elastic re-striping changes). Synchronous —
        the parity buffer is 1/g the size of a block write."""
        parity = np.asarray(parity)
        # XOR homes are (n_groups,); RS(k, m) homes are (n_groups, m) with
        # a (n_groups, m, E) parity array — each group's rows share a file,
        # keyed by row 0's host (the primary fingerprint row)
        homes = np.asarray(parity_homes, np.int32)
        paths = []
        for g in range(parity.shape[0]):
            if self.host_of_block is not None and domains is not None:
                key = int(np.ravel(homes[g])[0]) if homes.ndim > 1 \
                    else int(homes[g])
                host_dir = f"host_{int(domains.host_of(key)):04d}"
                os.makedirs(os.path.join(self.root, host_dir), exist_ok=True)
                rel = os.path.join(host_dir, f"parity_{g:06d}.npy")
            else:
                rel = f"parity_{g:06d}.npy"
            path = os.path.join(self.root, rel)
            tmp = path + ".tmp"
            with open(tmp, "wb") as f:
                np.save(f, parity[g])
            os.replace(tmp, path)
            paths.append(rel)
        meta = {"step": int(step), "n_groups": int(parity.shape[0]),
                "frame_elems": int(parity.shape[-1]) if parity.ndim > 1 else 1,
                "n_parity": int(parity.shape[1]) if parity.ndim == 3 else 1,
                "paths": paths,
                "parity_homes": homes.tolist()}
        if members is not None:
            meta["members"] = [[int(b) for b in row if b >= 0]
                               for row in np.asarray(members)]
        tmp = os.path.join(self.root, "PARITY.json.tmp")
        with open(tmp, "w") as f:
            json.dump(meta, f)
        os.replace(tmp, os.path.join(self.root, "PARITY.json"))
        return int(parity.nbytes)

    def read_parity(self) -> Optional[tuple[np.ndarray, dict]]:
        """(parity array, manifest) from the last mirror, or None."""
        meta_path = os.path.join(self.root, "PARITY.json")
        if not os.path.exists(meta_path):
            return None
        with open(meta_path) as f:
            meta = json.load(f)
        groups = [np.load(os.path.join(self.root, rel))
                  for rel in meta["paths"]]
        return np.stack(groups), meta

    def _ensure_worker(self) -> None:
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    # background-write retry budget: a failed batch is re-attempted this
    # many times with jittered exponential backoff (base * 2^attempt *
    # U[0.5, 1.5)) before the error is parked for flush(). Shared-FS blips
    # (NFS timeouts, transient ENOSPC during log rotation) usually clear
    # within one backoff; anything persistent still surfaces — never
    # silently. Tests shrink the base delay to keep the suite fast.
    _retry_limit = 2
    _retry_base_delay = 0.05

    def _drain(self) -> None:
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                _, jobs, step = item
                self._write_with_retry(item, jobs, step)
            except BaseException as e:  # keep draining; surface on flush()
                if self._worker_error is None:
                    # keep the FIRST failure's context — later failures
                    # are usually cascades of the same root cause
                    self._worker_error = e
                    self._worker_error_ctx = self._job_context(item)
                    if self.recorder.enabled:
                        # name the ROOT cause, not the retry-budget
                        # wrapper — that's what names the broken disk
                        root = e
                        while root.__cause__ is not None:
                            root = root.__cause__
                        self.recorder.event("store_write_failed",
                                            error=repr(root),
                                            **self._worker_error_ctx)
            finally:
                # task_done even on failure — otherwise q.join() in flush()
                # deadlocks forever on the first bad write
                self._q.task_done()

    def _write_with_retry(self, item, jobs, step: int) -> None:
        for attempt in range(self._retry_limit + 1):
            try:
                self._do_write(jobs, step)
                return
            except BaseException as e:
                if attempt >= self._retry_limit:
                    raise RuntimeError(
                        f"background write failed after "
                        f"{self._retry_limit + 1} attempts") from e
                delay = (self._retry_base_delay * (2 ** attempt)
                         * (0.5 + random.random()))
                if self.recorder.enabled:
                    self.recorder.event(
                        "store_write_retried", attempt=attempt + 1,
                        delay_seconds=delay, error=repr(e),
                        **self._job_context(item))
                time.sleep(delay)

    def _job_context(self, item) -> dict:
        """step/segment/host/path of a failed background write batch (its
        first job — enough to name the shard that broke), for the error
        ``flush()`` raises and the ``store_write_failed`` event."""
        ctx = {"step": None, "segment": None, "host": None, "path": None}
        try:
            _, jobs, step = item
            ctx["step"] = int(step)
            if jobs:
                seg = int(jobs[0][0])
                ctx["segment"] = seg
                ctx["path"] = self._shard_path(seg)
                if self.host_of_block is not None:
                    ctx["host"] = int(self.host_of_block[self._seg_gid(seg)])
        except BaseException:
            pass  # diagnostics must never mask the original failure
        return ctx

    def _do_write(self, jobs, step: int) -> None:
        """Append the segments' payloads to their shards, then publish the
        new offset index atomically — the log-structured write path.
        Each shard's payloads are coalesced into one buffer first, so a
        partial save costs ONE append write per touched host shard."""
        by_shard: dict[str, list[tuple[int, np.ndarray]]] = {}
        for seg, blk in jobs:
            by_shard.setdefault(self._shard_path(seg), []).append((seg, blk))
        new_segments: dict[int, list[int]] = {}
        for path, batch in by_shard.items():
            with open(path, "ab") as f:
                off = f.tell()
                chunks = []
                for seg, blk in batch:
                    payload = np.ascontiguousarray(blk)
                    new_segments[seg] = [off, int(payload.nbytes)]
                    off += int(payload.nbytes)
                    chunks.append(payload.tobytes())
                f.write(b"".join(chunks))
                f.flush()
                os.fsync(f.fileno())
        with open(self._manifest_path()) as f:
            manifest = json.load(f)
        for seg, _ in jobs:
            manifest["saved_iter"][self._seg_gid(seg)] = int(step)
            manifest["segments"][seg] = new_segments[seg]
        self._write_manifest(manifest)

    def flush(self) -> None:
        """Block until all background writes have landed.

        Raises if any background write failed since the last flush — a
        silently-lost mirror write would otherwise surface only at recovery
        time, when the data is already gone.
        """
        if self._worker is not None and self._worker.is_alive():
            self._q.join()
        if self._worker_error is not None:
            err, self._worker_error = self._worker_error, None
            ctx, self._worker_error_ctx = self._worker_error_ctx, None
            detail = ""
            if ctx:
                detail = (f" (step {ctx.get('step')}, "
                          f"segment {ctx.get('segment')}, "
                          f"host {ctx.get('host')}, "
                          f"shard {ctx.get('path')})")
            raise RuntimeError(
                f"background checkpoint write failed{detail}") from err

    def compact(self, rekey_homes: Optional[np.ndarray] = None,
                domains: Optional[Any] = None) -> int:
        """Rewrite every shard keeping only the live (indexed) segments.

        The append log grows by the write volume of overwritten blocks;
        compaction reclaims it. Returns the bytes reclaimed. Synchronous
        and exclusive — callers stop writing around it (the background
        queue is flushed first).

        ``rekey_homes`` (+ ``domains``) re-keys the domain layout during
        the same generational rewrite: each live segment is copied into
        the shard of its block's *current* home host, so after long
        elastic degradation the on-disk locality matches the placement
        engine's view again — the move rides the rewrite the compaction
        was paying for anyway. Subsequent writes land on the new homes.

        Crash-safe ordering: the live segments are copied into the *next
        generation's* files, the manifest (new offsets + generation +
        re-keyed ``host_of_block``) is published atomically, and only
        then are older generation files unlinked — stale offsets never
        point into a rewritten file; a crash before the unlink merely
        leaves an orphan generation that the next compaction sweeps up."""
        assert self.partition is not None
        self.flush()
        with open(self._manifest_path()) as f:
            manifest = json.load(f)
        segments = manifest["segments"]
        # source paths are resolved under the OLD keying, targets under
        # the new one — a re-key changes host_of_block between the two
        src_path = {seg: self._shard_path(seg)
                    for seg in range(len(segments))
                    if segments[seg] is not None}
        old_dirs = {self._shard_dir(seg) for seg in src_path}
        if rekey_homes is not None:
            assert domains is not None, "re-keying needs the domain map"
            self.host_of_block = np.asarray(
                domains.host_of(np.asarray(rekey_homes)), np.int32)
            manifest["host_of_block"] = [int(h) for h in self.host_of_block]
            for h in np.unique(self.host_of_block):
                os.makedirs(os.path.join(self.root, f"host_{int(h):04d}"),
                            exist_ok=True)
        by_dir: dict[str, list[int]] = {}
        for seg in src_path:
            by_dir.setdefault(self._shard_dir(seg), []).append(seg)
        old_sizes = {d: (os.path.getsize(os.path.join(
            d, _shard_name(self._gen.get(d, 0)))) if os.path.exists(
            os.path.join(d, _shard_name(self._gen.get(d, 0)))) else 0)
            for d in old_dirs | set(by_dir)}
        mmaps: dict[str, Optional[np.memmap]] = {}
        new_size = 0
        cleanup: list[str] = []
        for d, segs in by_dir.items():
            new_gen = self._gen.get(d, 0) + 1
            new_path = os.path.join(d, _shard_name(new_gen))
            os.makedirs(d, exist_ok=True)   # source dir may have vanished
            with open(new_path, "wb") as f:
                # preserve source order so compaction stays a sequential
                # read of the live bytes per source shard
                for seg in sorted(segs,
                                  key=lambda s: (src_path[s],
                                                 segments[s][0])):
                    path = src_path[seg]
                    if path not in mmaps:
                        ok = os.path.exists(path) and os.path.getsize(path)
                        mmaps[path] = (np.memmap(path, np.uint8, mode="r")
                                       if ok else None)
                    mm = mmaps[path]
                    if mm is None:
                        # source shard unreachable (crash orphan / dead
                        # host): the segment's data is gone — drop it from
                        # the index. Keeping the old offset would resolve
                        # inside the NEW generation file after the bump
                        # below and read another segment's bytes.
                        segments[seg] = None
                        continue
                    off, n = segments[seg]
                    new_off = f.tell()
                    f.write(mm[off:off + n].tobytes())
                    segments[seg] = [new_off, n]
                f.flush()
                os.fsync(f.fileno())
            self._gen[d] = new_gen
            new_size += os.path.getsize(new_path)
            cleanup.append(d)
        mmaps.clear()
        manifest["segments"] = segments
        manifest["shard_gen"] = {os.path.relpath(d, self.root): g
                                 for d, g in self._gen.items()}
        self._write_manifest(manifest)
        keep = {os.path.join(d, _shard_name(self._gen[d]))
                for d in cleanup}
        for d in set(cleanup) | old_dirs:   # old gens (and crash orphans)
            if not os.path.isdir(d):        # vanished with its host
                continue
            for name in os.listdir(d):      # die last
                p = os.path.join(d, name)
                if _is_shard_name(name) and p not in keep:
                    os.unlink(p)
        reclaimed = int(sum(old_sizes.values()) - new_size)
        if self.recorder.enabled:
            self.recorder.event("compact", reclaimed=reclaimed,
                                rekeyed=rekey_homes is not None)
        return reclaimed

    def disk_nbytes(self) -> dict[str, int]:
        """On-disk footprint: shard bytes (the append log), the subset of
        those bytes the index still references (live), and the parity
        mirror."""
        shard_bytes = 0
        parity_bytes = 0
        for dirpath, _, files in os.walk(self.root):
            for name in files:
                p = os.path.join(dirpath, name)
                if _is_shard_name(name):
                    shard_bytes += os.path.getsize(p)
                elif name.startswith("parity_") and name.endswith(".npy"):
                    parity_bytes += os.path.getsize(p)
        live = 0
        if self.partition is not None and os.path.exists(self._manifest_path()):
            with open(self._manifest_path()) as f:
                for seg in json.load(f)["segments"]:
                    if seg is not None:
                        live += seg[1]
        return {"shard": int(shard_bytes), "live": int(live),
                "parity": int(parity_bytes)}

    # -- read path ----------------------------------------------------------

    def _read_masked(self, block_mask: Optional[np.ndarray]) -> PyTree:
        """Reassemble from disk; ``block_mask=None`` reads every block.

        Blocks whose shard is unreachable (or that were never indexed)
        come back zero — callers select by the mask they asked for."""
        assert self.partition is not None
        self.flush()
        with open(self._manifest_path()) as f:
            segments = json.load(f)["segments"]
        br = self.partition.block_rows
        mmaps: dict[str, Optional[np.memmap]] = {}

        def _payload(seg, dtype):
            if segments[seg] is None:
                return None
            path = self._shard_path(seg)
            if path not in mmaps:
                ok = os.path.exists(path) and os.path.getsize(path) > 0
                mmaps[path] = (np.memmap(path, np.uint8, mode="r")
                               if ok else None)
            mm = mmaps[path]
            if mm is None:
                return None
            off, n = segments[seg]
            return np.frombuffer(mm[off:off + n].tobytes(), dtype)

        out = []
        for li, leaf_meta in enumerate(self.partition.leaves):
            rows = max(leaf_meta.rows, 1)
            width = max(leaf_meta.row_width, 1)
            dtype = np.dtype(leaf_meta.dtype)
            arr = np.zeros((rows, width), dtype)
            for b in range(leaf_meta.n_blocks):
                gid = leaf_meta.offset + b
                if block_mask is not None and not block_mask[gid]:
                    continue
                if self.arena_layout is not None:
                    # arena segment keyed by arena-block id: word-packable
                    # dtypes store raw element bytes (view the payload
                    # directly as the leaf dtype — bit-exact), legacy
                    # dtypes the f32 image (value cast back). Trim the
                    # zero padding the ragged/sub-word tail carries.
                    from repro.core.blocks import word_packable
                    seg = int(self._leaf_first_seg[li]) + b
                    packable = word_packable(dtype)
                    blk = _payload(seg, dtype if packable else np.float32)
                    if blk is None:
                        continue
                    lo = b * br
                    n_rows = min(br, rows - lo) if leaf_meta.n_blocks > 1 \
                        else rows
                    blk = blk[:n_rows * width].reshape(-1, width)
                    arr[lo:lo + blk.shape[0]] = (blk if packable
                                                 else blk.astype(dtype))
                else:
                    blk = _payload(gid, dtype)
                    if blk is None:
                        continue
                    blk = blk.reshape(-1, width)
                    arr[b * br:b * br + blk.shape[0]] = blk
            out.append(arr.reshape(leaf_meta.shape))
        return jax.tree_util.tree_unflatten(self.partition.treedef, out)

    def read_all(self) -> PyTree:
        """Reassemble the full running checkpoint from disk (total-failure
        recovery)."""
        return self._read_masked(None)

    def read_blocks(self, block_mask) -> PyTree:
        """Partial DISK-tier read: only the masked blocks' byte ranges are
        touched — with the domain-keyed layout, a post-domain-loss recovery
        memmaps only the shards its DISK blocks live in, not the whole
        mirror. Off-mask blocks come back zero (callers select by the same
        mask)."""
        return self._read_masked(np.asarray(block_mask, bool))

    def read_surviving(self, failed_hosts) -> tuple[PyTree, np.ndarray]:
        """Host-local-deployment read: blocks whose shard sits on a failed
        host are unreadable. Returns (values, present_mask) — missing
        blocks are zero in ``values`` and False in the mask; the parity
        mirror (:meth:`read_parity`) reconstructs them offline."""
        assert self.partition is not None
        if self.host_of_block is None:
            present = np.ones((self.partition.total_blocks,), bool)
            return self.read_all(), present
        failed = np.asarray(failed_hosts, np.int32)
        present = ~np.isin(self.host_of_block, failed)
        return self._read_masked(present), present

    def saved_iters(self) -> np.ndarray:
        with open(self._manifest_path()) as f:
            return np.asarray(json.load(f)["saved_iter"], np.int32)
