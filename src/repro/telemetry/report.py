"""Run-summary rendering over a :class:`~repro.telemetry.recorder.Recorder`.

``run_report`` folds the recorder's three streams (events, scopes/metrics,
ledger) into one structured summary dict; ``format_report`` renders it as
text. Consumed by ``examples/quickstart.py``, the ``bench_maintain``
telemetry rows, and the soak availability summary — the single place that
answers "what did this run's failures actually cost, in bound and in
wall-clock?".
"""
from __future__ import annotations

from typing import Any, Optional

import numpy as np

# canonical tier order for the recovery table
_TIER_ORDER = ("SURVIVOR", "PEER_REPLICA", "PARITY", "RUNNING_CKPT", "DISK")


def _tier_table(events: list[dict]) -> dict:
    """Per-tier totals over every ``recovery`` event: blocks recovered and
    the perturbation energy (‖δ′‖² share) each tier applied."""
    blocks: dict[str, int] = {}
    sq: dict[str, float] = {}
    n = lost = 0
    applied = 0.0
    for ev in events:
        if ev.get("kind") != "recovery":
            continue
        n += 1
        lost += int(ev.get("lost_blocks") or 0)
        applied += float(ev.get("applied_sq") or 0.0)
        for t, k in (ev.get("tier_counts") or {}).items():
            blocks[t] = blocks.get(t, 0) + int(k)
        for t, v in (ev.get("tier_sq") or {}).items():
            sq[t] = sq.get(t, 0.0) + float(v)
    order = [t for t in _TIER_ORDER if t in blocks or t in sq]
    order += [t for t in blocks if t not in order]
    return {"n_recoveries": n, "lost_blocks": lost,
            "applied_sq_total": applied,
            "per_tier": {t: {"blocks": blocks.get(t, 0),
                             "sq": sq.get(t, 0.0)} for t in order}}


def _bytes_breakdown(rec: Any) -> dict:
    """Bytes-moved breakdown from the registered component scopes plus the
    compact events' reclaim totals."""
    scopes = getattr(rec, "scopes", {}) or {}

    def _get(scope: str, key: str) -> int:
        return int(sum(v.get(key, 0) for name, v in scopes.items()
                       if name == scope or name.startswith(scope + "#")))

    compacted = sum(int(ev.get("reclaimed") or 0)
                    for ev in (getattr(rec, "events", []) or [])
                    if ev.get("kind") == "compact")
    # arena_padding_ratio is a gauge, not a counter: take the max across
    # fabric scope instances rather than summing (one fabric in practice)
    padding = max((float(v.get("arena_padding_ratio", 0.0))
                   for name, v in scopes.items()
                   if name == "fabric" or name.startswith("fabric#")),
                  default=0.0)
    return {"maintain": _get("fabric", "maintain_bytes_moved"),
            "save": _get("controller", "save_bytes_moved"),
            "mirrored": _get("controller", "bytes_mirrored"),
            "compact_reclaimed": compacted,
            "arena_padding_ratio": padding}


def _interconnect(rec: Any) -> dict:
    """ICI-vs-DCN split of the anti-affine replica transfer: cumulative
    totals from the fabric scope plus per-maintain averages from the
    ``maintain`` events' ``ici_bytes``/``dcn_bytes`` fields. On a real
    topology these are the link classes a block migration would cross, so
    the split is the input a Chameleon-style migration cost model needs
    (zero on an unmeshed fabric, where the replica never leaves the
    host)."""
    scopes = getattr(rec, "scopes", {}) or {}

    def _get(scope: str, key: str) -> int:
        return int(sum(v.get(key, 0) for name, v in scopes.items()
                       if name == scope or name.startswith(scope + "#")))

    per = [(int(ev.get("ici_bytes") or 0), int(ev.get("dcn_bytes") or 0))
           for ev in (getattr(rec, "events", []) or [])
           if ev.get("kind") == "maintain"
           and ("ici_bytes" in ev or "dcn_bytes" in ev)]
    n = len(per)
    return {"ici": _get("fabric", "ici_bytes_moved"),
            "dcn": _get("fabric", "dcn_bytes_moved"),
            "maintains": n,
            "ici_per_maintain": (sum(p[0] for p in per) / n) if n else 0.0,
            "dcn_per_maintain": (sum(p[1] for p in per) / n) if n else 0.0}


def _overhead(rec: Any) -> dict:
    """p50/p95/max of the maintenance-overhead histogram (clean steps
    only — the loops exclude failure/heal steps at observe time)."""
    hist = (getattr(rec, "histograms", {}) or {}).get(
        "train/overhead_seconds")
    if hist is None or not hist.samples:
        # classic runners book per-phase spans instead of a histogram —
        # fall back to the maintain-span durations
        tracer = getattr(rec, "tracer", None)
        samples = tracer.durations("maintain") if tracer is not None else []
        if not samples:
            return {"count": 0, "mean": 0.0, "p50": 0.0, "p95": 0.0,
                    "max": 0.0}
        a = np.asarray(samples)
        return {"count": int(a.size), "mean": float(a.mean()),
                "p50": float(np.percentile(a, 50)),
                "p95": float(np.percentile(a, 95)),
                "max": float(a.max())}
    return hist.summary()


def run_report(rec: Any, horizon: Optional[int] = None) -> dict:
    """The unified run summary. ``rec`` is a Recorder (a NullRecorder
    yields an empty-but-well-formed report). ``horizon`` optionally fixes
    the dense delta-series length for the joint cumulative bound."""
    events = list(getattr(rec, "events", []) or [])
    kinds: dict[str, int] = {}
    for ev in events:
        kinds[ev.get("kind", "?")] = kinds.get(ev.get("kind", "?"), 0) + 1
    ledger = getattr(rec, "ledger", None)
    out = {
        "events": {"total": len(events), "by_kind": kinds},
        "recovery": _tier_table(events),
        "overhead_seconds": _overhead(rec),
        "bytes": _bytes_breakdown(rec),
        "interconnect": _interconnect(rec),
        "ledger": (ledger.summary() if ledger is not None else None),
    }
    if ledger is not None and horizon is not None:
        out["ledger"]["cumulative_bound"] = \
            ledger.cumulative_bound(horizon)
    return out


def format_report(report: dict) -> str:
    """Render a report dict as a human-readable text block."""
    lines = []
    ev = report["events"]
    kinds = ", ".join(f"{k}={n}" for k, n in sorted(ev["by_kind"].items()))
    lines.append(f"telemetry: {ev['total']} events ({kinds or 'none'})")

    r = report["recovery"]
    if r["n_recoveries"]:
        lines.append(f"recoveries: {r['n_recoveries']} events, "
                     f"{r['lost_blocks']} blocks lost, "
                     f"applied ||d'||^2={r['applied_sq_total']:.3e}")
        sq_hdr = "||d'||^2"
        lines.append(f"  {'tier':<14}{'blocks':>8}  {sq_hdr:>12}")
        for t, row in r["per_tier"].items():
            lines.append(f"  {t:<14}{row['blocks']:>8}  {row['sq']:>12.3e}")
    else:
        lines.append("recoveries: none")

    o = report["overhead_seconds"]
    if o["count"]:
        lines.append(
            f"maintenance overhead: p50={o['p50'] * 1e3:.2f}ms "
            f"p95={o['p95'] * 1e3:.2f}ms max={o['max'] * 1e3:.2f}ms "
            f"({o['count']} clean steps)")

    b = report["bytes"]
    lines.append(f"bytes moved: maintain={b['maintain']:,} "
                 f"save={b['save']:,} mirrored={b['mirrored']:,} "
                 f"compact_reclaimed={b['compact_reclaimed']:,}")
    if b.get("arena_padding_ratio"):
        lines.append(
            f"arena padding ratio: {b['arena_padding_ratio']:.4f} "
            "(pad words / payload words, tail-packed layout)")

    ic = report.get("interconnect") or {}
    if ic.get("ici") or ic.get("dcn"):
        lines.append(
            f"replica interconnect: ici={ic['ici']:,} dcn={ic['dcn']:,} "
            f"(avg {ic['ici_per_maintain']:,.0f}/{ic['dcn_per_maintain']:,.0f}"
            f" per maintain over {ic['maintains']})")

    led = report.get("ledger")
    if led and led["n_events"]:
        owed = led["iterations_owed_total"]
        joint = led["cumulative_bound"]
        lines.append(
            "iterations owed to faults: "
            + (f"{owed:.2f} (sum of per-event Thm-3.2 bounds), "
               if owed is not None else "unpriced (set c/x0_err), ")
            + (f"joint bound {joint:.2f}" if joint is not None
               else "joint bound n/a"))
        for e in led["entries"]:
            bound = (f"{e['bound']:.3f}" if e["bound"] is not None
                     else "n/a")
            tiers = ",".join(f"{t}:{n}" for t, n in e["source_tiers"].items())
            lines.append(f"  step {e['step']}: lost {e['lost_blocks']} "
                         f"blocks via [{tiers}] ||d'||={e['delta_norm']:.3e}"
                         f" -> bound {bound}")
    return "\n".join(lines)
