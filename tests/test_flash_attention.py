"""Chunked flash attention (pure-JAX production path): fwd + custom VJP."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import flash_attention
from repro.sharding import single_device_ctx

CTX = single_device_ctx()


def naive(q, k, v, causal=True, window=0, kpos=None):
    B, S, Hq, Dh = q.shape
    Hk = k.shape[2]
    G = Hq // Hk
    Skv = k.shape[1]
    qg = q.reshape(B, S, Hk, G, Dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(Dh)
    qp = jnp.arange(S)[:, None]
    kp = (jnp.arange(Skv) if kpos is None else kpos)[None, :]
    mask = kp <= qp if causal else jnp.ones((S, Skv), bool)
    if window:
        mask = mask & (qp - kp < window)
    mask = mask & (kp >= 0)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, Hq, Dh)


@pytest.mark.parametrize("dims", [
    # (B, S, Hq, Hk, Dh, window, qc, kc)
    (2, 64, 4, 2, 16, 0, 16, 16),
    (1, 96, 6, 2, 8, 24, 32, 16),
    (2, 50, 2, 2, 8, 0, 16, 16),      # ragged seq vs chunks
    (1, 128, 8, 1, 16, 0, 64, 32),    # MQA
    (1, 64, 4, 4, 16, 16, 16, 32),    # MHA + window
])
def test_forward_matches_naive(dims):
    B, S, Hq, Hk, Dh, W, qc, kc = dims
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, S, Hq, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hk, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hk, Dh)), jnp.float32)
    pos = jnp.arange(S)
    got = flash_attention(q, k, v, pos, pos, causal=True, window=W,
                          q_chunk=qc, kv_chunk=kc, ctx=CTX)
    np.testing.assert_allclose(got, naive(q, k, v, window=W),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dims", [
    (2, 64, 4, 2, 16, 0, 16, 16),
    (1, 96, 6, 2, 8, 24, 32, 16),
])
def test_custom_vjp_matches_naive_grads(dims):
    B, S, Hq, Hk, Dh, W, qc, kc = dims
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(B, S, Hq, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hk, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hk, Dh)), jnp.float32)
    pos = jnp.arange(S)
    w = jnp.asarray(rng.normal(size=(Dh,)), jnp.float32)

    def f(q, k, v):
        o = flash_attention(q, k, v, pos, pos, causal=True, window=W,
                            q_chunk=qc, kv_chunk=kc, ctx=CTX)
        return jnp.sum(o * w)

    def g(q, k, v):
        return jnp.sum(naive(q, k, v, window=W) * w)

    g1 = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=5e-3, atol=5e-4)


def test_decode_single_query_ring_buffer():
    """Decode with a ring-buffer cache: only valid, in-window slots attend."""
    B, Hq, Hk, Dh, W = 1, 2, 1, 8, 4
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(B, 1, Hq, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, W, Hk, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, W, Hk, Dh)), jnp.float32)
    # ring buffer after 6 writes: slots hold positions [4, 5, 2, 3]
    kpos = jnp.asarray([4, 5, 2, 3])
    qpos = jnp.asarray([5])
    got = flash_attention(q, k, v, qpos, kpos, causal=True, window=W,
                          q_chunk=1, kv_chunk=2, ctx=CTX)
    # manual: mask slots with pos <= 5 and 5 - pos < 4 -> positions 2..5 all
    s = jnp.einsum("bqhd,bkhd->bhqk",
                   q.reshape(B, 1, Hq, Dh)[:, :, :1],
                   jnp.repeat(k, Hq // Hk, 2)[:, :, :1]) / math.sqrt(Dh)
    # direct reference over all four slots with the window mask
    mask = (kpos <= 5) & (5 - kpos < W)
    sref = jnp.einsum("bqhd,bkhd->bhqk", q, jnp.repeat(k, 2, 2)) / math.sqrt(Dh)
    sref = jnp.where(mask[None, None, None], sref, -1e30)
    pref = jax.nn.softmax(sref, axis=-1)
    want = jnp.einsum("bhqk,bkhd->bqhd", pref, jnp.repeat(v, 2, 2))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_invalid_slots_ignored():
    B, H, Dh = 1, 1, 8
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(B, 1, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, 8, H, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, 8, H, Dh)), jnp.float32)
    kpos = jnp.asarray([0, 1, 2, -1, -1, -1, -1, -1])   # only 3 valid
    qpos = jnp.asarray([2])
    got = flash_attention(q, k, v, qpos, kpos, causal=True, window=0,
                          q_chunk=1, kv_chunk=4, ctx=CTX)
    want = naive(q, k[:, :3], v[:, :3], causal=False)
    np.testing.assert_allclose(got, want[:, :1], rtol=1e-5, atol=1e-5)
