"""Beyond-paper: the §7 'predictive model' — an adaptive checkpoint advisor.

The paper closes by suggesting that approximating c and ‖x⁰−x*‖ yields a
predictive model "evaluated on-the-fly to inform decisions made by a
system during run-time". This example runs a training job, observes its
contraction rate / drift / checkpoint cost, and lets the advisor pick the
(r, C) policy minimizing expected overhead under a given failure rate.

Run:  PYTHONPATH=src python examples/adaptive_checkpoint_policy.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.advisor import RunObservations, advise
from repro.models.classic import make_model
from repro.training import run_clean
from repro.core.iteration_cost import estimate_contraction


def main():
    model = make_model("mlr", n=600, dim=64, n_classes=5, batch=200)
    print("== observing an unperturbed run of MLR...")
    res = run_clean(model, 80)
    losses = np.asarray(res["losses"])
    errs = np.sqrt(np.maximum(losses - losses.min() * 0.98, 1e-9))
    c = estimate_contraction(errs[:60], burn_in=3)
    print(f"   fitted contraction c = {c:.4f}; ‖x⁰−x*‖ ≈ {errs[0]:.2f}")

    for fail_rate in (1e-5, 1e-3, 5e-2):
        obs = RunObservations(
            drift_per_iter=float((errs[0] - errs[-1]) / len(errs)),
            x0_err=float(errs[0]), c=c,
            t_iter=0.05, t_dump_full=0.02,
            failure_rate=fail_rate, loss_fraction=0.5, current_iter=60)
        policy, report = advise(obs)
        print(f"   failure_rate={fail_rate:8.0e} -> advise r={policy.fraction}"
              f" C={policy.full_interval}"
              f" (partial ckpt every {policy.partial_interval} iters,"
              f" expected overhead {report['expected_overhead_s']*1e3:.2f}"
              f" ms/iter)")
    print("== higher failure rates push toward smaller, more frequent,"
          " prioritized checkpoints — the paper's §4.2 design, chosen"
          " automatically.")


if __name__ == "__main__":
    main()
