"""Core SCAR library: iteration-cost theory + checkpoint/recovery strategies.

The paper's contribution, expressed as composable JAX modules:

- :mod:`repro.core.iteration_cost` — Theorem 3.2 / Appendix B bounds.
- :mod:`repro.core.perturb`        — perturbation generators (random /
  adversarial / reset), the objects the theory quantifies.
- :mod:`repro.core.blocks`         — deterministic block partition of a
  parameter PyTree (the "PS partitions" of the paper, adapted to SPMD).
- :mod:`repro.core.norms`          — pluggable norms (L2, scaled TV).
- :mod:`repro.core.checkpoint`     — running checkpoint + priority/round/
  random selection (paper §4.2).
- :mod:`repro.core.recovery`       — full vs partial recovery (paper §4.1).
- :mod:`repro.core.controller`     — the fault-tolerance controller
  (paper §4.3) driving save/detect/recover.
"""
from repro.core.policy import CheckpointPolicy, SelectionStrategy, RecoveryMode
from repro.core.blocks import BlockPartition, partition_pytree
from repro.core.checkpoint import RunningCheckpoint, init_running_checkpoint, save_step
from repro.core.recovery import sample_failure_mask, apply_failure_and_recover
from repro.core.controller import FTController
from repro.core.iteration_cost import (
    iteration_cost_bound,
    delta_T,
    estimate_contraction,
    iterations_to_eps,
    infinite_perturbation_bound,
)

__all__ = [
    "CheckpointPolicy",
    "SelectionStrategy",
    "RecoveryMode",
    "BlockPartition",
    "partition_pytree",
    "RunningCheckpoint",
    "init_running_checkpoint",
    "save_step",
    "sample_failure_mask",
    "apply_failure_and_recover",
    "FTController",
    "iteration_cost_bound",
    "delta_T",
    "estimate_contraction",
    "iterations_to_eps",
    "infinite_perturbation_bound",
]
