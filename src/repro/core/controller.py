"""Fault-tolerance controller (paper §4.3, Figure 4).

Host-side orchestrator that owns the running checkpoint and drives:

1. *Checkpoint coordination* — every ``policy.partial_interval`` iterations,
   score blocks (priority), update the in-memory running checkpoint
   (jitted, device-resident), and mirror the saved blocks to persistent
   storage. Training resumes as soon as the in-memory cache is updated;
   the disk write is a background-able host callback (paper §4.3 step 4).
2. *Recovery coordination* — on a detected failure (a lost block mask),
   partially (or fully) restore from the running checkpoint. If the
   in-memory replica itself was lost (total failure), reload from the
   persistent store.

The controller is deliberately thin: all numerics are pure functions from
:mod:`repro.core.checkpoint` / :mod:`repro.core.recovery`, so it composes
with any training loop (including the big-model SPMD trainer).
"""
from __future__ import annotations

import time
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.blocks import BlockPartition, block_scores, partition_pytree
from repro.core.checkpoint import (RunningCheckpoint, full_save,
                                   init_running_checkpoint, save_step)
from repro.core.norms import get_norm
from repro.core.policy import CheckpointPolicy, RecoveryMode, SelectionStrategy
from repro.core.recovery import apply_failure_and_recover, sample_failure_mask

PyTree = Any


class FTController:
    """Checkpoint + recovery coordinator for one training job."""

    def __init__(self, params: PyTree, policy: CheckpointPolicy, *,
                 norm_aux: Optional[dict] = None,
                 store: Optional[Any] = None,
                 score_fn: Optional[Callable] = None,
                 rng: Optional[jax.Array] = None,
                 colocate: tuple = ()):
        self.policy = policy
        self.partition = partition_pytree(params, policy.block_rows,
                                          colocate=colocate)
        self.norm_fn = get_norm(policy.norm, aux=norm_aux,
                                block_rows=policy.block_rows)
        self.ckpt = init_running_checkpoint(params, self.partition)
        self.store = store
        self._score_fn = score_fn  # optional kernel-backed scorer
        self._rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.stats = {"saves": 0, "recoveries": 0, "save_seconds": 0.0,
                      "blocks_saved": 0, "bytes_mirrored": 0}
        self._jit_save = jax.jit(partial(
            save_step, policy=self.policy, partition=self.partition,
            norm_fn=self.norm_fn))
        if store is not None:
            store.init(params, self.partition)

    # -- checkpoint path ----------------------------------------------------

    def should_checkpoint(self, step: int) -> bool:
        interval = (self.policy.full_interval
                    if self.policy.fraction >= 1.0
                    else self.policy.partial_interval)
        return step > 0 and step % interval == 0

    def maybe_checkpoint(self, step: int, params: PyTree) -> bool:
        if not self.should_checkpoint(step):
            return False
        self.checkpoint_now(step, params)
        return True

    def checkpoint_now(self, step: int, params: PyTree) -> jnp.ndarray:
        """Update the running checkpoint; returns the saved block mask."""
        t0 = time.perf_counter()
        if self.policy.fraction >= 1.0 and \
                self.policy.strategy != SelectionStrategy.PRIORITY:
            self.ckpt = full_save(self.ckpt, params, jnp.int32(step))
            mask = jnp.ones((self.partition.total_blocks,), bool)
        else:
            self._rng, sub = jax.random.split(self._rng)
            scores = None
            if self._score_fn is not None and \
                    self.policy.strategy == SelectionStrategy.PRIORITY:
                scores = self._score_fn(params, self.ckpt.values)
            self.ckpt, mask = self._jit_save(self.ckpt, params,
                                             jnp.int32(step), rng=sub,
                                             scores=scores)
        # block until the in-memory cache is consistent (paper: training may
        # resume now), then mirror to disk
        jax.block_until_ready(self.ckpt.values)
        self.stats["saves"] += 1
        self.stats["blocks_saved"] += int(jnp.sum(mask))
        self.stats["save_seconds"] += time.perf_counter() - t0
        if self.store is not None:
            self.stats["bytes_mirrored"] += self.store.write_blocks(
                mask, self.ckpt.values, step,
                background=self.policy.async_persist)
        return mask

    # -- recovery path ------------------------------------------------------

    def sample_failure(self, fraction: float) -> jnp.ndarray:
        self._rng, sub = jax.random.split(self._rng)
        return sample_failure_mask(sub, self.partition, fraction)

    def on_failure(self, params: PyTree, lost_mask: jnp.ndarray,
                   ) -> tuple[PyTree, dict]:
        """Recover from a partial failure. Returns (params', diagnostics)."""
        ckpt = self.ckpt
        if self.store is not None and getattr(self.store, "must_reload", False):
            values = self.store.read_all()
            ckpt = RunningCheckpoint(values, ckpt.saved_iter, ckpt.rr_cursor)
        recovered, info = apply_failure_and_recover(
            params, ckpt, lost_mask, self.policy.recovery, self.partition)
        self.stats["recoveries"] += 1
        return recovered, {k: (float(v) if hasattr(v, "item") else v)
                           for k, v in info.items()}

    # -- analysis helpers ---------------------------------------------------

    def block_drift(self, params: PyTree) -> jnp.ndarray:
        """Per-block distance between live params and the running ckpt."""
        return block_scores(params, self.ckpt.values, self.partition,
                            self.norm_fn)
