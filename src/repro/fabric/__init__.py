"""Tiered checkpoint fabric: failure domains, peer replication, parity,
and an elastic placement engine.

The paper's SCAR recovers every lost block from one redundancy tier — the
in-memory running checkpoint (with a disk mirror behind it). Production
failures are *correlated* (a host or rack dies, taking every block homed
there), and cheaper redundancy tiers exist: anti-affine peer replicas and
XOR parity groups recover *live* block values at zero perturbation. This
package layers those tiers above the running checkpoint and resolves each
lost block to the cheapest surviving one. Placement is *elastic*: all
components share one mutable :class:`ClusterView`, and after a domain loss
the engine re-homes blocks, re-seeds replicas, and re-stripes parity across
the survivors so training continues degraded at full redundancy. See
DESIGN.md.
"""
from repro.fabric.availability import summarize_availability
from repro.fabric.domains import FailureDomainMap, FailureEvent
from repro.fabric.fabric import CheckpointFabric, FabricConfig
from repro.fabric.parity import ParityCodec
from repro.fabric.placement import (ClusterView, anti_affine_replica_homes,
                                    rebalance_homes, rehome_blocks,
                                    stripe_parity_groups)
from repro.fabric.replica import ReplicaSet
from repro.fabric.tiers import RecoveryTier, TieredRecovery, TierPlan

__all__ = ["FailureDomainMap", "FailureEvent", "CheckpointFabric",
           "FabricConfig", "ParityCodec", "ReplicaSet", "RecoveryTier",
           "TieredRecovery", "TierPlan", "ClusterView",
           "anti_affine_replica_homes", "rebalance_homes", "rehome_blocks",
           "stripe_parity_groups", "summarize_availability"]
