"""Pallas TPU kernel: Mamba2 SSD intra-chunk dual form [arXiv:2405.21060].

The SSD algorithm splits the selective-state-space recurrence into
(a) an O(Q²) *intra-chunk* quadratic (attention-like) matmul form and
(b) an O(nc) inter-chunk state recurrence. (a) dominates FLOPs and maps
onto the MXU; this kernel computes, per (batch, chunk, head) grid cell:

    cum     = cumsum(dt·A)                                   (Q,)
    scores  = C Bᵀ                                           (Q,Q)  MXU
    M       = tril(exp(cum_i − cum_j)) ⊙ scores ⊙ dt_j       (Q,Q)
    y_intra = M x                                            (Q,P)  MXU
    w       = exp(cum_Q − cum) ⊙ dt                          (Q,)
    state   = Bᵀ (x ⊙ w)                                     (N,P)  MXU

Q is the SSD chunk (128 → MXU-aligned); the cheap inter-chunk recurrence
and the rank-1 y_inter correction stay in jnp (see ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_intra_kernel(la_ref, dt_ref, x_ref, b_ref, c_ref,
                      y_ref, state_ref):
    la = la_ref[...].reshape(la_ref.shape[-2])          # (Q,)
    dt = dt_ref[...].reshape(dt_ref.shape[-2])          # (Q,)
    Q = la.shape[0]
    x = x_ref[...].reshape(Q, x_ref.shape[-1])          # (Q, P)
    Bm = b_ref[...].reshape(Q, b_ref.shape[-1])         # (Q, N)
    Cm = c_ref[...].reshape(Q, c_ref.shape[-1])         # (Q, N)

    cum = jnp.cumsum(la)                                 # (Q,)
    scores = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32)
    decay = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    causal = ii >= jj
    M = jnp.where(causal, jnp.exp(decay), 0.0) * scores * dt[None, :]
    y = jnp.dot(M, x, preferred_element_type=jnp.float32)
    w = jnp.exp(cum[-1] - cum) * dt
    state = jnp.dot(Bm.T, x * w[:, None],
                    preferred_element_type=jnp.float32)  # (N, P)
    y_ref[...] = y.reshape(y_ref.shape)
    state_ref[...] = state.reshape(state_ref.shape)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_pallas(la, dt, x, Bm, Cm, interpret: bool = False):
    """Intra-chunk SSD.

    la, dt: (B, nc, Q, H); x: (B, nc, Q, H, P); Bm, Cm: (B, nc, Q, N).
    Returns (y_intra (B, nc, Q, H, P), chunk_state (B, nc, H, N, P)).
    """
    B, nc, Q, H = la.shape
    P = x.shape[-1]
    N = Bm.shape[-1]
    grid = (B, nc, H)
    y, state = pl.pallas_call(
        _ssd_intra_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, 1), lambda b, c, h: (b, c, 0, h)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, c, h: (b, c, 0, h)),
            pl.BlockSpec((1, 1, Q, 1, P), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c, h: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c, h: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, 1, P), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, 1, N, P), lambda b, c, h: (b, c, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nc, Q, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, nc, H, N, P), jnp.float32),
        ],
        interpret=interpret,
    )(la, dt, x, Bm, Cm)
    return y, state
