"""Figure 7 + §5.3 headline numbers: partial vs full recovery.

For each model (MLR, MF, LDA, CNN) and failure fraction (1/4, 1/2, 3/4):
rework iterations under full recovery (constant at its max — every
parameter reloaded from the checkpoint) vs partial recovery (decreasing
with the failure fraction).

Paper claims: partial recovery reduces iteration cost by
12–42% (3/4 lost), 31–62% (1/2), 59–89% (1/4). Derived output reports the
measured reduction per (model × fraction) and whether the ordering holds.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import MODEL_KW, csv_row, summarize
from repro.core.policy import CheckpointPolicy, RecoveryMode, SelectionStrategy
from repro.models.classic import make_model
from repro.training import run_clean, run_with_failure

MODELS = ("mlr", "mf", "lda", "cnn")
FRACTIONS = (0.25, 0.5, 0.75)


def _policy(recovery: RecoveryMode, block_rows: int) -> CheckpointPolicy:
    # full checkpoints every 8 iterations; only the recovery mode differs
    return CheckpointPolicy(fraction=1.0, full_interval=8,
                            strategy=SelectionStrategy.ROUND_ROBIN,
                            recovery=recovery, block_rows=block_rows)


def run(trials: int = 6, quick: bool = False) -> list[str]:
    if quick:
        trials = 3
    rows = []
    reductions = {}
    for name in MODELS:
        model = make_model(name, **MODEL_KW[name])
        max_iters = 180
        clean = run_clean(model, max_iters, seed=0)["losses"]
        for frac in FRACTIONS:
            costs = {"full": [], "partial": []}
            for seed in range(trials):
                # geometric failure-iteration sampling as in the paper
                fail_iter = 10 + int(np.random.default_rng(seed).geometric(0.08))
                fail_iter = min(fail_iter, 60)
                for mode_name, mode in (("full", RecoveryMode.FULL),
                                        ("partial", RecoveryMode.PARTIAL)):
                    r = run_with_failure(
                        model, _policy(mode, model.block_rows),
                        fail_iter=fail_iter, fail_fraction=frac,
                        max_iters=max_iters, seed=seed, clean_losses=clean)
                    costs[mode_name].append(max(r["iteration_cost"], 0))
            fm, fs = summarize(costs["full"])
            pm, ps = summarize(costs["partial"])
            red = 100.0 * (fm - pm) / max(fm, 1e-9) if fm > 0 else 0.0
            reductions.setdefault(name, {})[frac] = red
            rows.append(csv_row(
                f"fig7_{name}_lost{frac}", 0.0,
                f"full={fm:.1f}±{fs:.1f};partial={pm:.1f}±{ps:.1f};"
                f"reduction={red:.0f}%"))
    # paper-claim check: reduction grows as the lost fraction shrinks
    ordering_ok = sum(
        1 for name in MODELS
        if reductions[name][0.25] >= reductions[name][0.75] - 10)
    rows.append(csv_row(
        "fig7_reduction_ordering", 0.0,
        f"models_with_smaller_loss_bigger_saving={ordering_ok}/{len(MODELS)};"
        f"paper_claims=59-89%@1/4,31-62%@1/2,12-42%@3/4"))
    return rows
