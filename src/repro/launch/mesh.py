"""Production mesh construction (TPU v5e pods).

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state.

- single-pod: (16, 16)   axes ("data", "model")   — 256 chips
- multi-pod:  (2, 16, 16) axes ("pod", "data", "model") — 512 chips,
  pure data parallelism across pods (gradient all-reduce crosses DCI).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1):
    """Tiny mesh over whatever devices exist (CPU tests)."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))
