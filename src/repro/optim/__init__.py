"""Optimizers + classic training algorithms (SGD/Adam/ALS/Gibbs)."""
from repro.optim.optimizers import sgd, momentum, adam, adamw, OptState

__all__ = ["sgd", "momentum", "adam", "adamw", "OptState"]
