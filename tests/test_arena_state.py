"""Arena-resident training state: equivalence + recovery + unit tests.

The tentpole invariant of the arena-native refactor: with the flat arena
as the canonical live representation (``ArenaTrainState``), training is
**bit-identical** to the PyTree path — same losses, same running
checkpoint, same final params — while the per-step maintenance runs
pack-free (the sweep reads the live arena directly) and the partial save
sources straight from the training state.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.arena import (build_arena_layout, pack_arena, unpack_arena,
                              as_live_arena)
from repro.core.blocks import partition_pytree
from repro.core.controller import FTController
from repro.core.policy import CheckpointPolicy
from repro.data.pipeline import ShardedLMDataset
from repro.fabric import FabricConfig
from repro.optim.optimizers import adamw, arena_apply, sgd
from repro.sharding import single_device_ctx
from repro.training import (ArenaTrainState, TrainLoop, TrainLoopConfig,
                            TrainState, run_with_failure)


def _tree_equal(a, b) -> bool:
    return all(bool((np.asarray(x) == np.asarray(y)).all())
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


def _lm_loop(arena_state: bool, **loop_kw):
    ctx = single_device_ctx()
    cfg = get_config("qwen2-1.5b", reduced=True)
    pol = loop_kw.pop("policy", CheckpointPolicy.scar(fraction=0.25,
                                                      interval=2))
    loop = TrainLoop(cfg, ctx, loop_cfg=TrainLoopConfig(
        policy=pol, fabric=FabricConfig(), arena_state=arena_state,
        **loop_kw))
    state = loop.init_state()
    ds = ShardedLMDataset(cfg, batch=2, seq=32, ctx=ctx)
    return loop, state, ds


# ---------------------------------------------------------------------------
# end-to-end equivalence (the acceptance-criterion test)
# ---------------------------------------------------------------------------

def test_arena_and_pytree_paths_bit_identical():
    """Quick config, both paths: bit-identical losses AND bit-identical
    saved running checkpoints (values + saved_iter) AND final params."""
    la, sa, dsa = _lm_loop(True)
    lt, st, dst = _lm_loop(False)
    assert isinstance(sa, ArenaTrainState)
    assert isinstance(st, TrainState)
    sa = la.run(sa, iter(dsa), 6)
    st = lt.run(st, iter(dst), 6)
    assert [m["loss"] for m in la.metrics] == [m["loss"] for m in lt.metrics]
    # the saved checkpoint is canonical in arena form in both modes
    assert (np.asarray(la.controller._ckpt_arena)
            == np.asarray(lt.controller._ckpt_arena)).all()
    assert (np.asarray(la.controller.ckpt.saved_iter)
            == np.asarray(lt.controller.ckpt.saved_iter)).all()
    assert _tree_equal(sa.params, st.params)
    # the arena loop never packed on the hot path
    fab = la.controller.fabric
    assert fab.stats["arena_resident_maintains"] \
        == fab.stats["arena_maintains"]
    assert lt.controller.fabric.stats["arena_resident_maintains"] == 0


def test_arena_failure_recovers_via_peer_replica():
    """Failure injection on the arena path: every lost block recovers from
    the PEER_REPLICA tier (live values — zero perturbation) and training
    continues finite, still arena-resident."""
    loop, state, ds = _lm_loop(True)
    it = iter(ds)
    state = loop.run(state, it, 3)
    state, info = loop.inject_failure(state, 0.5)
    assert isinstance(state, ArenaTrainState)
    tiers = info["tier_counts"]
    assert tiers["PEER_REPLICA"] == info["lost_blocks"] > 0
    assert tiers["RUNNING_CKPT"] == tiers["DISK"] == tiers["PARITY"] == 0
    assert info["applied_sq"] <= 1e-9   # replica holds this step's values
    state = loop.run(state, it, 3)
    assert all(np.isfinite(m["loss"]) for m in loop.metrics)


def test_classic_runner_arena_matches_tree():
    from repro.models.classic import make_model
    model = make_model("mlr", n=200, dim=32, n_classes=4, batch=100)
    pol = CheckpointPolicy.scar(fraction=0.25, interval=8)
    kw = dict(fail_iter=15, fail_fraction=0.5, max_iters=40, seed=3)
    ra = run_with_failure(model, pol, fabric=FabricConfig(),
                          arena_state=True, **kw)
    rt = run_with_failure(model, pol, fabric=FabricConfig(),
                          arena_state=False, **kw)
    assert ra["arena_state"] and not rt["arena_state"]
    assert ra["losses"] == rt["losses"]
    # runner mode: every maintain is an arena sweep fed by the runner's
    # own pack (own_live — adopted as the replica, not copied), and the
    # accounted bytes match the tree interface's internal-pack total
    assert ra["fabric_stats"]["arena_maintains"] == 40
    assert ra["fabric_stats"]["live_packs"] == 40
    assert ra["fabric_stats"]["arena_resident_maintains"] == 0
    assert (ra["fabric_stats"]["maintain_bytes_moved"]
            == rt["fabric_stats"]["maintain_bytes_moved"])
    # sparse tiers + shorter save interval: the post-save forced maintain
    # must also adopt the runner's pack (own_live threads through
    # maybe_checkpoint), never re-copy it or book it as resident. Byte
    # totals aren't identical here — an off-interval arena-input step
    # runs the full fused sweep where the tree interface runs only the
    # due per-component pass (documented, strictly fresher) — but the
    # arena path may never book MORE than the tree path.
    sparse = dict(replicate_interval=4, parity_interval=4)
    sa = run_with_failure(model, pol, fabric=FabricConfig(**sparse),
                          arena_state=True, **kw)
    st = run_with_failure(model, pol, fabric=FabricConfig(**sparse),
                          arena_state=False, **kw)
    assert sa["losses"] == st["losses"]
    assert sa["fabric_stats"]["arena_resident_maintains"] == 0
    assert (sa["fabric_stats"]["maintain_bytes_moved"]
            <= st["fabric_stats"]["maintain_bytes_moved"])


# ---------------------------------------------------------------------------
# unit: flat optimizer apply
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("opt_name", ["sgd", "adamw"])
def test_arena_apply_matches_tree_update(opt_name):
    """Flat elementwise apply over the word arena == per-leaf tree apply,
    bit-exactly, including the quantized-dtype round trip (grads and
    moments live in the f32 value domain); pads stay zero (I4)."""
    from repro.core.arena import pack_values
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(70, 9)), jnp.float32),
              "h": jnp.asarray(rng.normal(size=(33, 5)), jnp.bfloat16),
              "b": jnp.asarray(rng.normal(size=(7,)), jnp.float16)}
    part = partition_pytree(params, 16)
    layout = build_arena_layout(part)
    assert not layout.uniform_f32 and layout.total_values > layout.total_words
    opt = sgd(0.1) if opt_name == "sgd" else adamw(1e-2)
    arena = pack_arena(params, layout)
    st_tree = opt.init(params)
    st_flat = opt.init(jnp.zeros((layout.total_values,), jnp.float32))
    tree = params
    for i in range(3):
        grads = jax.tree_util.tree_map(
            lambda x: jnp.asarray(rng.normal(size=x.shape), x.dtype), tree)
        g_values = pack_values(grads, layout)
        tree, st_tree = opt.update(grads, st_tree, tree)
        arena, st_flat = arena_apply(opt, g_values, st_flat, arena, layout)
        assert (np.asarray(pack_arena(tree, layout))
                == np.asarray(arena)).all(), f"step {i} diverged"
    # word-domain pads still zero after three updates
    pad_mask = np.ones((layout.total_words,), bool)
    vpad_mask = np.ones((layout.total_values,), bool)
    for li, leaf in enumerate(part.leaves):
        off, seg, pay = (layout.leaf_offset[li], layout.seg_words[li],
                         layout.payload_words[li])
        voff, vseg, vpay = (layout.value_offset[li], layout.seg_elems[li],
                            layout.payload_elems[li])
        for b in range(leaf.n_blocks):
            pad_mask[off + b * seg: off + b * seg + pay] = False
            vpad_mask[voff + b * vseg: voff + b * vseg + vpay] = False
    assert (np.asarray(arena)[pad_mask] == 0.0).all()
    if opt_name == "adamw":
        # moments are value-domain mirrors; their pads stay zero too
        assert (np.asarray(st_flat.mu)[vpad_mask] == 0.0).all()


def test_arena_train_state_lazy_params_view():
    params = {"w": jnp.arange(48, dtype=jnp.float32).reshape(12, 4)}
    layout = build_arena_layout(partition_pytree(params, 8))
    state = ArenaTrainState.create(pack_arena(params, layout), sgd(0.1),
                                   layout)
    view = state.params
    assert _tree_equal(view, params)
    assert state.params is view          # cached, not re-decoded
    assert (np.asarray(state.opt_state.step) == 0).all()


def test_as_live_arena_detection():
    params = {"w": jnp.zeros((12, 4), jnp.float32)}
    layout = build_arena_layout(partition_pytree(params, 8))
    arena = pack_arena(params, layout)
    assert as_live_arena(arena, layout) is arena
    assert as_live_arena(params, layout) is None
    assert as_live_arena(arena, None) is None
    # wrong length / dtype are not arenas
    assert as_live_arena(arena[:-1], layout) is None
    assert as_live_arena(arena.astype(jnp.bfloat16), layout) is None


# ---------------------------------------------------------------------------
# unit: controller + fabric accept the live arena
# ---------------------------------------------------------------------------

def _small_controller(**kw):
    rng = np.random.default_rng(1)
    params = {"w": jnp.asarray(rng.normal(size=(96, 6)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(5,)), jnp.float32)}
    pol = kw.pop("policy", CheckpointPolicy.scar(fraction=0.25, interval=2))
    ctl = FTController(params, pol, fabric=FabricConfig(), **kw)
    assert ctl.arena_ready
    return params, ctl


def test_controller_maintain_and_save_accept_live_arena():
    params, ctl_a = _small_controller()
    _, ctl_t = _small_controller()
    drift = jax.tree_util.tree_map(lambda x: x + 0.25, params)
    live = ctl_a.pack_live(drift)
    ctl_a.maintain(2, live)
    ctl_t.maintain(2, drift)
    assert (np.asarray(ctl_a.fabric.last_scores)
            == np.asarray(ctl_t.fabric.last_scores)).all()
    assert (np.asarray(ctl_a.fabric.parity.parity)
            == np.asarray(ctl_t.fabric.parity.parity)).all()
    ma = ctl_a.maybe_checkpoint(2, live)
    mt = ctl_t.maybe_checkpoint(2, drift)
    assert ma and mt
    assert (np.asarray(ctl_a._ckpt_arena)
            == np.asarray(ctl_t._ckpt_arena)).all()
    assert ctl_a.fabric.stats["arena_resident_maintains"] == 1
    assert ctl_t.fabric.stats["arena_resident_maintains"] == 0


def test_controller_full_save_from_live_arena():
    from repro.core.policy import RecoveryMode, SelectionStrategy
    pol = CheckpointPolicy(fraction=1.0, full_interval=2,
                           strategy=SelectionStrategy.ROUND_ROBIN,
                           recovery=RecoveryMode.PARTIAL, block_rows=16)
    params, ctl = _small_controller(policy=pol)
    drift = jax.tree_util.tree_map(lambda x: x + 1.0, params)
    live = ctl.pack_live(drift)
    ctl.maintain(2, live)
    assert ctl.maybe_checkpoint(2, live)
    assert _tree_equal(ctl.ckpt.values, drift)
    assert (np.asarray(ctl.ckpt.saved_iter) == 2).all()


def test_controller_on_failure_round_trips_arena():
    params, ctl = _small_controller()
    drift = jax.tree_util.tree_map(lambda x: x + 0.5, params)
    live = ctl.pack_live(drift)
    ctl.maintain(1, live)
    lost = ctl.sample_failure(0.5)
    recovered, info = ctl.on_failure(live, lost, step=1)
    assert as_live_arena(recovered, ctl.arena_layout) is not None
    # replica tier recovery restores the live values exactly
    assert (np.asarray(recovered) == np.asarray(live)).all()
    assert info["tier_counts"]["PEER_REPLICA"] == info["lost_blocks"]


def test_fabric_resident_maintain_bytes_drop():
    """The no-pack accounting: a live-arena maintain moves exactly the
    live tree's bytes fewer than the pack-path maintain, and the staging
    footprint stays the sweep's compact outputs."""
    params, ctl = _small_controller()
    fab = ctl.fabric
    t = fab._traffic_model()
    assert t["arena_resident"] == t["arena"] - t["model"]
    live = ctl.pack_live(params)
    fab.maintain(1, live)
    assert fab.stats["maintain_bytes_moved"] == t["arena_resident"]
    assert fab.live_arena_mode
    assert fab.redundancy_nbytes()["parity_staging"] == t["staging_arena"]


def test_microbatched_arena_step_matches_single():
    """cfg.microbatch > 1 gives the same loss/update on the arena path."""
    from repro.data import lm_batch
    from repro.models import get_model
    from repro.training.step import make_arena_train_step
    ctx = single_device_ctx()
    cfg = get_config("qwen2-1.5b", reduced=True)
    cfg_mb = dataclasses.replace(cfg, microbatch=2)
    ops = get_model(cfg)
    params = ops.init_params(jax.random.PRNGKey(0), cfg)
    layout = build_arena_layout(partition_pytree(params, 128))
    batch = lm_batch(jax.random.PRNGKey(1), cfg, 4, 32)
    opt = sgd(0.1)
    s0 = ArenaTrainState.create(pack_arena(params, layout), opt, layout)
    s1, l1 = make_arena_train_step(ops, cfg, ctx, opt, layout)(s0, batch)
    s2, l2 = make_arena_train_step(ops, cfg_mb, ctx, opt, layout)(s0, batch)
    assert float(l1) == pytest.approx(float(l2), rel=1e-5)
    np.testing.assert_allclose(np.asarray(s1.arena), np.asarray(s2.arena),
                               rtol=1e-4, atol=1e-5)
