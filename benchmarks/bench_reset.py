"""Figure 6: reset-to-initial-values perturbations (MLR + LDA).

The realistic analogue of partial checkpoint recovery: a random fraction
of parameter blocks is reset to x^(0). Derived check: iteration cost is
monotone in the reset fraction.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import MODEL_KW, csv_row, summarize
from repro.models.classic import make_model
from repro.training import run_clean, run_with_perturbation


def run(trials: int = 8, quick: bool = False) -> list[str]:
    if quick:
        trials = 4
    rows = []
    for name in ("mlr", "lda"):
        model = make_model(name, **MODEL_KW[name])
        max_iters = 200
        clean = run_clean(model, max_iters, seed=0)["losses"]
        means = []
        for frac in (0.25, 0.5, 0.75):
            costs = []
            for seed in range(trials):
                r = run_with_perturbation(model, kind="reset", at_iter=25,
                                          fraction=frac, max_iters=max_iters,
                                          seed=seed, clean_losses=clean)
                costs.append(r["iteration_cost"])
            mean, sem = summarize(costs)
            means.append(mean)
            rows.append(csv_row(f"fig6_{name}_reset{frac}", 0.0,
                                f"mean_cost={mean:.1f}±{sem:.1f}"))
        mono = all(means[i] <= means[i + 1] + 2 for i in range(len(means) - 1))
        rows.append(csv_row(f"fig6_{name}_monotone_in_fraction", 0.0,
                            f"means={['%.1f' % m for m in means]};holds={mono}"))
    return rows
