"""Norm plugins + the persistent sharded checkpoint store."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint_io import ShardedCheckpointStore
from repro.core.blocks import LeafMeta, block_scores, partition_pytree
from repro.core.norms import get_norm


def test_l2_norm():
    a = jnp.asarray([[1.0, 2.0], [0.0, 0.0]])
    b = jnp.zeros((2, 2))
    leaf = LeafMeta("x", (2, 2), jnp.float32, 2, 2, 2, 0)
    got = get_norm("l2")(a, b, leaf)
    np.testing.assert_allclose(got, [5.0, 0.0])


def test_scaled_tv_norm_weights():
    # two "documents" (rows) that are distributions over 4 topics
    rows = jnp.asarray([[0.5, 0.5, 0.0, 0.0],
                        [0.25, 0.25, 0.25, 0.25]])
    prev = jnp.asarray([[1.0, 0.0, 0.0, 0.0],
                        [0.25, 0.25, 0.25, 0.25]])
    weights = np.asarray([10.0, 3.0], np.float32)
    params = {"theta": rows}
    ck = {"theta": prev}
    part = partition_pytree(params, block_rows=1)
    norm = get_norm("scaled_tv", aux={"['theta']": weights}, block_rows=1)
    scores = block_scores(params, ck, part, norm)
    # TV(row0) = 0.5 -> 5.0 weighted; TV(row1) = 0
    np.testing.assert_allclose(scores, [5.0, 0.0], rtol=1e-6)


def test_unknown_norm_raises():
    with pytest.raises(KeyError):
        get_norm("nope")


def test_store_roundtrip_partial_writes():
    params = {"w": jnp.arange(60.0, dtype=jnp.float32).reshape(20, 3),
              "b": jnp.ones((4,), jnp.float32)}
    part = partition_pytree(params, block_rows=8)
    with tempfile.TemporaryDirectory() as d:
        store = ShardedCheckpointStore(d)
        store.init(params, part)
        # overwrite one block with new values
        newp = jax.tree_util.tree_map(lambda x: x * 10, params)
        mask = np.zeros((part.total_blocks,), bool)
        w_leaf = [l for l in part.leaves if l.name == "['w']"][0]
        mask[w_leaf.offset + 1] = True   # rows 8..15 of w
        store.write_blocks(mask, newp, step=5, background=True)
        store.flush()
        back = store.read_all()
        w = np.asarray(back["w"])
        np.testing.assert_array_equal(w[:8], np.asarray(params["w"])[:8])
        np.testing.assert_array_equal(w[8:16], np.asarray(newp["w"])[8:16])
        np.testing.assert_array_equal(np.asarray(back["b"]),
                                      np.asarray(params["b"]))
        iters = store.saved_iters()
        assert iters[w_leaf.offset + 1] == 5
        assert iters[w_leaf.offset] == 0


def test_store_packed_append_log_and_compaction():
    """The packed layout appends overwritten blocks to the shard log and
    repoints the offset index at the latest copy; compaction reclaims
    exactly the dead bytes and reads still round-trip."""
    params = {"w": jnp.arange(60.0, dtype=jnp.float32).reshape(20, 3),
              "b": jnp.ones((4,), jnp.float32)}
    part = partition_pytree(params, block_rows=8)
    with tempfile.TemporaryDirectory() as d:
        store = ShardedCheckpointStore(d)
        store.init(params, part)
        assert os.path.exists(os.path.join(d, "blocks.g0000.shard"))
        base = store.disk_nbytes()
        assert base["shard"] == base["live"] > 0
        # three overwrites of the same block grow the log, not the live set
        w_leaf = [l for l in part.leaves if l.name == "['w']"][0]
        mask = np.zeros((part.total_blocks,), bool)
        mask[w_leaf.offset] = True
        for step in (1, 2, 3):
            newp = jax.tree_util.tree_map(lambda x: x * (step + 1), params)
            store.write_blocks(mask, newp, step=step, background=False)
        grown = store.disk_nbytes()
        blk_bytes = 8 * w_leaf.row_width * 4
        assert grown["shard"] == base["shard"] + 3 * blk_bytes
        assert grown["live"] == base["live"]
        # index points at the LAST copy
        np.testing.assert_array_equal(
            np.asarray(store.read_all()["w"])[:8],
            np.asarray(params["w"])[:8] * 4)
        reclaimed = store.compact()
        assert reclaimed == 3 * blk_bytes
        # crash-safe generational rewrite: new file, old one unlinked
        assert os.path.exists(os.path.join(d, "blocks.g0001.shard"))
        assert not os.path.exists(os.path.join(d, "blocks.g0000.shard"))
        after = store.disk_nbytes()
        assert after["shard"] == after["live"] == base["live"]
        np.testing.assert_array_equal(
            np.asarray(store.read_all()["w"])[:8],
            np.asarray(params["w"])[:8] * 4)
        iters = store.saved_iters()
        assert iters[w_leaf.offset] == 3


def test_compact_drops_segments_of_missing_shards(tmp_path):
    """A source shard that vanished (crash orphan / dead host) must have
    its segments dropped from the index during compact() — keeping the
    old offsets would resolve inside the bumped-generation file and read
    another segment's bytes."""
    import json
    import os
    import shutil

    import jax.numpy as jnp

    from repro.checkpoint_io import ShardedCheckpointStore
    from repro.core.blocks import partition_pytree
    from repro.fabric.domains import FailureDomainMap
    from repro.sharding.partition import block_device_homes

    params = {"w": jnp.arange(64, dtype=jnp.float32).reshape(16, 4)}
    part = partition_pytree(params, 4)
    dm = FailureDomainMap(n_devices=8, devices_per_host=2, hosts_per_rack=2)
    homes = block_device_homes(part, 8)
    store = ShardedCheckpointStore(str(tmp_path))
    store.init(params, part, homes=homes, domains=dm)
    lost_host = int(dm.host_of(homes[0]))
    shutil.rmtree(os.path.join(str(tmp_path), f"host_{lost_host:04d}"))
    store.compact()
    with open(os.path.join(str(tmp_path), "MANIFEST.json")) as f:
        segments = json.load(f)["segments"]
    lost_gids = [g for g in range(part.total_blocks)
                 if int(dm.host_of(homes[g])) == lost_host]
    assert lost_gids
    for g in lost_gids:
        assert segments[g] is None          # dropped, not stale
    vals = store.read_all()                 # lost blocks read back zero,
    arr = np.asarray(jax.tree_util.tree_leaves(vals)[0])  # never garbage
    for g in lost_gids:
        assert not arr[g * 4:(g + 1) * 4].any()
    survivors = [g for g in range(part.total_blocks) if g not in lost_gids]
    for g in survivors:
        np.testing.assert_array_equal(arr[g * 4:(g + 1) * 4],
                                      np.asarray(params["w"])[g * 4:(g + 1) * 4])
