"""Figure 9 analogue: SCAR system overhead.

The paper measures LDA-on-ClueWeb wall-clock: checkpoint overhead per
iteration is small relative to step time, and SCAR's reduced rework nets
out positive. Offline here, we measure on the LM trainer (reduced qwen2):

- t_step       — mean jitted train-step seconds,
- t_dump       — mean SCAR checkpoint_now seconds (priority scoring +
                 in-memory cache update; disk mirror is async),
- bytes        — bytes mirrored per checkpoint (constant-budget property:
                 r·(full bytes) per rC iterations ≈ full bytes per C).
"""
from __future__ import annotations

import shutil
import tempfile
import time

import jax
import numpy as np

from benchmarks.common import csv_row
from repro.checkpoint_io import ShardedCheckpointStore
from repro.configs import get_config
from repro.core.policy import CheckpointPolicy
from repro.data.pipeline import ShardedLMDataset
from repro.sharding import single_device_ctx
from repro.training import TrainLoop, TrainLoopConfig


def run(trials: int = 12, quick: bool = False) -> list[str]:
    steps = 8 if quick else 16
    ctx = single_device_ctx()
    cfg = get_config("qwen2-1.5b", reduced=True)
    rows = []
    byte_budget = {}
    for frac, interval in ((1.0, 8), (0.25, 8), (0.125, 8)):
        pol = CheckpointPolicy.scar(fraction=frac, interval=interval)
        mirror_dir = tempfile.mkdtemp(prefix="bench_overhead_")
        store = ShardedCheckpointStore(mirror_dir)
        try:
            loop = TrainLoop(cfg, ctx, loop_cfg=TrainLoopConfig(policy=pol),
                             store=store)
            state = loop.init_state()
            ds = ShardedLMDataset(cfg, batch=2, seq=64, ctx=ctx)
            # warm up the jitted save path so t_dump excludes compile time
            loop.controller.checkpoint_now(1, state.params)
            loop.controller.stats.update(saves=0, save_seconds=0.0,
                                         blocks_saved=0, bytes_mirrored=0)
            state = loop.run(state, iter(ds), steps)
            stats = loop.controller.stats
            t_step = np.mean([m["seconds"] for m in loop.metrics[2:]])
            t_dump = stats["save_seconds"] / max(stats["saves"], 1)
            per_iter_bytes = stats["bytes_mirrored"] / steps
            store.flush()   # all background writes landed before cleanup
        finally:
            shutil.rmtree(mirror_dir, ignore_errors=True)
        byte_budget[frac] = per_iter_bytes
        rows.append(csv_row(
            f"fig9_overhead_r{frac}", t_dump * 1e6,
            f"t_step={t_step*1e3:.1f}ms;t_dump={t_dump*1e3:.1f}ms;"
            f"dump_frac={t_dump/max(t_step,1e-9):.2f};"
            f"bytes_per_iter={per_iter_bytes:.0f}"))
    # constant write-budget property (§4.2): bytes/iter roughly equal
    vals = list(byte_budget.values())
    ratio = max(vals) / max(min(vals), 1.0)
    rows.append(csv_row("fig9_constant_write_budget", 0.0,
                        f"bytes_per_iter_ratio_max_min={ratio:.2f}"))
    return rows
