"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block
[arXiv:2411.15242].

38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000, ssm_state=64. The
shared transformer block (full attention + MLP, weights reused) is applied
every 6 Mamba2 layers, zamba2-style.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    ssm_state=64,
    ssm_headdim=64,
    attn_every=6,
    microbatch=4,
    source="arXiv:2411.15242",
))
