"""Pure-jnp oracles for the fused_maintain kernel family."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def fused_maintain_ref(x: jnp.ndarray, z: jnp.ndarray,
                       outrow_per_block: np.ndarray, n_out_rows: int,
                       ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Oracle for one leaf sweep: (replica copy, per-block squared-L2
    scores, per-row XOR of the blocks' float32 bit patterns).

    ``outrow_per_block[b]`` is the compact parity row block ``b`` folds
    into (natural block order, unlike the kernel's sorted ``perm``/
    ``outrow`` encoding).
    """
    x32 = x.astype(jnp.float32)
    z32 = z.astype(jnp.float32)
    scores = jnp.sum((x32 - z32) ** 2, axis=1)
    bits = np.asarray(jax.lax.bitcast_convert_type(x32, jnp.int32))
    par = np.zeros((n_out_rows, x.shape[1]), np.int32)
    for b, row in enumerate(np.asarray(outrow_per_block)):
        par[int(row)] ^= bits[b]
    return jnp.array(x), scores, jnp.asarray(par)


def scatter_save_ref(dst: jnp.ndarray, src: jnp.ndarray,
                     rows: np.ndarray, block_rows: int) -> jnp.ndarray:
    """Oracle for the in-place block scatter: ``dst`` with the selected
    blocks' rows overwritten from ``src`` (row-matrix layout)."""
    out = np.array(dst)
    src = np.asarray(src)
    n_rows = out.shape[0]
    for b in np.asarray(rows):
        lo = int(b) * block_rows
        hi = min(lo + block_rows, n_rows)
        out[lo:hi] = src[lo:hi]
    return jnp.asarray(out)
