"""Pure-jnp oracle for the sliding-window attention kernel."""
import math

import jax.numpy as jnp

NEG_INF = -1e30


def sw_attention_ref(q, k, v, *, window: int) -> jnp.ndarray:
    """Banded causal attention (materializes (S, S) — oracle only).

    q: (BH, G, S, Dh); k, v: (BH, S, Dh). Returns (BH, G, S, Dh) f32.
    """
    BH, G, S, Dh = q.shape
    scale = 1.0 / math.sqrt(Dh)
    s = jnp.einsum("bgqd,bkd->bgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = (kpos <= qpos) & (qpos - kpos < window)
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(mask[None, None], p, 0.0)
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bgqk,bkd->bgqd", p, v.astype(jnp.float32))
