"""Failure domains: device → host → rack topology + correlated sampling.

The paper (Thm 4.2) models blocks lost *uniformly at random*. Real clusters
lose whole failure domains: a host reboot takes all its devices, a rack
power event takes all its hosts. ``FailureDomainMap`` is the static
description of that hierarchy; correlated failures are sampled as whole
domains, and an MTBF-driven trace generator produces realistic multi-event
schedules for long runs. The paper's uniform model stays available in
:func:`repro.core.recovery.sample_failure_mask` — both plug into the same
tier planner.

Devices are numbered densely; host/rack membership is by contiguous ranges
(device d lives on host d // devices_per_host, etc.), which matches how TPU
data-axis slices map onto physical hosts.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

DOMAIN_KINDS = ("device", "host", "rack")


@dataclasses.dataclass(frozen=True)
class FailureEvent:
    """One correlated failure in a sampled trace."""
    step: int
    kind: str       # "device" | "host" | "rack"
    index: int      # domain index of that kind


@dataclasses.dataclass(frozen=True)
class FailureDomainMap:
    n_devices: int
    devices_per_host: int = 4
    hosts_per_rack: int = 2

    def __post_init__(self):
        if self.n_devices < 1:
            raise ValueError("n_devices must be >= 1")
        if self.devices_per_host < 1 or self.hosts_per_rack < 1:
            raise ValueError("domain sizes must be >= 1")

    # -- topology ------------------------------------------------------------

    @property
    def n_hosts(self) -> int:
        return math.ceil(self.n_devices / self.devices_per_host)

    @property
    def n_racks(self) -> int:
        return math.ceil(self.n_hosts / self.hosts_per_rack)

    def host_of(self, device):
        """Host index of a device (scalar or ndarray)."""
        return np.asarray(device) // self.devices_per_host

    def rack_of(self, device):
        return self.host_of(device) // self.hosts_per_rack

    def n_domains(self, kind: str) -> int:
        if kind == "device":
            return self.n_devices
        if kind == "host":
            return self.n_hosts
        if kind == "rack":
            return self.n_racks
        raise ValueError(f"unknown domain kind {kind!r}")

    def devices_in(self, kind: str, index: int) -> np.ndarray:
        """All device ids inside one failure domain."""
        if kind == "device":
            lo, hi = index, index + 1
        elif kind == "host":
            lo = index * self.devices_per_host
            hi = lo + self.devices_per_host
        elif kind == "rack":
            lo = index * self.hosts_per_rack * self.devices_per_host
            hi = lo + self.hosts_per_rack * self.devices_per_host
        else:
            raise ValueError(f"unknown domain kind {kind!r}")
        return np.arange(lo, min(hi, self.n_devices), dtype=np.int32)

    # -- correlated sampling -------------------------------------------------

    def sample_domain_failure(self, rng: np.random.Generator,
                              kind: str = "host") -> np.ndarray:
        """Lose one whole domain chosen uniformly: the failed device ids."""
        index = int(rng.integers(self.n_domains(kind)))
        return self.devices_in(kind, index)

    def sample_failure_trace(self, rng: np.random.Generator, n_steps: int,
                             mtbf: dict[str, float]) -> list[FailureEvent]:
        """MTBF-driven trace: per domain kind, exponential inter-arrival
        times with mean ``mtbf[kind]`` (in steps), uniformly-chosen victim.

        Mirrors how real incident logs decompose — independent Poisson
        processes per domain level, rack events far rarer than device ones.
        """
        events: list[FailureEvent] = []
        for kind, mean in mtbf.items():
            if kind not in DOMAIN_KINDS:
                raise ValueError(f"unknown domain kind {kind!r}")
            t = rng.exponential(mean)
            while t < n_steps:
                events.append(FailureEvent(
                    step=int(math.ceil(t)), kind=kind,
                    index=int(rng.integers(self.n_domains(kind)))))
                t += rng.exponential(mean)
        return sorted(events, key=lambda e: e.step)
