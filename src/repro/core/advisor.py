"""Predictive checkpoint-policy advisor — the paper's §7 future work,
implemented ("by approximating c and ‖x⁰−x*‖, we may obtain a predictive
model which can be evaluated on-the-fly to inform decisions made by a
system during run-time").

Model: expected run time per Daly (2006), with T_rework replaced by the
Theorem 3.2 iteration-cost bound applied to the *expected recovery
perturbation* of a (fraction r, interval C) policy:

    E‖δ‖ ≈ p_loss^{1/2} · drift(age)          (Thm 4.2: E‖δ′‖² = p‖δ‖²)
    age   ≈ staleness of the running checkpoint under (r, rC) saves
    ι(δ)  ≤ log(1 + c^{-T}·E‖δ‖ / ‖x⁰−x*‖) / log(1/c)

The advisor observes the live run (drift per iteration from the running
checkpoint, measured t_dump / t_iter, fitted c) and scores a grid of
candidate policies, returning the one minimizing expected time overhead:

    overhead(r, C) = t_dump(r)/interval(r,C)
                   + failure_rate · ι(r, C) · t_iter

This is deliberately a *planning* estimate — coarse, monotone in the right
arguments, cheap to evaluate every few hundred iterations.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

from repro.core.iteration_cost import estimate_contraction
from repro.core.policy import CheckpointPolicy, RecoveryMode, SelectionStrategy


@dataclasses.dataclass
class RunObservations:
    """What the advisor needs from the live run."""
    drift_per_iter: float        # mean ‖x_k − x_{k−1}‖ (or per-block drift sum)
    x0_err: float                # ‖x⁰ − x*‖ estimate (e.g. loss-scaled)
    c: float                     # fitted contraction factor
    t_iter: float                # seconds per training iteration
    t_dump_full: float           # seconds to save a FULL checkpoint
    failure_rate: float          # failures per iteration (per-iter prob)
    loss_fraction: float = 0.5   # expected fraction of blocks lost
    current_iter: int = 100


def expected_iteration_cost(obs: RunObservations, r: float, C: int) -> float:
    """Thm 3.2 bound on the rework iterations for policy (r, C)."""
    interval = max(1, round(r * C))
    # staleness: a block saved every C iterations on average (priority
    # saving reduces the *effective* drift of the hottest blocks; we use
    # the conservative round-robin age C/2 + interval/2)
    age = C / 2.0 + interval / 2.0
    delta = math.sqrt(obs.loss_fraction) * obs.drift_per_iter * age
    if delta <= 0:
        return 0.0
    # the Thm 3.2 bound for a single perturbation at the current iterate
    T = obs.current_iter
    c = min(max(obs.c, 1e-6), 1 - 1e-6)
    ratio = (c ** (-min(T, 500))) * delta / max(obs.x0_err, 1e-12)
    ratio = min(ratio, 1e12)
    return math.log1p(ratio) / math.log(1.0 / c)


def expected_overhead(obs: RunObservations, r: float, C: int) -> float:
    """Expected seconds of overhead per iteration for policy (r, C)."""
    interval = max(1, round(r * C))
    dump = obs.t_dump_full * r / interval             # amortized save cost
    rework = obs.failure_rate * expected_iteration_cost(obs, r, C) * obs.t_iter
    return dump + rework


def advise(obs: RunObservations,
           r_grid: Sequence[float] = (1.0, 0.5, 0.25, 0.125, 0.0625),
           C_grid: Sequence[int] = (4, 8, 16, 32, 64),
           norm: str = "l2") -> tuple[CheckpointPolicy, dict]:
    """Pick the (r, C) minimizing expected overhead. Returns (policy, report)."""
    best, best_cost, table = None, float("inf"), {}
    for r in r_grid:
        for C in C_grid:
            cost = expected_overhead(obs, r, C)
            table[(r, C)] = cost
            if cost < best_cost:
                best, best_cost = (r, C), cost
    r, C = best
    policy = CheckpointPolicy(fraction=r, full_interval=C,
                              strategy=SelectionStrategy.PRIORITY,
                              recovery=RecoveryMode.PARTIAL, norm=norm)
    return policy, {"chosen": best, "expected_overhead_s": best_cost,
                    "table": {f"r={k[0]},C={k[1]}": v
                              for k, v in sorted(table.items())}}


def _poisson_tail(lam: float, m: int) -> float:
    """P[N > m] for N ~ Poisson(lam) (summed complement, stable for the
    small lam / small m regime the advisor lives in)."""
    if lam <= 0:
        return 0.0
    term, acc = math.exp(-lam), math.exp(-lam)
    for i in range(1, m + 1):
        term *= lam / i
        acc += term
    return max(0.0, 1.0 - acc)


def advise_code(mtbf: dict, *, window: int, model_bytes: int,
                budget_bytes: Optional[int] = None,
                n_hosts: int = 4,
                k_grid: Sequence[int] = (2, 3, 4, 6, 8),
                m_grid: Sequence[int] = (1, 2, 3),
                target_risk: float = 1e-4) -> tuple[tuple[int, int], dict]:
    """Pick an RS(k, m) code from an MTBF trace and a redundancy budget.

    Failure model: domain losses arrive independently per kind with the
    given MTBF means (steps), so the number landing inside one
    maintenance ``window`` (the steps between re-encodes — losses in the
    same window are *simultaneous* as far as the code is concerned) is
    Poisson with rate ``window·Σ 1/mtbf``. A code of strength m dies
    when a window sees > m losses; conservatively every loss is assumed
    to hit the same parity group (correlated placement — the worst case
    the striping cannot always avoid on small topologies).

    Feasibility: k + m host-disjoint placements must exist
    (``k + m ≤ n_hosts``), the GF(256) Cauchy construction needs
    ``k + m ≤ 256``, and the parity arena footprint ``model_bytes·m/k``
    must fit ``budget_bytes`` (None = unbounded). Among candidates whose
    window-loss risk meets ``target_risk``, the cheapest redundancy
    fraction m/k wins (widest k tie-breaks). If nothing inside the
    budget meets the risk target the advisor still returns the
    minimum-risk affordable code — flagged ``met_risk=False``, never
    silently."""
    lam = float(window) * sum(1.0 / float(v) for v in mtbf.values() if v)
    table = {}
    feasible, affordable = [], []
    for k in k_grid:
        for m in m_grid:
            if k + m > min(int(n_hosts), 256):
                continue
            bytes_ = model_bytes * m / k
            risk = _poisson_tail(lam, m)
            table[(k, m)] = {"risk": risk, "parity_bytes": bytes_}
            if budget_bytes is not None and bytes_ > budget_bytes:
                continue
            affordable.append((risk, m / k, -k, (k, m)))
            if risk <= target_risk:
                feasible.append((m / k, -k, risk, (k, m)))
    if not affordable:
        raise ValueError("no RS(k, m) candidate fits the topology/budget")
    if feasible:
        choice = min(feasible)[-1]
        met = True
    else:
        choice = min(affordable)[-1]
        met = False
    k, m = choice
    return choice, {"chosen": {"k": k, "m": m}, "met_risk": met,
                    "window_loss_rate": lam,
                    "risk": table[choice]["risk"],
                    "parity_bytes": table[choice]["parity_bytes"],
                    "table": {f"k={kk},m={mm}": v
                              for (kk, mm), v in sorted(table.items())}}


def observe_from_controller(controller, losses: Sequence[float],
                            t_iter: float,
                            failure_rate: float) -> RunObservations:
    """Build observations from a live FTController + loss history."""
    drift = controller.block_drift  # callable; use sum of sqrt scores
    # crude ‖x⁰−x*‖ proxy: sqrt of initial loss gap scale
    losses = np.asarray(losses, dtype=np.float64)
    lo = float(losses.min())
    errs = np.sqrt(np.maximum(losses - lo * 0.98, 1e-12))
    c = estimate_contraction(errs[: max(len(errs) // 2, 2)], burn_in=1) \
        if len(errs) >= 4 else 0.95
    stats = controller.stats
    t_dump = stats["save_seconds"] / max(stats["saves"], 1)
    # drift per iter from the running checkpoint ages
    return RunObservations(
        drift_per_iter=float(errs[0] - errs[-1]) / max(len(errs), 1),
        x0_err=float(errs[0]),
        c=c,
        t_iter=t_iter,
        t_dump_full=t_dump / max(controller.policy.fraction, 1e-3),
        failure_rate=failure_rate,
        current_iter=len(losses),
    )
