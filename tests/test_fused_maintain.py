"""Fused single-pass maintenance: kernels, tree drivers, fabric + controller
integration, and the donation-based in-place partial save.

Kernels run in interpret=True mode on CPU (the kernel body executes in
Python) — the TPU is the compile target, interpret validates semantics.
Replica and parity outputs must be *bit-exact* vs the seed oracles (copy
and XOR are exact operations); scores are float reductions with a
different association order, so they get a tight allclose.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.blocks import (block_scores, partition_pytree, select_blocks,
                               tree_sq_norm)
from repro.core.checkpoint import init_running_checkpoint
from repro.core.controller import FTController
from repro.core.norms import get_norm
from repro.core.policy import CheckpointPolicy, RecoveryMode, SelectionStrategy
from repro.fabric import CheckpointFabric, FabricConfig
from repro.fabric.domains import FailureDomainMap
from repro.fabric.parity import ParityCodec
from repro.fabric.placement import ClusterView
from repro.kernels.fused_maintain.kernel import (fused_maintain_pallas,
                                                 scatter_save_pallas)
from repro.kernels.fused_maintain.ref import (fused_maintain_ref,
                                              scatter_save_ref)
from repro.kernels.fused_maintain.ops import (leaf_group_metas,
                                              make_fused_maintain_fn,
                                              maintain_traffic,
                                              tree_scatter_save)
from repro.sharding.partition import block_device_homes

RNG = np.random.default_rng(11)


def _tree_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _params():
    return {"w": jnp.asarray(RNG.normal(size=(50, 6)), jnp.float32),
            "emb": jnp.asarray(RNG.normal(size=(33, 8)), jnp.float32),
            "b": jnp.asarray(RNG.normal(size=(5,)), jnp.float32),
            "s": jnp.float32(2.5)}


def _codec(params, part, group_size=3):
    view = ClusterView(FailureDomainMap(8, 2, 2),
                       block_device_homes(part, 8))
    codec = ParityCodec(part, view, group_size=group_size, use_pallas=False)
    codec.encode(0, params)
    return codec


# ---------------------------------------------------------------------------
# kernel-level sweeps vs oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(1, 1), (5, 100), (8, 512), (13, 777)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_maintain_kernel_sweep(shape, dtype):
    s, e = shape
    x = jnp.asarray(RNG.normal(size=shape), dtype)
    z = jnp.asarray(RNG.normal(size=shape), dtype)
    group_of = RNG.integers(0, max(s // 2, 1), (s,))
    order = np.argsort(group_of, kind="stable").astype(np.int32)
    touched, inverse = np.unique(group_of, return_inverse=True)
    outrow = inverse.astype(np.int32)[order]
    first = np.ones_like(outrow)
    first[1:] = (outrow[1:] != outrow[:-1]).astype(np.int32)
    rep, sc, par = fused_maintain_pallas(
        x, z, jnp.asarray(order), jnp.asarray(outrow), jnp.asarray(first),
        n_out_rows=int(touched.size), interpret=True)
    want_rep, want_sc, want_par = fused_maintain_ref(
        x, z, inverse, int(touched.size))
    np.testing.assert_array_equal(np.asarray(rep), np.asarray(want_rep))
    np.testing.assert_array_equal(np.asarray(par), np.asarray(want_par))
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(sc), np.asarray(want_sc),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("shape,block_rows", [((50, 6), 16), ((7, 3), 4),
                                              ((128, 520), 64)])
def test_scatter_save_kernel_sweep(shape, block_rows):
    dst = jnp.asarray(RNG.normal(size=shape), jnp.float32)
    src = jnp.asarray(RNG.normal(size=shape), jnp.float32)
    n_blocks = -(-shape[0] // block_rows)
    k = max(1, n_blocks // 2)
    rows = np.sort(RNG.choice(n_blocks, k, replace=False)).astype(np.int32)
    got = scatter_save_pallas(jnp.array(dst), src, jnp.asarray(rows),
                              block_rows, interpret=True)
    want = scatter_save_ref(dst, src, rows, block_rows)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_scatter_save_kernel_duplicate_rows_idempotent():
    dst = jnp.asarray(RNG.normal(size=(20, 8)), jnp.float32)
    src = jnp.asarray(RNG.normal(size=(20, 8)), jnp.float32)
    rows = jnp.asarray([1, 1, 3, 3], jnp.int32)   # bucket-padding pattern
    got = scatter_save_pallas(jnp.array(dst), src, rows, 4, interpret=True)
    want = scatter_save_ref(dst, src, np.asarray([1, 3]), 4)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# tree-level drivers vs the seed-path oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_pallas", [False, True])
def test_tree_fused_maintain_matches_oracles(use_pallas):
    params = _params()
    ck = jax.tree_util.tree_map(
        lambda x: x + jnp.asarray(RNG.normal(size=x.shape), x.dtype), params)
    part = partition_pytree(params, 16)
    codec = _codec(params, part)
    fn = make_fused_maintain_fn(part, codec.layout, codec.group_of,
                                codec.n_groups, use_pallas=use_pallas,
                                interpret=True)
    rep, sc, par = fn(params, ck)
    _tree_equal(rep, params)                               # replica == copy
    np.testing.assert_array_equal(np.asarray(par),         # parity bit-exact
                                  np.asarray(codec.parity))
    want = block_scores(params, ck, part, get_norm("l2"))
    np.testing.assert_allclose(np.asarray(sc), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_tree_fused_maintain_colocated_leaves():
    """Colocated leaves share block ids: scores accumulate per group and
    every colocated payload folds into the same parity rows at its own
    frame columns — exactly like the seed pack_frames/encode path."""
    tree = {"net": {"w": jnp.asarray(RNG.normal(size=(16, 3)), jnp.float32)},
            "mu": {"w": jnp.asarray(RNG.normal(size=(16, 3)), jnp.float32)},
            "t": jnp.float32(1.0)}
    ck = jax.tree_util.tree_map(
        lambda x: x + jnp.asarray(RNG.normal(size=x.shape), x.dtype), tree)
    part = partition_pytree(tree, 8, colocate=("net", "mu"))
    codec = _codec(tree, part, group_size=2)
    for use_pallas in (False, True):
        fn = make_fused_maintain_fn(part, codec.layout, codec.group_of,
                                    codec.n_groups, use_pallas=use_pallas,
                                    interpret=True)
        rep, sc, par = fn(tree, ck)
        _tree_equal(rep, tree)
        np.testing.assert_array_equal(np.asarray(par),
                                      np.asarray(codec.parity))
        want = block_scores(tree, ck, part, get_norm("l2"))
        np.testing.assert_allclose(np.asarray(sc), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def test_leaf_group_metas_cover_all_blocks():
    params = _params()
    part = partition_pytree(params, 16)
    codec = _codec(params, part)
    metas = leaf_group_metas(part, codec.layout, codec.group_of)
    for leaf, meta in zip(part.leaves, metas):
        assert sorted(meta.perm.tolist()) == list(range(leaf.n_blocks))
        assert meta.first[0] == 1
        # members matrix lists every block exactly once
        listed = meta.members[meta.members >= 0]
        assert sorted(listed.tolist()) == list(range(leaf.n_blocks))


def test_maintain_traffic_model_fused_wins():
    params = _params()
    part = partition_pytree(params, 16)
    codec = _codec(params, part)
    t = maintain_traffic(part, codec.layout, codec.group_of, codec.n_groups,
                         codec.members.shape[1])
    assert t["fused"] < t["seed"]
    assert t["staging_fused"] < t["staging_seed"]


# ---------------------------------------------------------------------------
# fabric integration
# ---------------------------------------------------------------------------

def test_fabric_fused_matches_seed_maintain():
    params = _params()
    part = partition_pytree(params, 16)
    fused = CheckpointFabric(part, FabricConfig(fused=True))
    seed = CheckpointFabric(part, FabricConfig(fused=False))
    fused.maintain(3, params)
    seed.maintain(3, params)
    assert fused.stats["fused_maintains"] == 1
    assert seed.stats["fused_maintains"] == 0
    _tree_equal(fused.replicas.values, seed.replicas.values)
    np.testing.assert_array_equal(np.asarray(fused.parity.parity),
                                  np.asarray(seed.parity.parity))
    assert fused.replicas.is_fresh(3) and fused.parity.is_fresh(3)
    assert fused.stats["maintain_bytes_moved"] < \
        seed.stats["maintain_bytes_moved"]


def test_fabric_fused_recovery_after_domain_loss():
    """A host loss recovered from fused-maintained tiers is exact, and the
    fused program rebuilds against the re-striped topology."""
    params = _params()
    part = partition_pytree(params, 16)
    fab = CheckpointFabric(part, FabricConfig(elastic=True, fused=True))
    ck = init_running_checkpoint(params, part)
    fab.maintain(5, params)
    lost, failed = fab.domain_failure("host", 0)
    assert failed.size
    recovered, stats = fab.on_failure(params, ck.values, lost,
                                      failed_devices=failed, step=5)
    assert float(tree_sq_norm(recovered, params)) == 0.0
    # elastic replan re-striped: next fused maintain must rebuild and stay
    # bit-consistent with a fresh seed encode on the same topology
    fab.maintain(6, params, force=True)
    want = jnp.array(fab.parity.parity)
    fab.parity.encode(6, params)
    np.testing.assert_array_equal(np.asarray(want),
                                  np.asarray(fab.parity.parity))


def test_fabric_scores_cache_lifecycle():
    params = _params()
    part = partition_pytree(params, 16)
    fab = CheckpointFabric(part, FabricConfig(fused=True))
    ck = init_running_checkpoint(params, part)
    drifted = jax.tree_util.tree_map(lambda x: x + 1, params)
    fab.maintain(2, drifted, ckpt_values=ck.values)
    assert fab.last_scores_step == 2
    want = block_scores(drifted, ck.values, part, get_norm("l2"))
    np.testing.assert_allclose(np.asarray(fab.last_scores),
                               np.asarray(want), rtol=1e-5, atol=1e-5)
    fab.invalidate_scores()
    assert fab.last_scores is None and fab.last_scores_step == -1
    # without ckpt_values the sweep still maintains but caches no scores
    fab.maintain(3, drifted, force=True)
    assert fab.last_scores is None


def test_checkpoint_forces_freshness_despite_off_interval_maintain():
    """An off-interval maintain() must not mask the post-checkpoint force
    refresh: with replicate_interval=2 a checkpoint at an odd step still
    leaves every tier fresh (regression: the force was skipped whenever
    maintain ran the same step, even as a no-op)."""
    params = _params()
    pol = CheckpointPolicy(fraction=0.25, full_interval=1,
                           strategy=SelectionStrategy.ROUND_ROBIN,
                           recovery=RecoveryMode.PARTIAL, block_rows=16)
    ctl = FTController(params, pol,
                       fabric=FabricConfig(replicate_interval=2,
                                           parity_interval=2, fused=True))
    live = jax.tree_util.tree_map(lambda x: x + 1, params)
    ctl.maintain(3, live)                      # 3 % 2 != 0: refreshes nothing
    assert not ctl.fabric.is_fresh(3)
    ctl.maybe_checkpoint(3, live)
    assert ctl.fabric.is_fresh(3)
    assert ctl.fabric.replicas.is_fresh(3)
    assert ctl.fabric.parity.is_fresh(3)


# ---------------------------------------------------------------------------
# in-place partial save
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_pallas", [False, True])
def test_tree_scatter_save_matches_select_blocks(use_pallas):
    params = _params()
    part = partition_pytree(params, 16)
    ck = jax.tree_util.tree_map(
        lambda x: x + jnp.asarray(RNG.normal(size=x.shape), x.dtype), params)
    mask = np.asarray(RNG.random(part.total_blocks) < 0.4)
    mask[0] = True
    want = select_blocks(ck, params, jnp.asarray(mask), part)
    got, moved = tree_scatter_save(
        jax.tree_util.tree_map(jnp.array, ck), params,
        np.nonzero(mask)[0], part, use_pallas=use_pallas, interpret=True)
    _tree_equal(got, want)
    assert 0 < moved < sum(x.size * x.dtype.itemsize
                           for x in jax.tree_util.tree_leaves(params))


def test_tree_scatter_save_untouched_leaves_pass_through():
    params = _params()
    part = partition_pytree(params, 16)
    ck = jax.tree_util.tree_map(jnp.array, params)
    w_leaf = next(l for l in part.leaves if l.name == "['w']")
    idx = np.asarray([w_leaf.offset])
    got, moved = tree_scatter_save(ck, params, idx, part, use_pallas=False)
    # only w was touched; every other leaf is the same buffer object
    for leaf, a, b in zip(part.leaves, jax.tree_util.tree_leaves(got),
                          jax.tree_util.tree_leaves(ck)):
        if leaf.name != "['w']":
            assert a is b
    assert moved == 16 * w_leaf.row_width * 4


def test_controller_inplace_save_matches_rewrite_path():
    """The donation-scatter save path is bit-equivalent to the seed
    jnp.where rewrite over a multi-save PRIORITY run."""
    params = _params()
    pol = CheckpointPolicy(fraction=0.25, full_interval=4,
                           strategy=SelectionStrategy.PRIORITY,
                           recovery=RecoveryMode.PARTIAL, block_rows=16)
    a = FTController(params, pol, inplace_save=True,
                     rng=jax.random.PRNGKey(3))
    b = FTController(params, pol, inplace_save=False,
                     rng=jax.random.PRNGKey(3))
    live = params
    for step in (1, 2, 3):
        live = jax.tree_util.tree_map(
            lambda x: x + jnp.asarray(RNG.normal(size=x.shape) * step,
                                      x.dtype), live)
        ma = a.checkpoint_now(step, live)
        mb = b.checkpoint_now(step, live)
        np.testing.assert_array_equal(np.asarray(ma), np.asarray(mb))
    _tree_equal(a.ckpt.values, b.ckpt.values)
    np.testing.assert_array_equal(np.asarray(a.ckpt.saved_iter),
                                  np.asarray(b.ckpt.saved_iter))
    assert a.stats["save_bytes_moved"] > 0
    assert b.stats["save_bytes_moved"] == 0


def test_controller_fused_scores_reused_for_priority():
    """maintain() before a PRIORITY save caches fused scores; the save
    consumes them (no third pass) and still selects the same blocks."""
    params = _params()
    pol = CheckpointPolicy(fraction=0.25, full_interval=1,
                           strategy=SelectionStrategy.PRIORITY,
                           recovery=RecoveryMode.PARTIAL, block_rows=16)
    fab = FabricConfig(fused=True)
    ctl = FTController(params, pol, fabric=fab, rng=jax.random.PRNGKey(0))
    plain = FTController(params, pol, rng=jax.random.PRNGKey(0))
    live = jax.tree_util.tree_map(lambda x: x + 1, params)
    ctl.maintain(1, live)
    assert ctl.fabric.last_scores_step == 1
    m1 = ctl.checkpoint_now(1, live)
    m2 = plain.checkpoint_now(1, live)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
    _tree_equal(ctl.ckpt.values, plain.ckpt.values)
    assert ctl.fabric.last_scores is None   # consumed + invalidated


def test_incremental_inplace_save_property():
    """Hypothesis: a sequence of random partial saves applied through the
    in-place scatter equals the seed select_blocks fold, mask by mask."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    params = _params()
    part = partition_pytree(params, 16)

    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.lists(st.integers(0, part.total_blocks - 1),
                             min_size=1, max_size=part.total_blocks),
                    min_size=1, max_size=4),
           st.integers(0, 2 ** 31 - 1))
    def prop(mask_seq, seed):
        r = np.random.default_rng(seed)
        inplace = jax.tree_util.tree_map(jnp.array, params)
        fold = jax.tree_util.tree_map(jnp.array, params)
        for ids in mask_seq:
            src = jax.tree_util.tree_map(
                lambda x: x + jnp.asarray(r.normal(size=x.shape), x.dtype),
                params)
            idx = np.unique(np.asarray(ids))
            mask = np.zeros((part.total_blocks,), bool)
            mask[idx] = True
            inplace, _ = tree_scatter_save(inplace, src, idx, part,
                                           use_pallas=False)
            fold = select_blocks(fold, src, jnp.asarray(mask), part)
        _tree_equal(inplace, fold)

    prop()
