"""Elastic placement engine: mutable cluster view + degraded-topology planning.

The first fabric wired placement once, at construction: ``block_device_homes``
gave each block a permanent primary home, replicas were ring-shifted a fixed
domain over, and parity stripes were cut over the full topology. That wiring
assumes a failed domain comes back — a second hit on the same degraded
topology finds its replicas and parity homes dead and falls through to the
expensive RUNNING_CKPT/DISK tiers. This module makes placement *elastic*:

- :class:`ClusterView` — the mutable source of truth: which devices are
  alive and where every block currently lives (``homes``). Every fabric
  component reads placement through the view instead of private home arrays,
  so one re-plan is visible everywhere at once.
- :func:`rehome_blocks` — after a domain loss, displaced blocks move onto
  surviving devices, least-loaded first (capacity balanced).
- :func:`anti_affine_replica_homes` — replica homes recomputed in the
  *degraded* topology: a different rack when one survives, else a different
  host, else a different device.
- :func:`stripe_parity_groups` / :func:`parity_group_homes` — parity groups
  re-cut over the surviving hosts so every group keeps host-disjoint members
  and a live parity home; a lone tail member folds into the previous group
  so no group ever has fewer than two members.
- :func:`rebalance_homes` — after a domain heals, load is levelled back onto
  the re-admitted devices.

All placement decisions are deterministic (ties break by lowest device id),
so a re-planned cluster is reproducible across runs.
"""
from __future__ import annotations

import numpy as np

from repro.fabric.domains import FailureDomainMap


class ClusterView:
    """Mutable cluster state: device liveness + current block placement.

    ``alive`` is the per-device liveness mask over ``domains``; ``homes`` is
    the (total_blocks,) primary home of each block — *current*, not initial:
    :func:`rehome_blocks` rewrites it in place after a failure. ``version``
    increments on every mutation so consumers can detect a stale plan.
    """

    def __init__(self, domains: FailureDomainMap, homes: np.ndarray):
        self.domains = domains
        self.alive = np.ones((domains.n_devices,), bool)
        self.homes = np.array(homes, np.int32, copy=True)
        self.version = 0

    # -- topology over the living ---------------------------------------------

    @property
    def n_devices(self) -> int:
        return self.domains.n_devices

    @property
    def n_alive_devices(self) -> int:
        return int(self.alive.sum())

    def alive_devices(self) -> np.ndarray:
        return np.nonzero(self.alive)[0].astype(np.int32)

    def dead_devices(self) -> np.ndarray:
        return np.nonzero(~self.alive)[0].astype(np.int32)

    def alive_hosts(self) -> np.ndarray:
        """Host ids with at least one alive device."""
        return np.unique(self.domains.host_of(self.alive_devices()))

    @property
    def n_alive_hosts(self) -> int:
        return int(self.alive_hosts().size)

    @property
    def n_alive_racks(self) -> int:
        return int(np.unique(
            self.domains.rack_of(self.alive_devices())).size)

    def host_of(self, device):
        return self.domains.host_of(device)

    def rack_of(self, device):
        return self.domains.rack_of(device)

    # -- mutation -------------------------------------------------------------

    def mark_failed(self, devices) -> np.ndarray:
        """Mark devices dead; returns the ones that were alive before."""
        devices = np.asarray(devices, np.int32).ravel()
        newly = devices[self.alive[devices]]
        if newly.size:
            self.alive[newly] = False
            self.version += 1
        return newly

    def heal(self, devices) -> np.ndarray:
        """Re-admit devices to the view; returns the ones that were dead."""
        devices = np.asarray(devices, np.int32).ravel()
        healed = devices[~self.alive[devices]]
        if healed.size:
            self.alive[healed] = True
            self.version += 1
        return healed

    # -- placement introspection ----------------------------------------------

    def load(self) -> np.ndarray:
        """(n_devices,) block count homed per device."""
        return np.bincount(self.homes, minlength=self.n_devices)

    def displaced_blocks(self) -> np.ndarray:
        """Block ids currently homed on a dead device."""
        return np.nonzero(~self.alive[self.homes])[0].astype(np.int32)


def _pick_balanced(cands: np.ndarray, load: np.ndarray) -> int:
    """Least-loaded candidate; ties break by lowest device id."""
    d = int(cands[np.argmin(load[cands])])
    load[d] += 1
    return d


# ---------------------------------------------------------------------------
# Primary re-homing
# ---------------------------------------------------------------------------

def rehome_blocks(view: ClusterView) -> np.ndarray:
    """Move every block homed on a dead device onto a surviving one,
    least-loaded first. Mutates ``view.homes``; returns the moved block ids.
    """
    displaced = view.displaced_blocks()
    if displaced.size == 0:
        return displaced
    alive = view.alive_devices()
    if alive.size == 0:
        raise RuntimeError("cannot re-home: no surviving devices")
    load = np.bincount(view.homes[view.alive[view.homes]],
                       minlength=view.n_devices)
    for b in displaced:
        view.homes[b] = _pick_balanced(alive, load)
    view.version += 1
    return displaced


def rebalance_homes(view: ClusterView) -> np.ndarray:
    """Level block load across the alive devices (post-heal): move blocks
    off the most-loaded device onto the least-loaded until the spread is
    ≤ 1 block. Returns the moved block ids."""
    alive = view.alive_devices()
    if alive.size <= 1:
        return np.empty((0,), np.int32)
    load = view.load()
    moved: list[int] = []
    while True:
        hi = int(alive[np.argmax(load[alive])])
        lo = int(alive[np.argmin(load[alive])])
        if load[hi] - load[lo] <= 1:
            break
        b = int(np.nonzero(view.homes == hi)[0][0])
        view.homes[b] = lo
        load[hi] -= 1
        load[lo] += 1
        moved.append(b)
    if moved:
        view.version += 1
    return np.asarray(moved, np.int32)


# ---------------------------------------------------------------------------
# Replica re-seeding
# ---------------------------------------------------------------------------

def anti_affine_replica_homes(view: ClusterView) -> np.ndarray:
    """Replica home per block, anti-affine in the *current* (possibly
    degraded) topology: an alive device in a different rack when one
    survives, else on a different host, else a different device, always
    least-loaded first. Falls back to sharing the primary's device only
    when it is the sole survivor."""
    alive = view.alive_devices()
    if alive.size == 0:
        raise RuntimeError("cannot place replicas: no surviving devices")
    a_hosts = np.asarray(view.host_of(alive))
    a_racks = np.asarray(view.rack_of(alive))
    # replica load starts at the primary load so devices packed with
    # primaries attract fewer replicas
    load = view.load().astype(np.int64)
    out = np.empty_like(view.homes)
    for b, p in enumerate(view.homes):
        for cands in (alive[a_racks != int(view.rack_of(p))],
                      alive[a_hosts != int(view.host_of(p))],
                      alive[alive != p],
                      alive):
            if cands.size:
                out[b] = _pick_balanced(cands, load)
                break
    return out


def checkpoint_cache_homes(view: ClusterView,
                           replica_homes: np.ndarray | None = None,
                           ) -> np.ndarray:
    """Running-checkpoint cache home per block: an alive device on a host
    holding neither the primary nor (when possible) the replica, so one
    domain loss cannot take a block, its replica, and its checkpoint copy
    all at once."""
    alive = view.alive_devices()
    if alive.size == 0:
        raise RuntimeError("cannot place checkpoint cache: no devices")
    a_hosts = np.asarray(view.host_of(alive))
    load = view.load().astype(np.int64)
    out = np.empty_like(view.homes)
    for b, p in enumerate(view.homes):
        p_host = int(view.host_of(p))
        tiers = []
        if replica_homes is not None:
            r_host = int(view.host_of(replica_homes[b]))
            tiers.append(alive[(a_hosts != p_host) & (a_hosts != r_host)])
        tiers += [alive[a_hosts != p_host], alive[alive != p], alive]
        for cands in tiers:
            if cands.size:
                out[b] = _pick_balanced(cands, load)
                break
    return out


# ---------------------------------------------------------------------------
# Parity re-striping
# ---------------------------------------------------------------------------

def effective_parity_group(view: ClusterView, group_size: int,
                           reserve: int = 1) -> int:
    """RAID-style width clamp in the current topology: members + parity must
    fit in the alive host count, else a single host failure can erase two
    stripe units and the single-erasure code cannot recover. Leaves
    ``reserve`` hosts free for the parity rows (1 for the XOR codec, m for
    RS(k, m) — each row wants its own member-free host so one host loss
    never takes a member *and* the row that would recover it) whenever
    enough hosts survive to keep ≥ 2 members."""
    if view.n_alive_hosts >= reserve + 2:
        return min(group_size, view.n_alive_hosts - reserve)
    if view.n_alive_hosts >= 3:
        return min(group_size, view.n_alive_hosts - 1)
    return group_size


def rs_parity_homes(members: np.ndarray, view: ClusterView,
                    n_parity: int) -> np.ndarray:
    """(n_groups, n_parity) parity-row homes for the RS(k, m) tier.

    Each group's m parity rows want m *host-disjoint* homes that also
    avoid every member host — otherwise one host loss can erase a member
    and the parity row that would have recovered it, wasting the extra
    redundancy. Preference order per row: an alive device on a host free
    of both members and this group's earlier parity rows, then member-
    host-free, then member-device-free, then any alive device."""
    alive = view.alive_devices()
    if alive.size == 0:
        raise RuntimeError("cannot place parity: no surviving devices")
    a_hosts = np.asarray(view.host_of(alive))
    load = view.load().astype(np.int64)
    out = np.zeros((members.shape[0], n_parity), np.int32)
    for j, row in enumerate(members):
        ids = row[row >= 0]
        m_hosts = set(np.asarray(view.host_of(view.homes[ids])).ravel()
                      .tolist())
        m_devs = set(int(d) for d in view.homes[ids])
        p_hosts: set[int] = set()
        for r in range(n_parity):
            taken = m_hosts | p_hosts
            host_free_all = alive[~np.isin(a_hosts, list(taken))]
            host_free = alive[~np.isin(a_hosts, list(m_hosts))]
            dev_free = alive[~np.isin(alive, list(m_devs))]
            for cands in (host_free_all, host_free, dev_free, alive):
                if cands.size:
                    out[j, r] = _pick_balanced(cands, load)
                    break
            p_hosts.add(int(view.host_of(out[j, r])))
    return out


def stripe_parity_groups(view: ClusterView, group_size: int,
                         fold_tail: bool = True) -> np.ndarray:
    """(n_groups, width) int32 member block ids, -1 padded, striped over the
    *current* placement.

    Each group draws one member from each of the ``group_size`` *fullest*
    per-host block buckets (ties break by lowest host id), so groups stay
    host-disjoint — and a single host failure erases at most one member —
    whenever the load spread allows it at all. Byte-balanced primary
    placement can pack far more blocks onto one host than the others
    (many small leaves land together); plain round-robin interleaving
    leaves that host's surplus as a same-host tail whose groups a single
    host loss wipes entirely, while greedy max-first pairing defers the
    same-host groups to the true pigeonhole residue
    (``2·max_host_load − total`` at width 2). Whatever residue remains is
    chunked same-host as a last resort — the planner's fallback
    accounting prices what those groups cannot cover, never silently.

    A lone tail member is folded into the previous group (widening it by
    one) so every group has ≥ 2 members — a one-member group would make
    the parity a bare copy pinned to a single surviving frame. The RS
    codec passes ``fold_tail=False``: with m ≥ 2 rows a singleton group
    already has host-disjoint copies, and widening a group past the
    clamp can push members + rows over the alive-host count, re-opening
    the double-loss hole the clamp closed.
    """
    hosts = np.asarray(view.host_of(view.homes))
    buckets = {int(h): list(np.nonzero(hosts == h)[0])
               for h in np.unique(hosts)}
    groups: list[list[int]] = []
    while buckets:
        heads = sorted(buckets, key=lambda h: (-len(buckets[h]), h))
        if len(heads) == 1:
            # single host left: chunk its surplus into same-host groups
            tail = buckets.pop(heads[0])
            groups.extend([int(b) for b in tail[i:i + group_size]]
                          for i in range(0, len(tail), group_size))
            break
        grp: list[int] = []
        for h in heads[:group_size]:
            grp.append(int(buckets[h].pop(0)))
            if not buckets[h]:
                del buckets[h]
        groups.append(grp)
    if fold_tail and len(groups) > 1 and len(groups[-1]) == 1:
        groups[-2].extend(groups.pop())
    width = max(group_size, max(len(g) for g in groups))
    members = np.full((len(groups), width), -1, np.int32)
    for j, grp in enumerate(groups):
        members[j, :len(grp)] = grp
    return members


def parity_group_homes(members: np.ndarray, view: ClusterView) -> np.ndarray:
    """Parity block home per group: an alive device whose host holds no
    member, least-loaded first; falls back to an alive device holding no
    member, then any alive device (single-host degenerate topology)."""
    alive = view.alive_devices()
    if alive.size == 0:
        raise RuntimeError("cannot place parity: no surviving devices")
    a_hosts = np.asarray(view.host_of(alive))
    load = view.load().astype(np.int64)
    out = np.zeros((members.shape[0],), np.int32)
    for j, row in enumerate(members):
        ids = row[row >= 0]
        m_hosts = set(np.asarray(view.host_of(view.homes[ids])).ravel()
                      .tolist())
        m_devs = set(int(d) for d in view.homes[ids])
        host_free = alive[~np.isin(a_hosts, list(m_hosts))]
        dev_free = alive[~np.isin(alive, list(m_devs))]
        for cands in (host_free, dev_free, alive):
            if cands.size:
                out[j] = _pick_balanced(cands, load)
                break
    return out
