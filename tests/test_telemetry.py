"""Unified telemetry layer: recorder/event bus, span tracer, ledger, report.

Covers the PR's acceptance points: the events.jsonl round trip, Chrome
trace-export validity (Perfetto-loadable complete events with contained
nesting), the perturbation ledger's bounds bit-matching
``core/iteration_cost``, the NullRecorder zero-overhead default, and the
classic runners' stats-snapshot guarantee.
"""
import json
import time

import numpy as np
import pytest

from repro.core.controller import FTController
from repro.core.iteration_cost import (iteration_cost_bound,
                                       single_perturbation_bound)
from repro.core.policy import CheckpointPolicy
from repro.fabric import CheckpointFabric, FabricConfig
from repro.models.classic import make_model
from repro.telemetry import (EVENT_SCHEMA, NULL_RECORDER, Histogram,
                             NullRecorder, PerturbationLedger, Recorder,
                             SpanTracer, format_report, read_events_jsonl,
                             run_report)
from repro.training import run_with_failure, run_with_trace


# ---------------------------------------------------------------------------
# recorder + event bus
# ---------------------------------------------------------------------------

def test_events_jsonl_round_trip(tmp_path):
    out = tmp_path / "telemetry"
    rec = Recorder(out_dir=str(out))
    rec.event("failure", step=3, lost_blocks=np.int64(4), failed_devices=2)
    rec.event("maintain", step=np.int32(3), mode="arena",
              bytes_moved=1024, replica=True, parity=True)
    rec.event("save", step=4, blocks=2, bytes_moved=np.float64(8.0),
              seconds=0.01, mode="arena")
    rec.close()
    back = read_events_jsonl(str(out / "events.jsonl"))
    assert back == rec.events
    # stamped fields + monotone sequence, and every value JSON-native
    assert [e["seq"] for e in back] == [0, 1, 2]
    assert all(isinstance(e["ts"], float) for e in back)
    assert back[0]["lost_blocks"] == 4 and back[1]["mode"] == "arena"
    json.dumps(back)   # fully serializable after the round trip


def test_event_kinds_documented():
    """Every kind the instrumented components emit is in EVENT_SCHEMA."""
    m = make_model("qp")
    rec = Recorder()
    run_with_failure(m, CheckpointPolicy(fraction=0.5, full_interval=4),
                     fail_iter=6, fail_fraction=0.5, max_iters=12,
                     fabric=FabricConfig(n_devices=8), recorder=rec)
    kinds = {e["kind"] for e in rec.events}
    assert kinds  # the run must actually emit
    assert kinds <= set(EVENT_SCHEMA)


def test_scope_registration_by_reference():
    rec = Recorder()
    stats = rec.scope("fabric", {"x": 0})
    stats["x"] = 7
    assert rec.metrics()["scopes"]["fabric"]["x"] == 7
    # collisions get a unique suffix instead of silently aliasing
    other = rec.scope("fabric", {"x": 1})
    assert other is not stats
    assert set(rec.scopes) == {"fabric", "fabric#2"}
    # metrics() is a snapshot, not a live view
    snap = rec.metrics()
    stats["x"] = 99
    assert snap["scopes"]["fabric"]["x"] == 7


def test_background_thread_events_are_serialized(tmp_path):
    """The store's mirror events fire from its worker thread — the bus
    must keep the JSONL lines whole and the seq unique under that."""
    import threading
    rec = Recorder(out_dir=str(tmp_path / "t"))

    def emit(k):
        for i in range(50):
            rec.event("mirror", step=i, bytes=k, segments=1,
                      background=True)

    threads = [threading.Thread(target=emit, args=(k,)) for k in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    rec.close()
    back = read_events_jsonl(str(tmp_path / "t" / "events.jsonl"))
    assert len(back) == 200
    assert sorted(e["seq"] for e in back) == list(range(200))


# ---------------------------------------------------------------------------
# span tracer + Chrome trace export
# ---------------------------------------------------------------------------

def test_spans_nest_and_export_chrome_trace(tmp_path):
    tracer = SpanTracer()
    with tracer.span("outer", step=1):
        with tracer.span("inner"):
            time.sleep(0.002)
    doc = tracer.chrome_trace()
    assert set(doc) >= {"traceEvents", "displayTimeUnit"}
    evs = {e["name"]: e for e in doc["traceEvents"]}
    assert set(evs) == {"outer", "inner"}
    for e in evs.values():   # complete events, µs timestamps
        assert e["ph"] == "X"
        assert e["dur"] >= 0 and e["ts"] >= 0
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
    # containment: the inner span lies strictly inside the outer one, so
    # Perfetto renders the nesting on one track
    outer, inner = evs["outer"], evs["inner"]
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6
    assert evs["outer"]["args"] == {"step": 1}
    # the written file is valid JSON with the same events
    path = tracer.write_chrome_trace(str(tmp_path / "trace.json"))
    with open(path) as f:
        assert len(json.load(f)["traceEvents"]) == 2


def test_span_fence_runs_before_end_timestamp():
    """The fence (device sync) must be *inside* the measured interval."""
    tracer = SpanTracer()
    with tracer.span("maintain", fence=lambda: time.sleep(0.02)):
        pass
    (dur,) = tracer.durations("maintain")
    assert dur >= 0.02


def test_span_fence_accepts_arrays():
    import jax.numpy as jnp
    tracer = SpanTracer()
    x = jnp.ones((8,))
    with tracer.span("maintain", fence=x * 2):
        pass
    assert tracer.durations("maintain")


# ---------------------------------------------------------------------------
# perturbation ledger: bounds bit-match core/iteration_cost
# ---------------------------------------------------------------------------

def test_ledger_bounds_bit_match_iteration_cost():
    led = PerturbationLedger(c=0.9, x0_err=10.0)
    led.record(step=5, lost_blocks=3, tier_counts={"RUNNING_CKPT": 3},
               applied_sq=0.25)
    led.record(step=12, lost_blocks=1, tier_counts={"PEER_REPLICA": 1},
               applied_sq=0.0)
    for e in led.entries:
        assert e.bound == single_perturbation_bound(
            e.delta_norm, 0.9, T=e.step, x0_err=10.0)
    assert led.cumulative_bound(20) == float(iteration_cost_bound(
        led.delta_series(20), 0.9, 10.0))
    # the dense series carries each event's ‖δ'‖ at its iteration
    dense = led.delta_series(20)
    assert len(dense) == 21
    assert dense[5] == pytest.approx(0.5) and dense[12] == 0.0
    owed = led.iterations_owed()
    assert owed == sorted(owed)   # cumulative series is monotone


def test_ledger_backfills_bounds_on_set_rates():
    led = PerturbationLedger()
    e = led.record(step=7, lost_blocks=2, tier_counts=None, applied_sq=4.0)
    assert e.bound is None and led.cumulative_bound() is None
    led.set_rates(0.8, 5.0)
    assert e.bound == single_perturbation_bound(2.0, 0.8, T=7, x0_err=5.0)
    assert led.summary()["iterations_owed_total"] == pytest.approx(e.bound)


def test_record_recovery_feeds_ledger_and_bus():
    rec = Recorder()
    rec.record_recovery(step=9, lost_blocks=4,
                        tier_counts={"PARITY": 4}, applied_sq=1.0)
    (entry,) = rec.ledger.entries
    assert entry.delta_norm == 1.0 and entry.source_tiers == {"PARITY": 4}
    (ev,) = rec.events
    assert ev["kind"] == "recovery" and ev["tier_counts"] == {"PARITY": 4}


# ---------------------------------------------------------------------------
# NullRecorder: the zero-overhead default
# ---------------------------------------------------------------------------

def test_null_recorder_is_allocation_free_singletons():
    assert NULL_RECORDER.enabled is False
    assert isinstance(NULL_RECORDER, NullRecorder)
    # shared singletons, no per-call allocation
    assert NULL_RECORDER.span("a") is NULL_RECORDER.span("b")
    assert NULL_RECORDER.histogram("x") is NULL_RECORDER.counter("y")
    d = {"k": 1}
    assert NULL_RECORDER.scope("s", d) is d
    with NULL_RECORDER.span("noop", fence=lambda: 1 / 0):
        pass               # the fence must never run on the null path
    NULL_RECORDER.event("anything", x=1)
    NULL_RECORDER.record_recovery(step=1, lost_blocks=1,
                                  tier_counts=None, applied_sq=0.0)
    assert NULL_RECORDER.metrics() == {}


def test_components_default_to_null_recorder():
    m = make_model("qp")
    p = m.init(__import__("jax").random.PRNGKey(1))
    ctl = FTController(p, CheckpointPolicy(fraction=0.5, full_interval=4),
                       fabric=FabricConfig(n_devices=8))
    assert ctl.recorder is NULL_RECORDER
    assert ctl.fabric.recorder is NULL_RECORDER
    # stats stay plain dicts, registered nowhere
    assert isinstance(ctl.stats, dict) and isinstance(ctl.fabric.stats, dict)


def test_fabric_attach_recorder_rebinds_stats():
    m = make_model("qp")
    p = m.init(__import__("jax").random.PRNGKey(1))
    from repro.core.blocks import partition_pytree
    part = partition_pytree(p, 16)
    fab = CheckpointFabric(part, FabricConfig(n_devices=8))
    stats = fab.stats
    rec = Recorder()
    fab.attach_recorder(rec)
    assert fab.recorder is rec
    assert rec.scopes["fabric"] is stats     # same dict, now registered
    fab.attach_recorder(Recorder())          # second attach: no-op
    assert fab.recorder is rec
    fab2 = CheckpointFabric(part, FabricConfig(n_devices=8))
    fab2.attach_recorder(NULL_RECORDER)      # null attach: no-op
    assert fab2.recorder is NULL_RECORDER


# ---------------------------------------------------------------------------
# end-to-end: instrumented runs, snapshots, report
# ---------------------------------------------------------------------------

def test_run_with_failure_emits_and_prices(tmp_path):
    m = make_model("qp")
    rec = Recorder(out_dir=str(tmp_path / "t"))
    res = run_with_failure(m, CheckpointPolicy(fraction=0.5,
                                               full_interval=4),
                           fail_iter=8, fail_fraction=0.5, max_iters=16,
                           fabric=FabricConfig(n_devices=8), recorder=rec)
    kinds = {e["kind"] for e in rec.events}
    assert {"failure", "recovery", "maintain", "save"} <= kinds
    # the ledger entry mirrors the recovery diagnostics exactly
    (entry,) = rec.ledger.entries
    assert entry.applied_sq == pytest.approx(
        float(res["recovery"]["applied_sq"]))
    assert entry.lost_blocks == int(res["recovery"]["lost_blocks"])
    rec.ledger.set_rates(0.9, 10.0)
    assert entry.bound == single_perturbation_bound(
        entry.delta_norm, 0.9, T=8, x0_err=10.0)
    rec.close()
    # all three artifacts land
    for name in ("events.jsonl", "trace.json", "metrics.json"):
        assert (tmp_path / "t" / name).exists()
    report = run_report(rec, horizon=16)
    assert report["recovery"]["n_recoveries"] == 1
    assert report["ledger"]["cumulative_bound"] == float(
        iteration_cost_bound(rec.ledger.delta_series(16), 0.9, 10.0))
    assert "iterations owed" in format_report(report)


def test_classic_runner_results_are_snapshots():
    """Post-run mutation of the live controller/fabric stats must not
    corrupt the returned result dicts."""
    m = make_model("qp")
    rec = Recorder()
    res = run_with_failure(m, CheckpointPolicy(fraction=0.5,
                                               full_interval=4),
                           fail_iter=6, fail_fraction=0.5, max_iters=12,
                           fabric=FabricConfig(n_devices=8), recorder=rec)
    # the recorder scope IS the controller's live dict — mutate it
    live_ctl = rec.scopes["controller"]
    live_fab = rec.scopes["fabric"]
    assert res["controller_stats"]["saves"] == live_ctl["saves"]
    live_ctl["saves"] += 100
    live_fab["maintain_bytes_moved"] += 10 ** 9
    live_ctl["events"].append({"poison": True})
    assert res["controller_stats"]["saves"] == live_ctl["saves"] - 100
    assert res["fabric_stats"]["maintain_bytes_moved"] \
        == live_fab["maintain_bytes_moved"] - 10 ** 9
    assert all("poison" not in e for e in res["controller_stats"]["events"])


def test_run_with_trace_snapshots_events():
    m = make_model("qp")
    rec = Recorder()
    res = run_with_trace(m, CheckpointPolicy(fraction=0.5, full_interval=4),
                         fabric=FabricConfig(n_devices=8, elastic=True),
                         max_iters=20, mtbf={"device": 8.0}, recorder=rec)
    live = rec.scopes["controller"]
    n_before = len(res["controller_stats"]["events"])
    live["events"].append({"poison": True})
    assert len(res["controller_stats"]["events"]) == n_before
    assert "fabric_stats" in res


def test_report_on_null_recorder_is_well_formed():
    report = run_report(NULL_RECORDER)
    assert report["events"]["total"] == 0
    assert report["ledger"] is None
    assert "telemetry: 0 events" in format_report(report)


def test_histogram_summary_percentiles():
    h = Histogram()
    for v in [1.0, 2.0, 3.0, 4.0, 100.0]:
        h.observe(v)
    s = h.summary()
    assert s["count"] == 5 and s["max"] == 100.0
    assert s["p50"] == 3.0
    assert s["p95"] == pytest.approx(
        float(np.percentile([1, 2, 3, 4, 100], 95)))
