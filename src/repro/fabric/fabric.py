"""The checkpoint fabric facade: cluster view + replicas + parity + planner.

``CheckpointFabric`` is the single object the FTController (and the
training loops) talk to:

- ``maintain(step, params)``      — refresh replicas / re-encode parity on
                                    their configured intervals (idempotent
                                    per step).
- ``sample_domain_failure(...)``  — correlated whole-domain failure: the
                                    lost-block mask plus the failed devices.
- ``domain_failure(kind, index)`` — the lost mask for one *specific* domain
                                    (trace-driven injection).
- ``on_failure(...)``             — tier-plan the lost blocks, recover each
                                    from the cheapest surviving tier, and
                                    report per-tier perturbation norms. With
                                    ``elastic=True`` the failed devices stay
                                    dead in the :class:`ClusterView` and the
                                    placement engine re-homes the recovered
                                    blocks, re-seeds replicas, and
                                    re-stripes parity over the survivors.
- ``heal_domain(kind, index)``    — re-admit a healed domain to the view
                                    (and, elastic, rebalance onto it).

All components share one mutable :class:`~repro.fabric.placement.ClusterView`
— `block_device_homes` is only the *initial* placement; the view owns the
current one.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocks import BlockPartition
from repro.fabric.domains import FailureDomainMap
from repro.fabric.parity import ParityCodec
from repro.fabric.placement import ClusterView, rebalance_homes, rehome_blocks
from repro.fabric.replica import ReplicaSet
from repro.fabric.tiers import TieredRecovery
from repro.sharding.partition import block_device_homes
from repro.telemetry.recorder import NULL_RECORDER, Histogram

PyTree = Any


@dataclasses.dataclass(frozen=True)
class FabricConfig:
    n_devices: int = 8
    devices_per_host: int = 2
    hosts_per_rack: int = 2
    replicate: bool = True
    replicate_interval: int = 1    # steps between replica refreshes
    parity: bool = True
    parity_group: int = 4          # members per XOR parity group
    parity_interval: int = 1       # steps between parity re-encodes
    rs_parity: int = 0             # 0 = XOR codec; m >= 1 = RS(k, m) codec
                                   # with m GF(256) parity rows per group
    elastic: bool = False          # post-failure re-homing/re-seeding
    fused: bool = True             # single-sweep maintenance pipeline
    arena: bool = True             # flat-arena single-dispatch maintenance
    async_maintain: bool = False   # double-buffered pipelined sweep
    use_pallas: Optional[bool] = None   # None = auto: Pallas on TPU only

    def __post_init__(self):
        if self.replicate_interval < 1 or self.parity_interval < 1:
            raise ValueError("maintenance intervals must be >= 1")
        if self.parity_group < 2:
            raise ValueError("parity_group must be >= 2: a 1-member group "
                             "degenerates the XOR code to a bare copy")
        if self.rs_parity < 0:
            raise ValueError("rs_parity must be >= 0 (0 selects the XOR "
                             "codec, m >= 1 the RS(k, m) codec)")
        if self.async_maintain and not (self.fused and self.arena):
            raise ValueError(
                "async_maintain requires the fused arena pipeline "
                "(fused=True, arena=True): the double-buffer snapshot and "
                "deferred fence only exist for the single-dispatch sweep")


class CheckpointFabric:
    def __init__(self, partition: BlockPartition,
                 cfg: Optional[FabricConfig] = None,
                 homes: Optional[np.ndarray] = None,
                 recorder: Optional[Any] = None,
                 mesh: Optional[Any] = None):
        self.cfg = cfg or FabricConfig()
        self.recorder = recorder if recorder is not None else NULL_RECORDER
        self.partition = partition
        self.domains = FailureDomainMap(self.cfg.n_devices,
                                        self.cfg.devices_per_host,
                                        self.cfg.hosts_per_rack)
        # flat parameter arena: the canonical hot-path representation —
        # requires the single-sweep pipeline (``fused=False`` is the seed
        # baseline), both tiers (the sweep's pack is the replica write,
        # its XOR routing needs the parity striping), and word-packable
        # leaf dtypes (f32/bf16/f16/fp8/int8… stored as raw bit patterns;
        # only f64/int64/complex/bool gate — they fall back to the
        # per-leaf fused path with a warn+event upstream). With a
        # ``mesh`` the layout is built with one tile-aligned shard per
        # device (``shards=mesh size``) so every device owns a contiguous
        # span and the sweep runs shard-local (see arena.py "Sharded
        # form"); the meshed fabric additionally requires an all-f32
        # model for now — a quantized layout's value domain is not
        # tile-divisible, so the flat optimizer sharding would not line
        # up with the word shards.
        self.arena_layout = None
        if self.cfg.arena and self.cfg.fused and self.cfg.replicate \
                and self.cfg.parity:
            from repro.core.arena import arena_compatible, build_arena_layout
            uniform_f32 = all(np.dtype(l.dtype) == np.dtype(np.float32)
                              for l in partition.leaves)
            if arena_compatible(partition) \
                    and (mesh is None or uniform_f32):
                shards = 1
                if mesh is not None:
                    shards = int(np.asarray(mesh.devices).size)
                self.arena_layout = build_arena_layout(partition,
                                                       shards=shards)
        # SPMD binding: mesh position i (row-major) IS fabric logical
        # device i, so the sharded arena's span owners line up with the
        # failure-domain map. Requires the mesh to cover the configured
        # topology exactly at construction (shrunk meshes only ever come
        # from resize_mesh, which carries the surviving logical ids).
        self.mesh = None
        self._mesh_logical = None
        self._arena_sharding = None
        self._replica_sharding = None
        self._xfer_split = (0, 0, 0)    # (local, ici, dcn) bytes/transfer
        if mesh is not None:
            n = int(np.asarray(mesh.devices).size)
            if n != self.cfg.n_devices:
                raise ValueError(
                    f"mesh has {n} devices but the fabric topology is "
                    f"configured for {self.cfg.n_devices} "
                    "(FabricConfig.n_devices must match the mesh so "
                    "failure domains map onto real devices)")
            if self.arena_layout is None:
                raise ValueError(
                    "a meshed fabric needs the sharded arena pipeline "
                    "(arena=True, fused=True, both tiers, and an all-f32 "
                    "model — quantized dtypes are single-host-arena only "
                    "for now) — there is no sharded per-leaf fallback")
            self._bind_mesh(mesh, np.arange(n, dtype=np.int32))
        if homes is not None:
            initial = np.asarray(homes, np.int32)
        elif self.mesh is not None:
            # span-derived homes: a block lives where the sharded arena
            # places its first tile, so "primary home" and "owning shard"
            # agree and the sweep's writes are home-local by construction
            from repro.core.arena import arena_block_homes
            initial = arena_block_homes(self.arena_layout).astype(np.int32)
        else:
            initial = block_device_homes(partition, self.cfg.n_devices)
        self.view = ClusterView(self.domains, initial)
        self.replicas = (ReplicaSet(partition, self.view)
                         if self.cfg.replicate else None)
        self.parity = None
        if self.cfg.parity:
            if self.cfg.rs_parity > 0:
                from repro.fabric.rs import RSCodec
                self.parity = RSCodec(partition, self.view,
                                      group_size=self.cfg.parity_group,
                                      n_parity=self.cfg.rs_parity,
                                      use_pallas=self.cfg.use_pallas)
            else:
                self.parity = ParityCodec(partition, self.view,
                                          group_size=self.cfg.parity_group,
                                          use_pallas=self.cfg.use_pallas)
        self.planner = TieredRecovery(partition, self.view,
                                      replicas=self.replicas,
                                      parity=self.parity)
        if self.replicas is not None and self._arena_sharding is not None:
            self.replicas.main_sharding = self._arena_sharding
        self.last_maintained_step = -1
        # fused maintenance programs: (re)built lazily against the view's
        # current striping (see _fused_maintain_fn / _arena_maintain_fn)
        self._fused_fn = None
        self._fused_version = -1
        self._arena_fn = None
        self._arena_version = -1
        self._pack_fn = None
        self._traffic = None
        self.last_scores = None
        self.last_scores_step = -1
        # True once a maintain has been fed the live arena itself
        # (arena-resident training state): every sweep from then on is
        # pack-free and the accounting switches to the resident model
        self.live_arena_mode = False
        # async maintenance (cfg.async_maintain): two-slot snapshot arena
        # with an epoch/publish protocol. ``_async_maintain`` copies the
        # live arena into the inactive slot (one async device copy behind
        # optimization_barrier), flips ``_active_slot``, dispatches the
        # sweep against the published slot, and returns without fencing —
        # the sweep overlaps the trainer's next step. ``published_epoch``
        # is the step whose snapshot the live tiers currently hold (at
        # Python level the flip is atomic: replica + parity + scores are
        # always ingested for the same step, never torn). ``_pending``
        # holds the one in-flight sweep; it is settled (fenced) at the
        # next maintain, at any consume point (failure, checkpoint,
        # shutdown), or via ``block_until_maintained``.
        self._slots: list[Any] = [None, None]
        self._active_slot = 0
        self.published_epoch = -1
        self._pending: Optional[dict] = None
        self._snap_donate = None
        self._snap_fresh = None
        # donation lets the snapshot reuse the slot retired two epochs
        # ago; the CPU backend ignores donation (with a warning per call),
        # so fall back to fresh copies there — the protocol is identical
        self._donate_slots = jax.default_backend() not in ("cpu",)
        self.async_hidden_seconds = 0.0
        self.async_total_seconds = 0.0
        self.fence_hist = Histogram()
        self.stats = self.recorder.scope("fabric", {
            "replica_refreshes": 0, "parity_encodes": 0,
            "recoveries": 0, "rehomes": 0, "heals": 0,
            "fused_maintains": 0, "arena_maintains": 0,
            "arena_resident_maintains": 0, "live_packs": 0,
            "async_maintains": 0, "fence_count": 0,
            "maintain_bytes_moved": 0,
            "ici_bytes_moved": 0, "dcn_bytes_moved": 0,
            "mesh_resizes": 0, "tier_fallbacks": 0,
            "rs_arena_encodes": 0, "scrubs": 0,
            "silent_errors_detected": 0, "silent_errors_corrected": 0,
            "arena_padding_ratio": 0.0})
        if self.arena_layout is not None:
            # gauge, not a counter: pad words / payload words of the live
            # layout — the number tail packing shrinks (run-report +
            # maint_arena_padding bench read it from here)
            self.stats["arena_padding_ratio"] = float(
                self.arena_layout.padding_ratio)
        if self.recorder.enabled:
            self.recorder.adopt_histogram("fabric/fence_seconds",
                                          self.fence_hist)

    def attach_recorder(self, recorder: Any) -> None:
        """Late-bind a recorder (controller attach path for prebuilt
        fabrics). No-op if ``recorder`` is null or one is already live —
        the stats dict is re-registered by reference, so existing readers
        keep working."""
        if recorder is None or not getattr(recorder, "enabled", False) \
                or self.recorder.enabled:
            return
        self.recorder = recorder
        self.stats = recorder.scope("fabric", self.stats)
        recorder.adopt_histogram("fabric/fence_seconds", self.fence_hist)

    @property
    def homes(self) -> np.ndarray:
        """Current primary placement (the view's, not the initial one)."""
        return self.view.homes

    # -- SPMD mesh binding ---------------------------------------------------

    def _bind_mesh(self, mesh, logical_ids: np.ndarray) -> None:
        """Bind the fabric to a device mesh: mesh position ``i`` ↔ fabric
        logical device ``logical_ids[i]``. Computes the flat arena
        sharding, the anti-affine replica sharding (shard ``j``'s copy
        lands a whole failure domain away — the rotation maximizing
        cross-host, then cross-rack, pairs in the *bound* topology), and
        the per-transfer local/ICI/DCN byte split the maintain events
        report."""
        from jax.sharding import Mesh, NamedSharding, PartitionSpec
        from repro.sharding.partition import arena_sharding
        self.mesh = mesh
        self._mesh_logical = np.asarray(logical_ids, np.int32)
        self._arena_sharding = arena_sharding(mesh)
        devs = np.asarray(mesh.devices).reshape(-1)
        n = devs.size
        hosts = np.asarray(self.domains.host_of(self._mesh_logical))
        racks = np.asarray(self.domains.rack_of(self._mesh_logical))
        best, shift = (-1, -1), 0
        for s in range(1, n):
            dst = (np.arange(n) + s) % n
            key = (int(np.sum(hosts[dst] != hosts)),
                   int(np.sum(racks[dst] != racks)))
            if key > best:
                best, shift = key, s
        if shift == 0:
            self._replica_sharding = None   # single device: copy in place
            self._xfer_split = (0, 0, 0)
            return
        rolled = np.roll(devs, -shift)      # span j -> devs[(j+shift) % n]
        self._replica_sharding = NamedSharding(
            Mesh(rolled, ("arena",)), PartitionSpec("arena"))
        # classify each span's replica hop: same host = ICI, cross-host =
        # DCN (same device = no wire at all)
        dst = (np.arange(n) + shift) % n
        sw = self.arena_layout.shard_words * 4
        local = int(np.sum(dst == np.arange(n))) * sw
        ici = int(np.sum((hosts[dst] == hosts)
                         & (dst != np.arange(n)))) * sw
        dcn = int(np.sum(hosts[dst] != hosts)) * sw
        self._xfer_split = (local, ici, dcn)

    def _replica_xfer(self, rep):
        """Ship the replica arena to its anti-affine homes: one rotated
        ``device_put`` — every device sends its span to a device in a
        different failure domain (a true D2D transfer under SPMD; a no-op
        copy without a mesh). Books the ICI/DCN split."""
        if self._replica_sharding is None:
            return rep
        out = jax.device_put(rep, self._replica_sharding)
        _, ici, dcn = self._xfer_split
        self.stats["ici_bytes_moved"] += ici
        self.stats["dcn_bytes_moved"] += dcn
        return out

    # -- maintenance ---------------------------------------------------------

    def maintain(self, step: int, params: PyTree,
                 ckpt_values: Optional[PyTree] = None,
                 force: bool = False, own_live: bool = False) -> None:
        """Refresh redundancy tiers from live params (idempotent per step).

        With ``cfg.fused`` (default) and both tiers due, the refresh runs
        as one fused sweep (``kernels/fused_maintain``): each live leaf is
        read once and yields the replica snapshot, the XOR parity frames,
        and — when ``ckpt_values`` is passed — per-block PRIORITY scores
        against the running checkpoint, cached on ``last_scores`` for the
        controller's next partial save. Off-interval steps and
        partial-tier configs fall back to the independent per-component
        passes.

        ``params`` may be the live flat arena itself (arena-resident
        training state, requires ``arena_layout``): the sweep then runs
        pack-free — pure 2-read/1-write — and an off-interval step with
        only one tier due still takes the full sweep (the live state has
        no tree form for the per-component passes; refreshing the other
        tier early is strictly fresher, never stale).

        ``own_live=True`` (arena input only) transfers ownership of that
        buffer to the fabric: it becomes the replica directly, no copy —
        for tree-stepping callers whose per-iteration pack is throwaway.
        The caller must never donate or mutate the arena afterwards;
        truly resident state (donated through the train step) must leave
        this False so the sweep emits an independent replica copy.
        """
        from repro.core.arena import as_live_arena
        step = int(step)
        if step == self.last_maintained_step and not force:
            return
        # note: without an arena layout a 1-D input is treated as what it
        # always was — a bare single-leaf param tree on the per-component
        # paths (a genuine live arena can only come from an arena-capable
        # controller, which implies the layout exists here)
        live = as_live_arena(params, self.arena_layout)
        due_replica, due_parity = self.maintenance_due(step, force=force)
        b0 = self.stats["maintain_bytes_moved"]
        i0 = self.stats["ici_bytes_moved"]
        d0 = self.stats["dcn_bytes_moved"]
        if self.cfg.async_maintain and live is not None \
                and (due_replica or due_parity):
            # pipelined path: dispatch only, no fence — the sweep runs
            # under the trainer's next step. No sync span here either;
            # the deferred [dispatch, fence] span is recorded when the
            # pending sweep settles, so the trace shows the true overlap.
            self._async_maintain(step, live, ckpt_values, own_live=own_live)
            self.last_maintained_step = step
            if self.recorder.enabled:
                self.recorder.event(
                    "maintain", step=step, mode="arena_async",
                    bytes_moved=self.stats["maintain_bytes_moved"] - b0,
                    ici_bytes=self.stats["ici_bytes_moved"] - i0,
                    dcn_bytes=self.stats["dcn_bytes_moved"] - d0,
                    replica=due_replica, parity=due_parity)
            return
        mode = "components"
        with self.recorder.span("maintain", step=step,
                                fence=self.block_until_maintained):
            if self.arena_layout is not None and (
                    (due_replica and due_parity)
                    or (live is not None and (due_replica or due_parity))):
                self._arena_maintain(step, params, ckpt_values,
                                     own_live=own_live)
                mode = ("arena_resident" if self.live_arena_mode
                        and live is not None and not own_live else "arena")
            elif self.cfg.fused and due_replica and due_parity:
                self._fused_maintain(step, params, ckpt_values)
                mode = "fused"
            else:
                t = self._traffic_model()
                if due_replica:
                    self.replicas.refresh(step, params)
                    self.stats["replica_refreshes"] += 1
                    self.stats["maintain_bytes_moved"] += t["replica_pass"]
                if due_parity:
                    self.parity.encode(step, params)
                    self.stats["parity_encodes"] += 1
                    self.stats["maintain_bytes_moved"] += t["parity_pass"]
                if due_replica or due_parity:
                    self.published_epoch = step
        self.last_maintained_step = step
        if self.recorder.enabled:
            self.recorder.event(
                "maintain", step=step, mode=mode,
                bytes_moved=self.stats["maintain_bytes_moved"] - b0,
                ici_bytes=self.stats["ici_bytes_moved"] - i0,
                dcn_bytes=self.stats["dcn_bytes_moved"] - d0,
                replica=due_replica, parity=due_parity)

    def _fused_maintain(self, step: int, params: PyTree,
                        ckpt_values: Optional[PyTree]) -> None:
        fn = self._fused_maintain_fn()
        # without checkpoint values there is nothing to score against —
        # the sweep still runs, diffing params against itself (zero
        # scores, discarded), so the program stays one cached jit
        z = ckpt_values if ckpt_values is not None else params
        replica, scores, parity = fn(params, z)
        self.replicas.ingest(step, replica)
        if self.parity.needs_arena_encode:
            # the sweep's XOR parity does not generalize to RS rows —
            # re-encode from the live tree (per-leaf path has no arena)
            self.parity.encode(step, params)
        else:
            self.parity.ingest(step, parity)
        if ckpt_values is not None:
            self.last_scores = scores
            self.last_scores_step = step
        self.stats["replica_refreshes"] += 1
        self.stats["parity_encodes"] += 1
        self.stats["fused_maintains"] += 1
        self.stats["maintain_bytes_moved"] += self._traffic_model()["fused"]
        self.published_epoch = int(step)

    def _arena_maintain(self, step: int, params: PyTree,
                        ckpt_values, own_live: bool = False) -> None:
        """One pack + ONE kernel dispatch for the whole model: the pack
        is the replica write (arena form), the sweep emits group-sorted
        XOR parity and PRIORITY score partials. ``ckpt_values`` may be
        the running checkpoint as an arena (the controller's canonical
        form — zero conversion), a PyTree (packed once), or None (no
        scoring this step).

        With arena-resident live state (``params`` already the flat
        arena) there is no pack at all: the sweep reads the live and
        checkpoint arenas once each and emits the replica copy from the
        same read — the accounted bytes drop by the live tree's size."""
        from repro.core.arena import as_live_arena
        fn = self._arena_maintain_fn()
        z = self._as_arena(ckpt_values)
        is_arena = as_live_arena(params, self.arena_layout) is not None
        owned = own_live and is_arena
        resident = is_arena and not owned
        rep, scores, parity = fn(params, z, own_live=owned)
        self.replicas.ingest_arena(step, self._replica_xfer(rep),
                                   self.arena_layout)
        if self.parity.needs_arena_encode:
            # RS rows re-encode from the sweep's snapshot arena (the same
            # buffer the replica tier stores, pre-rotation — so the
            # refreshed_step == encoded_step arena recovery route and the
            # integrity scrub both see one consistent coded snapshot)
            self.parity.encode_from_arena(step, rep, self.arena_layout)
            self.stats["rs_arena_encodes"] += 1
        else:
            self.parity.ingest(step, parity)
        if z is not None:
            self.last_scores = scores
            self.last_scores_step = step
        self.stats["replica_refreshes"] += 1
        self.stats["parity_encodes"] += 1
        self.stats["fused_maintains"] += 1
        self.stats["arena_maintains"] += 1
        if is_arena:
            # either way every maintain from here on is an arena sweep —
            # the seed staging never materializes (redundancy_nbytes)
            self.live_arena_mode = True
        if resident:
            self.stats["arena_resident_maintains"] += 1
        self.stats["maintain_bytes_moved"] += self._traffic_model()[
            "arena_owned" if owned else
            "arena_resident" if resident else "arena"]
        self.published_epoch = int(step)

    def _async_maintain(self, step: int, live, ckpt_values,
                        own_live: bool = False) -> None:
        """Dispatch one pipelined sweep epoch and return immediately.

        Pipeline depth is one: the previous epoch's sweep is settled
        first, so the fence wait here is ``max(0, sweep - step_time)`` —
        exactly the stall the overlap failed to hide (zero when the
        sweep fits under a step). Then the live arena is snapshotted
        into the inactive slot (``optimization_barrier`` forces a real
        copy — the live buffer is donated through the train step and
        must not be aliased), the slot flips, ``published_epoch``
        advances, and the owned sweep (the snapshot IS the replica — no
        second copy) is dispatched against the published slot. Nothing
        blocks: JAX's async dispatch runs the copy + sweep while the
        caller computes step N+1, and any consumer that reaches the
        output arrays first waits on dataflow, never on a torn slot.

        ``own_live=True`` (tree-stepping callers, throwaway pack): the
        pack is adopted as the snapshot directly — no copy at all, same
        as the sync owned path, still dispatched without a fence."""
        self._settle_pending()
        span_t0 = self.recorder.tracer.now() if self.recorder.enabled \
            else 0.0
        t0 = time.perf_counter()
        fn = self._arena_maintain_fn()
        z = self._as_arena(ckpt_values)
        if own_live:
            snap = live
        else:
            inactive = 1 - self._active_slot
            stale = self._slots[inactive]
            if self._snap_fresh is None:
                self._snap_fresh = jax.jit(
                    lambda a: jax.lax.optimization_barrier(a))
                self._snap_donate = jax.jit(
                    lambda slot, a: jax.lax.optimization_barrier(a),
                    donate_argnums=(0,))
            if self._donate_slots and stale is not None \
                    and stale.shape == live.shape \
                    and stale.dtype == live.dtype:
                # reuse the buffer retired two epochs ago (the published
                # slot moved on; nothing references this one any more)
                snap = self._snap_donate(stale, live)
            else:
                snap = self._snap_fresh(live)
            self._slots[inactive] = snap
            self._active_slot = inactive
        _, scores, parity = fn(snap, z, own_live=True)
        self.replicas.ingest_arena(step, self._replica_xfer(snap),
                                   self.arena_layout)
        if self.parity.needs_arena_encode:
            # RS re-encode rides the same async dispatch — no fence here;
            # _settle_pending blocks on the parity rows like the XOR path
            self.parity.encode_from_arena(step, snap, self.arena_layout)
            self.stats["rs_arena_encodes"] += 1
        else:
            self.parity.ingest(step, parity)
        if z is not None:
            self.last_scores = scores
            self.last_scores_step = step
        self.live_arena_mode = True
        self.published_epoch = int(step)
        self._pending = {"step": int(step), "t0": t0, "span_t0": span_t0}
        self.stats["replica_refreshes"] += 1
        self.stats["parity_encodes"] += 1
        self.stats["fused_maintains"] += 1
        self.stats["arena_maintains"] += 1
        self.stats["async_maintains"] += 1
        self.stats["maintain_bytes_moved"] += self._traffic_model()[
            "arena_owned" if own_live else "arena_async"]

    @property
    def has_pending_maintenance(self) -> bool:
        """True while an async sweep epoch is dispatched but not yet
        settled (consumers fence via :meth:`block_until_maintained`)."""
        return self._pending is not None

    def _settle_pending(self) -> float:
        """Fence the in-flight async sweep (no-op without one); returns
        the seconds actually waited. Books the epoch's hidden/total time
        into the overlap-efficiency accounting and records the deferred
        ``maintain`` span covering [dispatch, fence] — the interval the
        Chrome trace shows overlapping the next ``train_step``."""
        p = self._pending
        if p is None:
            return 0.0
        self._pending = None
        w0 = time.perf_counter()
        if self.parity is not None and self.parity.parity is not None:
            jax.block_until_ready(self.parity.parity)
        if self.replicas is not None and self.replicas.arena is not None:
            jax.block_until_ready(self.replicas.arena)
        now = time.perf_counter()
        wait = now - w0
        total = now - p["t0"]
        self.fence_hist.observe(wait)
        self.stats["fence_count"] += 1
        self.async_total_seconds += total
        self.async_hidden_seconds += max(0.0, total - wait)
        if self.recorder.enabled:
            self.recorder.gauge("fabric/overlap_efficiency").set(
                self.overlap_efficiency())
            self.recorder.tracer.record(
                "maintain", p["span_t0"], self.recorder.tracer.now(),
                step=p["step"], mode="arena_async", deferred=True)
        return wait

    def overlap_efficiency(self) -> float:
        """Fraction of async sweep wall-clock hidden under the trainer's
        compute (0.0 until the first settled async epoch)."""
        if self.async_total_seconds <= 0.0:
            return 0.0
        return self.async_hidden_seconds / self.async_total_seconds

    def _as_arena(self, ckpt_values):
        """Coerce checkpoint values to arena form (None passes through)."""
        if ckpt_values is None:
            return None
        if isinstance(ckpt_values, (jnp.ndarray, np.ndarray)) \
                and getattr(ckpt_values, "ndim", None) == 1:
            assert ckpt_values.size == self.arena_layout.total_words, \
                "checkpoint arena does not match this fabric's layout"
            return ckpt_values
        if self._pack_fn is None:
            from repro.core.arena import pack_arena
            layout, sh = self.arena_layout, self._arena_sharding
            self._pack_fn = jax.jit(
                lambda t: pack_arena(t, layout, out_sharding=sh))
        return self._pack_fn(ckpt_values)

    def _arena_maintain_fn(self):
        """The arena sweep program, rebuilt whenever the placement engine
        re-striped since the last build."""
        if self._arena_fn is None or self._arena_version != self.view.version:
            from repro.kernels.fused_maintain.ops import ArenaMaintainProgram
            self._arena_fn = ArenaMaintainProgram(
                self.partition, self.arena_layout, self.parity.layout,
                self.parity.group_of, self.parity.n_groups,
                use_pallas=self.cfg.use_pallas,
                out_sharding=self._arena_sharding)
            self._arena_version = self.view.version
            self._traffic = None
        return self._arena_fn

    def _fused_maintain_fn(self):
        """The jitted single-sweep program, rebuilt whenever the placement
        engine re-striped since the last build (view.version moves on
        every re-home/re-stripe/heal)."""
        if self._fused_fn is None or self._fused_version != self.view.version:
            from repro.kernels.fused_maintain.ops import make_fused_maintain_fn
            self._fused_fn = make_fused_maintain_fn(
                self.partition, self.parity.layout, self.parity.group_of,
                self.parity.n_groups, use_pallas=self.cfg.use_pallas)
            self._fused_version = self.view.version
            self._traffic = None
        return self._fused_fn

    def block_until_maintained(self) -> None:
        """Block until the last maintenance sweep's device work is done
        (dispatch returns early under async execution). Timing-attribution
        helper for loops that report per-step maintenance overhead — owns
        the knowledge of which tensor represents the sweep's completion.
        With a pending async epoch this is the deferred fence: it settles
        the pending sweep (books overlap accounting + the deferred span)
        rather than bare-blocking."""
        if self._pending is not None:
            self._settle_pending()
            return
        if self.parity is not None and self.parity.parity is not None:
            jax.block_until_ready(self.parity.parity)
        elif self.replicas is not None and self.replicas.arena is not None:
            jax.block_until_ready(self.replicas.arena)

    def maintenance_due(self, step: int,
                        force: bool = False) -> tuple[bool, bool]:
        """(replica due, parity due) at ``step`` under the configured
        intervals — what :meth:`maintain` would actually refresh. Lets
        tree-stepping callers skip preparing a live value (e.g. the
        classic runners' shared pack) on steps where nothing reads it."""
        step = int(step)
        due_replica = self.replicas is not None and (
            force or step % self.cfg.replicate_interval == 0)
        due_parity = self.parity is not None and (
            force or step % self.cfg.parity_interval == 0
            or self.parity.parity is None)
        return due_replica, due_parity

    def is_fresh(self, step: int) -> bool:
        """True when every configured tier holds this step's live values —
        an off-interval :meth:`maintain` can run without refreshing a tier,
        so ``last_maintained_step`` alone does not imply freshness."""
        step = int(step)
        if self.replicas is not None and not self.replicas.is_fresh(step):
            return False
        if self.parity is not None and not self.parity.is_fresh(step):
            return False
        return True

    def invalidate_scores(self) -> None:
        """Drop the cached PRIORITY scores (the controller calls this
        after a partial save mutates the running checkpoint — the drift
        they measured no longer exists)."""
        self.last_scores = None
        self.last_scores_step = -1

    def _traffic_model(self) -> dict[str, int]:
        """Analytic bytes per maintenance step under the current striping
        (cached; placement changes invalidate)."""
        if self._traffic is None:
            model = sum(
                int(np.prod(l.shape) if l.shape else 1)
                * np.dtype(l.dtype).itemsize for l in self.partition.leaves)
            if self.parity is not None:
                from repro.kernels.fused_maintain.ops import maintain_traffic
                t = dict(maintain_traffic(
                    self.partition, self.parity.layout, self.parity.group_of,
                    self.parity.n_groups, self.parity.members.shape[1],
                    arena_layout=self.arena_layout))
                # per-component splits for off-interval steps: the scoring
                # pass (2·model) only happens at PRIORITY checkpoint time
                # on the seed path, so it is excluded from both
                t["parity_pass"] = t["seed"] - 4 * t["model"]
            else:
                t = {"seed": 2 * model, "fused": 2 * model, "model": model,
                     "parity": 0, "staging_seed": 0, "staging_fused": 0,
                     "parity_pass": 0}
            t["replica_pass"] = 2 * t["model"]
            self._traffic = t
        return self._traffic

    def redundancy_state(self) -> dict:
        """Cheap per-step health snapshot of the redundancy tiers under
        the view's *current* placement (pure metadata — no tensor data is
        touched, safe to call every step of a soak):

        - ``replica_alive_frac`` — fraction of replicas homed on alive
          devices;
        - ``parity_groups_ok_frac`` — fraction of parity groups whose
          parity home and every member's primary home are alive (the
          precondition for a free single-erasure reconstruction of the
          next failure);
        - ``full`` — every configured tier fully placed on live hardware,
          i.e. the next domain loss is guaranteed to recover from the
          live-value tiers.
        """
        rep_frac = par_frac = 1.0
        if self.replicas is not None:
            rep_frac = float(np.mean(
                self.view.alive[self.replicas.replica_homes]))
        if self.parity is not None:
            members = self.parity.members
            valid = members >= 0
            homes_ok = np.where(
                valid, self.view.alive[self.view.homes[
                    np.where(valid, members, 0)]], True).all(axis=1)
            # XOR homes are (n_groups,), RS homes (n_groups, m): a group
            # is fully placed only when every parity row's home is alive
            ph = np.asarray(self.parity.parity_homes).reshape(
                members.shape[0], -1)
            ok = self.view.alive[ph].all(axis=1) & homes_ok
            par_frac = float(np.mean(ok)) if ok.size else 1.0
        return {"replica_alive_frac": rep_frac,
                "parity_groups_ok_frac": par_frac,
                "full": bool(rep_frac >= 1.0 and par_frac >= 1.0)}

    def redundancy_nbytes(self, store: Optional[Any] = None) -> dict[str, int]:
        """Real memory/disk footprint of the redundancy machinery: replica
        and parity payloads, the parity codec's staging buffers (packed
        frames + member gather on the seed path, compact per-leaf
        contributions on the fused path — previously unaccounted), and,
        when a persistent ``store`` is given, its on-disk shard bytes."""
        staging = 0
        if self.parity is not None:
            # the fused sweep's compact staging applies only when every
            # maintain actually takes the fused branch — mismatched tier
            # intervals route off-interval steps through the seed encode,
            # whose frames+gather footprint is the real peak. In
            # live-arena mode that fallback no longer exists: every
            # maintain (on- or off-interval) is the resident arena sweep,
            # so neither the seed frames+gather staging nor the pack's
            # snapshot write ever materializes — only the sweep's compact
            # outputs count, whatever the tier intervals are.
            all_fused = ((self.cfg.fused or self.arena_layout is not None)
                         and self.cfg.replicate
                         and self.cfg.replicate_interval
                         == self.cfg.parity_interval)
            if self.live_arena_mode:
                staging = self._traffic_model()["staging_arena"]
            elif not all_fused:
                staging = self.parity.staging_nbytes()
            elif self.arena_layout is not None:
                staging = self._traffic_model()["staging_arena"]
            else:
                staging = self._traffic_model()["staging_fused"]
        out = {
            "replica": self.replicas.nbytes() if self.replicas else 0,
            "parity": self.parity.nbytes() if self.parity else 0,
            "parity_staging": staging,
        }
        if store is not None and hasattr(store, "disk_nbytes"):
            disk = store.disk_nbytes()
            # "live" is the indexed subset of "shard" — not additive
            out["store_disk"] = int(disk["shard"] + disk["parity"])
            out["store_disk_live"] = int(disk["live"] + disk["parity"])
        return out

    # -- failure injection ---------------------------------------------------

    def sample_domain_failure(self, rng: np.random.Generator,
                              kind: str = "host",
                              ) -> tuple[np.ndarray, np.ndarray]:
        """Correlated whole-domain loss → (lost block mask, failed devices)."""
        failed = self.domains.sample_domain_failure(rng, kind)
        failed = failed[self.view.alive[failed]]
        lost = np.isin(self.view.homes, failed)
        return lost, failed

    def domain_failure(self, kind: str, index: int,
                       ) -> tuple[np.ndarray, np.ndarray]:
        """Loss of one *specific* domain under the current placement
        (trace-driven injection). Devices already dead in the view are not
        failed again — an event on a fully-dead domain is a no-op."""
        failed = self.domains.devices_in(kind, index)
        failed = failed[self.view.alive[failed]]
        lost = np.isin(self.view.homes, failed)
        return lost, failed

    # -- recovery ------------------------------------------------------------

    def on_failure(self, params: PyTree, ckpt_values: PyTree,
                   lost_mask, failed_devices=None,
                   step: Optional[int] = None,
                   disk_values: Optional[PyTree] = None,
                   disk_reader=None,
                   persist_failure: Optional[bool] = None,
                   ) -> tuple[PyTree, dict]:
        """Tier-planned recovery. ``failed_devices=None`` models the paper's
        uniform block loss (no device actually died — every redundancy tier
        survives). ``step=None`` assumes the failure hit at the last
        maintained step, i.e. replicas/parity are fresh.

        ``persist_failure`` controls whether the failed devices stay dead in
        the cluster view after recovery (they do in a trace-driven soak,
        where the view tracks real cluster state; one-shot paper-style
        experiments leave it False so each event is independent). Defaults
        to ``cfg.elastic``. With ``elastic=True`` the placement engine then
        re-homes the lost blocks across the survivors, re-seeds replicas
        anti-affinely in the degraded topology, and re-stripes parity — the
        *next* failure still finds live redundancy tiers.
        """
        # consume point: a half-swept async epoch must never serve a
        # recovery — settle the in-flight sweep first, then every tier
        # holds exactly the last *published* epoch
        self._settle_pending()
        if failed_devices is None:
            failed_devices = np.empty((0,), np.int32)
        failed = np.asarray(failed_devices, np.int32).ravel()
        if step is None:
            step = self.last_maintained_step
        step = int(step)
        recovered_epoch, staleness = step, 0
        if self.cfg.async_maintain and 0 <= self.published_epoch < step:
            # async mode decouples the sweep from the step that produced
            # the params: the live tiers hold the published epoch, one or
            # more steps behind the failure. Plan against that epoch —
            # a slightly stale replica is a bounded perturbation (Thm
            # 4.1 regime, priced explicitly by the ledger via the
            # staleness fields below), far cheaper than falling all the
            # way back to the checkpoint tier.
            recovered_epoch = int(self.published_epoch)
            staleness = step - recovered_epoch
        persist = self.cfg.elastic if persist_failure is None else \
            bool(persist_failure)
        if persist and failed.size:
            self.view.mark_failed(failed)
        plan = self.planner.plan(lost_mask, failed, recovered_epoch)
        recovered, stats = self.planner.recover(params, ckpt_values, plan,
                                                disk_values=disk_values,
                                                disk_reader=disk_reader)
        self.stats["recoveries"] += 1
        stats["failed_devices"] = int(failed.size)
        stats["recovered_epoch"] = recovered_epoch
        stats["staleness"] = staleness
        # never-silent: every parity group whose losses exceeded the
        # code's surviving strength says why the cheap tier declined
        stats["tier_fallbacks"] = plan.fallbacks
        for fb in plan.fallbacks:
            self.stats["tier_fallbacks"] += 1
            if self.recorder.enabled:
                self.recorder.event("tier_fallback", step=step, **fb)
        if self.cfg.elastic and failed.size:
            stats["placement"] = self._replan(step, recovered)
        return recovered, stats

    def _replan(self, step: int, params: PyTree) -> dict:
        """Post-failure elastic re-plan: re-home displaced blocks, re-seed
        replicas, re-stripe parity — all against the recovered params, so
        every tier is fresh on the new placement."""
        displaced = rehome_blocks(self.view)
        if self.arena_layout is not None:
            # arena mode: re-seed + re-stripe, then one arena sweep
            # refreshes both tiers against the new striping (the program
            # rebuild rides the view-version check)
            self.replicas.reseed()
            self.parity.restripe()
            self._arena_maintain(step, params, None)
        else:
            if self.replicas is not None:
                self.replicas.reseed()
                self.replicas.refresh(step, params)
                self.stats["replica_refreshes"] += 1
            if self.parity is not None:
                self.parity.restripe()
                self.parity.encode(step, params)
                self.stats["parity_encodes"] += 1
            self.published_epoch = step
        self.planner.rehome()
        self.last_maintained_step = step
        self.stats["rehomes"] += 1
        out = {"rehomed_blocks": int(displaced.size),
               "alive_devices": self.view.n_alive_devices,
               "alive_hosts": self.view.n_alive_hosts,
               "parity_groups": (self.parity.n_groups
                                 if self.parity is not None else 0)}
        if self.recorder.enabled:
            self.recorder.event("rehome", step=step, **out)
        return out

    # -- integrity (silent errors) -------------------------------------------

    def scrub(self, step: Optional[int] = None) -> dict:
        """CodeNet-style integrity pass over the coded redundancy state.

        Recomputes the RS parity rows from the replica arena and XORs
        them against the stored rows: nonzero syndromes mean the coded
        snapshot was silently corrupted since encode — a soft error the
        liveness machinery cannot see. Localizable corruptions (single
        corrupted member or parity row, needs m ≥ 2) are corrected in
        place by XOR-ing the error pattern back out; the rest are
        detected and reported. Requires the RS codec (``rs_parity ≥ 1``
        for detection, ≥ 2 for localization) and an arena-mode replica
        whose snapshot matches the encode step — otherwise the pass
        reports ``checked=False`` and touches nothing.
        """
        out = {"checked": False, "detected": 0, "corrected": 0,
               "reports": []}
        codec = self.parity
        if codec is None or not getattr(codec, "supports_integrity",
                                        False):
            return out
        self._settle_pending()
        if codec.parity is None or self.replicas is None \
                or self.replicas.arena is None \
                or self.replicas.refreshed_step != codec.encoded_step:
            return out
        self.stats["scrubs"] += 1
        out["checked"] = True
        synd = codec.syndromes_from_arena(self.replicas.arena,
                                          self.replicas.arena_layout)
        for rep in codec.localize_corruption(synd):
            out["detected"] += 1
            self.stats["silent_errors_detected"] += 1
            corrected = False
            if rep["localized"]:
                new_arena = codec.correct_in_arena(self.replicas.arena,
                                                   rep)
                if rep["kind"] == "member":
                    self.replicas.ingest_arena(codec.encoded_step,
                                               new_arena,
                                               self.replicas.arena_layout)
                corrected = True
                out["corrected"] += 1
                self.stats["silent_errors_corrected"] += 1
            ev = dict(step=step, group=rep["group"], kind=rep["kind"],
                      member=rep["member"], block=rep["block"],
                      row=rep["row"], localized=rep["localized"],
                      corrected=corrected)
            out["reports"].append(ev)
            if self.recorder.enabled:
                # ``kind`` is the event bus's own discriminator — the
                # corruption's member/parity classification rides as
                # ``error_kind``
                fields = {("error_kind" if k == "kind" else k): v
                          for k, v in ev.items()}
                self.recorder.event("silent_error_detected", **fields)
        return out

    def inject_arena_bit_flip(self, block: Optional[int] = None,
                              word: Optional[int] = None,
                              bit: Optional[int] = None,
                              rng: Optional[np.random.Generator] = None,
                              ) -> dict:
        """Fault injection for soaks/tests: flip one bit of one block's
        payload in the *replica arena* — a silent corruption no liveness
        check sees, caught (and with RS m ≥ 2, localized and corrected)
        only by :meth:`scrub`. Returns where the flip landed."""
        assert self.replicas is not None \
            and self.replicas.arena is not None, \
            "bit-flip injection needs an arena-mode replica"
        assert self.parity is not None
        self._settle_pending()
        gather = np.asarray(self.parity._ensure_arena_gather(
            self.replicas.arena_layout))
        if rng is None:
            rng = np.random.default_rng(0)
        if block is None:
            block = int(rng.integers(self.partition.total_blocks))
        cols = np.nonzero(gather[block] >= 0)[0]
        col = int(cols[int(word) % cols.size]) if word is not None \
            else int(cols[rng.integers(cols.size)])
        b = int(bit) if bit is not None else int(rng.integers(32))
        idx = int(gather[block, col])
        arena = self.replicas.arena
        old = np.asarray(arena[idx], np.float32).view(np.int32).item()
        new = np.array([(old & 0xFFFFFFFF) ^ (1 << b)], np.uint32)
        arena = arena.at[idx].set(jnp.asarray(new.view(np.float32)[0]))
        self.replicas.ingest_arena(self.replicas.refreshed_step, arena,
                                   self.replicas.arena_layout)
        return {"block": int(block), "word": col, "bit": b,
                "arena_index": idx}

    # -- healing -------------------------------------------------------------

    def heal_domain(self, kind: str, index: int,
                    params: Optional[PyTree] = None,
                    step: Optional[int] = None) -> dict:
        """Re-admit a healed domain's devices to the view. With
        ``elastic=True`` the placement engine rebalances primary load onto
        the restored capacity and re-seeds/re-stripes the redundancy tiers
        (against ``params`` when given, so they are immediately fresh;
        otherwise the next ``maintain`` refreshes them)."""
        # consume point: an elastic heal re-stripes the tiers — never
        # against a half-swept async epoch
        self._settle_pending()
        healed = self.view.heal(self.domains.devices_in(kind, index))
        info = {"healed_devices": int(healed.size)}
        if healed.size == 0:
            return info
        self.stats["heals"] += 1
        if not self.cfg.elastic:
            if self.recorder.enabled:
                self.recorder.event("heal", kind=kind, index=int(index),
                                    step=step, **info)
            return info
        at = int(step) if step is not None else self.last_maintained_step
        moved = rebalance_homes(self.view)
        if self.arena_layout is not None and params is not None:
            self.replicas.reseed()
            self.parity.restripe()
            self._arena_maintain(at, params, None)
        else:
            if self.replicas is not None:
                self.replicas.reseed()
                if params is not None:
                    self.replicas.refresh(at, params)
            if self.parity is not None:
                self.parity.restripe()
                if params is not None:
                    self.parity.encode(at, params)
            if params is not None:
                self.published_epoch = at
        self.planner.rehome()
        info["rebalanced_blocks"] = int(moved.size)
        info["alive_hosts"] = self.view.n_alive_hosts
        if self.recorder.enabled:
            self.recorder.event("heal", domain_kind=kind,
                                domain_index=int(index), step=step, **info)
        return info

    # -- elastic mesh resize -------------------------------------------------

    def resize_mesh(self, mesh, logical_ids, step: Optional[int] = None,
                    params: Optional[Any] = None):
        """Re-bind a meshed fabric to a shrunk (or re-grown) device mesh.

        ``logical_ids[i]`` is the fabric logical device at mesh position
        ``i`` — on a shrink these are the survivors, on a re-grow the full
        original id range. Rebuilds the arena layout at the new shard
        count (the data region is identical, only the zero shard-pad tail
        changes — see :func:`~repro.core.arena.relayout_arena`), re-homes
        every block to its new owning shard, re-seeds replicas and
        re-stripes parity in the surviving topology, and invalidates every
        cached program/slot laid out for the old shard count.

        ``params`` — the live arena *already relayouted to the new layout
        and placed on the new mesh* — triggers an immediate maintain so
        every tier is fresh on the new placement; without it the tiers go
        stale until the caller's next ``maintain`` (the old-layout replica
        stays decodable meanwhile: the data region is layout-invariant).

        Returns the new :class:`~repro.core.arena.ArenaLayout`; the caller
        (the training loop) relayouts its own state against it and re-jits
        the step.
        """
        assert self.arena_layout is not None, \
            "resize_mesh is a sharded-arena operation (meshed fabric only)"
        self._settle_pending()
        from repro.core.arena import arena_block_homes, build_arena_layout
        logical_ids = np.asarray(logical_ids, np.int32)
        new_layout = build_arena_layout(
            self.partition, shards=int(np.asarray(mesh.devices).size))
        self.arena_layout = new_layout
        self._bind_mesh(mesh, logical_ids)
        # span-derived homes over the surviving shards
        self.view.homes[:] = logical_ids[arena_block_homes(new_layout)]
        self.view.version += 1
        # every cached artifact below is laid out for the old shard count
        self._arena_fn = None
        self._pack_fn = None
        self._traffic = None
        self._slots = [None, None]
        if self.replicas is not None:
            self.replicas.reseed()
            self.replicas.main_sharding = self._arena_sharding
        if self.parity is not None:
            self.parity.restripe()
        self.planner.rehome()
        at = int(step) if step is not None else self.last_maintained_step
        if params is not None:
            self._arena_maintain(at, params, None)
            self.last_maintained_step = at
        self.stats["mesh_resizes"] += 1
        self.stats["arena_padding_ratio"] = float(new_layout.padding_ratio)
        if self.recorder.enabled:
            self.recorder.event(
                "mesh_resize", step=at, shards=new_layout.shards,
                alive_devices=self.view.n_alive_devices,
                alive_hosts=self.view.n_alive_hosts)
        return new_layout
