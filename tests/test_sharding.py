"""Partition-spec assignment + divisibility fitting + failure domains."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.core.blocks import partition_pytree
from repro.models import get_model
from repro.sharding.partition import (DistContext, _fit_spec,
                                      blocks_on_failed_devices,
                                      make_dist_ctx, param_partition_specs,
                                      single_device_ctx,
                                      state_partition_specs)


@pytest.fixture(scope="module")
def mesh():
    # 1-device mesh (1,1) — spec logic is shape-only, works on CPU
    from repro.launch.mesh import make_mesh_compat
    return make_mesh_compat((1, 1), ("data", "model"))


@pytest.fixture(scope="module")
def fake16():
    """DistContext that *claims* a 16x16 mesh for pure spec logic tests."""
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    return DistContext(mesh=FakeMesh(), dp=("data",), tp="model")


def test_fit_spec_drops_nondivisible(fake16):
    # 2 kv heads cannot shard over model=16
    spec = _fit_spec((28, 1536, 2, 128), P(None, "data", "model", None), fake16)
    assert spec == P(None, "data", None, None)
    # 96 heads can
    spec = _fit_spec((64, 12288, 96, 128), P(None, "data", "model", None), fake16)
    assert spec == P(None, "data", "model", None)
    # odd vocab cannot shard
    spec = _fit_spec((51865, 1024), P("model", "data"), fake16)
    assert spec == P(None, "data")


def test_param_specs_cover_all_leaves(fake16):
    cfg = get_config("qwen3-moe-235b-a22b")
    ops = get_model(cfg)
    p_shape = jax.eval_shape(lambda: ops.init_params(jax.random.PRNGKey(0), cfg))
    specs = param_partition_specs(p_shape, fake16)
    leaves_p = jax.tree_util.tree_leaves(p_shape)
    leaves_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda s: isinstance(s, P))
    assert len(leaves_p) == len(leaves_s)
    # expert weights must be expert-parallel over model
    assert specs["layers"]["moe"]["w_gate_experts"][1] == "model"
    # embeddings vocab-parallel
    assert specs["embed"][0] == "model"


def test_state_specs_decode(fake16):
    cfg = get_config("yi-9b")
    ops = get_model(cfg)
    ctx = fake16
    state_shape = jax.eval_shape(lambda: ops.init_cache(cfg, 128, 4096,
                                                        single_device_ctx()))
    specs = state_partition_specs(state_shape, ctx)
    assert specs["k"][1] == "data"     # batch over data
    # kpos replicated (trailing Nones are semantically P())
    assert all(e is None for e in specs["kpos"])


def test_dp_spec_not_batch_shardable(fake16):
    import dataclasses
    ctx = dataclasses.replace(fake16, batch_shardable=False)
    assert ctx.dp_spec is None
    assert ctx.raw_dp_spec == "data"


def test_topology_aware_failure_mask(fake16):
    params = {"w": jnp.zeros((1600, 4), jnp.float32)}
    part = partition_pytree(params, 100)
    mask = blocks_on_failed_devices(part, params, fake16, 0.25,
                                    np.random.default_rng(0))
    # 4/16 data slices fail -> roughly a quarter of the blocks
    assert 0.1 <= mask.mean() <= 0.45


def test_real_1x1_mesh_constraint_roundtrip(mesh):
    ctx = make_dist_ctx(mesh)
    x = jnp.ones((4, 8))
    y = ctx.shard(x, "dp", None)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
