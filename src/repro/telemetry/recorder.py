"""Metrics registry + event bus for fault-tolerance runs.

One :class:`Recorder` per run unifies the three telemetry streams the
fabric/controller/loops previously kept as scattered ad-hoc state:

- **scopes** — the components' ``stats`` dicts (``FTController.stats``,
  ``CheckpointFabric.stats``, …) registered by name with the recorder, so
  one ``metrics()`` call snapshots every counter in the run under a shared
  schema. The dicts stay plain dicts: registration is by reference, the
  hot-path mutation cost is unchanged, and components keep working when no
  recorder is attached.
- **typed metrics** — :class:`Counter` / :class:`Gauge` /
  :class:`Histogram` for quantities that want more than a scalar (the
  maintenance-overhead distribution feeds ``overhead_summary``'s
  p50/p95/max from a histogram, not a re-derived mean).
- **structured events** — ``event(kind, **fields)`` appends one record to
  the in-memory log AND one line to an append-only JSONL file
  (``events.jsonl`` under ``out_dir``). Kinds and their fields are listed
  in :data:`EVENT_SCHEMA` (DESIGN.md "Observability" has the table).

The default everywhere is the :data:`NULL_RECORDER` singleton — every
method is a no-op returning shared singletons, so instrumented hot paths
cost one attribute check and no allocation.

A :class:`Recorder` also owns a :class:`~repro.telemetry.spans.SpanTracer`
(``span("maintain")`` context manager, Chrome-trace export) and a
:class:`~repro.telemetry.ledger.PerturbationLedger` fed by
``record_recovery`` — the Thm-3.2/4.1 iteration-cost bound of every
recovery event becomes a first-class observable of the run.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Optional

import numpy as np

# event kinds with their documented payload fields (informative — extra
# fields are allowed and preserved; the JSONL round-trip is schema-free).
# ``seq``/``ts``/``kind`` are stamped on every event by the recorder.
EVENT_SCHEMA: dict[str, tuple[str, ...]] = {
    "failure":  ("step", "lost_blocks", "failed_devices", "domain_kind",
                 "domain_index"),
    "recovery": ("step", "lost_blocks", "tier_counts", "tier_sq",
                 "applied_sq", "failed_devices"),
    "maintain": ("step", "mode", "bytes_moved", "replica", "parity"),
    "save":     ("step", "blocks", "bytes_moved", "seconds", "mode"),
    "mirror":   ("step", "bytes", "segments", "background"),
    "store_write_failed": ("step", "segment", "host", "path", "error"),
    "store_write_retried": ("step", "segment", "host", "path", "error",
                            "attempt", "delay_seconds"),
    "tier_fallback": ("step", "group", "lost_members", "unavailable",
                      "strength", "fresh"),
    "silent_error_detected": ("step", "group", "error_kind", "member",
                              "block", "row", "localized", "corrected"),
    "compact":  ("reclaimed", "rekeyed"),
    "rehome":   ("step", "rehomed_blocks", "alive_devices", "alive_hosts",
                 "parity_groups"),
    "heal":     ("step", "domain_kind", "domain_index", "healed_devices",
                 "rebalanced_blocks"),
}


def _jsonable(v):
    """Coerce numpy/jax scalars and arrays into JSON-serializable values."""
    if isinstance(v, dict):
        return {k: _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, np.ndarray):
        return v.tolist()
    if hasattr(v, "item") and getattr(v, "ndim", None) == 0:
        return v.item()
    return v


# -- typed metrics -----------------------------------------------------------


class Counter:
    """Monotonic counter."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Gauge:
    """Last-value-wins scalar."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Raw-sample histogram (run lengths here are small enough that
    keeping the samples beats committing to bucket edges up front)."""

    __slots__ = ("samples",)

    def __init__(self) -> None:
        self.samples: list[float] = []

    def observe(self, v: float) -> None:
        self.samples.append(float(v))

    def percentile(self, q: float) -> float:
        if not self.samples:
            return 0.0
        return float(np.percentile(np.asarray(self.samples), q))

    def summary(self) -> dict[str, float]:
        if not self.samples:
            return {"count": 0, "mean": 0.0, "p50": 0.0, "p95": 0.0,
                    "max": 0.0}
        a = np.asarray(self.samples)
        return {"count": int(a.size), "mean": float(a.mean()),
                "p50": float(np.percentile(a, 50)),
                "p95": float(np.percentile(a, 95)),
                "max": float(a.max())}


class _NullMetric:
    """Shared do-nothing stand-in for every typed metric."""

    __slots__ = ()
    value = 0.0
    samples: list[float] = []

    def inc(self, v: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def percentile(self, q: float) -> float:
        return 0.0

    def summary(self) -> dict[str, float]:
        return {"count": 0, "mean": 0.0, "p50": 0.0, "p95": 0.0, "max": 0.0}


_NULL_METRIC = _NullMetric()


class _NullSpan:
    """Reusable no-op context manager (one shared instance per process)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


# -- recorders ---------------------------------------------------------------


class NullRecorder:
    """The default: every instrumented emit point is a no-op.

    Components are written against this interface; the real
    :class:`Recorder` subclasses it. ``enabled`` lets hot paths skip
    building event payloads entirely.
    """

    enabled = False
    ledger = None
    tracer = None
    out_dir: Optional[str] = None

    def scope(self, name: str, stats: Optional[dict] = None) -> dict:
        """Return (and, when enabled, register) a component stats dict."""
        return stats if stats is not None else {}

    def counter(self, name: str) -> Counter:
        return _NULL_METRIC  # type: ignore[return-value]

    def gauge(self, name: str) -> Gauge:
        return _NULL_METRIC  # type: ignore[return-value]

    def histogram(self, name: str) -> Histogram:
        return _NULL_METRIC  # type: ignore[return-value]

    def adopt_histogram(self, name: str, hist: Histogram) -> None:
        pass

    def event(self, kind: str, **fields: Any) -> None:
        pass

    def span(self, name: str, fence: Any = None, **attrs: Any):
        return _NULL_SPAN

    def record_recovery(self, step: Optional[int], lost_blocks: int,
                        tier_counts: Optional[dict], applied_sq: float,
                        **extra: Any) -> None:
        pass

    def metrics(self) -> dict:
        return {}

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


NULL_RECORDER = NullRecorder()


class Recorder(NullRecorder):
    """The real thing: registry + JSONL event bus + tracer + ledger.

    ``out_dir`` (optional) is created on first use; events stream to
    ``events.jsonl`` as they happen (append-only — a crash loses at most
    the event being written), and :meth:`close` writes ``trace.json``
    (Chrome ``trace_event`` format, loadable in Perfetto) and
    ``metrics.json`` (the full registry snapshot + report).
    """

    enabled = True

    def __init__(self, out_dir: Optional[str] = None, *,
                 ledger: Optional[Any] = None,
                 clock=time.perf_counter) -> None:
        from repro.telemetry.ledger import PerturbationLedger
        from repro.telemetry.spans import SpanTracer
        self.out_dir = out_dir
        self._clock = clock
        self._t0 = clock()
        self.scopes: dict[str, dict] = {}
        self.counters: dict[str, Counter] = {}
        self.gauges: dict[str, Gauge] = {}
        self.histograms: dict[str, Histogram] = {}
        self.events: list[dict] = []
        self.tracer = SpanTracer(clock=clock)
        self.ledger = ledger if ledger is not None else PerturbationLedger()
        self._lock = threading.Lock()
        self._jsonl = None
        if out_dir is not None:
            os.makedirs(out_dir, exist_ok=True)
            self._jsonl = open(os.path.join(out_dir, "events.jsonl"), "a")

    # -- registry -----------------------------------------------------------

    def scope(self, name: str, stats: Optional[dict] = None) -> dict:
        """Register a component's stats dict by reference under ``name``
        (unique-suffixed on collision) and return it — the component keeps
        mutating its own plain dict; ``metrics()`` sees it live."""
        d = stats if stats is not None else {}
        key, n = name, 2
        while key in self.scopes and self.scopes[key] is not d:
            key, n = f"{name}#{n}", n + 1
        self.scopes[key] = d
        return d

    def counter(self, name: str) -> Counter:
        return self.counters.setdefault(name, Counter())

    def gauge(self, name: str) -> Gauge:
        return self.gauges.setdefault(name, Gauge())

    def histogram(self, name: str) -> Histogram:
        return self.histograms.setdefault(name, Histogram())

    def adopt_histogram(self, name: str, hist: Histogram) -> None:
        """Register a histogram a component already owns (e.g. the train
        loop's overhead distribution, which exists recorder or not)."""
        self.histograms[name] = hist

    # -- events -------------------------------------------------------------

    def event(self, kind: str, **fields: Any) -> None:
        rec = {"seq": 0, "ts": self._clock() - self._t0, "kind": kind}
        rec.update({k: _jsonable(v) for k, v in fields.items()})
        with self._lock:
            rec["seq"] = len(self.events)
            self.events.append(rec)
            if self._jsonl is not None:
                self._jsonl.write(json.dumps(rec) + "\n")
                self._jsonl.flush()

    # -- spans --------------------------------------------------------------

    def span(self, name: str, fence: Any = None, **attrs: Any):
        return self.tracer.span(name, fence=fence, **attrs)

    # -- ledger -------------------------------------------------------------

    def record_recovery(self, step: Optional[int], lost_blocks: int,
                        tier_counts: Optional[dict], applied_sq: float,
                        **extra: Any) -> None:
        """One recovery event: ledger entry (Thm-3.2/4.1 bound accounting)
        + a structured ``recovery`` event on the bus. Extra fields reach
        the ledger entry too (``LedgerEntry.extra``) — an async-mode
        recovery carries ``recovered_epoch``/``staleness`` so the entry
        records *which* epoch was actually restored, not just how far the
        restored values sat from the live ones."""
        self.ledger.record(step=step, lost_blocks=lost_blocks,
                           tier_counts=tier_counts, applied_sq=applied_sq,
                           **extra)
        self.event("recovery", step=step, lost_blocks=lost_blocks,
                   tier_counts=tier_counts, applied_sq=applied_sq, **extra)

    # -- snapshots ----------------------------------------------------------

    def metrics(self) -> dict:
        """Deep snapshot of every scope + typed metric (safe to mutate)."""
        return _jsonable({
            "scopes": {k: dict(v) for k, v in self.scopes.items()},
            "counters": {k: c.value for k, c in self.counters.items()},
            "gauges": {k: g.value for k, g in self.gauges.items()},
            "histograms": {k: h.summary()
                           for k, h in self.histograms.items()},
        })

    def flush(self) -> None:
        if self._jsonl is not None:
            self._jsonl.flush()

    def close(self) -> None:
        """Flush the JSONL stream and, with an ``out_dir``, write the
        Chrome trace + metrics/report snapshot artifacts."""
        self.flush()
        if self.out_dir is not None:
            self.tracer.write_chrome_trace(
                os.path.join(self.out_dir, "trace.json"))
            from repro.telemetry.report import run_report
            snap = {"metrics": self.metrics(), "report": run_report(self)}
            tmp = os.path.join(self.out_dir, "metrics.json.tmp")
            with open(tmp, "w") as f:
                json.dump(_jsonable(snap), f, indent=2)
            os.replace(tmp, os.path.join(self.out_dir, "metrics.json"))
        if self._jsonl is not None:
            self._jsonl.close()
            self._jsonl = None


def read_events_jsonl(path: str) -> list[dict]:
    """Load an ``events.jsonl`` back into event dicts (analysis helper —
    the round trip through this is covered by tests)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
