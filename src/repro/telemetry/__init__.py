"""Unified telemetry: metrics registry + event bus, span tracing, and the
perturbation-cost ledger.

Quick tour::

    from repro.telemetry import Recorder, run_report, format_report

    rec = Recorder(out_dir="telemetry_out")        # events.jsonl streams
    loop = TrainLoop(cfg, ctx, loop_cfg=TrainLoopConfig(
        policy=pol, fabric=FabricConfig(), mtbf={"host": 50.0},
        recorder=rec))
    state = loop.run(loop.init_state(), batches, 200)
    rec.ledger.set_rates(c, x0_err)                # price the faults
    print(format_report(run_report(rec)))
    rec.close()                                    # trace.json + metrics.json

The default everywhere is :data:`NULL_RECORDER` — all emit points are
no-ops and the hot path is unchanged. See DESIGN.md "Observability".
"""
from repro.telemetry.ledger import LedgerEntry, PerturbationLedger
from repro.telemetry.recorder import (EVENT_SCHEMA, NULL_RECORDER, Counter,
                                      Gauge, Histogram, NullRecorder,
                                      Recorder, read_events_jsonl)
from repro.telemetry.report import format_report, run_report
from repro.telemetry.spans import SpanRecord, SpanTracer

__all__ = ["Recorder", "NullRecorder", "NULL_RECORDER", "Counter", "Gauge",
           "Histogram", "EVENT_SCHEMA", "read_events_jsonl",
           "PerturbationLedger", "LedgerEntry", "SpanTracer", "SpanRecord",
           "run_report", "format_report"]
