"""Pallas TPU kernel: sliding-window (banded) flash attention.

The sub-quadratic attention used by the dense archs' long_500k variant.
Standard flash-attention tiling adapted to a causal band of width W:

- grid = (B·Hk, nq, nspan): for query chunk i only the kv chunks that can
  intersect the band [qpos − W, qpos] are visited — nspan =
  ⌈(W + QC)/KC⌉ + 1 blocks, *independent of sequence length*.
- online softmax state (m, l, acc) lives in VMEM scratch across the j
  sweep; the output block is written on the final j step.
- the kv block index is computed in the index_map (clamped so padding
  blocks resolve to block 0 and are masked out by position arithmetic
  inside the kernel).

GQA: queries are pre-grouped to (B·Hk, G, S, Dh); K/V are (B·Hk, S, Dh).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kv_start_block(i, window: int, q_chunk: int, kv_chunk: int, nk: int,
                    nspan: int):
    """First kv block visible to q chunk i (block units, clamped)."""
    lo = (i * q_chunk - window) // kv_chunk
    lo = jnp.maximum(lo, 0)
    return jnp.minimum(lo, jnp.maximum(nk - nspan, 0))


def _sw_attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                    window: int, q_chunk: int, kv_chunk: int, nk: int,
                    nspan: int, scale: float):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # absolute positions of this tile
    kb = _kv_start_block(i, window, q_chunk, kv_chunk, nk, nspan) + j
    qpos = i * q_chunk + jax.lax.broadcasted_iota(
        jnp.int32, (q_chunk, kv_chunk), 0)
    kpos = kb * kv_chunk + jax.lax.broadcasted_iota(
        jnp.int32, (q_chunk, kv_chunk), 1)
    mask = (kpos <= qpos) & (qpos - kpos < window)

    q = q_ref[...].reshape(-1, q_ref.shape[-1]).astype(jnp.float32)  # (G*QC, Dh)
    k = k_ref[...].reshape(kv_chunk, -1).astype(jnp.float32)         # (KC, Dh)
    v = v_ref[...].reshape(kv_chunk, -1).astype(jnp.float32)
    G = q.shape[0] // q_chunk

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (G*QC, KC)
    big_mask = jnp.tile(mask, (G, 1))
    s = jnp.where(big_mask, s, NEG_INF)

    m_prev = m_ref[...]                                # (G*QC, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    p = jnp.where(big_mask, p, 0.0)
    r = jnp.exp(m_prev - m_new)
    l_new = l_ref[...] * r + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * r + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(j == nspan - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l).reshape(o_ref.shape)


@functools.partial(jax.jit,
                   static_argnames=("window", "q_chunk", "kv_chunk",
                                    "interpret"))
def sw_attention_pallas(q, k, v, *, window: int, q_chunk: int = 128,
                        kv_chunk: int = 128,
                        interpret: bool = False) -> jnp.ndarray:
    """Banded causal attention.

    q: (BH, G, S, Dh); k, v: (BH, S, Dh). Returns (BH, G, S, Dh) f32.
    """
    BH, G, S, Dh = q.shape
    scale = 1.0 / math.sqrt(Dh)
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, S)
    nq = -(-S // q_chunk)
    nk = -(-S // kv_chunk)
    nspan = min(nk, -(-(window + q_chunk) // kv_chunk) + 1)
    pad = nq * q_chunk - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))

    kernel = functools.partial(
        _sw_attn_kernel, window=window, q_chunk=q_chunk, kv_chunk=kv_chunk,
        nk=nk, nspan=nspan, scale=scale)

    def kv_index(b, i, j):
        return (b, _kv_start_block(i, window, q_chunk, kv_chunk, nk, nspan) + j, 0)

    out = pl.pallas_call(
        kernel,
        grid=(BH, nq, nspan),
        in_specs=[
            pl.BlockSpec((1, G, q_chunk, Dh), lambda b, i, j: (b, 0, i, 0)),
            pl.BlockSpec((1, kv_chunk, Dh), kv_index),
            pl.BlockSpec((1, kv_chunk, Dh), kv_index),
        ],
        out_specs=pl.BlockSpec((1, G, q_chunk, Dh), lambda b, i, j: (b, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((G * q_chunk, 1), jnp.float32),
            pltpu.VMEM((G * q_chunk, 1), jnp.float32),
            pltpu.VMEM((G * q_chunk, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :S]
