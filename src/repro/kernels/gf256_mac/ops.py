"""Dispatch wrappers: GF(256) multiply-accumulate, RS encode/decode folds.

Same backend-selection contract as ``parity_xor.ops``: compiled Pallas on
TPU, the jnp log/antilog oracle elsewhere, interpret-mode Pallas only
when forced (kernel-semantics validation). Both paths are bit-exact on
the packed int32 frame words.

The RS tier composes everything from one primitive, ``gf256_mac`` — the
encode is m MAC folds (one per parity row), the erasure decode is ≤ m
MAC folds over [member frames, parity frames] with host-solved weights,
and the integrity syndromes are the encode XOR the stored parity.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.gf256_mac.kernel import gf256_mac_pallas
from repro.kernels.gf256_mac.ref import gf256_mac_ref


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


def gf256_mac(frames: jnp.ndarray, base: jnp.ndarray, coeff: jnp.ndarray,
              use_pallas: bool | None = None,
              interpret: bool | None = None) -> jnp.ndarray:
    """``out[j] = base[j] ^ XOR_i gf_mul(coeff[j, i], frames[j, i])``.

    frames: (n_groups, g, E) int32; base: (n_groups, E) int32;
    coeff: (n_groups, g) GF(256) bytes — 0 drops a member, 1 is XOR.
    ``use_pallas=None`` is auto: compiled kernel on TPU, oracle elsewhere.
    """
    if use_pallas is None:
        use_pallas = _is_tpu()
    if not use_pallas:
        return gf256_mac_ref(frames, base, coeff)
    if interpret is None:
        interpret = not _is_tpu()
    return gf256_mac_pallas(frames, base, coeff, interpret=interpret)


def rs_encode(frames: jnp.ndarray, coeff_rows: jnp.ndarray,
              use_pallas: bool | None = None,
              interpret: bool | None = None) -> jnp.ndarray:
    """All parity rows of every group: (n_groups, m, E) int32.

    frames: (n_groups, g, E) int32 grouped member frames;
    coeff_rows: (m, n_groups, g) per-row coefficient bytes with padding
    members already zeroed (the valid-mask generalization). m is tiny
    (≤ ~4), so one MAC dispatch per row.
    """
    base = jnp.zeros(frames.shape[::2], jnp.int32)
    rows = [gf256_mac(frames, base, coeff_rows[r], use_pallas, interpret)
            for r in range(coeff_rows.shape[0])]
    return jnp.stack(rows, axis=1)


def rs_decode(frames_ext: jnp.ndarray, weights: jnp.ndarray,
              use_pallas: bool | None = None,
              interpret: bool | None = None) -> jnp.ndarray:
    """One erased ordinal across every group: (n_groups, E) int32.

    frames_ext: (n_groups, g + m, E) int32 — member frames concatenated
    with the group's parity rows; weights: (n_groups, g + m) host-solved
    decode coefficients (all-zero rows yield zeros for groups with fewer
    erasures — callers scatter only real ordinals).
    """
    base = jnp.zeros(frames_ext.shape[::2], jnp.int32)
    return gf256_mac(frames_ext, base, weights, use_pallas, interpret)
