"""Elastic placement engine: cluster view, re-homing, degraded-mode soak.

Covers the elastic invariants the placement layer must hold after every
re-plan:
- no block is homed on a dead device,
- replicas stay anti-affine (replica host ≠ primary host) while ≥2 hosts
  survive,
- every parity group keeps ≥ 2 members with live homes,
and the headline behavior: after a host loss with ``elastic=True``, a
*subsequent* failure of a different host still recovers every lost block
from PEER_REPLICA or PARITY — never RUNNING_CKPT/DISK — while the
recover-in-place fabric falls through on the degraded topology.
"""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint_io import ShardedCheckpointStore
from repro.core.blocks import partition_pytree, tree_sq_norm
from repro.core.checkpoint import init_running_checkpoint
from repro.core.policy import CheckpointPolicy, RecoveryMode, SelectionStrategy
from repro.fabric import (CheckpointFabric, ClusterView, FabricConfig,
                          FailureDomainMap, FailureEvent, ParityCodec)
from repro.fabric.parity import pack_frames
from repro.fabric.placement import (anti_affine_replica_homes,
                                    parity_group_homes, rebalance_homes,
                                    rehome_blocks, stripe_parity_groups)
from repro.sharding.partition import block_device_homes

RNG = np.random.default_rng(23)


def _params(rows=256, width=6):
    return {"w": jnp.asarray(RNG.normal(size=(rows, width)), jnp.float32),
            "b": jnp.asarray(RNG.normal(size=(8,)), jnp.float32)}


def _dm():
    return FailureDomainMap(n_devices=8, devices_per_host=2, hosts_per_rack=2)


def _view(part):
    dm = _dm()
    return ClusterView(dm, block_device_homes(part, dm.n_devices))


def _fabric(part, **kw):
    kw.setdefault("elastic", True)
    cfg = FabricConfig(n_devices=8, devices_per_host=2, hosts_per_rack=2,
                       use_pallas=False, **kw)
    return CheckpointFabric(part, cfg)


def _noisy(params, seed=0):
    rng = np.random.default_rng(seed)
    return jax.tree_util.tree_map(
        lambda x: x + jnp.asarray(rng.normal(size=x.shape), jnp.float32),
        params)


def _assert_elastic_invariants(fab):
    """The three placement invariants every elastic re-plan must restore."""
    view = fab.view
    assert view.alive[view.homes].all(), "block homed on a dead device"
    if fab.replicas is not None:
        assert view.alive[fab.replicas.replica_homes].all()
        if view.n_alive_hosts >= 2:
            assert np.all(
                np.asarray(view.host_of(fab.replicas.replica_homes))
                != np.asarray(view.host_of(view.homes))), \
                "replica shares its primary's host"
    if fab.parity is not None:
        for j, row in enumerate(fab.parity.members):
            ids = row[row >= 0]
            assert ids.size >= 2, f"parity group {j} has < 2 members"
            assert view.alive[view.homes[ids]].all(), \
                f"parity group {j} has a dead member home"
        assert view.alive[fab.parity.parity_homes].all()


# ---------------------------------------------------------------------------
# ClusterView + placement primitives
# ---------------------------------------------------------------------------

def test_cluster_view_mutation_and_healing():
    part = partition_pytree(_params(), 16)
    view = _view(part)
    assert view.n_alive_devices == 8 and view.n_alive_hosts == 4
    newly = view.mark_failed([0, 1])
    assert newly.tolist() == [0, 1] and view.version == 1
    assert view.mark_failed([1]).size == 0        # already dead: no-op
    assert view.n_alive_hosts == 3
    assert view.displaced_blocks().size > 0
    healed = view.heal([0, 1, 2])                 # 2 was never dead
    assert healed.tolist() == [0, 1]
    assert view.n_alive_devices == 8


def test_rehome_moves_displaced_blocks_balanced():
    part = partition_pytree(_params(), 16)
    view = _view(part)
    view.mark_failed(view.domains.devices_in("host", 0))
    displaced = rehome_blocks(view)
    assert displaced.size > 0
    assert view.alive[view.homes].all()
    load = view.load()[view.alive_devices()]
    assert load.max() - load.min() <= 1, "re-homing left load unbalanced"
    # idempotent: nothing left to move
    assert rehome_blocks(view).size == 0


def test_replica_homes_anti_affine_in_degraded_view():
    part = partition_pytree(_params(), 16)
    view = _view(part)
    view.mark_failed(view.domains.devices_in("host", 0))
    rehome_blocks(view)
    rep = anti_affine_replica_homes(view)
    assert view.alive[rep].all()
    assert np.all(np.asarray(view.host_of(rep))
                  != np.asarray(view.host_of(view.homes)))


def test_parity_restripe_in_degraded_view():
    part = partition_pytree(_params(), 16)
    view = _view(part)
    view.mark_failed(view.domains.devices_in("host", 0))
    rehome_blocks(view)
    members = stripe_parity_groups(view, 2)   # 3 alive hosts → width ≤ 2
    hosts = np.asarray(view.host_of(view.homes))
    for row in members:
        ids = row[row >= 0]
        assert ids.size >= 2
        assert len(set(hosts[ids].tolist())) == ids.size
    homes = parity_group_homes(members, view)
    assert view.alive[homes].all()
    n_alive_hosts = view.n_alive_hosts
    for j, row in enumerate(members):
        ids = row[row >= 0]
        m_hosts = set(hosts[ids].tolist())
        if len(m_hosts) < n_alive_hosts:
            # a member-free host exists → parity must sit on one
            assert int(view.host_of(homes[j])) not in m_hosts
        else:
            # group as wide as the topology (the folded tail group):
            # fall back to a device holding no member
            assert int(homes[j]) not in set(view.homes[ids].tolist())


def test_rebalance_after_heal_levels_load():
    part = partition_pytree(_params(), 16)
    view = _view(part)
    view.mark_failed(view.domains.devices_in("host", 0))
    rehome_blocks(view)
    view.heal(view.domains.devices_in("host", 0))
    moved = rebalance_homes(view)
    assert moved.size > 0, "healed devices attracted no load"
    load = view.load()[view.alive_devices()]
    assert load.max() - load.min() <= 1


# ---------------------------------------------------------------------------
# FabricConfig validation + ragged parity groups
# ---------------------------------------------------------------------------

def test_fabric_config_rejects_degenerate_parity_group():
    with pytest.raises(ValueError):
        FabricConfig(parity_group=1)
    with pytest.raises(ValueError):
        FabricConfig(parity_group=0)


def test_ragged_last_parity_group_folds_and_recovers():
    # 17 blocks (16 of w + 1 of b), group_size 4 → 17 % 4 == 1: the lone
    # tail member must fold into the previous group, not form a 1-group
    params = _params()
    part = partition_pytree(params, 16)
    assert part.total_blocks % 4 == 1
    dm = FailureDomainMap(n_devices=8, devices_per_host=1)  # no width clamp < 4
    view = ClusterView(dm, block_device_homes(part, 8))
    codec = ParityCodec(part, view, group_size=4, use_pallas=False)
    sizes = [(row >= 0).sum() for row in codec.members]
    assert min(sizes) >= 2
    assert sum(sizes) == part.total_blocks
    assert (codec.group_of >= 0).all()
    # single erasure inside the widened tail group reconstructs bit-exactly
    codec.encode(3, params)
    tail = codec.members[-1]
    victim = int(tail[tail >= 0][-1])
    lost = np.zeros((part.total_blocks,), bool)
    lost[victim] = True
    rec_mask = codec.reconstructable(lost, ~lost, np.empty((0,), np.int32),
                                     step=3)
    assert rec_mask[victim]
    frames = codec.reconstruct(params, rec_mask, ~lost)
    want = pack_frames(params, part, codec.layout)
    np.testing.assert_array_equal(np.asarray(frames)[victim],
                                  np.asarray(want)[victim])


# ---------------------------------------------------------------------------
# Elastic fabric: invariants + the second-failure acceptance criterion
# ---------------------------------------------------------------------------

def test_elastic_failure_replans_and_keeps_invariants():
    params = _params()
    part = partition_pytree(params, 16)
    fab = _fabric(part)
    live = _noisy(params)
    ckpt = init_running_checkpoint(params, part)
    fab.maintain(1, live)
    lost, failed = fab.domain_failure("host", 0)
    rec, info = fab.on_failure(live, ckpt.values, lost, failed, step=1)
    assert info["placement"]["rehomed_blocks"] == int(lost.sum()) > 0
    assert fab.view.n_alive_hosts == 3
    _assert_elastic_invariants(fab)
    assert float(tree_sq_norm(rec, live)) < 1e-12


def test_elastic_subsequent_failures_never_hit_ckpt_tiers():
    """Acceptance: after a host loss with elastic=True, every later loss of
    a different host recovers from PEER_REPLICA or PARITY only."""
    params = _params()
    part = partition_pytree(params, 16)
    fab = _fabric(part)
    ckpt = init_running_checkpoint(params, part)
    live = _noisy(params)
    for step, host in ((1, 0), (2, 1), (3, 2)):
        fab.maintain(step, live, force=True)
        lost, failed = fab.domain_failure("host", host)
        assert lost.any()
        rec, info = fab.on_failure(live, ckpt.values, lost, failed,
                                   step=step)
        tc = info["tier_counts"]
        assert tc["RUNNING_CKPT"] == 0 and tc["DISK"] == 0, \
            f"event {step} (host {host}) fell through: {tc}"
        assert tc["PEER_REPLICA"] + tc["PARITY"] == int(lost.sum())
        assert float(tree_sq_norm(rec, live)) < 1e-12
        _assert_elastic_invariants(fab)


def test_inplace_fabric_falls_through_on_degraded_topology():
    """The contrast case: recover-in-place (elastic=False) leaves replicas
    pointing at dead devices, so a later failure in the other rack falls
    through to RUNNING_CKPT/DISK."""
    params = _params()
    part = partition_pytree(params, 16)
    fab = _fabric(part, elastic=False, parity=False)
    ckpt = init_running_checkpoint(params, part)
    live = _noisy(params)
    last = None
    for step, host in ((1, 0), (2, 1), (3, 2)):
        fab.maintain(step, live, force=True)
        lost, failed = fab.domain_failure("host", host)
        _, last = fab.on_failure(live, ckpt.values, lost, failed,
                                 step=step, persist_failure=True)
    # host 2 sits in rack 1; its replicas were seeded in rack 0 — both of
    # whose hosts are already dead — and were never re-seeded
    tc = last["tier_counts"]
    assert tc["PEER_REPLICA"] == 0
    assert tc["RUNNING_CKPT"] + tc["DISK"] > 0


def test_inplace_parity_cannot_use_long_dead_members():
    """Regression: parity availability must respect view liveness — a group
    member homed on a device dead since an *earlier* persisted event is
    physically gone and cannot serve as an XOR survivor, even though the
    simulation still holds its value."""
    params = _params()
    part = partition_pytree(params, 16)
    fab = _fabric(part, elastic=False)     # replicas + parity, in-place
    ckpt = init_running_checkpoint(params, part)
    live = _noisy(params)
    last = lost = None
    for step, host in ((1, 0), (2, 1), (3, 2)):
        fab.maintain(step, live, force=True)
        lost, failed = fab.domain_failure("host", host)
        _, last = fab.on_failure(live, ckpt.values, lost, failed,
                                 step=step, persist_failure=True)
    # by event 3, every parity group containing a host-2 member has lost a
    # second member (or its parity home) to the earlier host-0/1 deaths,
    # and every replica of a host-2 block sat in the dead rack 0: nothing
    # cheap survives
    tc = last["tier_counts"]
    assert tc["PEER_REPLICA"] == 0 and tc["PARITY"] == 0
    assert tc["RUNNING_CKPT"] + tc["DISK"] == int(lost.sum()) > 0


def test_healing_readmits_and_reseeds():
    params = _params()
    part = partition_pytree(params, 16)
    fab = _fabric(part)
    ckpt = init_running_checkpoint(params, part)
    live = _noisy(params)
    fab.maintain(1, live)
    lost, failed = fab.domain_failure("host", 0)
    fab.on_failure(live, ckpt.values, lost, failed, step=1)
    info = fab.heal_domain("host", 0, live, step=1)
    assert info["healed_devices"] == 2
    assert info["rebalanced_blocks"] > 0
    assert fab.view.n_alive_hosts == 4
    _assert_elastic_invariants(fab)
    # healed capacity is a real failure domain again: losing another host
    # still recovers everything from the re-seeded tiers
    fab.maintain(2, live, force=True)
    lost2, failed2 = fab.domain_failure("host", 1)
    _, info2 = fab.on_failure(live, ckpt.values, lost2, failed2, step=2)
    tc = info2["tier_counts"]
    assert tc["RUNNING_CKPT"] == 0 and tc["DISK"] == 0


# ---------------------------------------------------------------------------
# Trace-driven soak: classic runner + controller accounting
# ---------------------------------------------------------------------------

def test_run_with_trace_elastic_vs_inplace():
    from repro.models.classic import make_model
    from repro.training import run_clean, run_with_trace
    model = make_model("mlr", n=400, dim=48, n_classes=4, batch=150)
    clean = run_clean(model, 70)["losses"]
    pol = CheckpointPolicy(fraction=0.25, full_interval=8,
                           strategy=SelectionStrategy.ROUND_ROBIN,
                           recovery=RecoveryMode.PARTIAL,
                           block_rows=model.block_rows)
    trace = [FailureEvent(step=12, kind="host", index=0),
             FailureEvent(step=28, kind="host", index=1),
             FailureEvent(step=44, kind="host", index=2)]
    kw = dict(max_iters=70, seed=0, clean_losses=clean, trace=trace)
    elastic = run_with_trace(model, pol, fabric=FabricConfig(
        n_devices=8, devices_per_host=2, elastic=True, use_pallas=False),
        **kw)
    inplace = run_with_trace(model, pol, fabric=FabricConfig(
        n_devices=8, devices_per_host=2, elastic=False, parity=False,
        use_pallas=False), **kw)
    assert len(elastic["events"]) == 3 == len(inplace["events"])
    for ev in elastic["events"]:
        assert ev["tier_counts"]["RUNNING_CKPT"] == 0
        assert ev["tier_counts"]["DISK"] == 0
    last = inplace["events"][-1]["tier_counts"]
    assert last["RUNNING_CKPT"] + last["DISK"] > 0
    assert all(np.isfinite(elastic["losses"]))
    # per-event accounting is surfaced through the controller too
    assert len(elastic["controller_stats"]["events"]) == 3
    assert elastic["events"][-1]["applied_sq"] <= inplace["events"][-1][
        "applied_sq"] + 1e-9


def test_run_with_trace_healing_restores_capacity():
    from repro.models.classic import make_model
    from repro.training import run_with_trace
    model = make_model("mlr", n=400, dim=48, n_classes=4, batch=150)
    pol = CheckpointPolicy(fraction=0.25, full_interval=8,
                           strategy=SelectionStrategy.ROUND_ROBIN,
                           recovery=RecoveryMode.PARTIAL,
                           block_rows=model.block_rows)
    trace = [FailureEvent(step=10, kind="host", index=0),
             FailureEvent(step=30, kind="host", index=1)]
    r = run_with_trace(model, pol, fabric=FabricConfig(
        n_devices=8, devices_per_host=2, elastic=True, use_pallas=False),
        max_iters=45, seed=0, trace=trace, heal_after=10)
    assert len(r["events"]) == 2
    assert r["controller_stats"]["recoveries"] == 2
    assert all(np.isfinite(r["losses"]))


def test_train_loop_mtbf_soak_mode():
    """SPMD trainer path: mtbf-driven multi-event soak with healing."""
    from repro.configs import get_config
    from repro.data.pipeline import ShardedLMDataset
    from repro.sharding import single_device_ctx
    from repro.training import TrainLoop, TrainLoopConfig
    ctx = single_device_ctx()
    cfg = get_config("qwen2-1.5b", reduced=True)
    pol = CheckpointPolicy.scar(fraction=0.25, interval=3)
    loop_cfg = TrainLoopConfig(
        policy=pol, mtbf={"host": 2.0}, heal_after=2, seed=3,
        fabric=FabricConfig(n_devices=8, devices_per_host=2, elastic=True,
                            use_pallas=False))
    loop = TrainLoop(cfg, ctx, loop_cfg=loop_cfg)
    state = loop.init_state()
    ds = ShardedLMDataset(cfg, batch=2, seq=64, ctx=ctx)
    state = loop.run(state, iter(ds), 8)
    events = loop.controller.stats["events"]
    assert events, "mtbf of 2 steps should fire within 8 steps"
    for ev in events:
        assert ev["tier_counts"]["RUNNING_CKPT"] == 0
        assert ev["tier_counts"]["DISK"] == 0
    assert all(np.isfinite(m["loss"]) for m in loop.metrics)


def test_train_loop_config_validates_mtbf():
    from repro.training import TrainLoopConfig
    with pytest.raises(ValueError):
        TrainLoopConfig(mtbf={"host": 100.0})   # fabric missing


# ---------------------------------------------------------------------------
# Fabric-aware persistent store
# ---------------------------------------------------------------------------

def test_store_domain_keyed_layout_and_partial_read(tmp_path):
    params = _params()
    part = partition_pytree(params, 16)
    dm = _dm()
    homes = block_device_homes(part, dm.n_devices)
    store = ShardedCheckpointStore(str(tmp_path))
    store.init(params, part, homes=homes, domains=dm)
    hosts = np.asarray(dm.host_of(homes))
    # packed layout: one append-mode shard per home host, and every block
    # indexed into its own host's shard
    for h in np.unique(hosts):
        host_dir = os.path.join(str(tmp_path), f"host_{h:04d}")
        shards = [f for f in os.listdir(host_dir)
                  if f.startswith("blocks.") and f.endswith(".shard")]
        assert shards, f"host {h} has no packed shard"
    for gid in range(part.total_blocks):
        assert os.path.dirname(store._shard_path(gid)).endswith(
            f"host_{hosts[gid]:04d}"), f"block {gid} not keyed by its domain"
    assert store.saved_iters().shape == (part.total_blocks,)
    # partial read: only the masked blocks come back
    mask = np.zeros((part.total_blocks,), bool)
    mask[hosts == 2] = True
    got = store.read_blocks(mask)
    full = store.read_all()
    wleaf = next(l for l in part.leaves if l.name.endswith("'w']"))
    masked_w = [b for b in range(wleaf.n_blocks) if mask[wleaf.offset + b]]
    assert masked_w, "expected some of w's blocks homed on host 2"
    for b in masked_w:
        np.testing.assert_array_equal(
            np.asarray(got["w"][b * 16:(b + 1) * 16]),
            np.asarray(full["w"][b * 16:(b + 1) * 16]))
    # read_surviving: blocks of a failed host are absent from the mask
    vals, present = store.read_surviving([1])
    np.testing.assert_array_equal(present, hosts != 1)


def test_store_parity_mirror_offline_reconstruction(tmp_path):
    """Host-local shard dies; its blocks reconstruct offline from the
    surviving shards + the disk parity mirror, bit-exactly."""
    params = _params()
    part = partition_pytree(params, 16)
    dm = _dm()
    homes = block_device_homes(part, dm.n_devices)
    view = ClusterView(dm, homes)
    codec = ParityCodec(part, view, group_size=3, use_pallas=False)
    codec.encode(0, params)
    store = ShardedCheckpointStore(str(tmp_path))
    store.init(params, part, homes=homes, domains=dm)
    nbytes = store.write_parity(0, np.asarray(codec.parity),
                                codec.parity_homes, domains=dm,
                                members=codec.members)
    assert nbytes > 0
    parity, meta = store.read_parity()
    assert meta["step"] == 0 and parity.shape[0] == codec.n_groups
    # the whole of host 1's local shard is gone; reconstruction below uses
    # ONLY what is on disk (parity buffers + PARITY.json membership) — a
    # restarted process has no live codec to ask
    shutil.rmtree(os.path.join(str(tmp_path), "host_0001"))
    vals, present = store.read_surviving([1])
    frames = np.asarray(pack_frames(vals, part, codec.layout))
    want = np.asarray(pack_frames(params, part, codec.layout))
    checked = 0
    for j, ids in enumerate(meta["members"]):
        ids = np.asarray(ids, np.int32)
        missing = ids[~present[ids]]
        if missing.size != 1:
            continue
        acc = parity[j].copy()
        for b in ids[present[ids]]:
            acc ^= frames[b]
        np.testing.assert_array_equal(acc, want[int(missing[0])])
        checked += 1
    assert checked > 0, "no singly-erased group to reconstruct"
