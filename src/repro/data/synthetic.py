"""Synthetic datasets + ShapeDtypeStruct input specs.

Two roles:

1. **Concrete batches** for smoke tests / examples / the classic-model
   reproduction (offline container: synthetic stand-ins for MNIST,
   CoverType, MovieLens, Jester, 20news, Reuters — sizes matched to the
   paper's parameter-count regime).
2. **``input_specs``** — ShapeDtypeStruct stand-ins for every model input
   of a given (arch × input shape), used by the multi-pod dry-run (no
   allocation).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

PyTree = Any


# ---------------------------------------------------------------------------
# LM batches (assigned architectures)
# ---------------------------------------------------------------------------

def _lm_batch_struct(cfg: ModelConfig, batch: int, seq: int) -> dict:
    d = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    if cfg.family == "vlm":
        d["patches"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_patches, cfg.vit_dim), jnp.bfloat16
            if cfg.dtype == "bfloat16" else jnp.float32)
    if cfg.family == "audio":
        d["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.enc_seq, cfg.d_model), jnp.bfloat16
            if cfg.dtype == "bfloat16" else jnp.float32)
    return d


def lm_batch(rng: jax.Array, cfg: ModelConfig, batch: int, seq: int) -> dict:
    """Concrete random batch matching ``input_specs`` (smoke tests)."""
    ks = jax.random.split(rng, 3)
    specs = _lm_batch_struct(cfg, batch, seq)
    out = {
        "tokens": jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab, jnp.int32),
        "labels": jax.random.randint(ks[1], (batch, seq), 0, cfg.vocab, jnp.int32),
    }
    for key in ("patches", "frames"):
        if key in specs:
            s = specs[key]
            out[key] = jax.random.normal(ks[2], s.shape, jnp.float32).astype(s.dtype)
    return out


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """ShapeDtypeStruct inputs for a named input shape (dry-run)."""
    shapes = {
        "train_4k": dict(seq=4096, batch=256, kind="train"),
        "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
        "decode_32k": dict(seq=32768, batch=128, kind="decode"),
        "long_500k": dict(seq=524288, batch=1, kind="decode"),
        # reduced shapes for CPU-side integration tests
        "smoke_train": dict(seq=64, batch=2, kind="train"),
        "smoke_decode": dict(seq=64, batch=2, kind="decode"),
    }
    s = shapes[shape_name]
    if s["kind"] in ("train", "prefill"):
        return _lm_batch_struct(cfg, s["batch"], s["seq"])
    # decode: one new token
    return {"tokens": jax.ShapeDtypeStruct((s["batch"], 1), jnp.int32)}


def shape_params(shape_name: str) -> dict:
    return {
        "train_4k": dict(seq=4096, batch=256, kind="train"),
        "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
        "decode_32k": dict(seq=32768, batch=128, kind="decode"),
        "long_500k": dict(seq=524288, batch=1, kind="decode"),
        "smoke_train": dict(seq=64, batch=2, kind="train"),
        "smoke_decode": dict(seq=64, batch=2, kind="decode"),
    }[shape_name]


# ---------------------------------------------------------------------------
# classic-model datasets (paper §5.1 stand-ins)
# ---------------------------------------------------------------------------

def classification_data(rng: np.random.Generator, n: int = 2000, dim: int = 784,
                        n_classes: int = 10, sep: float = 2.0):
    """Gaussian-cluster classification (MNIST/CoverType stand-in)."""
    centers = rng.normal(0, sep, (n_classes, dim))
    y = rng.integers(0, n_classes, n)
    x = centers[y] + rng.normal(0, 1.0, (n, dim))
    return x.astype(np.float32), y.astype(np.int32)


def ratings_matrix(rng: np.random.Generator, m: int = 600, n: int = 900,
                   rank: int = 5, noise: float = 0.05, density: float = 0.1):
    """Low-rank ratings (MovieLens/Jester stand-in). Returns (R, mask)."""
    L = rng.normal(0, 1.0, (m, rank))
    R = rng.normal(0, 1.0, (rank, n))
    full = L @ R + noise * rng.normal(0, 1.0, (m, n))
    mask = rng.random((m, n)) < density
    return (full * mask).astype(np.float32), mask.astype(np.float32)


def lda_corpus(rng: np.random.Generator, n_docs: int = 200, vocab: int = 500,
               n_topics: int = 10, doc_len_mean: int = 80):
    """Documents sampled from the LDA generative model (20news stand-in).

    Returns (tokens (n_docs, max_len) int32 padded with -1, doc_lens).
    """
    alpha, beta = 0.5, 0.1
    topic_word = rng.dirichlet([beta] * vocab, n_topics)
    doc_lens = np.maximum(10, rng.poisson(doc_len_mean, n_docs))
    max_len = int(doc_lens.max())
    tokens = np.full((n_docs, max_len), -1, np.int32)
    for d in range(n_docs):
        theta = rng.dirichlet([alpha] * n_topics)
        zs = rng.choice(n_topics, doc_lens[d], p=theta)
        for i, z in enumerate(zs):
            tokens[d, i] = rng.choice(vocab, p=topic_word[z])
    return tokens, doc_lens.astype(np.int32)


def image_batch(rng: np.random.Generator, n: int = 512, size: int = 28,
                n_classes: int = 10):
    """Class-dependent structured images (MNIST stand-in for the CNN)."""
    y = rng.integers(0, n_classes, n)
    x = rng.normal(0, 0.3, (n, size, size, 1)).astype(np.float32)
    xs = np.linspace(-1, 1, size)
    xx, yy = np.meshgrid(xs, xs)
    for c in range(n_classes):
        pat = np.sin((c + 1) * np.pi * xx) * np.cos((c + 1) * np.pi * yy)
        x[y == c] += pat[None, :, :, None].astype(np.float32)
    return x, y.astype(np.int32)
