"""Failure injection + recovery (paper §4.1, Theorems 4.1/4.2).

A *failure* destroys a subset of parameter blocks (the partitions homed on
failed PS nodes / mesh devices). Recovery replaces state from the running
checkpoint:

- FULL    — traditional: *all* parameters reset to the checkpoint. The
            perturbation is δ = z − x^{(T)} over the whole tree.
- PARTIAL — SCAR: only the *lost* blocks are restored; survivors keep their
            newer values. The perturbation is δ' = (z − x^{(T)}) restricted
            to the lost blocks, and ||δ'|| ≤ ||δ|| (Thm 4.1), with
            E||δ'||² = p·||δ||² for uniform loss (Thm 4.2).

Failure masks can be sampled uniformly over blocks (the paper's model) or
derived from a mesh failure domain (a host / pod slice) via
:func:`repro.sharding.partition.blocks_on_failed_devices`.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.blocks import (BlockPartition, masked_sq_norm, select_blocks,
                               tree_sq_norm)
from repro.core.checkpoint import RunningCheckpoint
from repro.core.policy import RecoveryMode

PyTree = Any


def sample_failure_mask(rng: jax.Array, partition: BlockPartition,
                        fraction: float) -> jnp.ndarray:
    """Lose a fraction ``p`` of blocks chosen uniformly at random (Thm 4.2)."""
    total = partition.total_blocks
    k = max(1, round(fraction * total))
    idx = jax.random.choice(rng, total, (min(k, total),), replace=False)
    return jnp.zeros((total,), bool).at[idx].set(True)


def recover(params: PyTree, ckpt: RunningCheckpoint, lost_mask: jnp.ndarray,
            mode: RecoveryMode, partition: BlockPartition) -> PyTree:
    """Apply checkpoint recovery after ``lost_mask`` blocks were destroyed."""
    if mode == RecoveryMode.FULL:
        return jax.tree_util.tree_map(jnp.array, ckpt.values)
    return select_blocks(params, ckpt.values, lost_mask, partition)


def perturbation_norms(params: PyTree, ckpt: RunningCheckpoint,
                       lost_mask: jnp.ndarray, partition: BlockPartition,
                       ) -> dict[str, jnp.ndarray]:
    """||δ||² (full recovery) and ||δ'||² (partial) for this failure —
    the quantities Theorems 4.1/4.2 relate."""
    full_sq = tree_sq_norm(ckpt.values, params)
    part_sq = masked_sq_norm(ckpt.values, params, lost_mask, partition)
    return {"full_sq": full_sq, "partial_sq": part_sq}


def apply_failure_and_recover(params: PyTree, ckpt: RunningCheckpoint,
                              lost_mask: jnp.ndarray, mode: RecoveryMode,
                              partition: BlockPartition,
                              ) -> tuple[PyTree, dict[str, jnp.ndarray]]:
    """Simulate the failure + recovery transition in one step.

    The lost blocks' live values are unrecoverable (the paper's PS node is
    gone); what remains is the survivors' live values plus the checkpoint.
    Returns the post-recovery params and the perturbation diagnostics.
    """
    info = perturbation_norms(params, ckpt, lost_mask, partition)
    recovered = recover(params, ckpt, lost_mask, mode, partition)
    delta_sq = tree_sq_norm(recovered, params)
    info["applied_sq"] = delta_sq
    info["lost_blocks"] = jnp.sum(lost_mask)
    return recovered, info
