"""qwen2-1.5b [dense] — GQA with QKV bias [arXiv:2407.10671].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    qkv_bias=True,
    sliding_window=4096,
    source="arXiv:2407.10671",
))
