"""Model definitions (assigned architectures + the paper's classic models)."""
from repro.models.api import get_model, ModelOps

__all__ = ["get_model", "ModelOps"]
