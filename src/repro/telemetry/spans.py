"""Span tracing with device-sync fencing and Chrome-trace export.

``SpanTracer.span("maintain")`` is a nestable context manager that records
wall-clock begin/end per phase. Under JAX's async dispatch a phase's
Python exit time routinely precedes the device work it launched; the
``fence`` argument closes that gap — on exit, before the end timestamp is
taken, the tracer either calls the fence (a callable like
``fabric.block_until_maintained``) or runs ``jax.block_until_ready`` on it
(an array / pytree). The recorded duration is then the phase's *device*
work, not its dispatch.

Export is the Chrome ``trace_event`` JSON format (complete events,
``"ph": "X"``, microsecond timestamps), loadable in Perfetto
(https://ui.perfetto.dev) or ``chrome://tracing`` — nesting renders
automatically for properly contained events on one track.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import threading
import time
from typing import Any


def _run_fence(fence: Any) -> None:
    """Synchronize on a phase's device work: call it, or block on it."""
    if callable(fence):
        fence()
        return
    import jax
    jax.block_until_ready(fence)


@dataclasses.dataclass
class SpanRecord:
    name: str
    t0: float          # seconds since tracer start
    t1: float
    depth: int         # nesting depth at entry (0 = top level)
    tid: int           # recording thread id
    args: dict

    @property
    def duration(self) -> float:
        return self.t1 - self.t0


class SpanTracer:
    """Collects :class:`SpanRecord`s; one instance per run/Recorder."""

    def __init__(self, clock=time.perf_counter) -> None:
        self._clock = clock
        self._t0 = clock()
        self._lock = threading.Lock()
        self._local = threading.local()
        self.spans: list[SpanRecord] = []

    def _depth(self) -> int:
        return getattr(self._local, "depth", 0)

    @contextlib.contextmanager
    def span(self, name: str, fence: Any = None, **args: Any):
        depth = self._depth()
        self._local.depth = depth + 1
        t0 = self._clock() - self._t0
        try:
            yield
        finally:
            if fence is not None:
                _run_fence(fence)
            t1 = self._clock() - self._t0
            self._local.depth = depth
            rec = SpanRecord(name=name, t0=t0, t1=t1, depth=depth,
                             tid=threading.get_ident(), args=dict(args))
            with self._lock:
                self.spans.append(rec)

    def now(self) -> float:
        """Current tracer-relative timestamp (seconds since tracer start)
        — the time base :meth:`record` expects."""
        return self._clock() - self._t0

    def record(self, name: str, t0: float, t1: float,
               **args: Any) -> SpanRecord:
        """Record a span retroactively from explicit tracer-relative
        timestamps (see :meth:`now`). This is how deferred device work
        gets an honest interval: an async maintenance sweep is *dispatched*
        inside one step but only *fenced* when its outputs are consumed —
        the span covering [dispatch, fence] can't be a context manager, it
        is closed after the fact by whoever takes the fence. Depth is 0
        (deferred spans overlap the top-level step spans by design, which
        is exactly what the Chrome trace should show)."""
        rec = SpanRecord(name=name, t0=float(t0), t1=float(t1), depth=0,
                         tid=threading.get_ident(), args=dict(args))
        with self._lock:
            self.spans.append(rec)
        return rec

    # -- analysis -----------------------------------------------------------

    def durations(self, name: str) -> list[float]:
        """All recorded durations (seconds) of spans named ``name``."""
        return [s.duration for s in self.spans if s.name == name]

    def intervals(self, name: str) -> list[tuple[float, float]]:
        """All recorded (t0, t1) intervals of spans named ``name`` —
        overlap assertions (does ``maintain`` run under ``train_step``?)
        read these directly instead of re-parsing the Chrome export."""
        return [(s.t0, s.t1) for s in self.spans if s.name == name]

    # -- export -------------------------------------------------------------

    def chrome_trace(self) -> dict:
        """The ``trace_event`` document: one complete ("X") event per
        span. Timestamps/durations are microseconds per the format."""
        events = []
        for s in sorted(self.spans, key=lambda s: s.t0):
            args = {k: v for k, v in s.args.items() if v is not None}
            events.append({
                "name": s.name, "cat": "repro", "ph": "X",
                "ts": round(s.t0 * 1e6, 3),
                "dur": round(s.duration * 1e6, 3),
                "pid": os.getpid(), "tid": s.tid,
                "args": args,
            })
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"source": "repro.telemetry"}}

    def write_chrome_trace(self, path: str) -> str:
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.chrome_trace(), f)
        os.replace(tmp, path)
        return path
