"""Theory layer: Theorem 3.2 / Appendix B bounds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.iteration_cost import (delta_T, discounted_delta,
                                       empirical_iteration_cost,
                                       estimate_contraction,
                                       infinite_perturbation_bound,
                                       irreducible_error,
                                       iteration_cost_bound,
                                       iterations_to_eps,
                                       sgd_iteration_bound,
                                       single_perturbation_bound)


def test_delta_T_single_perturbation():
    # one perturbation of norm 2 at iter 3, c=0.5 -> Δ = c^{-3}·2 = 16
    deltas = np.array([0, 0, 0, 2.0])
    assert float(delta_T(deltas, 0.5)) == pytest.approx(16.0)


def test_delta_T_matches_discounted():
    deltas = np.array([1.0, 0.5, 0.0, 2.0])
    c = 0.8
    T = len(deltas) - 1
    assert float(discounted_delta(deltas, c, T)) == pytest.approx(
        float(delta_T(deltas, c)) * c ** T, rel=1e-5)


def test_bound_zero_perturbation_is_zero():
    assert float(iteration_cost_bound(np.zeros(5), 0.9, 10.0)) == pytest.approx(0.0)


def test_bound_monotone_in_delta():
    prev = 0.0
    for size in [0.1, 1.0, 10.0, 100.0]:
        b = single_perturbation_bound(size, 0.9, T=10, x0_err=5.0)
        assert b > prev
        prev = b


def test_bound_grows_with_T():
    # later perturbations are costlier (discounted by c^{-T})
    b1 = single_perturbation_bound(1.0, 0.9, T=5, x0_err=5.0)
    b2 = single_perturbation_bound(1.0, 0.9, T=50, x0_err=5.0)
    assert b2 > b1


def test_bound_tight_on_linear_contraction():
    """Synthetic exactly-linear iteration: bound should match measured cost
    (the paper's tightness claim for adversarial perturbations)."""
    c, x0 = 0.9, 10.0
    eps = 1e-3
    T, size = 40, 5.0

    def run(perturb):
        x, errs = x0, []
        for k in range(1, 400):
            if perturb and k == T:
                x += size          # adversarial: directly away from 0
            x = c * x
            errs.append(abs(x))
        return errs

    clean, pert = run(False), run(True)
    measured = empirical_iteration_cost(pert, clean, eps)
    bound = single_perturbation_bound(size, c, T=T, x0_err=x0)
    assert measured <= bound + 1.0
    # tight within a couple of iterations (integer effects)
    assert bound - measured < 3.0


def test_estimate_contraction_exact_geometric():
    errs = [5.0 * 0.85 ** k for k in range(50)]
    assert estimate_contraction(errs) == pytest.approx(0.85, rel=1e-3)


def test_iterations_to_eps():
    errs = [10, 5, 2, 1, 0.5, 0.2]
    assert iterations_to_eps(errs, 0.6) == 4
    assert iterations_to_eps(errs, 0.01) == len(errs)


def test_infinite_perturbation_irreducible():
    # Appendix B.1: below the irreducible error the bound is infinite
    c, D = 0.9, 0.5
    irr = irreducible_error(D, c)
    assert irr == pytest.approx(4.5)
    assert infinite_perturbation_bound(D, c, x0_err=100.0, eps=irr * 0.9) == float("inf")
    finite = infinite_perturbation_bound(D, c, x0_err=100.0, eps=irr * 2)
    assert np.isfinite(finite) and finite > 0


def test_sgd_bound_reasonable():
    # no perturbations: must still converge in finite iterations
    k0 = sgd_iteration_bound(np.zeros(1), alpha0=1.0, G=1.0,
                             x0_err=10.0, eps=0.5)
    k1 = sgd_iteration_bound(np.array([5.0]), alpha0=1.0, G=1.0,
                             x0_err=10.0, eps=0.5)
    assert 0 < k0 <= k1 < 1_000_000
