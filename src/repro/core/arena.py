"""Flat parameter arena: one contiguous per-host buffer for all leaves.

The fabric's hot loop (replica refresh + parity encode + PRIORITY scoring
+ in-place partial save) previously operated on a *forest* of leaves: one
kernel dispatch per touched leaf, `(1, BE)` row tiles that waste TPU
sublanes, and per-leaf eager dispatch overhead that dominates wall-clock
at small scale (see ``BENCH_maintain.json``: the donation save moved 7.7×
fewer bytes than the rewrite yet ran ~18× slower).

The arena collapses the forest to a single contiguous ``float32`` buffer:

  - every leaf is cast to float32 (value-exact for f32/bf16/f16 — the same
    convention the parity frames already use) and laid out block-major:
    leaf segments in flatten order, each block's payload zero-padded to a
    multiple of ``ARENA_TILE`` = 8·128 words, so every block covers whole
    ``(8, 128)`` sublane-aligned tiles of the 2D ``(rows, 128)`` retiling;
  - the **block table** maps ``(leaf, block) → (offset, words, payload)``
    — ``payload`` is the live words, the tail up to ``words`` is zero
    padding (XOR-neutral for parity, diff-neutral for scores);
  - colocated leaves (shared global block ids) get *separate* segments —
    the table is keyed by arena-block id, so a partial save or disk
    mirror of one gid moves every colocated payload for that gid;
  - per-leaf arena column starts equal the (tile-aligned) parity
    ``FrameLayout`` columns, so an XOR over arena tiles lands bit-exactly
    in the codec's ``(n_groups, frame_elems)`` parity frames.

Invariants (relied on by kernels, the store, and the property tests):

  I1  ``offset`` and ``words`` of every table row are multiples of
      ``ARENA_TILE``; ``data_words`` and ``total_words`` too.
  I2  segments are disjoint and cover ``[0, data_words)`` exactly;
      ``[data_words, total_words)`` is the arena-level shard pad (zero
      tiles appended so ``n_tiles`` divides ``shards`` evenly — empty
      when ``shards == 1``, which is the historical layout bit-for-bit).
  I3  ``unpack(pack(tree)) == tree`` bit-exactly for every supported
      dtype (f32/bf16/f16), any shape (including scalars and ragged
      tail blocks).
  I4  pad words are 0.0f (bit pattern 0x00000000) after ``pack`` and are
      *kept* zero by every arena mutation (scatter saves copy whole
      segments, so pads are overwritten with source pads — also zero;
      the shard-pad tail is never a scatter target).

Sharded form: when the trainer runs on a mesh, the same 1-D buffer
carries a flat ``NamedSharding`` over every mesh axis — device ``d`` of
``n`` owns words ``[d·total/n, (d+1)·total/n)``, a whole number of
``(8, 128)`` tiles by I1/I2. ``arena_block_homes`` derives the
block→device map *from* that span ownership, so "each device owns the
tile-aligned segments of its home blocks" holds by construction.

.. warning:: jax 0.4.37's CPU SPMD partitioner miscompiles
   ``concatenate`` of 1-D operands that carry a minor-mesh-axis
   sharding (wrong *values*, not a perf hazard). ``pack_arena`` takes
   ``out_sharding`` and pins every part and the result to the flat
   arena sharding, which sidesteps the bug and is the layout we want
   anyway; sharded callers must pass it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocks import (BlockPartition, expand_block_mask,
                               leaf_block_view, leaf_frame_width)

PyTree = Any

ARENA_LANES = 128          # lane width of the 2D retiling
ARENA_SUBLANES = 8         # f32 sublane tile height
ARENA_TILE = ARENA_LANES * ARENA_SUBLANES   # words per (8, 128) tile

# dtypes whose values survive a float32 round trip bit-exactly — the same
# contract the parity frames have always assumed, now checked explicitly
ARENA_DTYPES = (jnp.float32, jnp.bfloat16, jnp.float16)


def _align(n: int, a: int = ARENA_TILE) -> int:
    return -(-max(int(n), 1) // a) * a


def leaf_payload_words(leaf, block_rows: int) -> int:
    """Live f32 words per block of this leaf — the parity frame payload
    width (:func:`repro.core.blocks.leaf_frame_width`)."""
    return leaf_frame_width(leaf, block_rows)


def arena_compatible(partition: BlockPartition) -> bool:
    """True when every leaf dtype round-trips float32 bit-exactly."""
    names = {np.dtype(d).name for d in
             ("float32", "bfloat16", "float16")}
    return all(np.dtype(l.dtype).name in names for l in partition.leaves)


@dataclasses.dataclass(frozen=True)
class ArenaBlock:
    """One block-table row: where block ``b`` of leaf ``li`` lives."""
    leaf: int          # leaf index in flatten order
    gid: int           # global block id (colocated leaves share gids)
    offset: int        # word offset of the segment (ARENA_TILE aligned)
    words: int         # aligned segment length (ARENA_TILE multiple)
    payload: int       # live words; [payload, words) is zero padding


@dataclasses.dataclass(frozen=True, eq=False)
class ArenaLayout:
    """Static block table + tile routing for one partition.

    ``ab_t0``/``ab_nt`` (first tile / tile count per arena block) and the
    gid→arena-block CSR (``gid_ab``/``gid_ptr``) make the per-save
    lookups O(selected) — the save hot path never scans the full table.

    ``eq=False``: identity comparison/hash, so a layout can ride as a
    static (meta) field of a registered pytree (``ArenaTrainState``) —
    the numpy tables would make the generated ``__eq__`` ill-defined, and
    every consumer shares the one instance its fabric built anyway."""
    partition: BlockPartition
    blocks: tuple[ArenaBlock, ...]      # leaf-major, block-minor
    leaf_offset: tuple[int, ...]        # word offset of each leaf's segment
    seg_words: tuple[int, ...]          # aligned words per block, per leaf
    payload_words: tuple[int, ...]      # live words per block, per leaf
    total_words: int                    # ARENA_TILE multiple (incl. shard pad)
    ab_t0: np.ndarray                   # (n_ab,) first tile per arena block
    ab_nt: np.ndarray                   # (n_ab,) tiles per arena block
    gid_ab: np.ndarray                  # arena blocks sorted by gid (CSR)
    gid_ptr: np.ndarray                 # (total_blocks + 1,) CSR pointers
    shards: int = 1                     # even flat-sharding divisor of n_tiles
    data_words: int = -1                # words before the shard-pad tail

    @property
    def n_tiles(self) -> int:
        return self.total_words // ARENA_TILE

    @property
    def pad_words(self) -> int:
        """Zero words of the shard-pad tail (0 when ``shards == 1``)."""
        return self.total_words - (self.total_words if self.data_words < 0
                                   else self.data_words)

    @property
    def shard_words(self) -> int:
        """Words each of the ``shards`` flat shards owns (tile multiple)."""
        return self.total_words // self.shards

    @property
    def rows_2d(self) -> int:
        return self.total_words // ARENA_LANES

    @property
    def nbytes(self) -> int:
        return self.total_words * 4

    # -- host-side routing (O(selected), not O(table)) -----------------------

    def tile_gids(self) -> np.ndarray:
        """(n_tiles,) global block id owning each (8, 128) tile.

        Shard-pad tail tiles report gid 0: their words are zero in every
        arena (I4), so any per-gid reduction over tiles (scores, diffs)
        sees an exact ``+0.0`` contribution — bit-neutral."""
        gids = np.asarray([ab.gid for ab in self.blocks], np.int32)
        gids = np.repeat(gids, self.ab_nt)
        pad = self.n_tiles - gids.size
        if pad:
            gids = np.concatenate([gids, np.zeros(pad, np.int32)])
        return gids

    def blocks_for_gids(self, global_ids) -> np.ndarray:
        """Ascending arena-block indices covering the given gids — every
        colocated leaf's segment rides along (they share gids)."""
        gids = np.unique(np.asarray(global_ids, np.int64).ravel())
        if gids.size == 0:
            return np.empty((0,), np.int64)
        parts = [self.gid_ab[self.gid_ptr[g]:self.gid_ptr[g + 1]]
                 for g in gids]
        return np.sort(np.concatenate(parts))

    def tiles_for_blocks(self, global_ids) -> np.ndarray:
        """Ascending (8-row-) tile indices covered by the given gids."""
        abs_ = self.blocks_for_gids(global_ids)
        if abs_.size == 0:
            return np.empty((0,), np.int32)
        t0, nt = self.ab_t0[abs_], self.ab_nt[abs_]
        total = int(nt.sum())
        starts = np.cumsum(nt) - nt
        return (np.repeat(t0, nt)
                + (np.arange(total) - np.repeat(starts, nt))).astype(np.int32)

    def seg_bytes_for_blocks(self, global_ids) -> int:
        """Aligned bytes a scatter of these gids actually moves."""
        abs_ = self.blocks_for_gids(global_ids)
        return 4 * ARENA_TILE * int(self.ab_nt[abs_].sum())


def as_live_arena(x: Any, layout: Optional[ArenaLayout]):
    """Return ``x`` when it is a live flat arena for ``layout``, else None.

    The training stack's arena-native hot path passes the flat ``(N,)``
    f32 buffer where tree-form params used to flow; consumers
    (FTController, CheckpointFabric, ArenaMaintainProgram) use this one
    predicate so the two forms share every entry point. A 1-D leaf tree
    can only be mistaken for an arena if it is a single bare f32 array of
    exactly ``total_words`` (a tile-aligned size no real model hits) —
    and the arena path is only reachable with a fabric-built layout."""
    if layout is None:
        return None
    if getattr(x, "ndim", None) == 1 and getattr(x, "size", 0) \
            == layout.total_words and x.dtype == jnp.float32:
        return x
    return None


def build_arena_layout(partition: BlockPartition,
                       shards: int = 1) -> ArenaLayout:
    """Lay out ``partition`` in the flat arena.

    ``shards > 1`` appends zero tiles so ``n_tiles % shards == 0`` —
    every flat shard of the 1-D buffer then owns a whole number of
    ``(8, 128)`` tiles and the data region ``[0, data_words)`` is
    *identical* to the ``shards=1`` layout (relayout across shard counts
    is a slice + re-pad, bit-exact)."""
    blocks: list[ArenaBlock] = []
    leaf_offset, seg_words, payload_words = [], [], []
    off = 0
    for li, leaf in enumerate(partition.leaves):
        payload = leaf_payload_words(leaf, partition.block_rows)
        seg = _align(payload)
        leaf_offset.append(off)
        seg_words.append(seg)
        payload_words.append(payload)
        for b in range(leaf.n_blocks):
            blocks.append(ArenaBlock(leaf=li, gid=leaf.offset + b,
                                     offset=off, words=seg,
                                     payload=payload))
            off += seg
    ab_gid = np.asarray([ab.gid for ab in blocks], np.int64)
    order = np.argsort(ab_gid, kind="stable")
    gid_ptr = np.searchsorted(ab_gid[order],
                              np.arange(partition.total_blocks + 1))
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    data_words = off
    pad_tiles = (-(data_words // ARENA_TILE)) % shards
    total_words = data_words + pad_tiles * ARENA_TILE
    return ArenaLayout(partition=partition, blocks=tuple(blocks),
                       leaf_offset=tuple(leaf_offset),
                       seg_words=tuple(seg_words),
                       payload_words=tuple(payload_words),
                       total_words=total_words,
                       ab_t0=np.asarray([ab.offset // ARENA_TILE
                                         for ab in blocks], np.int64),
                       ab_nt=np.asarray([ab.words // ARENA_TILE
                                         for ab in blocks], np.int64),
                       gid_ab=order, gid_ptr=gid_ptr,
                       shards=shards, data_words=data_words)


# ---------------------------------------------------------------------------
# pack / unpack / restore (pure, jittable; layout is static)
# ---------------------------------------------------------------------------

def pack_arena(values: PyTree, layout: ArenaLayout,
               out_sharding=None) -> jnp.ndarray:
    """Pack a tree into the flat (total_words,) float32 arena.

    One read of every leaf, one write of the arena — this *is* the replica
    refresh cost when the fabric snapshots into arena form.

    ``out_sharding`` (a flat 1-D ``NamedSharding``) pins every part and
    the result; **required** when any input leaf is mesh-sharded — see
    the module warning on the jax 0.4.37 sharded-``concatenate``
    miscompile this constraint sidesteps."""
    part = layout.partition
    con = ((lambda v: jax.lax.with_sharding_constraint(v, out_sharding))
           if out_sharding is not None else (lambda v: v))
    parts = []
    for x, leaf, seg, payload in zip(jax.tree_util.tree_leaves(values),
                                     part.leaves, layout.seg_words,
                                     layout.payload_words):
        view = leaf_block_view(x.astype(jnp.float32), part.block_rows)
        if view.shape[1] < seg:
            view = jnp.pad(view, ((0, 0), (0, seg - view.shape[1])))
        parts.append(con(view.reshape(-1)))
    if layout.pad_words:
        parts.append(con(jnp.zeros((layout.pad_words,), jnp.float32)))
    out = jnp.concatenate(parts) if len(parts) > 1 else parts[0]
    return con(out)


def _decode_leaf(arena: jnp.ndarray, layout: ArenaLayout, li: int):
    """Contiguous slice of leaf ``li``'s segment, decoded to leaf shape."""
    leaf = layout.partition.leaves[li]
    seg, payload = layout.seg_words[li], layout.payload_words[li]
    off = layout.leaf_offset[li]
    flat = jax.lax.dynamic_slice(arena, (off,), (leaf.n_blocks * seg,))
    vals = flat.reshape(leaf.n_blocks, seg)[:, :payload]
    rows = max(leaf.rows, 1)
    vals = vals.reshape(-1, max(leaf.row_width, 1))[:rows]
    return vals.reshape(leaf.shape).astype(leaf.dtype)


def unpack_arena(arena: jnp.ndarray, layout: ArenaLayout) -> PyTree:
    """Inverse of :func:`pack_arena`, bit-exact (invariant I3)."""
    out = [_decode_leaf(arena, layout, li)
           for li in range(len(layout.partition.leaves))]
    return jax.tree_util.tree_unflatten(layout.partition.treedef, out)


def relayout_arena(arena, old: ArenaLayout, new: ArenaLayout,
                   out_sharding=None):
    """Re-pad an arena across a shard-count change, bit-exactly.

    The data region ``[0, data_words)`` is identical for every shard
    count of the same partition (``build_arena_layout`` only moves the
    zero tail), so relayout is a host-side slice + re-pad. Used on the
    elastic resize path (mesh shrink / re-grow), which is failure-rate —
    not per-step — so the device round trip is acceptable; the result is
    ``device_put`` onto ``out_sharding`` when given."""
    if old.data_words != new.data_words:
        raise ValueError("relayout_arena: layouts disagree on the data "
                         f"region ({old.data_words} vs {new.data_words} "
                         "words) — not the same partition")
    host = np.asarray(arena)
    data = host[:new.data_words]
    out = np.concatenate(
        [data, np.zeros((new.total_words - new.data_words,), np.float32)])
    return jax.device_put(out, out_sharding) if out_sharding is not None \
        else jnp.asarray(out)


def arena_block_homes(layout: ArenaLayout,
                      n_devices: Optional[int] = None) -> np.ndarray:
    """(total_blocks,) home device of each gid, derived from flat-shard
    span ownership: the device whose contiguous word span holds the
    first tile of the gid's first arena block. With ``shards ==
    n_devices`` every device's span is tile-aligned (I1/I2), so a
    device's home blocks are exactly the tile-aligned segments it
    already owns — the sharded maintain sweep and the partial save read
    only local (plus boundary-straddling) tiles."""
    n = layout.shards if n_devices is None else int(n_devices)
    if layout.n_tiles % n:
        raise ValueError(f"n_tiles {layout.n_tiles} not divisible by "
                         f"{n} devices — build the layout with shards={n}")
    tiles_per = layout.n_tiles // n
    first_ab = layout.gid_ab[layout.gid_ptr[:-1]]
    return (layout.ab_t0[first_ab] // tiles_per).astype(np.int64)


def arena_restore(dst: PyTree, arena: jnp.ndarray, global_mask,
                  layout: ArenaLayout) -> PyTree:
    """Overwrite the masked blocks of ``dst`` from the arena.

    The arena-source counterpart of ``select_blocks`` /
    ``tree_masked_restore``: each touched leaf decodes one contiguous
    arena slice; untouched leaves pass through as the same buffer."""
    part = layout.partition
    mask = np.asarray(global_mask, bool)
    out = []
    for li, (x, leaf) in enumerate(zip(jax.tree_util.tree_leaves(dst),
                                       part.leaves)):
        seg = mask[leaf.offset:leaf.offset + leaf.n_blocks]
        if not seg.any():
            out.append(x)
            continue
        decoded = _decode_leaf(arena, layout, li).astype(x.dtype)
        em = expand_block_mask(jnp.asarray(seg), leaf, part.block_rows)
        out.append(jnp.where(em, decoded, x))
    return jax.tree_util.tree_unflatten(part.treedef, out)


# ---------------------------------------------------------------------------
# parity frame bridge
# ---------------------------------------------------------------------------

def frames_gather_index(layout: ArenaLayout, frame_layout) -> np.ndarray:
    """(total_blocks, frame_elems) arena word index per frame position
    (-1 where the frame is zero padding) — ``frames_from_arena``'s map.

    Valid because the arena's per-leaf columns match the (tile-aligned)
    ``FrameLayout`` columns: frame row ``gid`` is the side-by-side concat
    of every colocated leaf's segment for that gid."""
    part = layout.partition
    idx = np.full((part.total_blocks, frame_layout.frame_elems), -1,
                  np.int64)
    for ab in layout.blocks:
        col = frame_layout.cols[ab.leaf]
        idx[ab.gid, col:col + ab.payload] = np.arange(
            ab.offset, ab.offset + ab.payload)
    return idx


def frames_from_arena(arena: jnp.ndarray, gather_idx: np.ndarray,
                      ) -> jnp.ndarray:
    """(total_blocks, frame_elems) int32 bit-pattern frames — bit-exact
    vs ``pack_frames`` of the unpacked tree (one gather, no per-leaf
    pass)."""
    idx = jnp.asarray(np.where(gather_idx >= 0, gather_idx, 0))
    vals = jnp.where(jnp.asarray(gather_idx >= 0), arena[idx],
                     jnp.float32(0.0))
    return jax.lax.bitcast_convert_type(vals, jnp.int32)
