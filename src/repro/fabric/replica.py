"""Anti-affine peer replication of running-state blocks (tier 1).

Each block's replica is placed in a different failure domain (the farthest
one the *current* topology offers: another rack when racks survive, else
another host), so a whole-domain failure never takes a block *and* its
replica together. Placement is read from the fabric's mutable
:class:`~repro.fabric.placement.ClusterView` — after a domain loss the set
is :meth:`reseed`-ed so replicas stay anti-affine in the degraded topology
instead of pointing at dead devices. Replicas hold live parameter values as
of the last refresh — refreshing is a device-to-device copy (no host trip,
no disk), cheap enough to run every iteration, so a replica-recovered block
is restored to its *live* value: zero perturbation in the Thm 4.1
accounting (see DESIGN.md).

The snapshot lives in one of two forms: a PyTree (the seed/per-leaf fused
paths) or a flat **parameter arena** (:mod:`repro.core.arena`) ingested by
the arena maintenance sweep — the canonical hot-path form. Recovery reads
whichever is present; ``values`` materializes a tree from the arena on
demand (recovery-path only, never the hot loop).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocks import BlockPartition
from repro.fabric.placement import ClusterView, anti_affine_replica_homes

PyTree = Any


class ReplicaSet:
    """One replica per block, anti-affine to the block's primary home."""

    def __init__(self, partition: BlockPartition, view: ClusterView):
        self.partition = partition
        self.view = view
        self.domains = view.domains
        self.replica_homes = anti_affine_replica_homes(view)
        self._tree: Optional[PyTree] = None
        self._arena: Optional[jnp.ndarray] = None
        self.arena_layout = None
        # SPMD meshes: the fabric sets this to the flat arena sharding.
        # The ingested replica then lives on the *rotated* (anti-affine)
        # device order, and consumers that feed it into a jit alongside
        # flat-sharded state re-place it here first — XLA requires one
        # consistent device assignment per computation.
        self.main_sharding = None
        self.refreshed_step = -1

    # -- maintenance ---------------------------------------------------------

    def refresh(self, step: int, params: PyTree) -> None:
        """Snapshot live params into the replicas (device copy)."""
        self._tree = jax.tree_util.tree_map(jnp.array, params)
        self._arena = None
        self.refreshed_step = int(step)

    def ingest(self, step: int, values: PyTree) -> None:
        """Adopt a snapshot already produced elsewhere (the fused
        maintenance sweep emits the replica copy in the same pass that
        encodes parity — no second read of the live params)."""
        self._tree = values
        self._arena = None
        self.refreshed_step = int(step)

    def ingest_arena(self, step: int, arena: jnp.ndarray,
                     arena_layout) -> None:
        """Adopt an arena-form snapshot (the arena sweep's pack output —
        the pack IS the replica write). The tree form is materialized
        lazily and only on the recovery path.

        Under async maintenance this call IS the publish: the fabric's
        double-buffer snapshot becomes the replica arena here, atomically
        at Python level with the parity ingest for the same step — a
        reader never observes replica and parity from different epochs.
        The adopted arena may still have device work in flight; readers
        either fence through ``fabric.block_until_maintained`` or wait on
        dataflow, so a torn (half-swept) slot is unobservable."""
        self._arena = arena
        self.arena_layout = arena_layout
        self._tree = None
        self.refreshed_step = int(step)

    @property
    def arena(self) -> Optional[jnp.ndarray]:
        """The arena-form snapshot, or None when tree-form (or empty)."""
        return self._arena

    def arena_local(self) -> Optional[jnp.ndarray]:
        """The arena snapshot re-placed on the primary (flat) sharding —
        for consumers that mix it with flat-sharded state in one jit.
        Identity without a mesh (or when no snapshot exists)."""
        if self._arena is None or self.main_sharding is None:
            return self._arena
        return jax.device_put(self._arena, self.main_sharding)

    @property
    def values(self) -> Optional[PyTree]:
        """Tree-form snapshot; decodes the arena on first access."""
        if self._tree is None and self._arena is not None:
            from repro.core.arena import unpack_arena
            self._tree = unpack_arena(self.arena_local(), self.arena_layout)
        return self._tree

    def is_fresh(self, step: int) -> bool:
        """True when replicas hold the *current* live values (no parameter
        update has happened since the refresh)."""
        return (self._tree is not None or self._arena is not None) \
            and self.refreshed_step == int(step)

    def staleness(self, step: int) -> int:
        """Steps between ``step`` and the snapshot the replicas hold
        (0 = fresh; -1 = no snapshot at all). The async pipeline's
        bounded-staleness accounting reads this to price a recovery
        against the epoch actually restored."""
        if self._tree is None and self._arena is None:
            return -1
        return max(0, int(step) - self.refreshed_step)

    def reseed(self) -> None:
        """Recompute replica placement in the view's current (possibly
        degraded) topology. Values are untouched — re-seeding is a
        placement change; the next :meth:`refresh` lands on the new homes."""
        self.replica_homes = anti_affine_replica_homes(self.view)

    # -- survivorship --------------------------------------------------------

    def surviving(self, failed_devices) -> np.ndarray:
        """(total_blocks,) bool — replicas whose home device is alive in the
        view and not among this event's failed devices."""
        if self._tree is None and self._arena is None:
            return np.zeros((self.partition.total_blocks,), bool)
        failed = np.asarray(failed_devices, np.int32)
        return (self.view.alive[self.replica_homes]
                & ~np.isin(self.replica_homes, failed))

    def nbytes(self) -> int:
        if self._arena is not None:
            return int(self._arena.nbytes)
        if self._tree is None:
            return 0
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree_util.tree_leaves(self._tree))
