"""Sharded LM data pipeline.

Deterministic synthetic token stream, sharded across the data-parallel
axes: each step yields a global batch laid out host-side then
device_put with the batch NamedSharding. On a real cluster the generator
would be replaced by per-host file readers; the interface (``__iter__`` of
sharded batches) is what the trainer consumes.
"""
from __future__ import annotations

from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.sharding.partition import DistContext


class ShardedLMDataset:
    def __init__(self, cfg: ModelConfig, batch: int, seq: int,
                 ctx: DistContext, seed: int = 0):
        self.cfg, self.batch, self.seq, self.ctx = cfg, batch, seq, ctx
        self._rng = np.random.default_rng(seed)
        self._step = 0

    def _sharding(self):
        if self.ctx.mesh is None:
            return None
        return NamedSharding(self.ctx.mesh, P(self.ctx.dp_spec, None))

    def next_batch(self) -> dict:
        cfg = self.cfg
        tokens = self._rng.integers(0, cfg.vocab,
                                    (self.batch, self.seq + 1), dtype=np.int32)
        batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
        if cfg.family == "vlm":
            batch["patches"] = self._rng.normal(
                0, 1, (self.batch, cfg.n_patches, cfg.vit_dim)).astype(np.float32)
        if cfg.family == "audio":
            batch["frames"] = self._rng.normal(
                0, 1, (self.batch, cfg.enc_seq, cfg.d_model)).astype(np.float32)
        sh = self._sharding()
        if sh is not None:
            out = {}
            for k, v in batch.items():
                spec = P(self.ctx.dp_spec, *([None] * (v.ndim - 1)))
                out[k] = jax.device_put(v, NamedSharding(self.ctx.mesh, spec))
            batch = out
        else:
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
        self._step += 1
        return batch

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()
