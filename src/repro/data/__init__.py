"""Deterministic synthetic data pipeline (offline container — no downloads)."""
from repro.data.synthetic import (lm_batch, input_specs, classification_data,
                                  ratings_matrix, lda_corpus, image_batch)
from repro.data.pipeline import ShardedLMDataset

__all__ = ["lm_batch", "input_specs", "classification_data", "ratings_matrix",
           "lda_corpus", "image_batch", "ShardedLMDataset"]
