"""Shared helpers for the paper-reproduction benchmarks."""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

# small-but-faithful model configs (offline synthetic stand-ins, sized so a
# full benchmark run stays CPU-tractable; convergence ~60 iters as in paper)
MODEL_KW = {
    "qp": {},
    "mlr": dict(n=600, dim=64, n_classes=5, batch=200),
    "mf": dict(m=120, n=180, rank=4),
    "lda": dict(n_docs=60, vocab=120, n_topics=5, doc_len_mean=40),
    "cnn": dict(n=256, size=16, batch=64),
}


def timed(fn, *args, repeats=3, **kw):
    ts = []
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        ts.append(time.perf_counter() - t0)
    return out, 1e6 * float(np.median(ts))


def csv_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"


def summarize(vals):
    a = np.asarray(vals, float)
    return float(np.mean(a)), float(np.std(a) / max(np.sqrt(a.size), 1))
