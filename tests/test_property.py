"""Hypothesis property tests on SCAR's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.blocks import (masked_sq_norm, partition_pytree,
                               select_blocks, tree_sq_norm)
from repro.core.checkpoint import init_running_checkpoint
from repro.core.iteration_cost import (delta_T, iteration_cost_bound,
                                       single_perturbation_bound)
from repro.core.recovery import perturbation_norms, sample_failure_mask

SETTINGS = dict(max_examples=25, deadline=None)


def _tree(seed, rows, width):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(rows, width)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(max(1, rows // 7),)), jnp.float32)}


@given(seed=st.integers(0, 2**16), rows=st.integers(4, 120),
       width=st.integers(1, 9), block_rows=st.integers(1, 32),
       frac=st.floats(0.05, 1.0))
@settings(**SETTINGS)
def test_theorem_4_1_holds_for_arbitrary_trees(seed, rows, width, block_rows,
                                               frac):
    """||δ'|| ≤ ||δ|| for every tree shape, blocking, and failure mask."""
    params = _tree(seed, rows, width)
    part = partition_pytree(params, block_rows)
    ckpt = init_running_checkpoint(params, part)
    live = jax.tree_util.tree_map(lambda x: x * 1.3 + 0.1, params)
    mask = sample_failure_mask(jax.random.PRNGKey(seed), part, frac)
    info = perturbation_norms(live, ckpt, mask, part)
    assert float(info["partial_sq"]) <= float(info["full_sq"]) * (1 + 1e-5) + 1e-5


@given(seed=st.integers(0, 2**16), rows=st.integers(4, 80),
       block_rows=st.integers(1, 16))
@settings(**SETTINGS)
def test_select_blocks_partition_of_unity(seed, rows, block_rows):
    """select(a,b,m) + select(b,a,m) == a + b elementwise."""
    a = _tree(seed, rows, 3)
    b = jax.tree_util.tree_map(lambda x: x * -0.5 + 2.0, a)
    part = partition_pytree(a, block_rows)
    mask = sample_failure_mask(jax.random.PRNGKey(seed + 1), part, 0.5)
    s1 = select_blocks(a, b, mask, part)
    s2 = select_blocks(b, a, mask, part)
    tot1 = jax.tree_util.tree_map(lambda x, y: x + y, s1, s2)
    tot2 = jax.tree_util.tree_map(lambda x, y: x + y, a, b)
    for x, y in zip(jax.tree_util.tree_leaves(tot1),
                    jax.tree_util.tree_leaves(tot2)):
        np.testing.assert_allclose(x, y, rtol=1e-6)


@given(seed=st.integers(0, 2**16), rows=st.integers(4, 80),
       block_rows=st.integers(1, 16))
@settings(**SETTINGS)
def test_full_mask_equals_tree_norm(seed, rows, block_rows):
    a = _tree(seed, rows, 4)
    b = jax.tree_util.tree_map(lambda x: x + 1.7, a)
    part = partition_pytree(a, block_rows)
    full = jnp.ones((part.total_blocks,), bool)
    np.testing.assert_allclose(float(masked_sq_norm(a, b, full, part)),
                               float(tree_sq_norm(a, b)), rtol=1e-5)


@given(c=st.floats(0.05, 0.95), x0=st.floats(0.5, 100.0),
       sizes=st.lists(st.floats(0.0, 50.0), min_size=1, max_size=8))
@settings(**SETTINGS)
def test_bound_nonnegative_and_monotone(c, x0, sizes):
    deltas = np.asarray(sizes)
    b = float(iteration_cost_bound(deltas, c, x0))
    assert b >= -1e-9
    b2 = float(iteration_cost_bound(deltas * 2, c, x0))
    assert b2 >= b - 1e-9


@given(c=st.floats(0.1, 0.9), size=st.floats(0.01, 10.0),
       T=st.integers(1, 30), x0=st.floats(0.5, 50.0))
@settings(**SETTINGS)
def test_single_perturbation_consistent_with_general(c, size, T, x0):
    deltas = np.zeros(T + 1)
    deltas[T] = size
    general = float(iteration_cost_bound(deltas, c, x0))
    special = single_perturbation_bound(size, c, T, x0)
    np.testing.assert_allclose(general, special, rtol=1e-4)


@given(seed=st.integers(0, 2**16), frac=st.floats(0.01, 1.0),
       rows=st.integers(8, 100))
@settings(**SETTINGS)
def test_failure_mask_size(seed, frac, rows):
    params = _tree(seed, rows, 2)
    part = partition_pytree(params, 8)
    mask = sample_failure_mask(jax.random.PRNGKey(seed), part, frac)
    expected = max(1, round(frac * part.total_blocks))
    assert int(mask.sum()) == min(expected, part.total_blocks)
