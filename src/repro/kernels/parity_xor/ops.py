"""Dispatch wrappers: XOR parity encode / single-erasure reconstruct.

Same backend-selection contract as masked_restore.ops: Pallas compiled on
TPU, Pallas interpret elsewhere, with the jnp oracle as an opt-out.

Role note: on the maintenance hot loop the per-group XOR encode is now
folded into the flat-arena sweep (``kernels/fused_maintain`` — one
dispatch for the whole model, bit-identical output), so these wrappers
serve the recovery paths: re-encode after an elastic restripe/heal, and
the single-erasure ``parity_reconstruct`` fold at recovery time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.parity_xor.kernel import parity_xor_pallas
from repro.kernels.parity_xor.ref import parity_xor_ref


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


def parity_xor(frames: jnp.ndarray, base: jnp.ndarray, keep: jnp.ndarray,
               use_pallas: bool | None = None,
               interpret: bool | None = None) -> jnp.ndarray:
    """``use_pallas=None`` (default) is *auto*: the compiled kernel on TPU,
    the jnp oracle elsewhere. Parity encode sits in the per-iteration
    maintenance loop, where interpret-mode Pallas would be orders of
    magnitude slower than the oracle — force ``use_pallas=True`` only to
    validate kernel semantics."""
    if use_pallas is None:
        use_pallas = _is_tpu()
    if not use_pallas:
        return parity_xor_ref(frames, base, keep)
    if interpret is None:
        interpret = not _is_tpu()
    return parity_xor_pallas(frames, base, keep, interpret=interpret)


def parity_encode(frames: jnp.ndarray, valid: jnp.ndarray,
                  use_pallas: bool | None = None,
                  interpret: bool | None = None) -> jnp.ndarray:
    """Parity block per group: XOR of the group's valid members.

    frames: (n_groups, g, E) int32 member frames (padded members arbitrary);
    valid: (n_groups, g) — 1 for real members, 0 for padding.
    """
    base = jnp.zeros(frames.shape[::2], jnp.int32)
    return parity_xor(frames, base, valid, use_pallas, interpret)


def parity_reconstruct(frames: jnp.ndarray, parity: jnp.ndarray,
                       survivors: jnp.ndarray,
                       use_pallas: bool | None = None,
                       interpret: bool | None = None) -> jnp.ndarray:
    """Reconstruct each group's single missing member:
    parity ^ XOR of surviving members. Groups with zero or >1 missing
    members produce unused garbage — callers gate on eligibility.
    """
    return parity_xor(frames, parity, survivors, use_pallas, interpret)
