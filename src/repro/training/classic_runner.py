"""Experiment runner for the classic iterative-convergent models.

Drives the paper's §5 experiments:

- ``run_clean``              — unperturbed trajectory (the κ(x, ε) baseline).
- ``run_with_perturbation``  — inject one synthetic perturbation at iteration
                               T (random / adversarial / reset): Figures 3/5/6.
- ``run_with_failure``       — full SCAR lifecycle: periodic (partial)
                               checkpoints via FTController, a failure of a
                               fraction p of parameter blocks at a sampled
                               iteration, recovery (full or partial), then
                               continue to convergence: Figures 7/8.
- ``run_with_trace``         — beyond-paper degraded-mode soak: an
                               MTBF-sampled (or explicit) multi-event
                               failure trace where failed domains stay dead
                               in the fabric's cluster view; elastic fabrics
                               re-home/re-seed between events, and domains
                               optionally heal ``heal_after`` iters later.

All return loss trajectories + the empirical iteration cost
ι = κ(y, ε) − κ(x, ε) measured exactly as the paper does.
"""
from __future__ import annotations

import copy
import dataclasses
import time
from typing import Any, Optional

import jax
import numpy as np

from repro.core.controller import FTController
from repro.core.iteration_cost import empirical_iteration_cost, iterations_to_eps
from repro.core.perturb import (adversarial_perturbation, random_perturbation,
                                reset_perturbation)
from repro.core.policy import CheckpointPolicy
from repro.core.blocks import partition_pytree, tree_sq_norm
from repro.models.classic import IterativeModel
from repro.telemetry.recorder import NULL_RECORDER

PyTree = Any


def _keys(seed: int):
    base = jax.random.PRNGKey(seed)

    def key(i: int):
        return jax.random.fold_in(base, i)
    return key


def iterations_to_converge(model: IterativeModel, max_iters: int = 400,
                           seed: int = 0) -> int:
    traj = run_clean(model, max_iters, seed)["losses"]
    return iterations_to_eps(traj, model.eps)


def run_clean(model: IterativeModel, max_iters: int, seed: int = 0,
              stop_at_eps: bool = False) -> dict:
    key = _keys(seed)
    p = model.init(jax.random.PRNGKey(1))
    losses = []
    for i in range(1, max_iters + 1):
        p = model.step(p, key(i), i)
        losses.append(float(model.loss(p)))
        if stop_at_eps and losses[-1] < model.eps:
            break
    return {"losses": losses, "params": p}


def run_with_perturbation(model: IterativeModel, *, kind: str,
                          at_iter: int, size: Optional[float] = None,
                          fraction: Optional[float] = None,
                          max_iters: int = 400, seed: int = 0,
                          clean_losses: Optional[list] = None) -> dict:
    """One perturbation at ``at_iter`` (types of §5.2), run to max_iters.

    kind: "random" (needs size), "adversarial" (needs size),
    "reset" (needs fraction — reset random blocks to x^(0)).
    """
    key = _keys(seed)
    p0 = model.init(jax.random.PRNGKey(1))
    partition = partition_pytree(p0, model.block_rows,
                                 colocate=model.colocate)
    p = p0
    losses = []
    delta_norm = 0.0
    for i in range(1, max_iters + 1):
        if i == at_iter:
            prng = jax.random.fold_in(jax.random.PRNGKey(seed + 77), i)
            if kind == "random":
                p, dn = random_perturbation(prng, p, size)
            elif kind == "adversarial":
                p, dn = adversarial_perturbation(p, model.x_star(), size)
            elif kind == "reset":
                p, dn = reset_perturbation(prng, p, p0, fraction, partition)
            else:
                raise ValueError(kind)
            delta_norm = float(dn)
        p = model.step(p, key(i), i)
        losses.append(float(model.loss(p)))
    if clean_losses is None:
        clean_losses = run_clean(model, max_iters, seed)["losses"]
    cost = empirical_iteration_cost(losses, clean_losses, model.eps)
    return {"losses": losses, "delta_norm": delta_norm,
            "iteration_cost": cost,
            "kappa_perturbed": iterations_to_eps(losses, model.eps),
            "kappa_clean": iterations_to_eps(clean_losses, model.eps)}


def run_with_failure(model: IterativeModel, policy: CheckpointPolicy, *,
                     fail_iter: int, fail_fraction: float,
                     max_iters: int = 400, seed: int = 0,
                     clean_losses: Optional[list] = None,
                     store=None, fabric=None,
                     fail_domain: str = "uniform",
                     arena_state: bool = True,
                     recorder=None) -> dict:
    """Full SCAR lifecycle on one classic model (Figures 7/8).

    The failure destroys ``fail_fraction`` of parameter blocks (uniformly at
    random, the paper's model) or — with ``fabric`` and
    ``fail_domain="host"``/``"rack"``/``"device"`` — one whole correlated
    failure domain. Recovery follows ``policy.recovery`` from the running
    checkpoint, or the fabric's tier planner when a fabric is given.

    ``arena_state`` (default): when the controller is arena-capable, the
    live params are packed ONCE per consuming iteration and every
    controller call (maintain + save) uses that arena — with
    ``own_live`` the fabric adopts the pack as the replica directly, so
    the total cost matches the tree interface exactly (whose sweep made
    the same one pack internally) while exercising the same arena-native
    controller surface the LM trainer uses. ``False`` keeps the pure
    PyTree interface (bit-identical results either way).
    """
    if fail_domain != "uniform" and fabric is None:
        raise ValueError("correlated fail_domain needs a fabric")
    key = _keys(seed)
    rec = recorder if recorder is not None else NULL_RECORDER
    p = model.init(jax.random.PRNGKey(1))
    ctl = FTController(p, policy, norm_aux=model.norm_aux, store=store,
                       rng=jax.random.PRNGKey(seed + 13),
                       colocate=model.colocate, fabric=fabric,
                       recorder=recorder)
    use_arena = arena_state and ctl.arena_ready
    losses = []
    recovery_info = {}
    maint_seconds = 0.0
    for i in range(1, max_iters + 1):
        p = model.step(p, key(i), i)
        # maintain before the checkpoint: the fused sweep's PRIORITY
        # scores are measured against the pre-save running checkpoint
        t0 = time.perf_counter()
        # pack only on iterations whose maintain/save reads the live
        # value (always, under the default every-step tier intervals)
        packed = use_arena and ctl.live_value_needed(i)
        live = ctl.pack_live(p, account=True) if packed else p
        # own_live: the throwaway pack becomes the replica directly (no
        # copy inside the sweep) — same total cost as the tree interface
        ctl.maintain(i, live, own_live=packed)
        ctl.maybe_checkpoint(i, live, own_live=packed)
        # block on the sweep's outputs so maint_seconds books the
        # maintenance device work, not just its dispatch (same
        # attribution TrainLoop.run uses for overhead_seconds). Under
        # async maintenance the per-iteration fence is deliberately
        # skipped — the sweep settles under the next iteration's model
        # step and maint_seconds books the dispatch cost; the final
        # pending epoch is settled once after the loop.
        if ctl.fabric is not None \
                and not getattr(ctl.fabric.cfg, "async_maintain", False):
            ctl.fabric.block_until_maintained()
        maint_seconds += time.perf_counter() - t0
        if i == fail_iter:
            with rec.span("recovery", step=i, domain=fail_domain):
                if fail_domain == "uniform":
                    lost = ctl.sample_failure(fail_fraction)
                    p, recovery_info = ctl.on_failure(p, lost, step=i)
                else:
                    lost, failed = ctl.sample_domain_failure(fail_domain)
                    p, recovery_info = ctl.on_failure(p, lost,
                                                      failed_devices=failed,
                                                      step=i)
        losses.append(float(model.loss(p)))
    if ctl.fabric is not None:
        # settle the last async epoch (no-op in sync mode) — its fence
        # wait belongs to the run, not to whoever touches the fabric next
        t0 = time.perf_counter()
        ctl.fabric.block_until_maintained()
        maint_seconds += time.perf_counter() - t0
    if clean_losses is None:
        clean_losses = run_clean(model, max_iters, seed)["losses"]
    cost = empirical_iteration_cost(losses, clean_losses, model.eps)
    # snapshot (not alias) the live stats: the controller/fabric keep
    # mutating their dicts if reused after return — results must not
    # change retroactively
    return {"losses": losses, "iteration_cost": cost,
            "recovery": copy.deepcopy(recovery_info),
            "controller_stats": copy.deepcopy(ctl.stats),
            "fabric_stats": (copy.deepcopy(ctl.fabric.stats)
                             if ctl.fabric is not None else None),
            "arena_state": use_arena,
            "maint_seconds_per_iter": maint_seconds / max_iters,
            "kappa_perturbed": iterations_to_eps(losses, model.eps),
            "kappa_clean": iterations_to_eps(clean_losses, model.eps)}


def run_with_trace(model: IterativeModel, policy: CheckpointPolicy, *,
                   fabric, max_iters: int = 400, seed: int = 0,
                   mtbf: Optional[dict] = None, trace=None,
                   heal_after: Optional[int] = None,
                   clean_losses: Optional[list] = None,
                   store=None, arena_state: bool = True,
                   recorder=None) -> dict:
    """Degraded-mode soak on one classic model: a multi-event failure trace
    (explicit ``trace`` list of :class:`FailureEvent`, or MTBF-sampled from
    ``mtbf``), recovered through the fabric's tier planner.

    Unlike ``run_with_failure``, failed domains stay *dead* in the fabric's
    cluster view between events — the second hit lands on a degraded
    topology. With ``FabricConfig(elastic=True)`` the placement engine
    re-homes/re-seeds/re-stripes after every event so the next failure still
    finds live redundancy tiers; with ``elastic=False`` ("recover in place
    and pray the host returns") later events fall through to the expensive
    RUNNING_CKPT/DISK tiers. ``heal_after`` re-admits a failed domain that
    many iterations after its event.

    Returns the loss trajectory, the per-event recovery diagnostics, and
    the paper's §5 empirical iteration cost.
    """
    if fabric is None:
        raise ValueError("run_with_trace needs a fabric")
    key = _keys(seed)
    rec = recorder if recorder is not None else NULL_RECORDER
    p = model.init(jax.random.PRNGKey(1))
    ctl = FTController(p, policy, norm_aux=model.norm_aux, store=store,
                       rng=jax.random.PRNGKey(seed + 13),
                       colocate=model.colocate, fabric=fabric,
                       recorder=recorder)
    if trace is None:
        if mtbf is None:
            raise ValueError("pass an explicit trace or mtbf means")
        trace = ctl.fabric.domains.sample_failure_trace(
            np.random.default_rng(seed + 5), max_iters, mtbf)
    events_at: dict[int, list] = {}
    for ev in trace:
        events_at.setdefault(max(1, min(ev.step, max_iters)), []).append(ev)
    use_arena = arena_state and ctl.arena_ready
    heal_at: dict[int, list] = {}
    events_out: list[dict] = []
    losses = []
    redundancy_full: list[bool] = []
    for i in range(1, max_iters + 1):
        p = model.step(p, key(i), i)
        # arena-native controller interface: one shared pack feeds both
        # maintain and the save (own_live: the pack IS the replica),
        # skipped on iterations where neither reads the live value
        # (see run_with_failure)
        packed = use_arena and ctl.live_value_needed(i)
        live = ctl.pack_live(p, account=True) if packed else p
        ctl.maintain(i, live, own_live=packed)
        ctl.maybe_checkpoint(i, live, own_live=packed)
        evs = events_at.pop(i, [])
        if len(evs) > 1:
            # same-step events are one correlated multi-domain loss:
            # recover the union in one tier-planned pass (multi-erasure)
            names = ",".join(f"{e.kind}:{e.index}" for e in evs)
            with rec.span("recovery", step=i, domain=names):
                p, info = ctl.on_domain_events(
                    p, [(e.kind, e.index) for e in evs], step=i)
            info["step"] = i
            events_out.append(info)
            if heal_after is not None:
                applied = {(a["kind"], a["index"])
                           for a in info.get("events", [])}
                for ev in evs:
                    if (ev.kind, ev.index) in applied:
                        heal_at.setdefault(i + heal_after, []).append(ev)
        elif evs:
            ev = evs[0]
            with rec.span("recovery", step=i,
                          domain=f"{ev.kind}:{ev.index}"):
                p, info = ctl.on_domain_event(p, ev.kind, ev.index, step=i)
            info["step"] = i
            events_out.append(info)
            if heal_after is not None and not info.get("skipped"):
                heal_at.setdefault(i + heal_after, []).append(ev)
        for ev in heal_at.pop(i, []):
            with rec.span("heal", step=i, domain=f"{ev.kind}:{ev.index}"):
                ctl.heal_domain(ev.kind, ev.index, p, step=i)
        # placement-health flag AFTER this step's events/heals — the
        # availability report turns these into time-to-full-redundancy
        redundancy_full.append(ctl.fabric.redundancy_state()["full"])
        losses.append(float(model.loss(p)))
    # settle the last async epoch before the stats snapshot (no-op sync)
    ctl.fabric.block_until_maintained()
    if clean_losses is None:
        clean_losses = run_clean(model, max_iters, seed)["losses"]
    cost = empirical_iteration_cost(losses, clean_losses, model.eps)
    from repro.fabric.availability import summarize_availability
    # snapshot the live stats/events (see run_with_failure): the
    # controller keeps appending to ctl.stats["events"] if reused
    return {"losses": losses, "iteration_cost": cost,
            "events": copy.deepcopy(events_out),
            "controller_stats": copy.deepcopy(ctl.stats),
            "fabric_stats": copy.deepcopy(ctl.fabric.stats),
            "availability": summarize_availability(events_out,
                                                   redundancy_full),
            "kappa_perturbed": iterations_to_eps(losses, model.eps),
            "kappa_clean": iterations_to_eps(clean_losses, model.eps)}
