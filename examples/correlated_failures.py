"""Correlated failures vs the tiered checkpoint fabric, end to end.

The paper's SCAR assumes blocks die uniformly at random; real clusters lose
whole hosts and racks. This example builds a device→host→rack failure-domain
map over an MLR training job, kills one whole host, and shows how the
fabric resolves every lost block to the cheapest surviving redundancy tier
— peer replicas and XOR parity recover *live* values (zero perturbation),
while checkpoint-only SCAR pays the running checkpoint's staleness.

Run:  PYTHONPATH=src python examples/correlated_failures.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.policy import CheckpointPolicy, RecoveryMode, SelectionStrategy
from repro.fabric import FabricConfig, FailureDomainMap, FailureEvent
from repro.models.classic import make_model
from repro.training import run_clean, run_with_failure, run_with_trace

VARIANTS = (
    ("checkpoint-only", dict(replicate=False, parity=False)),
    ("parity (1/g mem)", dict(replicate=False, parity=True)),
    ("replicas+parity", dict(replicate=True, parity=True)),
)


def main():
    dm = FailureDomainMap(n_devices=8, devices_per_host=2, hosts_per_rack=2)
    print("== topology:", f"{dm.n_devices} devices / {dm.n_hosts} hosts /",
          f"{dm.n_racks} racks")
    trace = dm.sample_failure_trace(np.random.default_rng(7), 2000,
                                    {"device": 300.0, "host": 600.0,
                                     "rack": 1500.0})
    kinds = {k: sum(e.kind == k for e in trace)
             for k in ("device", "host", "rack")}
    print("   MTBF trace over 2000 steps:", kinds, "\n")

    model = make_model("mlr", n=600, dim=64, n_classes=5, batch=200)
    clean = run_clean(model, 120)["losses"]
    policy = CheckpointPolicy(fraction=0.25, full_interval=8,
                              strategy=SelectionStrategy.ROUND_ROBIN,
                              recovery=RecoveryMode.PARTIAL,
                              block_rows=model.block_rows)

    print("== one whole host dies at iteration 15 (SCAR r=0.25 checkpoints)")
    print(f"{'fabric variant':18s} {'applied ||δ'+chr(39)+'||²':>14s} "
          f"{'ι (rework iters)':>17s}  recovery tiers")
    for name, kw in VARIANTS:
        costs, sq, tiers = [], [], None
        for seed in range(4):
            r = run_with_failure(
                model, policy, fail_iter=15, fail_fraction=0.5,
                max_iters=120, seed=seed, clean_losses=clean,
                fabric=FabricConfig(n_devices=8, devices_per_host=2,
                                    hosts_per_rack=2, **kw),
                fail_domain="host")
            costs.append(max(r["iteration_cost"], 0))
            sq.append(r["recovery"]["applied_sq"])
            tiers = {k: v for k, v in r["recovery"]["tier_counts"].items()
                     if v and k != "SURVIVOR"}
        print(f"{name:18s} {np.mean(sq):>14.3e} {np.mean(costs):>17.1f}  "
              f"{tiers}")

    print("\nReplica/parity tiers restore live values — the Thm 4.1 "
          "perturbation vanishes,\nso the failure costs (near) zero rework "
          "iterations; checkpoint-only SCAR pays\nthe running checkpoint's "
          "staleness on every correlated loss.")

    # -- degraded-mode soak: hosts die and STAY dead -----------------------
    print("\n== degraded-mode soak: 3 hosts die over a trace and stay dead")
    soak_trace = [FailureEvent(step=15, kind="host", index=0),
                  FailureEvent(step=45, kind="host", index=1),
                  FailureEvent(step=75, kind="host", index=2)]
    print(f"{'placement policy':20s} {'ι (rework)':>11s} "
          f"{'Σ||δ'+chr(39)+'||²':>11s}  per-event recovery tiers")
    for name, kw in (("recover-in-place", dict(elastic=False)),
                     ("elastic re-homing", dict(elastic=True))):
        r = run_with_trace(
            model, policy, max_iters=120, seed=0, clean_losses=clean,
            trace=soak_trace,
            fabric=FabricConfig(n_devices=8, devices_per_host=2,
                                hosts_per_rack=2, **kw))
        per_event = [
            {k: v for k, v in e["tier_counts"].items()
             if v and k != "SURVIVOR"}
            for e in r["events"] if not e.get("skipped")]
        sq = sum(e["applied_sq"] for e in r["events"])
        print(f"{name:20s} {max(r['iteration_cost'], 0):>11.1f} "
              f"{sq:>11.3e}  {per_event}")

    print("\nRecover-in-place leaves replicas and parity homes pointing at "
          "dead devices, so\nlater failures fall through to RUNNING_CKPT/"
          "DISK; the elastic engine re-homes\nblocks, re-seeds replicas, and "
          "re-stripes parity after every loss — each new\nfailure still "
          "finds live redundancy and training continues degraded at "
          "‖δ′‖²≈0.")

    # -- multi-erasure: two hosts die the SAME step ------------------------
    print("\n== multi-erasure: hosts 0 and 2 (one per rack) die at the "
          "same step")
    double = [FailureEvent(step=15, kind="host", index=0),
              FailureEvent(step=15, kind="host", index=2)]
    print(f"{'erasure code':18s} {'ι (rework)':>11s} "
          f"{'||δ'+chr(39)+'||²':>11s} {'fallbacks':>10s}  recovery tiers")
    for name, kw in (("XOR parity (m=1)", dict()),
                     ("RS(k, 2)  (m=2)", dict(rs_parity=2))):
        r = run_with_trace(
            model, policy, max_iters=120, seed=0, clean_losses=clean,
            trace=double,
            fabric=FabricConfig(n_devices=8, devices_per_host=2,
                                hosts_per_rack=2, elastic=True, **kw))
        ev = next(e for e in r["events"] if not e.get("skipped"))
        tiers = {k: v for k, v in ev["tier_counts"].items()
                 if v and k != "SURVIVOR"}
        print(f"{name:18s} {max(r['iteration_cost'], 0):>11.1f} "
              f"{ev['applied_sq']:>11.3e} "
              f"{len(ev.get('tier_fallbacks', [])):>10d}  {tiers}")

    print("\nLosing one host per rack in a single step erases some blocks' "
          "primary AND\nanti-affine replica at once. The XOR code absorbs "
          "one erasure per parity\ngroup — the rest fall back to the "
          "running checkpoint (each fallback is an\nexplained "
          "`tier_fallback` event, never silent) and the failure is priced "
          "at\nthe checkpoint's staleness. RS(k, 2) holds two GF(256) "
          "parity rows on\nhost-disjoint homes per group, decodes both "
          "erasures bit-exactly, and the\nsame double loss costs "
          "‖δ′‖² = 0 — no rework iterations owed.")


if __name__ == "__main__":
    main()
