"""Reed-Solomon RS(k, m) erasure codec + CodeNet-style integrity tier.

Generalizes :class:`~repro.fabric.parity.ParityCodec` from one XOR
parity block per group to ``m`` GF(256) parity rows over the *same*
striping, frames, and ARENA_TILE-aligned :class:`FrameLayout` — so the
arena sweep's snapshot lands bit-exactly in coded frames and every
recovery path (PyTree pack or arena gather) is shared with the XOR tier.

Three capabilities the XOR tier lacks:

- **Multi-erasure recovery**: any ≤ m simultaneous member losses per
  group decode bit-exactly (Cauchy coefficients: every square submatrix
  is nonsingular, so any erasure pattern against any surviving parity
  rows is solvable). A simultaneous host + replica-domain loss that
  previously fell back to RUNNING_CKPT (paying checkpoint staleness in
  the ledger) recovers at ‖δ′‖² ≈ 0.
- **Silent-error detection**: recomputing the parity rows over the
  replica arena and XOR-ing against the stored rows yields per-group
  syndromes that are all-zero iff the coded redundancy state is
  uncorrupted — a failure class (soft errors) the fabric otherwise
  cannot see.
- **Localization + correction** (m ≥ 2): parity row 0 is normalized to
  all-ones, so for a single corrupted member the row-0 syndrome *is*
  the error pattern and row r is that pattern scaled by the member's
  coefficient — matching the scaling fingerprints identifies the
  member, and XOR-ing the pattern back out corrects it in place. A
  single nonzero row with the rest zero fingerprints a corrupted
  stored parity row instead.

Row 0's all-ones normalization also makes RS(k, 1) encode bit-identical
to the XOR tier's parity blocks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocks import BlockPartition
from repro.fabric.parity import ParityCodec, pack_frames
from repro.fabric.placement import ClusterView, rs_parity_homes
from repro.kernels.gf256_mac.ops import rs_decode, rs_encode
from repro.kernels.gf256_mac.tables import (gf_scale_words_np,
                                            rs_coefficients,
                                            rs_decode_weights)


class RSCodec(ParityCodec):
    """RS(k, m) over GF(256) on the shared grouped-frames layout.

    ``group_size`` is k (data members per group, subject to the same
    topology clamp and tail-fold as the XOR codec); ``n_parity`` is m.
    The fused arena sweep only emits XOR parity, so this codec re-encodes
    its rows from the snapshot arena each maintenance
    (``needs_arena_encode``) — m extra MAC passes over the frame bytes.
    """

    needs_arena_encode = True
    supports_integrity = True

    def __init__(self, partition: BlockPartition, view: ClusterView,
                 group_size: int = 4, n_parity: int = 2,
                 use_pallas: bool | None = None):
        if n_parity < 1:
            raise ValueError("rs n_parity must be >= 1")
        self.n_parity = int(n_parity)
        self._arena_encode_fn = None
        self._arena_encode_layout = None
        super().__init__(partition, view, group_size, use_pallas)

    def _build(self) -> None:
        self._stripe()
        self.parity_homes = rs_parity_homes(self.members, self.view,
                                            self.n_parity)
        width = self.members.shape[1]
        self.coeff = rs_coefficients(width, self.n_parity)  # (m, width)
        # padding members carry coefficient 0 (dropped from the fold)
        self._coeff_rows = np.where(self.valid[None],
                                    self.coeff[:, None, :],
                                    0).astype(np.int32)  # (m, n_groups, g)
        self._build_encode()

    def _build_encode(self) -> None:
        gather = jnp.asarray(self._gather_ids)
        coeff_rows = jnp.asarray(self._coeff_rows)

        def _encode(values):
            frames = pack_frames(values, self.partition, self.layout)
            return rs_encode(frames[gather], coeff_rows,
                             use_pallas=self.use_pallas)
        self._encode_fn = jax.jit(_encode)
        self._arena_encode_fn = None
        self._arena_encode_layout = None

    # -- arena encode / integrity -------------------------------------------

    def _arena_encode(self, arena: jnp.ndarray, arena_layout) -> jnp.ndarray:
        """All parity rows recomputed from a snapshot arena:
        (n_groups, m, E) int32."""
        gather_idx = self._ensure_arena_gather(arena_layout)
        if self._arena_encode_fn is None \
                or self._arena_encode_layout is not arena_layout:
            from repro.core.arena import frames_from_arena
            gi = gather_idx  # numpy: frames_from_arena masks host-side
            gids = jnp.asarray(self._gather_ids)
            coeff_rows = jnp.asarray(self._coeff_rows)

            def _enc(buf):
                frames = frames_from_arena(buf, gi)
                return rs_encode(frames[gids], coeff_rows,
                                 use_pallas=self.use_pallas)
            self._arena_encode_fn = jax.jit(_enc)
            self._arena_encode_layout = arena_layout
        return self._arena_encode_fn(arena)

    def encode_from_arena(self, step: int, arena: jnp.ndarray,
                          arena_layout) -> None:
        """Encode from the maintenance sweep's snapshot arena — the same
        buffer the replica tier stores, so ``refreshed_step ==
        encoded_step`` holds and the arena recovery route stays open."""
        self.parity = self._arena_encode(arena, arena_layout)
        self.encoded_step = int(step)

    def syndromes_from_arena(self, arena: jnp.ndarray,
                             arena_layout) -> jnp.ndarray:
        """(n_groups, m, E) syndromes of the coded redundancy state: the
        parity recomputed from the replica arena XOR the stored parity.
        All-zero unless a silent error corrupted the arena snapshot or a
        stored parity row since encode."""
        assert self.parity is not None, "no parity encoded yet"
        return self._arena_encode(arena, arena_layout) ^ self.parity

    def localize_corruption(self, syndromes) -> list[dict]:
        """Turn nonzero syndromes into per-group corruption reports.

        Each report carries ``kind`` ("member" or "parity"), the guilty
        ``block``/``member`` slot or parity ``row`` when localization
        succeeds, ``localized``, and the raw error pattern ``delta``
        (the row-0 syndrome) that :meth:`correct_in_arena` XORs back
        out. m = 1 degenerates to detect-only (no fingerprint to match).
        """
        synd = np.asarray(syndromes)
        reports: list[dict] = []
        for j in np.nonzero(synd.any(axis=(1, 2)))[0]:
            s = synd[j]                       # (m, E)
            rows_nz = np.nonzero(s.any(axis=1))[0]
            if self.n_parity >= 2 and rows_nz.size == 1:
                # a member error perturbs every row (all coefficients are
                # nonzero), so a single nonzero row is the stored parity
                # row itself gone bad
                r = int(rows_nz[0])
                reports.append(dict(group=int(j), kind="parity", row=r,
                                    member=-1, block=-1, localized=True,
                                    delta=s[r]))
                continue
            delta = s[0]  # row 0 is all-ones: the raw error pattern
            cand = []
            if self.n_parity >= 2:
                for slot in np.nonzero(self.valid[j])[0]:
                    if all(np.array_equal(
                            gf_scale_words_np(delta,
                                              int(self.coeff[r, slot])),
                            s[r]) for r in range(1, self.n_parity)):
                        cand.append(int(slot))
            if len(cand) == 1:
                slot = cand[0]
                reports.append(dict(group=int(j), kind="member", row=-1,
                                    member=slot,
                                    block=int(self.members[j, slot]),
                                    localized=True, delta=delta))
            else:
                # zero or multiple fingerprints match: multi-symbol or
                # multi-member corruption — detected, not localized
                reports.append(dict(group=int(j), kind="member", row=-1,
                                    member=-1, block=-1, localized=False,
                                    delta=delta))
        return reports

    def correct_in_arena(self, arena: jnp.ndarray,
                         report: dict) -> jnp.ndarray:
        """Apply one localized correction: XOR the error pattern out of
        the replica arena (member corruption; returns the corrected
        arena) or out of the stored parity row (parity corruption;
        returns the arena unchanged)."""
        delta = np.asarray(report["delta"])
        if report["kind"] == "parity":
            assert self.parity is not None
            j, r = report["group"], report["row"]
            cur = np.asarray(self.parity[j, r])
            self.parity = self.parity.at[j, r].set(
                jnp.asarray(cur ^ delta))
            return arena
        assert report["localized"] and report["block"] >= 0
        gather = np.asarray(self._arena_gather)[report["block"]]
        cols = np.nonzero(delta)[0]
        cols = cols[gather[cols] >= 0]
        if cols.size == 0:
            return arena
        idx = jnp.asarray(gather[cols])
        bits = np.asarray(arena[idx]).view(np.int32) ^ delta[cols]
        return arena.at[idx].set(jnp.asarray(bits.view(np.float32)))

    # -- recovery ------------------------------------------------------------

    def _reconstruct_frames(self, frames: jnp.ndarray,
                            recover_mask: np.ndarray,
                            available_mask: np.ndarray) -> jnp.ndarray:
        assert self.parity is not None
        recover = np.asarray(recover_mask, bool)
        available = np.asarray(available_mask, bool)
        width = self.members.shape[1]
        m = self.n_parity
        member_unavail = self.valid & ~available[self._gather_ids]
        member_recover = self.valid & recover[self._gather_ids]
        # host-solved decode weights per targeted group: one (width + m)
        # coefficient row per erased ordinal, folding survivors and
        # parity rows in a single MAC
        weights = np.zeros((self.n_groups, m, width + m), np.int32)
        ordinal_of = np.full((self.n_groups, width), -1, np.int32)
        for j in np.nonzero(member_recover.any(axis=1))[0]:
            erased = np.nonzero(member_unavail[j])[0]
            if erased.size == 0 or erased.size > m:
                continue  # planner never routes such a group here
            survivors = np.nonzero(self.valid[j] & ~member_unavail[j])[0]
            # prefer parity rows homed on currently-alive devices; the
            # planner already guaranteed at least ``erased.size`` of them
            rows_alive = self.view.alive[self.parity_homes[j]]
            rows = np.concatenate([np.nonzero(rows_alive)[0],
                                   np.nonzero(~rows_alive)[0]])
            weights[j, :erased.size] = rs_decode_weights(
                self.coeff, erased, survivors, rows)
            for q, slot in enumerate(erased):
                ordinal_of[j, slot] = q
        grouped = frames[jnp.asarray(self._gather_ids)]
        ext = jnp.concatenate([grouped, self.parity], axis=1)
        out = jnp.zeros_like(frames)
        for q in range(m):  # one MAC dispatch per erased ordinal
            wq = weights[:, q, :]
            if not wq.any():
                continue
            rec = rs_decode(ext, jnp.asarray(wq),
                            use_pallas=self.use_pallas)
            gids, slots = np.nonzero(member_recover
                                     & (ordinal_of == q))
            if gids.size:
                ids = self.members[gids, slots]
                out = out.at[jnp.asarray(ids)].set(
                    rec[jnp.asarray(gids)])
        return out
