"""Minimal functional optimizers (optax-free: the container is offline and
the framework owns its substrate per the brief).

Each optimizer is ``init(params) -> state`` + ``update(grads, state, params)
-> (new_params, new_state)``. Optimizer state tensors mirror the parameter
pytree so SCAR block partitioning / sharding specs apply unchanged. Adam
moments are fp32 regardless of param dtype (TPU practice).

**Arena-native apply**: every optimizer here is elementwise, so the same
``update`` applies unchanged to the flat parameter arena
(:mod:`repro.core.arena`) — the arena is a one-leaf pytree and the moment
buffers become flat mirrors of it. :func:`arena_apply` wraps that call
with the one step the flat form can't express on its own: the per-leaf
dtype round trip (the arena stores the f32 *image* of the leaf-dtype
value, so non-f32 segments must pass through their dtype after the f32
update, exactly like the tree path's ``.astype(p.dtype)``).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: PyTree        # first moment (or momentum buffer); None-like zeros for sgd
    nu: PyTree        # second moment; zeros for sgd/momentum


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], OptState]
    update: Callable[[PyTree, OptState, PyTree], tuple[PyTree, OptState]]
    name: str = "opt"


def _zeros_like_f32(params):
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), params)


def sgd(lr: float) -> Optimizer:
    def init(params):
        return OptState(jnp.zeros((), jnp.int32), (), ())

    def update(grads, state, params):
        new = jax.tree_util.tree_map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new, OptState(state.step + 1, (), ())
    return Optimizer(init, update, "sgd")


def momentum(lr: float, beta: float = 0.9) -> Optimizer:
    def init(params):
        return OptState(jnp.zeros((), jnp.int32), _zeros_like_f32(params), ())

    def update(grads, state, params):
        mu = jax.tree_util.tree_map(
            lambda m, g: beta * m + g.astype(jnp.float32), state.mu, grads)
        new = jax.tree_util.tree_map(
            lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
            params, mu)
        return new, OptState(state.step + 1, mu, ())
    return Optimizer(init, update, "momentum")


def adam(lr: float, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, moment_dtype=jnp.float32) -> Optimizer:
    return _adam_like(lr, b1, b2, eps, wd=0.0, name="adam",
                      moment_dtype=moment_dtype)


def adamw(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          wd: float = 0.01, moment_dtype=jnp.float32) -> Optimizer:
    # moment_dtype=jnp.bfloat16 halves optimizer-state HBM -- the
    # production lever for the largest (400B-class) architectures.
    return _adam_like(lr, b1, b2, eps, wd=wd, name="adamw",
                      moment_dtype=moment_dtype)


def _adam_like(lr, b1, b2, eps, wd, name, moment_dtype=jnp.float32) -> Optimizer:
    def _zeros_like_m(params):
        return jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, moment_dtype), params)

    def init(params):
        return OptState(jnp.zeros((), jnp.int32),
                        _zeros_like_m(params), _zeros_like_m(params))

    def update(grads, state, params):
        t = state.step + 1
        tf = t.astype(jnp.float32)
        mu = jax.tree_util.tree_map(
            lambda m, g: (b1 * m.astype(jnp.float32)
                          + (1 - b1) * g.astype(jnp.float32)
                          ).astype(moment_dtype), state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: (b2 * v.astype(jnp.float32)
                          + (1 - b2) * jnp.square(g.astype(jnp.float32))
                          ).astype(moment_dtype), state.nu, grads)
        bc1 = 1 - b1 ** tf
        bc2 = 1 - b2 ** tf

        def upd(p, m, v):
            m, v = m.astype(jnp.float32), v.astype(jnp.float32)
            step = lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            out = p.astype(jnp.float32) - step
            if wd:
                out = out - lr * wd * p.astype(jnp.float32)
            return out.astype(p.dtype)

        new = jax.tree_util.tree_map(upd, params, mu, nu)
        return new, OptState(t, mu, nu)
    return Optimizer(init, update, name)


# ---------------------------------------------------------------------------
# Arena-native apply (flat parameter arena as the live representation)
# ---------------------------------------------------------------------------

def arena_apply(optimizer: Optimizer, grads: jnp.ndarray, state: OptState,
                arena: jnp.ndarray, layout) -> tuple[jnp.ndarray, OptState]:
    """One optimizer step over the flat parameter arena.

    ``arena``/``grads`` are ``(total_words,)`` f32 buffers laid out by
    ``layout`` (:class:`repro.core.arena.ArenaLayout`); ``state``'s moment
    buffers are flat mirrors (``optimizer.init(arena)``). The update is
    the optimizer's own elementwise math — bit-identical to the per-leaf
    tree apply — followed by a dtype round trip on non-f32 leaves'
    segments so the arena keeps holding the f32 image of the leaf-dtype
    value (pack convention, invariant I3). Pad words stay zero: zero
    grads give zero moments and a zero step, and weight decay of 0 is 0
    (invariant I4), so no masking pass is needed.
    """
    new_arena, new_state = optimizer.update(grads, state, arena)
    f32 = np.dtype(np.float32)
    for li, leaf in enumerate(layout.partition.leaves):
        if np.dtype(leaf.dtype) == f32:
            continue
        off = layout.leaf_offset[li]
        n = layout.seg_words[li] * leaf.n_blocks
        seg = jax.lax.dynamic_slice(new_arena, (off,), (n,))
        seg = seg.astype(leaf.dtype).astype(jnp.float32)
        new_arena = jax.lax.dynamic_update_slice(new_arena, seg, (off,))
    return new_arena, new_state
