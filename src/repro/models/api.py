"""Unified model API across families.

``get_model(cfg)`` returns a ``ModelOps`` bundle:

- ``init_params(rng, cfg)``                       -> params pytree
- ``train_loss(params, batch, cfg, ctx, **kw)``   -> scalar
- ``init_cache(cfg, batch_size, seq_len, ctx)``   -> serving state
- ``prefill(params, batch, cfg, ctx)``            -> (logits, state)
- ``decode_step(params, state, tokens, cfg, ctx)``-> (logits, state)
- ``make_batch(cfg, batch, seq, rng|specs)``      handled by repro.data

Decode shapes in the brief lower ``decode_step`` with a cache of
``seq_len``; the cache geometry (ring vs linear) is decided by
``serve_cache_len``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, hybrid, ssm, transformer
from repro.sharding.partition import DistContext

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ModelOps:
    init_params: Callable
    train_loss: Callable
    init_cache: Callable          # (cfg, batch, seq_len, ctx) -> state
    prefill: Callable
    decode_step: Callable         # (params, state, tokens, cfg, ctx) -> (logits, state)
    supports_long_context: bool   # sub-quadratic serve path exists


def serve_cache_len(cfg: ModelConfig, seq_len: int) -> int:
    """Slots in the dense KV cache for a decode at context ``seq_len``."""
    if cfg.sliding_window and seq_len > cfg.sliding_window:
        return cfg.sliding_window
    return seq_len


def _transformer_ops(cfg: ModelConfig) -> ModelOps:
    def init_cache(cfg, batch, seq_len, ctx):
        spec = transformer.cache_spec(cfg, seq_len, use_window=True)
        return transformer.init_cache(None, cfg, batch, spec, ctx)

    def prefill(params, batch, cfg, ctx, *, slack: int = 64):
        S = batch["tokens"].shape[1]
        if cfg.family == "vlm" and "patches" in batch:
            S += cfg.n_patches          # image prefix occupies cache slots
        # slack: empty slots for tokens generated after the prefill
        spec = transformer.cache_spec(cfg, S + slack, use_window=False)
        spec = transformer.CacheSpec(cache_len=spec.cache_len, ring=spec.ring)
        return transformer.prefill(params, batch, cfg, ctx, spec)

    def decode_step(params, cache, tokens, cfg, ctx):
        # geometry is static: infer ring from cache length vs window
        cache_len = cache["k"].shape[2]
        spec = transformer.CacheSpec(
            cache_len=cache_len,
            ring=bool(cfg.sliding_window) and cache_len == cfg.sliding_window)
        return transformer.decode_step(params, cache, tokens, cfg, ctx, spec)

    return ModelOps(
        init_params=transformer.init_params,
        train_loss=transformer.train_loss,
        init_cache=init_cache,
        prefill=prefill,
        decode_step=decode_step,
        supports_long_context=bool(cfg.sliding_window),
    )


def _ssm_ops(cfg: ModelConfig) -> ModelOps:
    return ModelOps(
        init_params=ssm.init_params,
        train_loss=ssm.train_loss,
        init_cache=lambda cfg, batch, seq_len, ctx: ssm.init_state(cfg, batch, ctx),
        prefill=ssm.prefill,
        decode_step=ssm.decode_step,
        supports_long_context=True,
    )


def _hybrid_ops(cfg: ModelConfig) -> ModelOps:
    return ModelOps(
        init_params=hybrid.init_params,
        train_loss=hybrid.train_loss,
        init_cache=lambda cfg, batch, seq_len, ctx: hybrid.init_state(
            cfg, batch, seq_len, ctx),
        prefill=hybrid.prefill,
        decode_step=hybrid.decode_step,
        supports_long_context=True,
    )


def _encdec_ops(cfg: ModelConfig) -> ModelOps:
    return ModelOps(
        init_params=encdec.init_params,
        train_loss=encdec.train_loss,
        init_cache=lambda cfg, batch, seq_len, ctx: encdec.init_cache(
            cfg, batch, seq_len, ctx),
        prefill=encdec.prefill,
        decode_step=encdec.decode_step,
        supports_long_context=False,   # 30 s enc-dec format (DESIGN.md skip)
    )


def get_model(cfg: ModelConfig) -> ModelOps:
    if cfg.family in ("dense", "moe", "vlm"):
        return _transformer_ops(cfg)
    if cfg.family == "ssm":
        return _ssm_ops(cfg)
    if cfg.family == "hybrid":
        return _hybrid_ops(cfg)
    if cfg.family == "audio":
        return _encdec_ops(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")
