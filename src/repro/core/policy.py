"""Checkpoint/recovery policy configuration (paper §4).

``CheckpointPolicy`` is the single object users pass to the trainer to turn
SCAR on. It encodes the paper's knobs:

- ``fraction r``       — fraction of parameter blocks saved per partial
                         checkpoint (paper §4.2; r = 1 is the traditional
                         full checkpoint).
- ``full_interval C``  — the *budget-equivalent* full-checkpoint interval;
                         partial checkpoints fire every ``max(1, round(rC))``
                         iterations so bytes/iteration match the full
                         strategy (paper §4.2).
- ``strategy``         — PRIORITY (largest distance since last save),
                         ROUND_ROBIN, RANDOM (paper §5.4 baselines).
- ``recovery``         — PARTIAL (paper §4.1) or FULL (traditional).
- ``norm``             — name of the block norm used for priority scoring
                         ("l2" default; "scaled_tv" for distribution-valued
                         parameters, paper Appendix C).
"""
from __future__ import annotations

import dataclasses
import enum


class SelectionStrategy(str, enum.Enum):
    PRIORITY = "priority"
    ROUND_ROBIN = "round"
    RANDOM = "random"


class RecoveryMode(str, enum.Enum):
    PARTIAL = "partial"
    FULL = "full"


@dataclasses.dataclass(frozen=True)
class CheckpointPolicy:
    fraction: float = 1.0          # r
    full_interval: int = 4         # C (iterations between full-budget ckpts)
    strategy: SelectionStrategy = SelectionStrategy.PRIORITY
    recovery: RecoveryMode = RecoveryMode.PARTIAL
    norm: str = "l2"
    block_rows: int = 128          # block granularity (TPU-aligned)
    persist_dir: str | None = None  # on-disk mirror (None = in-memory only)
    async_persist: bool = True     # paper §4.3: resume as soon as cache updated

    def __post_init__(self):
        if not (0.0 < self.fraction <= 1.0):
            raise ValueError(f"fraction r must be in (0, 1], got {self.fraction}")
        if self.full_interval < 1:
            raise ValueError("full_interval C must be >= 1")
        if self.block_rows < 1:
            raise ValueError("block_rows must be >= 1")

    @property
    def partial_interval(self) -> int:
        """rC rounded to at least one iteration (paper §4.2)."""
        return max(1, round(self.fraction * self.full_interval))

    @classmethod
    def traditional(cls, interval: int = 4) -> "CheckpointPolicy":
        """The baseline the paper compares against: full checkpoints every C
        iterations, full recovery."""
        return cls(fraction=1.0, full_interval=interval,
                   strategy=SelectionStrategy.ROUND_ROBIN,
                   recovery=RecoveryMode.FULL)

    @classmethod
    def scar(cls, fraction: float = 0.125, interval: int = 8,
             norm: str = "l2") -> "CheckpointPolicy":
        """The paper's headline configuration: prioritized 1/8th checkpoints
        at 8× frequency + partial recovery (§5.4)."""
        return cls(fraction=fraction, full_interval=interval,
                   strategy=SelectionStrategy.PRIORITY,
                   recovery=RecoveryMode.PARTIAL, norm=norm)
