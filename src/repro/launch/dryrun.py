import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh).

MUST be run as its own process (``python -m repro.launch.dryrun``) — the
XLA_FLAGS line above must execute before any other jax import in the
process, which is why it is the first statement of this file.

For every combination this script:

1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
2. constructs ShapeDtypeStruct stand-ins for params / optimizer state /
   serving caches / input batch (``jax.eval_shape`` — no allocation),
3. lowers + compiles the appropriate step function
   (train_step for train_4k, prefill for prefill_32k, decode_step for
   decode_32k & long_500k),
4. records ``memory_analysis()`` (fits-per-device proof),
   ``cost_analysis()`` (FLOPs/bytes for §Roofline) and the collective
   bytes parsed from the compiled HLO.

Results stream to stdout and are appended as JSON to
``results/dryrun/<arch>__<shape>__<mesh>.json`` for the roofline report.
"""
import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_configs
from repro.data.synthetic import input_specs, shape_params
from repro.launch.mesh import make_production_mesh
from repro.models import get_model
from repro.models.api import serve_cache_len
from repro.optim.optimizers import adamw
from repro.sharding.partition import (batch_partition_specs, make_dist_ctx,
                                      named_shardings, param_partition_specs,
                                      state_partition_specs)
from repro.training.train_state import TrainState

SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

# long_500k needs a sub-quadratic serve path (see DESIGN.md):
#  - ssm / hybrid: recurrent state — native
#  - dense / moe / vlm: sliding-window ring cache variant (opt-in)
#  - audio (whisper): SKIPPED — 30 s enc-dec format, noted in DESIGN.md
def applicable(cfg, shape: str) -> tuple[bool, str]:
    if shape == "long_500k":
        if cfg.family == "audio":
            return False, "enc-dec 30s format: 500k decode out of family (DESIGN.md)"
        if cfg.family in ("dense", "moe", "vlm") and not cfg.sliding_window:
            return False, "full attention is quadratic at 500k"
    return True, ""


# ---------------------------------------------------------------------------
# HLO collective-bytes accounting
# ---------------------------------------------------------------------------

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shapes_bytes(type_str: str) -> int:
    """Sum bytes over all array types in an HLO result-type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-op-kind output bytes of every collective in the compiled HLO.

    Uses each collective instruction's *result* shape (bytes that cross
    the network per device, modulo algorithm factors — a consistent,
    comparable accounting for the roofline's collective term).
    """
    stats = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"^(?:ROOT )?[%\w.\-]+ = (.+?) (\S+)\(", ls)
        if not m:
            continue
        type_str, opname = m.groups()
        for kind in _COLLECTIVES:
            if opname.startswith(kind):
                stats[kind]["count"] += 1
                stats[kind]["bytes"] += _shapes_bytes(type_str)
                break
    stats["total_bytes"] = sum(v["bytes"] for k, v in stats.items()
                               if isinstance(v, dict))
    return stats


# ---------------------------------------------------------------------------
# lowering per shape kind
# ---------------------------------------------------------------------------

def lower_combination(arch: str, shape: str, mesh, *, window_for_long=True):
    """Returns (lowered, meta). Raises on sharding/compile errors."""
    cfg = get_config(arch)
    sp = shape_params(shape)
    ctx = make_dist_ctx(mesh, batch_shardable=(sp["batch"] >= 1 and
                                               sp["batch"] % _dp_total(mesh) == 0))
    if cfg.moe_no_fsdp:
        ctx = dataclasses.replace(ctx, expert_fsdp=False)
    ops = get_model(cfg)
    rng = jax.random.PRNGKey(0)

    p_shape = jax.eval_shape(lambda: ops.init_params(rng, cfg))
    p_shard = named_shardings(p_shape, ctx)

    batch_struct = input_specs(cfg, shape)
    b_specs = batch_partition_specs(batch_struct, ctx)
    b_shard = jax.tree_util.tree_map(
        lambda s: jax.NamedSharding(mesh, s), b_specs,
        is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))

    if sp["kind"] == "train":
        optimizer = adamw(3e-4, moment_dtype=jnp.dtype(cfg.opt_moment_dtype))
        state_shape = jax.eval_shape(
            lambda p: TrainState.create(p, optimizer), p_shape)
        # opt-state moments mirror param sharding; scalars replicated
        ps = param_partition_specs(p_shape, ctx)

        def opt_specs(tree):
            return jax.tree_util.tree_map(
                lambda leaf_spec: leaf_spec, ps)

        state_shardings = TrainState(
            params=p_shard,
            opt_state=type(state_shape.opt_state)(
                step=jax.NamedSharding(mesh, jax.sharding.PartitionSpec()),
                mu=jax.tree_util.tree_map(
                    lambda s: jax.NamedSharding(mesh, s), ps,
                    is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))
                if state_shape.opt_state.mu else (),
                nu=jax.tree_util.tree_map(
                    lambda s: jax.NamedSharding(mesh, s), ps,
                    is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))
                if state_shape.opt_state.nu else (),
            ),
            step=jax.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        )

        from repro.training.step import make_train_step
        train_step = make_train_step(ops, cfg, ctx, optimizer)

        fn = jax.jit(train_step,
                     in_shardings=(state_shardings, b_shard),
                     out_shardings=(state_shardings,
                                    jax.NamedSharding(mesh, jax.sharding.PartitionSpec())))
        with mesh:
            lowered = fn.lower(state_shape, batch_struct)
        return lowered, {"step": "train_step", "ctx": ctx, "cfg": cfg}

    if sp["kind"] == "prefill":
        def prefill(params, batch):
            return ops.prefill(params, batch, cfg, ctx)
        fn = jax.jit(prefill, in_shardings=(p_shard, b_shard))
        with mesh:
            lowered = fn.lower(p_shape, batch_struct)
        return lowered, {"step": "prefill", "ctx": ctx, "cfg": cfg}

    # decode: ONE new token against a cache of seq_len
    cache_len = serve_cache_len(cfg, sp["seq"])
    cache_shape = jax.eval_shape(
        lambda: ops.init_cache(cfg, sp["batch"], sp["seq"], ctx))
    c_specs = state_partition_specs(cache_shape, ctx)
    c_shard = jax.tree_util.tree_map(
        lambda s: jax.NamedSharding(mesh, s), c_specs,
        is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))

    def serve_step(params, cache, tokens):
        return ops.decode_step(params, cache, tokens, cfg, ctx)

    fn = jax.jit(serve_step,
                 in_shardings=(p_shard, c_shard, b_shard["tokens"]))
    with mesh:
        lowered = fn.lower(p_shape, cache_shape, batch_struct["tokens"])
    return lowered, {"step": "serve_step", "ctx": ctx, "cfg": cfg,
                     "cache_len": cache_len}


def _dp_total(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def run_one(arch: str, shape: str, multi_pod: bool, outdir: str,
            skip_memory: bool = False) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name, "ok": False}
    cfg = get_config(arch)
    ok, why = applicable(cfg, shape)
    if not ok:
        rec.update(skipped=True, reason=why, ok=True)
        if outdir:
            os.makedirs(outdir, exist_ok=True)
            with open(os.path.join(
                    outdir, f"{arch}__{shape}__{mesh_name}.json"), "w") as f:
                json.dump(rec, f, indent=1)
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        lowered, meta = lower_combination(arch, shape, mesh)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1
        cost = compiled.cost_analysis() or {}
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
        coll = collective_stats(hlo)
        rec.update(
            ok=True,
            step=meta["step"],
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            flops=float(cost.get("flops", 0.0)),
            bytes_accessed=float(cost.get("bytes accessed", 0.0)),
            collectives=coll,
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_bytes": getattr(
                    mem, "generated_code_size_in_bytes", None),
            },
        )
    except Exception as e:  # a failure here is a bug in the system
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    finally:
        jax.clear_caches()   # keep host RSS bounded across 80 compiles
    if outdir:
        os.makedirs(outdir, exist_ok=True)
        path = os.path.join(outdir, f"{arch}__{shape}__{mesh_name}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all", choices=SHAPES + ["all"])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--outdir", default="results/dryrun")
    args = ap.parse_args()

    archs = list_configs() if args.arch == "all" else [args.arch]
    shapes = SHAPES if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_one(arch, shape, mp, args.outdir)
                status = ("SKIP " + rec.get("reason", "") if rec.get("skipped")
                          else ("OK" if rec["ok"] else "FAIL " + rec.get("error", "")))
                print(f"[dryrun] {arch:28s} {shape:12s} {rec['mesh']:10s} "
                      f"{status}", flush=True)
                if rec["ok"] and not rec.get("skipped"):
                    print(f"         flops={rec['flops']:.3e} "
                          f"bytes={rec['bytes_accessed']:.3e} "
                          f"coll={rec['collectives']['total_bytes']:.3e} "
                          f"temp/device={rec['memory']['temp_bytes']} "
                          f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)",
                          flush=True)
                n_fail += 0 if rec["ok"] else 1
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
