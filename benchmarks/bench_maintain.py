"""Beyond-paper: fused single-pass redundancy maintenance vs the seed path.

The paper's §4.3 constant-budget property (a fraction-r partial checkpoint
writes the same bytes per C iterations as a full checkpoint) only holds if
the *maintenance* hot path is itself O(r)-ish: the seed implementation made
three-plus independent full passes per maintained step (replica tree copy,
pack-frames + member gather + XOR parity encode with two materialized
full-model staging buffers, and a third full read for PRIORITY scoring),
and the partial save rewrote every leaf through a full-size ``jnp.where``.

Measured here, on the reduced qwen2 config (quick mode shrinks repeats,
not the model):

  maint_sweep_*      — analytic HBM bytes + measured wall-clock per
                       maintenance step, fused single sweep vs the seed
                       three-pass path (both including PRIORITY scoring).
  maint_sweep_quant  — word-level quantized arena: the reduced model's
                       redundancy bytes per sweep (replica + parity +
                       staging) and analytic bytes/step with every leaf
                       cast to bf16, vs the f32 baseline of the same
                       shapes. REQUIRED: the bf16 run moves ≤ 0.55× the
                       f32 bytes (``quant_bytes_le_half_f32``) and the
                       all-f32 e2e run stays loss-bit-equal to the
                       PyTree path (``f32_loss_bit_equal`` — the word
                       arena is a bitwise no-op at f32).
  maint_arena_padding — tail packing: pad-word overhead of the default
                       (tail-packed) layout vs ``tail_pack=False``; the
                       ``padding_ratio`` gauge is RECORDED for the perf
                       trajectory.
  maint_partial_save — bytes moved into the running checkpoint by the
                       donation-based in-place save at r=0.125 vs the full
                       rewrite (the §4.3 property, now true in memory).
  maint_store_packed — packed append-mode shard mirror: bytes appended per
                       partial save, live index bytes, compaction reclaim.
  maint_kernel       — interpret-mode bit-exactness of the fused_maintain
                       kernel vs its jnp oracles.
  e2e_step_maintain  — full trainer pipeline (train step + maintain +
                       partial save) on the reduced LM, PyTree-pack path
                       vs arena-resident training state: accounted
                       bytes/step of the fault-tolerance machinery (the
                       resident path drops the per-step pack — exactly
                       the live tree's bytes fewer), maintenance
                       wall-clock, and bit-equality of the two paths'
                       training losses.
  maint_overlap_*    — sync vs async (double-buffered, deferred-fence)
                       every-step maintenance on the reduced LM:
                       clean-step overhead p50 per mode, bit-equality
                       of losses + running checkpoint, fraction of the
                       async sweep hidden under the next step's compute
                       (``overlap_efficiency``), and maintain-span /
                       train-step span overlap counts from the tracer.
  maint_sweep_sharded / tier_soak_elastic_mesh
                     — SPMD rows, measured in a forced-8-device CPU
                       subprocess (this process stays single-device so
                       the committed byte baselines hold): the sharded
                       arena loop's maintenance bytes/step vs the
                       PyTree-pack loop on the SAME (4, 2) mesh with
                       loss bit-equality, the ICI/DCN split of the
                       anti-affine replica transfer, and the host-loss →
                       mesh-shrink → heal → re-grow soak.
  maint_telemetry    — trace-driven soak with a live telemetry Recorder:
                       events.jsonl + Chrome trace + run report (written
                       under ``--telemetry-out`` when given), clean-step
                       overhead p50/p95 from the recorded histogram, and
                       a bit-exactness check of the perturbation ledger's
                       Thm-3.2/4.1 bounds against ``core/iteration_cost``.
                       The gated e2e rows above run with the default
                       NullRecorder — their bytes/step are untouched.
  tier_soak_multi_erasure
                     — RS(k, 2) vs XOR under a correlated two-host
                       same-step loss plus an injected in-arena bit
                       flip with an every-step integrity scrub: the RS
                       run must recover bit-exactly through the parity
                       tier (no checkpoint fallback, ‖δ′‖² = 0) and
                       detect/localize/correct the flip; the XOR
                       control's fallbacks and paid perturbation ride
                       along. Ledger artifact lands under
                       ``<telemetry-out>/multi_erasure``.

Bytes are the roofline currency here: on this CPU host the in-place save's
per-leaf eager dispatch overhead exceeds the memcpy it saves at the
reduced model size (the rewrite is one fused XLA program), so its
wall-clock row is honest-but-unflattering; the byte ratios are what
transfer to a bandwidth-bound accelerator.

Standalone: ``python -m benchmarks.bench_maintain [--quick]
[--out BENCH_maintain.json]`` (the CI smoke job's entry point).
"""
from __future__ import annotations

import argparse
import json
import math
import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, timed
from repro.checkpoint_io import ShardedCheckpointStore
from repro.configs import get_config
from repro.core.blocks import block_scores, partition_pytree
from repro.core.controller import FTController
from repro.core.norms import get_norm
from repro.core.policy import CheckpointPolicy
from repro.fabric import CheckpointFabric, FabricConfig
from repro.models import get_model


def _reduced_params():
    cfg = get_config("qwen2-1.5b", reduced=True)
    ops = get_model(cfg)
    return ops.init_params(jax.random.PRNGKey(0), cfg)


def _tree_nbytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))


def _drift(tree, scale=1e-2):
    return jax.tree_util.tree_map(lambda x: x + jnp.asarray(scale, x.dtype),
                                  tree)


def _kernel_check_rows(quick: bool) -> list[str]:
    from repro.core.arena import build_arena_layout, pack_arena
    from repro.fabric.domains import FailureDomainMap
    from repro.fabric.placement import ClusterView
    from repro.fabric.parity import ParityCodec
    from repro.kernels.fused_maintain.ops import (ArenaMaintainProgram,
                                                  make_fused_maintain_fn)
    from repro.sharding.partition import block_device_homes

    rng = np.random.default_rng(5)
    rows_n = 40 if quick else 200
    params = {"w": jnp.asarray(rng.normal(size=(rows_n, 24)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(7,)), jnp.float32)}
    ck = jax.tree_util.tree_map(
        lambda x: x + jnp.asarray(rng.normal(size=x.shape), x.dtype), params)
    part = partition_pytree(params, 16)
    view = ClusterView(FailureDomainMap(8, 2, 2),
                       block_device_homes(part, 8))
    codec = ParityCodec(part, view, group_size=3, use_pallas=False)
    codec.encode(0, params)
    fn = make_fused_maintain_fn(part, codec.layout, codec.group_of,
                                codec.n_groups, use_pallas=True,
                                interpret=True)
    (rep, sc, par), us = timed(
        lambda: jax.block_until_ready(fn(params, ck)), repeats=2)
    rep_ok = all(
        bool((np.asarray(a) == np.asarray(b)).all())
        for a, b in zip(jax.tree_util.tree_leaves(rep),
                        jax.tree_util.tree_leaves(params)))
    par_ok = bool((np.asarray(par) == np.asarray(codec.parity)).all())
    want_sc = np.asarray(block_scores(params, ck, part, get_norm("l2")))
    sc_ok = bool(np.allclose(np.asarray(sc), want_sc, rtol=1e-5, atol=1e-5))
    rows = [csv_row(
        "maint_kernel", us,
        f"replica_bit_exact={rep_ok};parity_bit_exact={par_ok};"
        f"scores_match={sc_ok};blocks={part.total_blocks}")]
    # interpret-mode arena sweep vs the same tree-path oracles: the whole
    # model in ONE Pallas dispatch
    layout = build_arena_layout(part)
    prog = ArenaMaintainProgram(part, layout, codec.layout, codec.group_of,
                                codec.n_groups, use_pallas=True,
                                interpret=True)
    z = pack_arena(ck, layout)
    (arep, asc, apar), aus = timed(
        lambda: jax.block_until_ready(prog(params, z)), repeats=2)
    arep_ok = bool((np.asarray(arep)
                    == np.asarray(pack_arena(params, layout))).all())
    apar_ok = bool((np.asarray(apar) == np.asarray(codec.parity)).all())
    asc_ok = bool(np.allclose(np.asarray(asc), want_sc,
                              rtol=1e-5, atol=1e-5))
    rows.append(csv_row(
        "maint_arena_kernel", aus,
        f"replica_bit_exact={arep_ok};parity_bit_exact={apar_ok};"
        f"scores_match={asc_ok};tiles={layout.n_tiles};dispatches=1"))
    return rows


def _sweep_rows(params, quick: bool) -> tuple[list[str], dict]:
    """Arena-resident vs arena-pack vs per-leaf-fused vs seed maintenance
    sweep: analytic bytes + wall clock. ``arena_resident`` feeds the
    sweep the live flat arena itself (the trainer default — pack-free,
    pure 2-read/1-write); ``arena`` packs a live tree first (one pack +
    ONE kernel dispatch); ``arena=False`` gives the per-leaf fused path
    (one dispatch per leaf), ``fused=False`` the seed three-pass path."""
    part = partition_pytree(params, 128)
    ck_values = _drift(params)
    reps = 2 if quick else 4
    out = {}
    rows = []
    variants = (("arena_resident", FabricConfig()),
                ("arena", FabricConfig()),
                ("fused", FabricConfig(arena=False)),
                ("seed", FabricConfig(fused=False)))
    for name, cfg in variants:
        fab = CheckpointFabric(part, cfg)
        ck_arg = ck_values
        live_arg = params
        if name in ("arena", "arena_resident"):
            from repro.core.arena import pack_arena
            pack = jax.jit(lambda t: pack_arena(t, fab.arena_layout))
            ck_arg = pack(ck_values)
            if name == "arena_resident":
                # arena-resident live state: the sweep's input IS the
                # flat arena — no pack inside the maintain at all
                live_arg = pack(params)
        fab.maintain(0, live_arg, ckpt_values=ck_arg, force=True)  # compile
        t0 = time.perf_counter()
        for i in range(1, reps + 1):
            fab.maintain(i, live_arg, ckpt_values=ck_arg, force=True)
            if name == "seed":
                # the seed path scores separately (the third full pass the
                # fused sweep folds in)
                jax.block_until_ready(
                    block_scores(params, ck_values, part, get_norm("l2")))
        jax.block_until_ready(fab.parity.parity)
        wall_us = (time.perf_counter() - t0) / reps * 1e6
        t = fab._traffic_model()
        bytes_step = {"arena_resident": t.get("arena_resident"),
                      "arena": t.get("arena"), "fused": t["fused"],
                      "seed": t["seed"]}[name]
        staging = {"arena_resident": t.get("staging_arena"),
                   "arena": t.get("staging_arena"),
                   "fused": t["staging_fused"],
                   "seed": t["staging_seed"]}[name]
        out[name] = {"bytes": bytes_step, "us": wall_us, "staging": staging,
                     "nbytes": fab.redundancy_nbytes()}
        rows.append(csv_row(
            f"maint_sweep_{name}", wall_us,
            f"bytes_per_step={bytes_step};staging_bytes={staging};"
            f"model_bytes={t['model']};fused_maintains="
            f"{fab.stats['fused_maintains']};arena_maintains="
            f"{fab.stats['arena_maintains']}"))
    # headline: the default (arena) path vs the seed path — the committed
    # floor the CI regression guard holds every run
    ratio = out["seed"]["bytes"] / max(out["arena"]["bytes"], 1)
    wall_ratio = out["seed"]["us"] / max(out["arena"]["us"], 1e-9)
    rows.append(csv_row(
        "maint_headline", 0.0,
        f"bytes_ratio_seed_over_fused={ratio:.2f};"
        f"meets_2x={bool(ratio >= 2.0)};"
        f"wall_ratio_seed_over_fused={wall_ratio:.2f};"
        f"arena_wall_vs_leaf_fused="
        f"{out['fused']['us'] / max(out['arena']['us'], 1e-9):.2f};"
        f"resident_bytes_vs_pack="
        f"{out['arena_resident']['bytes'] / max(out['arena']['bytes'], 1):.3f}"))
    return rows, out


def _padding_rows(params, quick: bool) -> list[str]:
    """Tail packing: alignment overhead of the default layout vs the
    fully tile-aligned (``tail_pack=False``) layout on the reduced
    model. ``padding_ratio`` = pad words / live payload words."""
    from repro.core.arena import build_arena_layout

    part = partition_pytree(params, 128)
    packed = build_arena_layout(part)
    aligned = build_arena_layout(part, tail_pack=False)
    n_tail = (sum(1 for ab in packed.blocks
                  if ab.offset >= packed.tail_start)
              if packed.has_tail else 0)
    saved = (aligned.total_words - packed.total_words) * 4
    return [csv_row(
        "maint_arena_padding", 0.0,
        f"padding_ratio={packed.padding_ratio:.4f};"
        f"padding_ratio_unpacked={aligned.padding_ratio:.4f};"
        f"tail_blocks={n_tail};bytes_saved={saved};"
        f"arena_bytes={packed.nbytes};"
        f"tail_packed_not_larger="
        f"{bool(packed.total_words <= aligned.total_words)}")]


def _quant_rows(params, quick: bool, f32_loss_bit_equal: bool) -> list[str]:
    """Word-level quantized arena: redundancy bytes of the reduced model
    with every leaf cast to bf16 vs the f32 baseline of the same shapes.
    The arena stores raw words (2 bf16 elements per 32-bit word), so the
    replica, parity and sweep traffic all halve; the 0.55 gate leaves
    slack for tile-alignment padding on narrow leaves.

    ``f32_loss_bit_equal`` re-surfaces the e2e headline's
    ``loss_bit_equal`` under the quant gate: for an all-f32 model the
    word arena is bitwise the historical layout, so the arena-resident
    training run must stay bit-identical to the PyTree path."""
    p16 = jax.tree_util.tree_map(lambda x: x.astype(jnp.bfloat16), params)
    out = {}
    for name, tree in (("f32", params), ("bf16", p16)):
        part = partition_pytree(tree, 128)
        fab = CheckpointFabric(part, FabricConfig())
        fab.maintain(1, tree, force=True)
        t = fab._traffic_model()
        out[name] = {"bytes": int(t["arena"]),
                     "red": int(sum(fab.redundancy_nbytes().values())),
                     "padding": float(t.get("padding_ratio", 0.0))}
    ratio_bytes = out["bf16"]["bytes"] / max(out["f32"]["bytes"], 1)
    ratio_red = out["bf16"]["red"] / max(out["f32"]["red"], 1)
    ok = bool(ratio_bytes <= 0.55 and ratio_red <= 0.55)
    return [csv_row(
        "maint_sweep_quant", 0.0,
        f"bytes_per_step_bf16={out['bf16']['bytes']};"
        f"bytes_per_step_f32={out['f32']['bytes']};"
        f"redundancy_bytes_bf16={out['bf16']['red']};"
        f"redundancy_bytes_f32={out['f32']['red']};"
        f"bytes_ratio_bf16_over_f32={ratio_bytes:.3f};"
        f"redundancy_ratio_bf16_over_f32={ratio_red:.3f};"
        f"quant_bytes_le_half_f32={ok};"
        f"f32_loss_bit_equal={bool(f32_loss_bit_equal)};"
        f"padding_ratio={out['bf16']['padding']:.4f}")]


def _partial_save_rows(params, quick: bool) -> list[str]:
    """In-place partial save: O(k·block_bytes) AND faster than the
    full-leaf rewrite.

    The ``inplace`` variant is the production shape: an arena fabric
    maintains every step (that cost is the sweep's, measured above) and
    the save is ONE donated tile scatter from the sweep's replica arena
    into the checkpoint arena — wall-clock now beats the single-program
    ``jnp.where`` rewrite that used to win on dispatch count. A
    ``inplace_tree`` row keeps the old per-leaf scatter honest. The
    budget headline uses ROUND_ROBIN over one full rotation, so the
    average bytes per save is ≈ ``r``·(full bytes) (arena tile padding
    adds the small ``frac_of_full − r`` gap); a PRIORITY row rides along
    for context — drift-weighted selection legitimately concentrates on
    the biggest (most-drifted) blocks."""
    from repro.core.policy import RecoveryMode, SelectionStrategy

    model_bytes = _tree_nbytes(params)
    frac = 0.125
    part = partition_pytree(params, 128)
    k = part.blocks_for_k(frac)
    cycle = -(-part.total_blocks // k)          # saves per full rotation
    rr_pol = CheckpointPolicy(fraction=frac, full_interval=8,
                              strategy=SelectionStrategy.ROUND_ROBIN,
                              recovery=RecoveryMode.PARTIAL)
    rows = []
    moved_per_save = {}
    wall_per_save = {}
    variants = (("inplace", dict(inplace_save=True,
                                 fabric=FabricConfig())),
                ("inplace_tree", dict(inplace_save=True)),
                ("rewrite", dict(inplace_save=False)))
    # warm one full ROUND_ROBIN *selection period*, not one rotation:
    # when total_blocks % k != 0 the selection window shifts each
    # rotation, so distinct (selection size → jit bucket) keys keep
    # appearing for total/gcd(total, k) saves — timing before that pays
    # a recompile mid-measurement
    period = part.total_blocks // math.gcd(part.total_blocks, k)
    warm = -(-period // cycle) * cycle
    for name, kw in variants:
        ctl = FTController(params, rr_pol, **kw)
        has_fabric = ctl.fabric is not None
        live = params
        for i in range(warm):                   # compile every
            live = _drift(live)                 # (leaf, bucket) pair
            if has_fabric:
                ctl.maintain(1 + i, live)
            ctl.checkpoint_now(1 + i, live)
        ctl.stats.update(saves=0, save_seconds=0.0, save_bytes_moved=0)
        for i in range(cycle):
            live = _drift(live)
            if has_fabric:
                # production loop order: the sweep refreshes the tiers
                # (and the replica arena the save scatters from); block on
                # it so save_seconds times the save, not the sweep's async
                # tail (the sweep is measured by the maint_sweep_* rows)
                ctl.maintain(1 + warm + i, live)
                jax.block_until_ready(ctl.fabric.replicas.arena)
            ctl.checkpoint_now(1 + warm + i, live)
        if kw.get("inplace_save"):
            moved = ctl.stats["save_bytes_moved"] / max(ctl.stats["saves"], 1)
        else:
            moved = float(model_bytes)   # jnp.where rewrites every leaf
        moved_per_save[name] = moved
        t_save = ctl.stats["save_seconds"] / max(ctl.stats["saves"], 1)
        wall_per_save[name] = t_save * 1e6
        rows.append(csv_row(
            f"maint_partial_save_{name}", t_save * 1e6,
            f"bytes_moved_per_save={moved:.0f};"
            f"frac_of_full={moved / model_bytes:.4f};"
            f"saves_per_rotation={cycle};"
            f"arena={bool(has_fabric)}"))
    frac_of_full = moved_per_save["inplace"] / model_bytes
    rows.append(csv_row(
        "maint_partial_save_headline", 0.0,
        f"r={frac};frac_of_full={frac_of_full:.4f};"
        f"near_r={bool(frac_of_full <= 1.5 * frac)};"
        f"rewrite_over_inplace="
        f"{moved_per_save['rewrite'] / max(moved_per_save['inplace'], 1):.1f};"
        f"inplace_beats_rewrite_wallclock="
        f"{bool(wall_per_save['inplace'] < wall_per_save['rewrite'])};"
        f"wall_rewrite_over_inplace="
        f"{wall_per_save['rewrite'] / max(wall_per_save['inplace'], 1e-9):.2f}"))
    # drift-weighted PRIORITY context row
    ctl = FTController(params, CheckpointPolicy.scar(fraction=frac,
                                                     interval=8))
    live = _drift(params)
    ctl.checkpoint_now(1, live)
    rows.append(csv_row(
        "maint_partial_save_priority", 0.0,
        f"bytes_moved={ctl.stats['save_bytes_moved']};"
        f"frac_of_full="
        f"{ctl.stats['save_bytes_moved'] / model_bytes:.4f};"
        f"blocks_frac={frac}"))
    return rows


def _store_rows(params, quick: bool) -> list[str]:
    """Packed append-mode shard mirror: append volume, live bytes,
    compaction reclaim."""
    part = partition_pytree(params, 128)
    store_dir = tempfile.mkdtemp(prefix="bench_maintain_store_")
    try:
        store = ShardedCheckpointStore(store_dir)
        store.init(params, part)
        k = part.blocks_for_k(0.125)
        rng = np.random.default_rng(0)
        saves = 3 if quick else 6
        appended = 0
        for i in range(saves):
            mask = np.zeros((part.total_blocks,), bool)
            mask[rng.choice(part.total_blocks, k, replace=False)] = True
            appended += store.write_blocks(mask, params, step=i + 1,
                                           background=False)
        before = store.disk_nbytes()
        reclaimed = store.compact()
        after = store.disk_nbytes()
        rows = [csv_row(
            "maint_store_packed", 0.0,
            f"appended_bytes={appended};log_bytes={before['shard']};"
            f"live_bytes={before['live']};reclaimed={reclaimed};"
            f"compacted_log={after['shard']};"
            f"compaction_exact={bool(after['shard'] == after['live'])}")]
    finally:
        shutil.rmtree(store_dir, ignore_errors=True)
    rows.extend(_arena_store_rows(params, quick))
    return rows


def _arena_store_rows(params, quick: bool) -> list[str]:
    """Domain-keyed arena-segment mirror: a fraction-r save appends ONE
    contiguous buffer per touched host shard, and a re-keying compact()
    migrates segments to their blocks' *current* homes."""
    import os

    from repro.core.arena import ARENA_TILE
    from repro.core.policy import RecoveryMode, SelectionStrategy

    part = partition_pytree(params, 128)
    store_dir = tempfile.mkdtemp(prefix="bench_maintain_arena_store_")
    try:
        store = ShardedCheckpointStore(store_dir)
        pol = CheckpointPolicy(fraction=0.125, full_interval=8,
                               strategy=SelectionStrategy.ROUND_ROBIN,
                               recovery=RecoveryMode.PARTIAL)
        ctl = FTController(params, pol, store=store,
                           fabric=FabricConfig(elastic=True))
        assert ctl._arena_layout is not None
        live = params
        saves = 2 if quick else 4
        t0 = time.time()
        for i in range(1, saves + 1):
            live = _drift(live)
            ctl.maintain(i, live)
            ctl.checkpoint_now(i, live)
        store.flush()
        mirror_us = (time.time() - t0) / saves * 1e6
        hosts = sum(1 for n in os.listdir(store_dir)
                    if n.startswith("host_"))
        # degrade placement (host loss + elastic re-home), then re-key the
        # mirror during the generational rewrite
        lost, failed = ctl.fabric.domain_failure("host", 0)
        live, _ = ctl.on_failure(live, lost, failed_devices=failed,
                                 step=saves)
        before = store.disk_nbytes()
        reclaimed = store.compact(rekey_homes=ctl.fabric.view.homes,
                                  domains=ctl.fabric.domains)
        vals = store.read_all()
        ck = ctl.ckpt.values
        ok = all(bool((np.asarray(a) == np.asarray(b)).all())
                 for a, b in zip(jax.tree_util.tree_leaves(vals),
                                 jax.tree_util.tree_leaves(ck)))
        after = store.disk_nbytes()
        return [csv_row(
            "maint_store_arena", mirror_us,
            f"host_shards={hosts};appended_per_save="
            f"{ctl.stats['bytes_mirrored'] // max(ctl.stats['saves'], 1)};"
            f"tile_words={ARENA_TILE};log_before={before['shard']};"
            f"reclaimed={reclaimed};rekeyed_read_exact={ok};"
            f"compaction_exact={bool(after['shard'] == after['live'])}")]
    finally:
        shutil.rmtree(store_dir, ignore_errors=True)


def _e2e_rows(quick: bool) -> list[str]:
    """Full step+maintain pipeline: PyTree-pack vs arena-resident state.

    Bytes/step is the fault-tolerance machinery's accounted traffic
    (fabric ``maintain_bytes_moved`` + controller ``save_bytes_moved``
    per step) — the resident path must move strictly fewer bytes (the
    pack is gone). Wall-clock: the maintenance overhead (maintain +
    save, ``overhead_seconds``) robustly wins on the resident path; the
    *total* step+maintain wall-clock also rides along but on this CPU
    the arena step itself pays tile-padding overhead in the optimizer's
    elementwise passes, so the total is recorded, never gated (bytes
    are the roofline currency — see the module docstring)."""
    from repro.data.pipeline import ShardedLMDataset
    from repro.sharding import single_device_ctx
    from repro.training import ArenaTrainState, TrainLoop, TrainLoopConfig

    cfg = get_config("qwen2-1.5b", reduced=True)
    warm = 2 if quick else 3
    steps = 5 if quick else 12
    out = {}
    rows = []
    for name, arena_state in (("arena", True), ("pytree", False)):
        ctx = single_device_ctx()
        pol = CheckpointPolicy.scar(fraction=0.125, interval=4)
        loop = TrainLoop(cfg, ctx, loop_cfg=TrainLoopConfig(
            policy=pol, fabric=FabricConfig(), arena_state=arena_state))
        state = loop.init_state()
        assert isinstance(state, ArenaTrainState) == arena_state
        ds = ShardedLMDataset(cfg, batch=2, seq=64, ctx=ctx)
        it = iter(ds)
        state = loop.run(state, it, warm)          # compile everything
        ctl = loop.controller
        b0 = (ctl.fabric.stats["maintain_bytes_moved"]
              + ctl.stats["save_bytes_moved"])
        t0 = time.perf_counter()
        state = loop.run(state, it, steps)
        total_us = (time.perf_counter() - t0) / steps * 1e6
        bytes_step = (ctl.fabric.stats["maintain_bytes_moved"]
                      + ctl.stats["save_bytes_moved"] - b0) / steps
        ms = loop.metrics[warm:]
        # medians: single OS-scheduler spikes otherwise dominate the
        # handful of quick-mode steps and flip the recorded wall flags
        overhead_us = float(np.median(
            [m["overhead_seconds"] for m in ms])) * 1e6
        step_us = float(np.median([m["seconds"] for m in ms])) * 1e6
        out[name] = {"bytes": bytes_step, "total_us": total_us,
                     "overhead_us": overhead_us,
                     "losses": [m["loss"] for m in loop.metrics],
                     "resident":
                         ctl.fabric.stats["arena_resident_maintains"]}
        rows.append(csv_row(
            f"e2e_step_maintain_{name}", total_us,
            f"bytes_per_step={bytes_step:.0f};"
            f"overhead_us_per_step={overhead_us:.0f};"
            f"step_us={step_us:.0f};steps={steps};"
            f"resident_maintains={out[name]['resident']}"))
    ratio = out["pytree"]["bytes"] / max(out["arena"]["bytes"], 1)
    over_ratio = (out["pytree"]["overhead_us"]
                  / max(out["arena"]["overhead_us"], 1e-9))
    rows.append(csv_row(
        "e2e_step_maintain_headline", 0.0,
        f"bytes_ratio_pack_over_resident={ratio:.3f};"
        f"arena_fewer_bytes="
        f"{bool(out['arena']['bytes'] < out['pytree']['bytes'])};"
        f"loss_bit_equal="
        f"{bool(out['arena']['losses'] == out['pytree']['losses'])};"
        f"overhead_wall_ratio_pack_over_resident={over_ratio:.2f};"
        f"resident_overhead_faster={bool(over_ratio > 1.0)};"
        f"total_wall_ratio_pack_over_resident="
        f"{out['pytree']['total_us'] / max(out['arena']['total_us'], 1e-9):.2f}"))
    return rows


def _overlap_rows(quick: bool) -> list[str]:
    """Sync vs async every-step maintenance on the reduced LM.

    Both runs maintain every step; partial saves land every 4 steps
    (fraction=0.25 of full_interval=16 — NOT the scar every-step-save
    schedule, whose PRIORITY selection consumes the sweep's scores and
    so forces a settle on every step, leaving no overlap window).  The
    async run snapshots the live arena into the inactive replica slot
    behind an ``optimization_barrier`` copy and defers the fence to the
    next consume point, so the sweep runs under step N+1's compute.
    Gated: losses + running checkpoint bit-identical across modes, and
    async clean-step overhead p50 <= 0.5x the sync overhead p50.
    ``overlap_efficiency`` (hidden/total async sweep seconds) is
    RECORDED for the perf trajectory."""
    from repro.core.policy import RecoveryMode, SelectionStrategy
    from repro.data.pipeline import ShardedLMDataset
    from repro.sharding import single_device_ctx
    from repro.telemetry import Recorder
    from repro.training import TrainLoop, TrainLoopConfig

    cfg = get_config("qwen2-1.5b", reduced=True)
    warm = 2 if quick else 3
    steps = 8 if quick else 16
    out = {}
    rows = []
    for name, async_m in (("sync", False), ("async", True)):
        ctx = single_device_ctx()
        pol = CheckpointPolicy(fraction=0.25, full_interval=16,
                               strategy=SelectionStrategy.PRIORITY,
                               recovery=RecoveryMode.PARTIAL)
        rec = Recorder()
        loop = TrainLoop(cfg, ctx, loop_cfg=TrainLoopConfig(
            policy=pol, fabric=FabricConfig(async_maintain=async_m),
            arena_state=True, recorder=rec))
        state = loop.init_state()
        ds = ShardedLMDataset(cfg, batch=2, seq=64, ctx=ctx)
        it = iter(ds)
        state = loop.run(state, it, warm)          # compile everything
        ctl = loop.controller
        b0 = ctl.fabric.stats["maintain_bytes_moved"]
        state = loop.run(state, it, steps)
        ms = loop.metrics[warm:]
        overhead_us = float(np.median(
            [m["overhead_seconds"] for m in ms])) * 1e6
        step_us = float(np.median([m["seconds"] for m in ms])) * 1e6
        trains = rec.tracer.intervals("train_step")
        overlapping = sum(
            any(m0 < t1 and t0 < m1 for (t0, t1) in trains)
            for (m0, m1) in rec.tracer.intervals("maintain"))
        eff = loop.overhead_summary()["overlap_efficiency"]
        out[name] = {
            "overhead_us": overhead_us,
            "losses": [m["loss"] for m in loop.metrics],
            "ckpt": np.asarray(ctl._ckpt_arena),
            "maint_bytes":
                (ctl.fabric.stats["maintain_bytes_moved"] - b0) / steps,
            "eff": eff,
        }
        rows.append(csv_row(
            f"maint_overlap_{name}", overhead_us,
            f"step_us={step_us:.0f};steps={steps};"
            f"maint_bytes_per_step={out[name]['maint_bytes']:.0f};"
            f"overlap_efficiency={eff:.3f};"
            f"maintain_spans_overlapping_train={overlapping};"
            f"fence_count={ctl.fabric.stats['fence_count']};"
            f"async_maintains={ctl.fabric.stats['async_maintains']};"
            f"published_epoch={ctl.fabric.published_epoch};"
            f"epoch_staleness="
            f"{ctl.fabric.replicas.staleness(int(state.step))}"))
    bit = (out["sync"]["losses"] == out["async"]["losses"]
           and out["sync"]["ckpt"].shape == out["async"]["ckpt"].shape
           and bool((out["sync"]["ckpt"] == out["async"]["ckpt"]).all()))
    ratio = (out["async"]["overhead_us"]
             / max(out["sync"]["overhead_us"], 1e-9))
    rows.append(csv_row(
        "maint_overlap_headline", 0.0,
        f"async_over_sync_overhead_ratio={ratio:.3f};"
        f"async_overhead_lt_sync={bool(ratio <= 0.5)};"
        f"overlap_bit_equal={bit};"
        f"overlap_efficiency={out['async']['eff']:.3f};"
        f"maint_bytes_ratio_async_over_sync="
        f"{out['async']['maint_bytes'] / max(out['sync']['maint_bytes'], 1):.3f}"))
    return rows


def _telemetry_rows(quick: bool, out_dir: str = "") -> list[str]:
    """Soak the reduced LM under an MTBF failure trace with a live
    Recorder attached: streams ``events.jsonl``, exports the Perfetto
    trace + run report (kept under ``out_dir`` when given), and asserts
    the perturbation ledger's bounds are bit-identical to the theory
    module's. Runs separately from the gated e2e rows, which keep the
    default NullRecorder and therefore the committed byte baselines."""
    import os

    from repro.core.iteration_cost import (iteration_cost_bound,
                                           single_perturbation_bound)
    from repro.data.pipeline import ShardedLMDataset
    from repro.sharding import single_device_ctx
    from repro.telemetry import Recorder, format_report, run_report
    from repro.training import TrainLoop, TrainLoopConfig

    cfg = get_config("qwen2-1.5b", reduced=True)
    steps = 12 if quick else 30
    tmp = None
    if not out_dir:
        tmp = tempfile.mkdtemp(prefix="bench_maintain_telemetry_")
        out_dir = tmp
    try:
        rec = Recorder(out_dir=out_dir)
        ctx = single_device_ctx()
        loop = TrainLoop(cfg, ctx, loop_cfg=TrainLoopConfig(
            policy=CheckpointPolicy.scar(fraction=0.125, interval=4),
            fabric=FabricConfig(elastic=True),
            mtbf={"device": steps / 2.0}, heal_after=3,
            recorder=rec, seed=0))
        state = loop.init_state()
        ds = ShardedLMDataset(cfg, batch=2, seq=64, ctx=ctx)
        loop.run(state, iter(ds), steps)
        # price the faults with reference rates, then hold the ledger to
        # its contract: every bound bit-identical to core/iteration_cost
        c, x0_err = 0.9, 10.0
        rec.ledger.set_rates(c, x0_err)
        exact = all(
            e.bound == single_perturbation_bound(e.delta_norm, c,
                                                 T=e.step, x0_err=x0_err)
            for e in rec.ledger.entries)
        if rec.ledger.entries:
            exact = exact and (
                rec.ledger.cumulative_bound(steps)
                == float(iteration_cost_bound(
                    rec.ledger.delta_series(steps), c, x0_err)))
        over = loop.overhead_summary()
        report = run_report(rec, horizon=steps)
        with open(os.path.join(out_dir, "report.txt"), "w") as f:
            f.write(format_report(report) + "\n")
        rec.close()   # trace.json + metrics.json land next to the JSONL
        return [csv_row(
            "maint_telemetry", 0.0,
            f"ledger_bound_exact={bool(exact)};"
            f"events={len(rec.events)};"
            f"recoveries={report['recovery']['n_recoveries']};"
            f"overhead_p50_us={over['overhead_seconds_p50'] * 1e6:.0f};"
            f"overhead_p95_us={over['overhead_seconds_p95'] * 1e6:.0f};"
            f"clean_steps={over['overhead_clean_steps']};"
            f"artifacts={'temp' if tmp is not None else out_dir}")]
    finally:
        if tmp is not None:
            shutil.rmtree(tmp, ignore_errors=True)


def _multi_erasure_rows(quick: bool, out_dir: str = "") -> list[str]:
    """RS(k, 2) multi-erasure + silent-error soak on the reduced LM.

    One run with the RS tier: a simultaneous two-host loss (both events
    in the same trace step, recovered through the controller's combined
    multi-domain path) plus an injected in-arena bit flip under an
    every-step integrity scrub. One XOR control run with the identical
    loss schedule. REQUIRED flags (deterministic):

      rs_recovery_bit_equal   — the double loss recovered bit-exactly
                                through replicas + RS parity: zero
                                applied perturbation, no RUNNING_CKPT or
                                DISK blocks, no tier fallback.
      silent_error_detected   — the scrub caught the injected flip,
                                localized it to its block, corrected it
                                in place, and its ledger entry prices
                                the detection at ‖δ′‖² = 0.

    The XOR control's fallback count and paid perturbation ride along
    recorded — the staleness cost the RS tier deletes. The RS run's
    telemetry (events.jsonl + ledger.json with the priced entries) lands
    under ``<out_dir>/multi_erasure`` when ``--telemetry-out`` is given."""
    import dataclasses
    import os

    from repro.data.pipeline import ShardedLMDataset
    from repro.sharding import single_device_ctx
    from repro.telemetry import Recorder
    from repro.training import TrainLoop, TrainLoopConfig

    cfg = get_config("qwen2-1.5b", reduced=True)
    steps = 8 if quick else 14
    tmp = None
    if out_dir:
        out_dir = os.path.join(out_dir, "multi_erasure")
        os.makedirs(out_dir, exist_ok=True)
    else:
        tmp = tempfile.mkdtemp(prefix="bench_maintain_rs_")
        out_dir = tmp
    try:
        out = {}
        for name, rs in (("rs", 2), ("xor", 0)):
            rec = Recorder(out_dir=out_dir if name == "rs" else None)
            ctx = single_device_ctx()
            loop = TrainLoop(cfg, ctx, loop_cfg=TrainLoopConfig(
                policy=CheckpointPolicy.scar(fraction=0.125, interval=4),
                fabric=FabricConfig(rs_parity=rs, elastic=True),
                # same-step host events = one correlated double loss
                # spanning both racks (kills primaries AND the
                # anti-affine replicas of some blocks). Hosts 1 + 3, not
                # 0: byte-balanced placement packs the many small leaves
                # onto host 0, and its pigeonhole surplus (more blocks
                # than the other hosts combined) forces same-host parity
                # groups no code survives losing — a real fallback the
                # XOR row prices, not the bit-equal path gated here.
                fail_schedule=[(4, "host", 1), (4, "host", 3)],
                flip_schedule=[6] if rs else None,
                scrub_interval=1 if rs else 0,
                recorder=rec, seed=0))
            state = loop.init_state()
            ds = ShardedLMDataset(cfg, batch=2, seq=64, ctx=ctx)
            loop.run(state, iter(ds), steps)
            fails = [f for m in loop.metrics
                     for f in m.get("failures", [])]
            assert len(fails) == 1 and len(fails[0]["events"]) == 2
            scrubs = [m["scrub"] for m in loop.metrics if "scrub" in m]
            out[name] = {
                "counts": fails[0]["tier_counts"],
                "lost": fails[0]["lost_blocks"],
                "applied_sq": fails[0]["applied_sq"],
                "fallbacks": len(fails[0].get("tier_fallbacks", [])),
                "detected": sum(s["detected"] for s in scrubs),
                "corrected": sum(s["corrected"] for s in scrubs),
                "ledger": rec.ledger,
                "rec": rec,
            }
        rs_, xor_ = out["rs"], out["xor"]
        bit_equal = bool(
            rs_["lost"] > 0 and rs_["applied_sq"] == 0.0
            and rs_["counts"]["RUNNING_CKPT"] == 0
            and rs_["counts"]["DISK"] == 0 and rs_["fallbacks"] == 0)
        silent_entries = [
            e for e in rs_["ledger"].entries
            if (e.tier_counts or {}).get("SILENT_ERROR")]
        detected = bool(
            rs_["detected"] == 1 and rs_["corrected"] == 1
            and len(silent_entries) == 1
            and silent_entries[0].applied_sq == 0.0)
        with open(os.path.join(out_dir, "ledger.json"), "w") as f:
            json.dump({"summary": rs_["ledger"].summary(),
                       "entries": [dataclasses.asdict(e)
                                   for e in rs_["ledger"].entries]},
                      f, indent=2, default=float)
        rs_["rec"].close()
        xor_["rec"].close()
        return [csv_row(
            "tier_soak_multi_erasure", 0.0,
            f"rs_recovery_bit_equal={bit_equal};"
            f"silent_error_detected={detected};"
            f"rs_lost_blocks={rs_['lost']};"
            f"rs_parity_blocks={rs_['counts']['PARITY']};"
            f"xor_fallbacks={xor_['fallbacks']};"
            f"xor_ckpt_blocks="
            f"{xor_['counts']['RUNNING_CKPT'] + xor_['counts']['DISK']};"
            f"xor_applied_sq={xor_['applied_sq']:.3e};"
            f"artifacts={'temp' if tmp is not None else out_dir}")]
    finally:
        if tmp is not None:
            shutil.rmtree(tmp, ignore_errors=True)


def _sharded_rows(quick: bool) -> list[str]:
    """SPMD rows: the sharded arena sweep and the elastic-mesh soak.

    These need more than one XLA device, which this process deliberately
    does not have (the committed single-device byte baselines would
    shift), so the measurement runs in a subprocess with
    ``--xla_force_host_platform_device_count=8`` — see
    ``benchmarks/_sharded_probe.py`` for what each number means. The
    headline flags (``sharded_loss_bit_equal``, ``sharded_bytes_le_pack``,
    ``elastic_cycle_ok``) are deterministic and REQUIRED by
    ``check_maintain_regression``; the wall-clock rides along recorded."""
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    cmd = [sys.executable, "-m", "benchmarks._sharded_probe"]
    if quick:
        cmd.append("--quick")
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                          timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(
            f"sharded probe failed (rc={proc.returncode}):\n{proc.stderr}")
    res = json.loads(proc.stdout.splitlines()[-1])
    sh, el = res["sharded"], res["elastic"]
    a = sh["arena"]
    rows = [csv_row(
        "maint_sweep_sharded", a["overhead_us"],
        f"bytes_per_step={a['bytes_per_step']:.0f};"
        f"pack_bytes_per_step={sh['pytree']['bytes_per_step']:.0f};"
        f"shards={sh['shards']};"
        f"sharded_loss_bit_equal={bool(sh['loss_bit_equal'])};"
        f"sharded_bytes_le_pack={bool(sh['bytes_le_pack'])};"
        f"live_packs={a['live_packs']};"
        f"resident_maintains={a['resident_maintains']};"
        f"ici_bytes_per_maintain={a['ici_per_maintain']:.0f};"
        f"dcn_bytes_per_maintain={a['dcn_per_maintain']:.0f}")]
    rows.append(csv_row(
        "tier_soak_elastic_mesh", el["us_per_step"],
        f"steps={el['steps']};mesh_resizes={el['mesh_resizes']};"
        f"min_shards={el['min_shards']};final_shards={el['final_shards']};"
        f"live_packs={el['live_packs']};"
        f"losses_finite={bool(el['losses_finite'])};"
        f"elastic_cycle_ok={bool(el['cycle_ok'])}"))
    return rows


def run(trials: int = 4, quick: bool = False,
        telemetry_out: str = "") -> list[str]:
    rows = _kernel_check_rows(quick)
    params = _reduced_params()
    sweep_rows, _ = _sweep_rows(params, quick)
    rows.extend(sweep_rows)
    rows.extend(_padding_rows(params, quick))
    rows.extend(_partial_save_rows(params, quick))
    rows.extend(_store_rows(params, quick))
    e2e_rows = _e2e_rows(quick)
    rows.extend(e2e_rows)
    f32_bit = any(r.startswith("e2e_step_maintain_headline")
                  and "loss_bit_equal=True" in r for r in e2e_rows)
    rows.extend(_quant_rows(params, quick, f32_bit))
    rows.extend(_overlap_rows(quick))
    rows.extend(_sharded_rows(quick))
    rows.extend(_telemetry_rows(quick, telemetry_out))
    rows.extend(_multi_erasure_rows(quick, telemetry_out))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="",
                    help="also write rows as JSON (CI perf trajectory)")
    ap.add_argument("--telemetry-out", default="",
                    help="keep the soak's telemetry artifacts "
                         "(events.jsonl, trace.json, metrics.json, "
                         "report.txt) in this directory")
    args = ap.parse_args()
    rows = run(quick=args.quick, telemetry_out=args.telemetry_out)
    print("name,us_per_call,derived")
    for row in rows:
        print(row, flush=True)
    if args.out:
        parsed = []
        for row in rows:
            name, us, derived = row.split(",", 2)
            parsed.append({"name": name, "us_per_call": float(us),
                           "derived": derived})
        with open(args.out, "w") as f:
            json.dump({"bench": "maintain", "quick": args.quick,
                       "rows": parsed}, f, indent=2)


if __name__ == "__main__":
    main()
