"""Benchmark driver — one section per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig7,...]

Prints ``name,us_per_call,derived`` CSV rows. Sections:

  fig3  — QP iteration cost vs Theorem 3.2 bound        (bench_qp_bound)
  fig5  — MLR random vs adversarial perturbations       (bench_mlr_bound)
  fig6  — reset-to-init perturbations, MLR + LDA        (bench_reset)
  fig7  — partial vs full recovery, 4 models × 3 fracs  (bench_partial_recovery)
  fig8  — priority/round/random checkpoints + headline  (bench_priority)
  fig9  — system overhead (t_dump vs t_step, budget)    (bench_overhead)
  kern  — Pallas kernel microbenches vs jnp oracles     (bench_kernels)
  tier  — tiered recovery fabric vs checkpoint-only     (bench_tiered_recovery)
  maint — fused single-pass maintenance vs seed path    (bench_maintain)
"""
from __future__ import annotations

import argparse
import time

from benchmarks import (bench_kernels, bench_maintain, bench_mlr_bound,
                        bench_overhead, bench_partial_recovery,
                        bench_priority, bench_qp_bound, bench_reset,
                        bench_tiered_recovery)

SECTIONS = {
    "fig3": bench_qp_bound.run,
    "fig5": bench_mlr_bound.run,
    "fig6": bench_reset.run,
    "fig7": bench_partial_recovery.run,
    "fig8": bench_priority.run,
    "fig9": bench_overhead.run,
    "kern": bench_kernels.run,
    "tier": bench_tiered_recovery.run,
    "maint": bench_maintain.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else set(SECTIONS)

    print("name,us_per_call,derived")
    for name, fn in SECTIONS.items():
        if name not in only:
            continue
        t0 = time.time()
        try:
            rows = fn(quick=args.quick)
        except Exception as e:  # keep the harness running; report the break
            rows = [f"{name}_ERROR,0.0,{type(e).__name__}:{e}"]
        for row in rows:
            print(row, flush=True)
        print(f"_section_{name}_seconds,{(time.time()-t0)*1e6:.0f},"
              f"wall={time.time()-t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
