"""Quickstart: SCAR fault tolerance in 60 lines.

Trains a small classic model (multinomial logistic regression — one of the
paper's §5 workloads), takes prioritized partial checkpoints, kills half
the parameters mid-training, partially recovers, and reports the measured
iteration cost next to the Theorem 3.2 bound.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core.iteration_cost import (estimate_contraction,
                                       single_perturbation_bound)
from repro.core.policy import CheckpointPolicy
from repro.models.classic import make_model
from repro.training import run_clean, run_with_failure


def main():
    print("== SCAR quickstart: MLR + priority checkpoints + partial recovery")
    model = make_model("mlr", n=600, dim=64, n_classes=5, batch=200)

    # 1. unperturbed baseline (the κ(x, ε) reference)
    clean = run_clean(model, max_iters=150)["losses"]
    kappa_clean = int(np.argmax(np.asarray(clean) < model.eps))
    print(f"   clean run reaches ε in {kappa_clean} iterations")

    # 2. SCAR: prioritized 1/4-checkpoints at 4× frequency, partial recovery
    scar = CheckpointPolicy.scar(fraction=0.25, interval=32)
    res = run_with_failure(model, scar, fail_iter=25, fail_fraction=0.5,
                           max_iters=150, clean_losses=clean)
    print(f"   failure at iter 25 lost 50% of blocks;"
          f" ||δ'||²={res['recovery']['partial_sq']:.2e}"
          f" vs full-recovery ||δ||²={res['recovery']['full_sq']:.2e}")
    print(f"   SCAR iteration cost: {res['iteration_cost']}")

    # 3. traditional full checkpoint-restore, same failure
    trad = run_with_failure(model, CheckpointPolicy.traditional(32),
                            fail_iter=25, fail_fraction=0.5, max_iters=150,
                            clean_losses=clean)
    print(f"   traditional iteration cost: {trad['iteration_cost']}")

    # 4. Theorem 3.2 bound for the SCAR perturbation
    c = estimate_contraction(np.sqrt(np.maximum(
        np.asarray(clean) - min(clean) * 0.98, 1e-9))[:100], burn_in=3)
    delta = float(np.sqrt(res["recovery"]["applied_sq"]))
    x0 = model.distance(model.init(jax.random.PRNGKey(1)))
    bound = single_perturbation_bound(delta, c, T=25, x0_err=x0)
    print(f"   Theorem 3.2 bound: {bound:.1f} iterations (c={c:.3f})")
    saved = trad["iteration_cost"] - res["iteration_cost"]
    print(f"== SCAR saved {saved} iterations vs traditional recovery")


if __name__ == "__main__":
    main()
