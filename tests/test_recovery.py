"""Recovery semantics + Theorems 4.1 / 4.2."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.blocks import partition_pytree, tree_sq_norm
from repro.core.checkpoint import init_running_checkpoint
from repro.core.policy import RecoveryMode
from repro.core.recovery import (apply_failure_and_recover,
                                 perturbation_norms, recover,
                                 sample_failure_mask)


def _setup(seed=0, rows=96, width=3, block_rows=8):
    rng = np.random.default_rng(seed)
    params = {"w": jnp.asarray(rng.normal(size=(rows, width)), jnp.float32)}
    part = partition_pytree(params, block_rows)
    ckpt = init_running_checkpoint(params, part)
    live = jax.tree_util.tree_map(
        lambda x: x + jnp.asarray(rng.normal(size=x.shape), jnp.float32), params)
    return params, part, ckpt, live


def test_theorem_4_1_partial_leq_full():
    params, part, ckpt, live = _setup()
    for seed in range(20):
        mask = sample_failure_mask(jax.random.PRNGKey(seed), part, 0.5)
        info = perturbation_norms(live, ckpt, mask, part)
        assert float(info["partial_sq"]) <= float(info["full_sq"]) * (1 + 1e-5) + 1e-6


def test_theorem_4_2_expectation():
    """E||δ'||² = p||δ||² for uniformly-random block loss."""
    params, part, ckpt, live = _setup(rows=512, block_rows=8)
    full = float(tree_sq_norm(ckpt.values, live))
    for p in (0.25, 0.5, 0.75):
        sqs = []
        for seed in range(200):
            mask = sample_failure_mask(jax.random.PRNGKey(seed), part, p)
            info = perturbation_norms(live, ckpt, mask, part)
            sqs.append(float(info["partial_sq"]))
        ratio = np.mean(sqs) / full
        assert ratio == pytest.approx(p, rel=0.15)


def test_partial_recovery_only_touches_lost_blocks():
    params, part, ckpt, live = _setup()
    mask = sample_failure_mask(jax.random.PRNGKey(1), part, 0.25)
    rec = recover(live, ckpt, mask, RecoveryMode.PARTIAL, part)
    # survivors identical to live; lost equal to checkpoint
    lost_rows = np.repeat(np.asarray(mask), part.block_rows)[:96]
    live_w = np.asarray(live["w"])
    rec_w = np.asarray(rec["w"])
    ck_w = np.asarray(ckpt.values["w"])
    np.testing.assert_array_equal(rec_w[~lost_rows], live_w[~lost_rows])
    np.testing.assert_array_equal(rec_w[lost_rows], ck_w[lost_rows])


def test_full_recovery_restores_checkpoint():
    params, part, ckpt, live = _setup()
    mask = sample_failure_mask(jax.random.PRNGKey(1), part, 0.25)
    rec, info = apply_failure_and_recover(live, ckpt, mask,
                                          RecoveryMode.FULL, part)
    assert float(tree_sq_norm(rec, ckpt.values)) == 0.0
    assert info["applied_sq"] == pytest.approx(info["full_sq"], rel=1e-5)


def test_partial_applied_delta_matches_partial_norm():
    params, part, ckpt, live = _setup()
    mask = sample_failure_mask(jax.random.PRNGKey(2), part, 0.5)
    rec, info = apply_failure_and_recover(live, ckpt, mask,
                                          RecoveryMode.PARTIAL, part)
    assert float(info["applied_sq"]) == pytest.approx(
        float(info["partial_sq"]), rel=1e-5)
