"""Mesh-aware sharding: DistContext, per-arch partition specs, failure domains."""
from repro.sharding.partition import (DistContext, single_device_ctx,
                                      make_dist_ctx, param_partition_specs,
                                      blocks_on_failed_devices)

__all__ = ["DistContext", "single_device_ctx", "make_dist_ctx",
           "param_partition_specs", "blocks_on_failed_devices"]
