"""whisper-medium [audio] — enc-dec, conv frontend (stub) [arXiv:2212.04356].

24L d_model=1024 16H (MHA kv=16) d_ff=4096 vocab=51865. The mel-spectrogram
+ conv feature extractor is a STUB: input_specs() provides precomputed frame
embeddings (1500 frames, the 30 s Whisper window). long_500k is skipped for
this arch (see DESIGN.md — 524288-token decode is out of family for the
30 s enc-dec format).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51865,
    enc_layers=24,
    enc_seq=1500,
    rope_theta=0.0,   # whisper uses learned/sinusoidal positions, not RoPE
    microbatch=2,
    source="arXiv:2212.04356",
))
