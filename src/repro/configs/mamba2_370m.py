"""mamba2-370m [ssm] — SSD (state-space duality) [arXiv:2405.21060].

48L d_model=1024 (attention-free) vocab=50280, ssm_state=128.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_headdim=64,
    source="arXiv:2405.21060",
))
