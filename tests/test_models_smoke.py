"""Per-architecture smoke tests (deliverable f).

Each assigned architecture is instantiated as a REDUCED variant
(2 layers, d_model ≤ 512, ≤ 4 experts) and runs one forward/train step on
CPU, asserting output shapes and absence of NaNs. The FULL configs are
exercised only via the dry-run (launch/dryrun.py, ShapeDtypeStructs).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs
from repro.data import lm_batch
from repro.models import get_model
from repro.sharding import single_device_ctx

ARCHS = list_configs()
B, S = 2, 64


@pytest.fixture(scope="module")
def ctx():
    return single_device_ctx()


def _setup(name):
    cfg = get_config(name, reduced=True)
    ops = get_model(cfg)
    params = ops.init_params(jax.random.PRNGKey(0), cfg)
    batch = lm_batch(jax.random.PRNGKey(1), cfg, B, S)
    return cfg, ops, params, batch


def test_all_ten_assigned_archs_registered():
    expected = {"internvl2-76b", "zamba2-1.2b", "granite-8b",
                "command-r-plus-104b", "qwen3-moe-235b-a22b", "mamba2-370m",
                "llama4-maverick-400b-a17b", "qwen2-1.5b", "yi-9b",
                "whisper-medium"}
    assert expected == set(ARCHS)


@pytest.mark.parametrize("name", ARCHS)
def test_full_config_matches_assignment(name):
    cfg = get_config(name)
    full = {
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "yi-9b": (48, 4096, 32, 4, 11008, 64000),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
    }[name]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab) == full
    assert cfg.source  # every config cites its source


@pytest.mark.parametrize("name", ARCHS)
def test_reduced_constraints(name):
    cfg = get_config(name, reduced=True)
    assert cfg.n_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_smoke(name, ctx):
    cfg, ops, params, batch = _setup(name)
    loss, grads = jax.value_and_grad(ops.train_loss)(params, batch, cfg, ctx)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
                for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_decode_smoke(name, ctx):
    cfg, ops, params, batch = _setup(name)
    logits, cache = ops.prefill(params, batch, cfg, ctx)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    tok = jnp.zeros((B, 1), jnp.int32)
    logits2, cache2 = ops.decode_step(params, cache, tok, cfg, ctx)
    assert logits2.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("name", ARCHS)
def test_fresh_cache_decode(name, ctx):
    cfg, ops, params, _ = _setup(name)
    cache = ops.init_cache(cfg, B, S, ctx)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, _ = ops.decode_step(params, cache, tok, cfg, ctx)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_decode_matches_prefill_dense(ctx):
    """Teacher-forcing consistency: token-by-token decode logits equal a
    fresh prefill's last-position logits (dense family)."""
    cfg = get_config("yi-9b", reduced=True)
    ops = get_model(cfg)
    params = ops.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 9), 0, cfg.vocab)
    # prefill on first 8 tokens
    logits_p, cache = ops.prefill(
        params, {"tokens": toks[:, :8]}, cfg, ctx)
    # decode the 9th
    logits_d, _ = ops.decode_step(params, cache, toks[:, 8:9], cfg, ctx)
    # reference: prefill of all 9
    logits_f, _ = ops.prefill(params, {"tokens": toks}, cfg, ctx)
    np.testing.assert_allclose(np.asarray(logits_d[:, -1]),
                               np.asarray(logits_f[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_decode_matches_prefill_ssm(ctx):
    cfg = get_config("mamba2-370m", reduced=True)
    ops = get_model(cfg)
    params = ops.init_params(jax.random.PRNGKey(0), cfg)
    # seq length must be a multiple of the ssd chunk for prefill
    Sq = cfg.ssm_chunk * 2
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, Sq + 1), 0, cfg.vocab)
    logits_p, state = ops.prefill(params, {"tokens": toks[:, :Sq]}, cfg, ctx)
    logits_d, _ = ops.decode_step(params, state, toks[:, Sq:], cfg, ctx)
    logits_f, _ = ops.prefill(
        params, {"tokens": jnp.pad(toks, ((0, 0), (0, cfg.ssm_chunk - 1)))},
        cfg, ctx)
    # compare against a direct step-by-step reference instead: decode all
    state2 = ops.init_cache(cfg, 1, Sq, ctx)
    for t in range(Sq + 1):
        logits_s, state2 = ops.decode_step(params, state2, toks[:, t:t + 1],
                                           cfg, ctx)
    np.testing.assert_allclose(np.asarray(logits_d[:, -1]),
                               np.asarray(logits_s[:, -1]),
                               rtol=2e-3, atol=2e-3)
