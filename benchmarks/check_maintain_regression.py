"""CI bench regression guard for the maintenance hot path.

Compares a fresh ``bench_maintain --quick`` JSON against the committed
baseline (``BENCH_maintain.json`` at the repo root) and **fails** when the
analytic bytes-per-step of any guarded row regresses by more than the
allowed ratio (default 1.5×). Cross-row gates additionally pin the
arena-resident paths at ≤1.0× the committed *pack-path* baselines — the
per-step ``pack_arena`` the arena-resident training state eliminated must
stay eliminated. Wall-clock ratios are *recorded* alongside (CI machines
are too noisy to gate on, but the trajectory should be visible in the job
log and artifact), and the headline invariants (bit-exactness, the ≥2×
seed-over-fused floor, near-r byte budget, the e2e bit-equality of the
arena-resident and PyTree training paths, the bit-equality of the async
double-buffered maintenance pipeline against the sync path plus its
overhead halving, and the SPMD rows' same-mesh loss bit-equality /
bytes-at-or-below-pack / elastic shrink-heal cycle) are asserted. The
in-place-save wall-clock inversion is RECORDED with a threshold instead
(see ``RECORDED_THRESHOLD_FLAGS`` for why the quick config legitimately
inverts it); ``overlap_efficiency`` rides along as a recorded value.

Standalone::

    python -m benchmarks.check_maintain_regression \
        --baseline BENCH_maintain.json --fresh BENCH_maintain.new.json
"""
from __future__ import annotations

import argparse
import json
import re
import sys

# rows whose derived "bytes" field is the guarded per-step byte cost
GUARDED_BYTES = {
    "maint_sweep_arena_resident": "bytes_per_step",
    "maint_sweep_arena": "bytes_per_step",
    "maint_sweep_fused": "bytes_per_step",
    "maint_sweep_sharded": "bytes_per_step",
    "maint_partial_save_inplace": "bytes_moved_per_save",
    "e2e_step_maintain_arena": "bytes_per_step",
    "e2e_step_maintain_pytree": "bytes_per_step",
}
# cross-row gates: (fresh row, key, BASELINE row, max ratio) — the fresh
# arena-resident e2e bytes/step must stay at or below the committed
# pytree-pack baseline (the pack must stay eliminated: the resident path
# may never regress back to pack-path traffic)
CROSS_GUARDS = [
    ("e2e_step_maintain_arena", "bytes_per_step",
     "e2e_step_maintain_pytree", 1.0),
    ("maint_sweep_arena_resident", "bytes_per_step",
     "maint_sweep_arena", 1.0),
]
# headline flags that must stay true on every run (exactness + analytic
# byte floors only — deterministic on any machine)
REQUIRED_FLAGS = [
    ("maint_kernel", "replica_bit_exact=True"),
    ("maint_kernel", "parity_bit_exact=True"),
    ("maint_kernel", "scores_match=True"),
    ("maint_arena_kernel", "replica_bit_exact=True"),
    ("maint_arena_kernel", "parity_bit_exact=True"),
    ("maint_arena_kernel", "scores_match=True"),
    ("maint_headline", "meets_2x=True"),
    ("maint_partial_save_headline", "near_r=True"),
    ("maint_store_packed", "compaction_exact=True"),
    ("maint_store_arena", "rekeyed_read_exact=True"),
    ("e2e_step_maintain_headline", "arena_fewer_bytes=True"),
    ("e2e_step_maintain_headline", "loss_bit_equal=True"),
    ("maint_overlap_headline", "overlap_bit_equal=True"),
    ("maint_overlap_headline", "async_overhead_lt_sync=True"),
    ("maint_sweep_sharded", "sharded_loss_bit_equal=True"),
    ("maint_sweep_sharded", "sharded_bytes_le_pack=True"),
    ("tier_soak_elastic_mesh", "elastic_cycle_ok=True"),
    ("maint_telemetry", "ledger_bound_exact=True"),
    # RS(k, 2) must recover the correlated two-host loss through the
    # parity tier bit-exactly (no checkpoint fallback, zero applied
    # perturbation) and the integrity scrub must catch + correct the
    # injected arena bit flip — both deterministic on any machine
    ("tier_soak_multi_erasure", "rs_recovery_bit_equal=True"),
    ("tier_soak_multi_erasure", "silent_error_detected=True"),
    # word-level quantized arena: a bf16 model's redundancy bytes per
    # sweep must stay at or below 0.55x the f32 baseline of the same
    # shapes, and the all-f32 e2e run must stay loss-bit-equal to the
    # PyTree path (the word arena is a bitwise no-op at f32) — both
    # deterministic (analytic bytes + bit comparison)
    ("maint_sweep_quant", "quant_bytes_le_half_f32=True"),
    ("maint_sweep_quant", "f32_loss_bit_equal=True"),
]
# wall-clock flags: recorded loudly, never gated (shared CI runners are
# too noisy — the committed baseline documents the local inversion)
RECORDED_FLAGS = [
    ("e2e_step_maintain_headline", "resident_overhead_faster=True"),
]
# wall-clock flags recorded WITH a loose threshold on an accompanying
# ratio. ``inplace_beats_rewrite_wallclock`` is the canonical case: on
# the quick config the full rewrite is ONE fused XLA program over a tiny
# model, while the in-place save pays fixed per-dispatch overhead that
# cannot amortize at that size — so the boolean legitimately inverts
# (committed baseline: wall 0.95x) even though the byte win (``near_r``,
# REQUIRED above) is intact and the inversion disappears at production
# sizes where the memcpy dominates the dispatch. Gating the boolean
# would make quick-mode CI red on a config artifact; dropping it
# entirely would hide a real dispatch-count regression. The compromise:
# the flag is printed every run, and the run only FAILS when the ratio
# falls below ``min_ratio`` — i.e. the in-place save got catastrophically
# slower than the rewrite, which no config-size effect explains.
RECORDED_THRESHOLD_FLAGS = [
    # (row, flag, ratio key, min ratio)
    ("maint_partial_save_headline", "inplace_beats_rewrite_wallclock=True",
     "wall_rewrite_over_inplace", 1 / 3),
]
# numeric values lifted from the fresh run's derived fields and printed
# for the job log / perf trajectory — never gated (wall-clock noise)
RECORDED_VALUES = [
    ("maint_telemetry", "overhead_p50_us"),
    ("maint_telemetry", "overhead_p95_us"),
    ("maint_overlap_headline", "overlap_efficiency"),
    ("maint_overlap_headline", "async_over_sync_overhead_ratio"),
    # the XOR control's staleness price under the same double loss —
    # the contrast the RS tier's bit-equal gate is measured against
    ("tier_soak_multi_erasure", "xor_fallbacks"),
    ("tier_soak_multi_erasure", "xor_applied_sq"),
    # quantized-arena byte trajectory + tail-packing alignment overhead
    ("maint_sweep_quant", "redundancy_ratio_bf16_over_f32"),
    ("maint_arena_padding", "padding_ratio"),
    ("maint_arena_padding", "padding_ratio_unpacked"),
]


def _rows(path: str) -> dict[str, dict]:
    with open(path) as f:
        data = json.load(f)
    return {r["name"]: r for r in data["rows"]}


def _derived_num(row: dict, key: str) -> float:
    m = re.search(rf"{key}=([0-9.eE+-]+)", row["derived"])
    if m is None:
        raise SystemExit(f"row {row['name']}: no '{key}' in derived field")
    return float(m.group(1))


def check(baseline_path: str, fresh_path: str,
          max_ratio: float = 1.5) -> int:
    base = _rows(baseline_path)
    fresh = _rows(fresh_path)
    failures = []
    for name, key in GUARDED_BYTES.items():
        if name not in base:
            print(f"[guard] {name}: not in baseline yet — skipped")
            continue
        if name not in fresh:
            failures.append(f"{name}: missing from fresh run")
            continue
        b = _derived_num(base[name], key)
        f = _derived_num(fresh[name], key)
        ratio = f / max(b, 1.0)
        wall_b = base[name]["us_per_call"]
        wall_f = fresh[name]["us_per_call"]
        wall = wall_f / max(wall_b, 1e-9)
        status = "OK" if ratio <= max_ratio else "REGRESSION"
        print(f"[guard] {name}: {key} {b:.0f} -> {f:.0f} "
              f"({ratio:.2f}x, limit {max_ratio}x) | wall-clock "
              f"{wall_b:.0f}us -> {wall_f:.0f}us ({wall:.2f}x, recorded) "
              f"[{status}]")
        if ratio > max_ratio:
            failures.append(
                f"{name}: {key} regressed {ratio:.2f}x (> {max_ratio}x)")
    for name, key, base_name, limit in CROSS_GUARDS:
        if base_name not in base:
            print(f"[cross] {name}: baseline row {base_name} missing — "
                  "skipped")
            continue
        if name not in fresh:
            failures.append(f"{name}: missing from fresh run")
            continue
        b = _derived_num(base[base_name], key)
        f = _derived_num(fresh[name], key)
        ratio = f / max(b, 1.0)
        status = "OK" if ratio <= limit else "REGRESSION"
        print(f"[cross] {name}: {key} {f:.0f} vs baseline "
              f"{base_name} {b:.0f} ({ratio:.3f}x, limit {limit}x) "
              f"[{status}]")
        if ratio > limit:
            failures.append(
                f"{name}: {key} {ratio:.3f}x of baseline {base_name} "
                f"(> {limit}x — the eliminated pack came back)")
    for name, flag in REQUIRED_FLAGS:
        if name not in fresh:
            failures.append(f"{name}: row missing from fresh run")
        elif flag not in fresh[name]["derived"]:
            failures.append(f"{name}: expected '{flag}', got "
                            f"'{fresh[name]['derived']}'")
    for name, flag in RECORDED_FLAGS:
        held = name in fresh and flag in fresh[name]["derived"]
        print(f"[recorded] {name}: '{flag}' "
              f"{'held' if held else 'DID NOT HOLD (not gated)'}")
    for name, flag, key, min_ratio in RECORDED_THRESHOLD_FLAGS:
        if name not in fresh:
            failures.append(f"{name}: row missing from fresh run")
            continue
        held = flag in fresh[name]["derived"]
        ratio = _derived_num(fresh[name], key)
        status = "OK" if ratio >= min_ratio else "REGRESSION"
        note = ("held" if held else "did not hold (quick-config "
                "inversion, see RECORDED_THRESHOLD_FLAGS)")
        print(f"[recorded] {name}: '{flag}' {note} | "
              f"{key}={ratio:.2f} (floor {min_ratio:.2f}) [{status}]")
        if ratio < min_ratio:
            failures.append(
                f"{name}: {key} {ratio:.2f} below floor {min_ratio:.2f} "
                "— beyond any quick-config dispatch-overhead inversion")
    for name, key in RECORDED_VALUES:
        if name not in fresh:
            print(f"[recorded] {name}: row missing (not gated)")
            continue
        try:
            v = _derived_num(fresh[name], key)
        except SystemExit:
            print(f"[recorded] {name}: no '{key}' field (not gated)")
            continue
        print(f"[recorded] {name}: {key}={v:g} (not gated)")
    if failures:
        print("\nBENCH REGRESSION GUARD FAILED:")
        for f in failures:
            print("  -", f)
        return 1
    print("\nbench regression guard OK")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default="BENCH_maintain.json")
    ap.add_argument("--fresh", required=True)
    ap.add_argument("--max-ratio", type=float, default=1.5)
    args = ap.parse_args()
    sys.exit(check(args.baseline, args.fresh, args.max_ratio))


if __name__ == "__main__":
    main()
