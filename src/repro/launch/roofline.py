import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Roofline analysis from the compiled dry-run (single-pod 16×16 mesh).

Three terms per (arch × input shape), in seconds:

    compute    = FLOPs / (chips · 197e12 bf16 FLOP/s)
    memory     = bytes / (chips · 819e9 B/s HBM)
    collective = collective_bytes / (chips · 50e9 B/s ICI link)

**Scan-body correction.** XLA's ``cost_analysis()`` counts a ``while``
body ONCE regardless of trip count, so a scanned 94-layer stack reports
~1 layer of FLOPs. We reconstruct full-depth totals by *depth probing*:
lower the same (arch × shape) at depth 1 and depth 2 (family-aware — the
hybrid probes mamba vs shared-attention deltas separately, the enc-dec
probes encoder vs decoder), take per-layer deltas, and extrapolate:

    corrected = nonlayer + Σ_block n_block · delta_block

Residual undercounts (the chunked loss/embedding scans, whose bodies are
also counted once) are covered by the analytic MODEL_FLOPS column; the
discrepancy is called out where it matters. Probes run with microbatch=1;
grad-accumulation repeats identical work so totals are equivalent.

Usage:  python -m repro.launch.roofline [--outdir results/roofline]
Reads:  results/dryrun/*.json (raw records, for reference columns)
Writes: results/roofline/roofline.json + roofline.md (the §Roofline table)
"""
import argparse
import dataclasses
import json
import time

import jax
import numpy as np

import repro.configs.base as config_base
from repro.configs import get_config, list_configs
from repro.launch.dryrun import (SHAPES, applicable, collective_stats,
                                 lower_combination)
from repro.launch.mesh import make_production_mesh

CHIPS = 256                    # single pod 16×16
PEAK_FLOPS = 197e12            # bf16 / chip
HBM_BW = 819e9                 # B/s / chip
ICI_BW = 50e9                  # B/s / link

PyTree = None


# ---------------------------------------------------------------------------
# analytic MODEL_FLOPS
# ---------------------------------------------------------------------------

def count_params(cfg) -> tuple[float, float]:
    """(total_params, active_params) from the real init shapes."""
    from repro.models import get_model
    ops = get_model(cfg)
    p_shape = jax.eval_shape(
        lambda: ops.init_params(jax.random.PRNGKey(0), cfg))
    flat = jax.tree_util.tree_flatten_with_path(p_shape)[0]
    total = active = 0.0
    for path, leaf in flat:
        n = float(np.prod(leaf.shape))
        name = jax.tree_util.keystr(path)
        total += n
        if "experts" in name and cfg.n_experts:
            active += n * cfg.top_k / cfg.n_experts
        else:
            active += n
    return total, active


def model_flops(cfg, shape_name: str) -> float:
    """6·N_active·tokens (train) / 2·N_active·tokens (inference)."""
    from repro.data.synthetic import shape_params
    sp = shape_params(shape_name)
    total, active = count_params(cfg)
    if sp["kind"] == "train":
        tokens = sp["batch"] * sp["seq"]
        return 6.0 * active * tokens
    if sp["kind"] == "prefill":
        tokens = sp["batch"] * sp["seq"]
        return 2.0 * active * tokens
    # decode: one token per sequence
    return 2.0 * active * sp["batch"]


# ---------------------------------------------------------------------------
# depth probing
# ---------------------------------------------------------------------------

def _probe(arch: str, shape: str, mesh, **overrides) -> dict:
    """Depth probe with UNROLLED layer/loss/embed scans, so cost_analysis
    counts every layer. The flash-attention inner scans stay rolled (their
    tile costs are added analytically — see attention_flops/bytes)."""
    from repro.models import layers as mlayers
    orig = get_config(arch)
    cfg = dataclasses.replace(orig, microbatch=1, **overrides)
    config_base._REGISTRY[arch] = cfg
    mlayers.UNROLL_FOR_COSTING = True
    try:
        lowered, _ = lower_combination(arch, shape, mesh)
        compiled = lowered.compile()
        cost = compiled.cost_analysis() or {}
        coll = collective_stats(compiled.as_text())
        return {"flops": float(cost.get("flops", 0.0)),
                "bytes": float(cost.get("bytes accessed", 0.0)),
                "coll": float(coll["total_bytes"])}
    finally:
        mlayers.UNROLL_FOR_COSTING = False
        config_base._REGISTRY[arch] = orig
        jax.clear_caches()


def corrected_costs(arch: str, shape: str, mesh, extra=None) -> dict:
    """Scan-corrected totals via family-aware depth probes."""
    extra = extra or {}
    cfg = get_config(arch)
    keys = ("flops", "bytes", "coll")

    def lin(p1, p2, n):
        """nonlayer + n·(p2−p1) per key, given depth-1 and depth-2 probes."""
        return {k: (p1[k] - (p2[k] - p1[k])) + n * (p2[k] - p1[k])
                for k in keys}

    if cfg.family == "hybrid":
        pa = _probe(arch, shape, mesh, n_layers=1, attn_every=1, **extra)
        pb = _probe(arch, shape, mesh, n_layers=2, attn_every=2, **extra)
        pc = _probe(arch, shape, mesh, n_layers=2, attn_every=1, **extra)
        mamba = {k: pb[k] - pa[k] for k in keys}
        shared = {k: pc[k] - pb[k] for k in keys}
        base = {k: pa[k] - mamba[k] - shared[k] for k in keys}
        from repro.models.hybrid import n_segments
        nseg = n_segments(cfg)
        return {k: base[k] + cfg.n_layers * mamba[k] + nseg * shared[k]
                for k in keys}
    if cfg.family == "audio":
        pa = _probe(arch, shape, mesh, n_layers=1, enc_layers=1, **extra)
        pb = _probe(arch, shape, mesh, n_layers=2, enc_layers=1, **extra)
        pc = _probe(arch, shape, mesh, n_layers=1, enc_layers=2, **extra)
        dec = {k: pb[k] - pa[k] for k in keys}
        enc = {k: pc[k] - pa[k] for k in keys}
        base = {k: pa[k] - dec[k] - enc[k] for k in keys}
        return {k: base[k] + cfg.n_layers * dec[k] + cfg.enc_layers * enc[k]
                for k in keys}
    if cfg.n_experts and cfg.moe_every > 1:
        # interleaved (llama4): the unit is a (dense, moe) layer PAIR
        p1 = _probe(arch, shape, mesh, n_layers=2, **extra)
        p2 = _probe(arch, shape, mesh, n_layers=4, **extra)
        return lin(p1, p2, cfg.n_layers // 2)
    p1 = _probe(arch, shape, mesh, n_layers=1, **extra)
    p2 = _probe(arch, shape, mesh, n_layers=2, **extra)
    return lin(p1, p2, cfg.n_layers)


def attention_cost(cfg, shape_name: str) -> dict:
    """Analytic flash-attention tile costs (GLOBAL, all layers).

    The flash inner scans are rolled even in the probes, so their tile
    matmuls are invisible to cost_analysis; we add them analytically:
    fwd FLOPs/layer = 4·B·Hq·Dh·Sq·Skv_visited (QKᵀ + PV, 2 flops/MAC),
    train ×4 (forward + remat recompute + ~2× backward). Streaming bytes:
    K/V re-read once per q chunk.
    """
    from repro.data.synthetic import shape_params
    sp = shape_params(shape_name)
    fam = cfg.family
    if fam == "ssm":
        return {"flops": 0.0, "bytes": 0.0}
    B, seq, kind = sp["batch"], sp["seq"], sp["kind"]
    Hq, Dh = max(cfg.n_heads, 1), cfg.head_dim
    dtype_b = 2.0

    def attn(Sq, Skv, layers, train):
        f = 4.0 * B * Hq * Dh * Sq * Skv * layers
        if train:
            f *= 4.0
        nq = max(1, Sq // cfg.attn_chunk)
        by = B * Hq * Dh * dtype_b * (Sq + 2.0 * nq * Skv) * layers
        return f, by

    train = kind == "train"
    if fam == "hybrid":
        from repro.models.hybrid import n_segments
        layers = n_segments(cfg)
    elif fam == "audio":
        layers = cfg.n_layers
    else:
        layers = cfg.n_layers

    if kind in ("train", "prefill"):
        Sq = seq + (cfg.n_patches if fam == "vlm" else 0)
        Skv = Sq
        if kind == "prefill" and cfg.triangle_prefill:
            Skv = Sq / 2.0 + cfg.attn_chunk / 2.0   # lower-triangle tiles only
    else:  # decode: one token against a cache
        Sq = 1
        Skv = min(seq, cfg.sliding_window or seq) if fam in (
            "dense", "moe", "vlm") else seq
        if fam == "hybrid":
            Skv = seq
    f, by = attn(Sq, Skv, layers, train)
    if fam == "audio":
        # + encoder self-attention (bidirectional) + decoder cross-attn
        fe, be = attn(cfg.enc_seq, cfg.enc_seq, cfg.enc_layers, train)
        if kind in ("train", "prefill"):
            fc, bc = attn(seq, cfg.enc_seq, cfg.n_layers, train)
        else:
            fc, bc = attn(1, cfg.enc_seq, cfg.n_layers, False)
        f, by = f + fe + fc, by + be + bc
    return {"flops": f, "bytes": by}


# ---------------------------------------------------------------------------
# terms + report
# ---------------------------------------------------------------------------

def roofline_terms(flops, bytes_, coll) -> dict:
    compute = flops / (CHIPS * PEAK_FLOPS)
    memory = bytes_ / (CHIPS * HBM_BW)
    collective = coll / (CHIPS * ICI_BW)
    dom = max(("compute", compute), ("memory", memory),
              ("collective", collective), key=lambda t: t[1])[0]
    return {"compute_s": compute, "memory_s": memory,
            "collective_s": collective, "dominant": dom}


WHAT_MOVES = {
    "compute": "raise arithmetic efficiency: larger fused matmul tiles / "
               "remove remat recompute (MODEL/HLO ratio shows the waste)",
    "memory": "cut HBM traffic: fuse elementwise chains, bf16 residuals, "
              "bigger flash tiles so Q/K/V stream once",
    "collective": "reshard: move the dominant all-gather/reduce-scatter off "
                  "the critical axis, overlap collectives with compute, or "
                  "shrink TP degree for this op",
}


def analyze(arch: str, shape: str, mesh, dryrun_dir: str,
            overrides=None) -> dict:
    overrides = overrides or {}
    cfg = dataclasses.replace(get_config(arch), **overrides)
    ok, why = applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "skipped": True, "reason": why}
    raw_path = os.path.join(dryrun_dir, f"{arch}__{shape}__pod16x16.json")
    raw = {}
    if os.path.exists(raw_path):
        with open(raw_path) as f:
            raw = json.load(f)
    t0 = time.time()
    corr = corrected_costs(arch, shape, mesh, extra=overrides)
    # deltas can be slightly noisy (fusion differences between depths)
    corr = {k: max(v, 0.0) for k, v in corr.items()}
    attn = attention_cost(cfg, shape)
    corr["flops"] += attn["flops"] / CHIPS    # per-device accounting
    corr["bytes"] += attn["bytes"] / CHIPS
    terms = roofline_terms(corr["flops"], corr["bytes"], corr["coll"])
    mf = model_flops(cfg, shape)
    ratio = mf / max(corr["flops"] * CHIPS, 1.0)
    return {
        "arch": arch, "shape": shape, "skipped": False,
        "hlo_flops_raw_per_device": raw.get("flops"),
        "hlo_flops_corrected_per_device": corr["flops"],
        "hlo_bytes_corrected_per_device": corr["bytes"],
        "collective_bytes_corrected_per_device": corr["coll"],
        "model_flops_global": mf,
        "model_over_hlo_ratio": ratio,
        **terms,
        "bottleneck_fix": WHAT_MOVES[terms["dominant"]],
        "probe_seconds": round(time.time() - t0, 1),
        "temp_bytes_per_device": (raw.get("memory") or {}).get("temp_bytes"),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="results/roofline")
    ap.add_argument("--dryrun-dir", default="results/dryrun")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)
    mesh = make_production_mesh(multi_pod=False)

    archs = list_configs() if args.arch == "all" else [args.arch]
    shapes = SHAPES if args.shape == "all" else [args.shape]
    out = []
    out_path = os.path.join(args.outdir, "roofline.json")
    if os.path.exists(out_path):     # resume: keep completed pairs
        with open(out_path) as f:
            out = json.load(f)
    done = {(r["arch"], r["shape"]) for r in out}
    for arch in archs:
        for shape in shapes:
            if (arch, shape) in done:
                continue
            rec = analyze(arch, shape, mesh, args.dryrun_dir)
            out.append(rec)
            if rec.get("skipped"):
                print(f"[roofline] {arch:28s} {shape:12s} SKIP {rec['reason']}",
                      flush=True)
            else:
                print(f"[roofline] {arch:28s} {shape:12s} "
                      f"comp={rec['compute_s']:.2e}s mem={rec['memory_s']:.2e}s "
                      f"coll={rec['collective_s']:.2e}s -> {rec['dominant']:10s} "
                      f"model/hlo={rec['model_over_hlo_ratio']:.2f}", flush=True)
            with open(out_path, "w") as f:
                json.dump(out, f, indent=1)
    _write_md(out, os.path.join(args.outdir, "roofline.md"))


def _write_md(records: list, path: str) -> None:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant |"
        " MODEL_FLOPS | model/HLO | next move |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in records:
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skip ({r['reason']}) | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
            f"**{r['dominant']}** | {r['model_flops_global']:.2e} | "
            f"{r['model_over_hlo_ratio']:.2f} | {r['bottleneck_fix']} |")
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")


if __name__ == "__main__":
    main()
