"""Pallas TPU kernels: fused single-pass redundancy maintenance.

The checkpoint fabric's hot loop previously made three-plus independent
full passes over the live parameters every maintained step: a full-tree
replica copy, a pack-into-frames + gather + XOR parity encode (two
materialized full-model intermediates), and a third full read for PRIORITY
block scoring. Both kernels here collapse that to the memory-roofline
floor:

``fused_maintain`` — one sweep per parameter leaf that reads each element
of the live leaf (and its running-checkpoint counterpart) from HBM exactly
once and, in that single pass,

  (a) writes the replica snapshot (plain copy, original dtype),
  (b) XOR-accumulates the leaf's float32 bit-pattern rows directly into
      compact per-group parity frames — no ``(total_blocks, frame_width)``
      packed intermediate and no ``(n_groups, g, E)`` gather buffer ever
      exists, and
  (c) emits per-block squared-L2 distance partials for PRIORITY selection.

Layout: the grid is ``(E_tiles, S)`` — element tiles *outer*, blocks
*inner* — and the block axis is driven by three scalar-prefetched arrays:
``perm`` visits the leaf's blocks sorted by parity group, so all members
of one group arrive on consecutive grid steps and the parity output block
can be revisit-accumulated in VMEM (init on ``first``, XOR otherwise)
exactly like ``block_dist``'s running sum; ``outrow`` maps each sorted
position to its compact parity row. Replica rows and score partials are
written back through the inverse map so they land in natural block order.

``scatter_save`` — donation-based in-place partial-checkpoint write: the
running checkpoint buffer is aliased as the output and the grid walks only
the ``k`` selected blocks (scalar-prefetched row ids), so saving ``k``
blocks moves ``O(k · block_bytes)`` — never the full leaf. Unvisited rows
are never DMA'd and keep their previous contents (the §4.3 running
checkpoint is a mutable mix of iterations by construction).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BE = 512    # elements per tile (lanes; multiple of 128)


# ---------------------------------------------------------------------------
# fused_maintain: replica copy + parity XOR + priority scores, one read
# ---------------------------------------------------------------------------

def _fused_maintain_kernel(perm_ref, outrow_ref, first_ref, x_ref, z_ref,
                           rep_ref, sc_ref, par_ref):
    s = pl.program_id(1)
    x = x_ref[...]                               # (1, BE), leaf dtype
    rep_ref[...] = x                             # (a) replica snapshot
    x32 = x.astype(jnp.float32)
    d = x32 - z_ref[...].astype(jnp.float32)
    sc_ref[0, 0] = jnp.sum(d * d)                # (c) score partial
    bits = jax.lax.bitcast_convert_type(x32, jnp.int32)

    @pl.when(first_ref[s] == 1)
    def _init():                                 # (b) first member: seed
        par_ref[...] = bits

    @pl.when(first_ref[s] == 0)
    def _fold():                                 # (b) later member: fold
        par_ref[...] ^= bits


def fused_maintain_pallas(x: jnp.ndarray, z: jnp.ndarray,
                          perm: jnp.ndarray, outrow: jnp.ndarray,
                          first: jnp.ndarray, n_out_rows: int,
                          interpret: bool = False,
                          ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One fused maintenance sweep over a leaf's block view.

    x, z:    (S, E) live leaf view / running-checkpoint view (same shapes).
    perm:    (S,) int32 — block ids sorted by parity group (group members
             consecutive; within a group any order).
    outrow:  (S,) int32 — compact parity row of sorted position s.
    first:   (S,) int32 — 1 where s is the first sorted position of its row.
    n_out_rows — number of distinct parity rows (static).

    Returns (replica (S, E) x.dtype, scores (S,) f32,
    parity_contrib (n_out_rows, E) int32 — XOR of the f32 bit patterns of
    each row's member blocks).
    """
    s_dim, e = x.shape
    e_pad = -e % BE
    if e_pad:
        x = jnp.pad(x, ((0, 0), (0, e_pad)))
        z = jnp.pad(z, ((0, 0), (0, e_pad)))
    ep = x.shape[1]
    jt = ep // BE
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(jt, s_dim),                        # E tiles OUTER: parity row
        in_specs=[                               # revisits stay consecutive
            pl.BlockSpec((1, BE), lambda j, s, p, o, f: (p[s], j)),
            pl.BlockSpec((1, BE), lambda j, s, p, o, f: (p[s], j)),
        ],
        out_specs=[
            pl.BlockSpec((1, BE), lambda j, s, p, o, f: (p[s], j)),
            pl.BlockSpec((1, 1), lambda j, s, p, o, f: (p[s], j)),
            pl.BlockSpec((1, BE), lambda j, s, p, o, f: (o[s], j)),
        ],
    )
    rep, sc, par = pl.pallas_call(
        _fused_maintain_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((s_dim, ep), x.dtype),
            jax.ShapeDtypeStruct((s_dim, jt), jnp.float32),
            jax.ShapeDtypeStruct((n_out_rows, ep), jnp.int32),
        ],
        interpret=interpret,
    )(perm, outrow, first, x, z)
    return rep[:, :e], jnp.sum(sc, axis=1), par[:, :e]


# ---------------------------------------------------------------------------
# scatter_save: donation-based in-place partial checkpoint write
# ---------------------------------------------------------------------------

def _scatter_save_kernel(rows_ref, src_ref, dst_ref, out_ref):
    del rows_ref, dst_ref                        # routing/alias only
    out_ref[...] = src_ref[...]


def scatter_save_pallas(dst: jnp.ndarray, src: jnp.ndarray,
                        rows: jnp.ndarray, block_rows: int,
                        interpret: bool = False) -> jnp.ndarray:
    """In-place block scatter over a leaf's raw row matrix.

    dst, src: (R, W) — the leaf reshaped to (rows, row_width), NOT the
    zero-padded block view (padding would materialize a full copy and
    defeat the O(k) goal). rows: (k,) int32 selected *block* ids
    (duplicates are idempotent — callers pad short selections with
    repeats). Block ``b`` covers dst rows ``[b·block_rows, (b+1)·block_rows)``;
    the ragged tail block is handled by Pallas's partial-block masking.

    ``dst`` is donated and aliased to the output, so unselected rows are
    never read or written — saving ``k`` blocks moves ``O(k·block_bytes)``.
    """
    r, w = dst.shape
    k = rows.shape[0]
    br = min(block_rows, r)
    bw = min(BE, w)
    jt = -(-w // bw)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k, jt),
        in_specs=[
            pl.BlockSpec((br, bw), lambda i, j, rows: (rows[i], j)),
            pl.BlockSpec(memory_space=pltpu.ANY),     # aliased, untouched
        ],
        out_specs=pl.BlockSpec((br, bw), lambda i, j, rows: (rows[i], j)),
    )
    return pl.pallas_call(
        _scatter_save_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r, w), dst.dtype),
        input_output_aliases={2: 0},             # dst (after scalars) -> out
        interpret=interpret,
    )(rows, src, dst)
