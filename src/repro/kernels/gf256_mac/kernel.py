"""Pallas TPU kernel: fused GF(256) multiply-accumulate over packed
int32 frames (Reed-Solomon erasure tier).

Same grid/layout as ``parity_xor`` — (n_groups, E) tiles of (BG, BE),
the small group axis riding whole inside each tile — but the fold is a
field multiply-accumulate instead of a masked XOR:

    out[j] = base[j] ^ XOR_i gf_mul(coeff[j, i], frames[j, i])

The multiply is SWAR shift-and-add (Russian peasant) on the packed
words: each int32 lane carries four GF(256) symbols, and one conditional
double step advances all four at once —

    msb = (b >> 7) & 0x01010101          # per-byte high bit
    b   = ((b << 1) & 0xFEFEFEFE) ^ msb * 0x1D   # xtime, poly 0x11D

8 unrolled bit steps per member (coefficient bytes are ≤ 8 bits), so a
group of g members costs 8g vector ops per tile — no tables in VMEM, no
byte unpack, and each member frame is read from HBM exactly once. XOR
parity is the coeff ∈ {0, 1} special case (bit 0 adds, bits 1–7 see
zero), so this kernel strictly generalizes ``parity_xor``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

BG = 8
BE = 512

# int32 bit patterns for the SWAR masks (numpy round-trip avoids the
# Python-int overflow on 0xFEFEFEFE)
_MASK_FE = int(np.int32(np.uint32(0xFEFEFEFE)))
_MASK_LO = 0x01010101
_POLY_LO = 0x1D


def _xtime(b: jax.Array) -> jax.Array:
    """Multiply four packed GF(256) bytes by x (alpha), SWAR."""
    msb = jax.lax.shift_right_logical(b, 7) & _MASK_LO
    return ((b << 1) & _MASK_FE) ^ (msb * _POLY_LO)


def _gf256_mac_kernel(frames_ref, base_ref, coeff_ref, out_ref, *, g: int):
    c = coeff_ref[...]                       # (BG, g) int32 bytes
    acc = base_ref[...]                      # (BG, BE) int32
    for i in range(g):                       # g is static and small
        b = frames_ref[:, i, :]              # (BG, BE) int32
        ci = c[:, i]                         # (BG,)
        part = jnp.zeros_like(b)
        for bit in range(8):                 # shift-and-add over coeff bits
            take = ((ci >> bit) & 1) > 0
            part = part ^ jnp.where(take[:, None], b, 0)
            if bit < 7:
                b = _xtime(b)
        acc = acc ^ part
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def gf256_mac_pallas(frames: jnp.ndarray, base: jnp.ndarray,
                     coeff: jnp.ndarray,
                     interpret: bool = False) -> jnp.ndarray:
    """frames: (n_groups, g, E) int32; base: (n_groups, E) int32;
    coeff: (n_groups, g) int32 bytes in [0, 256) → (n_groups, E) int32.
    """
    n, g, e = frames.shape
    n_pad = -n % BG
    e_pad = -e % BE
    coeff_i = coeff.astype(jnp.int32)
    if n_pad or e_pad:
        frames = jnp.pad(frames, ((0, n_pad), (0, 0), (0, e_pad)))
        base = jnp.pad(base, ((0, n_pad), (0, e_pad)))
        coeff_i = jnp.pad(coeff_i, ((0, n_pad), (0, 0)))
    np_, _, ep_ = frames.shape
    grid = (np_ // BG, ep_ // BE)
    out = pl.pallas_call(
        functools.partial(_gf256_mac_kernel, g=g),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BG, g, BE), lambda i, j: (i, 0, j)),
            pl.BlockSpec((BG, BE), lambda i, j: (i, j)),
            pl.BlockSpec((BG, g), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BG, BE), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, ep_), jnp.int32),
        interpret=interpret,
    )(frames, base, coeff_i)
    return out[:n, :e]
