"""HLO collective-bytes accounting + dry-run applicability rules."""
from repro.configs import get_config
from repro.launch.dryrun import (_shapes_bytes, applicable, collective_stats,
                                 SHAPES)

HLO = """
HloModule jit_train_step

%fused (a: f32[8,128]) -> f32[8,128] {
  ROOT %x = f32[8,128] add(%a, %a)
}

ENTRY %main {
  %ag = bf16[256,4096] all-gather(%p0), replica_groups={...}, dimensions={0}
  %ar = f32[1024] all-reduce(%p1), to_apply=%sum
  %rs = bf16[16,128] reduce-scatter(%p2), dimensions={0}
  %a2a = f32[64,64] all-to-all(%p3), dimensions={1}
  %cp = u32[32] collective-permute(%p4), source_target_pairs={{0,1}}
  %notacoll = f32[999,999] dot(%p5, %p6)
  ROOT %out = (f32[1]) tuple(%r)
}
"""


def test_shapes_bytes():
    assert _shapes_bytes("f32[10,10]") == 400
    assert _shapes_bytes("bf16[8]") == 16
    assert _shapes_bytes("(f32[2], s32[3])") == 8 + 12
    assert _shapes_bytes("pred[7]") == 7
    assert _shapes_bytes("token[]") == 0


def test_collective_stats_counts_and_bytes():
    st = collective_stats(HLO)
    assert st["all-gather"]["count"] == 1
    assert st["all-gather"]["bytes"] == 256 * 4096 * 2
    assert st["all-reduce"]["bytes"] == 1024 * 4
    assert st["reduce-scatter"]["count"] == 1
    assert st["all-to-all"]["bytes"] == 64 * 64 * 4
    assert st["collective-permute"]["bytes"] == 32 * 4
    # the dot is not counted
    assert st["total_bytes"] == (256 * 4096 * 2 + 4096 + 16 * 128 * 2
                                 + 64 * 64 * 4 + 128)


def test_applicability_rules():
    whisper = get_config("whisper-medium")
    ok, why = applicable(whisper, "long_500k")
    assert not ok and "enc-dec" in why
    for shape in ("train_4k", "prefill_32k", "decode_32k"):
        assert applicable(whisper, shape)[0]
    # every non-audio arch runs all four shapes (dense via sliding window)
    for name in ("granite-8b", "mamba2-370m", "zamba2-1.2b",
                 "qwen3-moe-235b-a22b"):
        cfg = get_config(name)
        for shape in SHAPES:
            assert applicable(cfg, shape)[0], (name, shape)
