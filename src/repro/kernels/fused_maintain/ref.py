"""Pure-jnp oracles for the fused_maintain kernel family."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def fused_maintain_ref(x: jnp.ndarray, z: jnp.ndarray,
                       outrow_per_block: np.ndarray, n_out_rows: int,
                       ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Oracle for one leaf sweep: (replica copy, per-block squared-L2
    scores, per-row XOR of the blocks' float32 bit patterns).

    ``outrow_per_block[b]`` is the compact parity row block ``b`` folds
    into (natural block order, unlike the kernel's sorted ``perm``/
    ``outrow`` encoding).
    """
    x32 = x.astype(jnp.float32)
    z32 = z.astype(jnp.float32)
    scores = jnp.sum((x32 - z32) ** 2, axis=1)
    bits = np.asarray(jax.lax.bitcast_convert_type(x32, jnp.int32))
    par = np.zeros((n_out_rows, x.shape[1]), np.int32)
    for b, row in enumerate(np.asarray(outrow_per_block)):
        par[int(row)] ^= bits[b]
    return jnp.array(x), scores, jnp.asarray(par)


def arena_maintain_ref(x2d: jnp.ndarray, z2d: jnp.ndarray,
                       tile_dest: np.ndarray, n_dest_tiles: int,
                       ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle for the arena sweep: per-tile score partials (natural tile
    order) and compact parity tiles (XOR of the f32 bit patterns of every
    ``(8, 128)`` tile routed to the same destination).

    ``tile_dest[t]`` is the compact parity tile index arena tile ``t``
    folds into (natural order, unlike the kernel's sorted ``perm``/
    ``dest`` encoding)."""
    from repro.core.arena import ARENA_LANES, ARENA_SUBLANES, ARENA_TILE
    words = x2d.shape[0] * x2d.shape[1]
    n_tiles = words // ARENA_TILE
    xt = np.asarray(x2d, np.float32).reshape(n_tiles, ARENA_TILE)
    zt = np.asarray(z2d, np.float32).reshape(n_tiles, ARENA_TILE)
    partials = ((xt - zt) ** 2).sum(axis=1)
    bits = xt.view(np.int32)
    par = np.zeros((n_dest_tiles, ARENA_TILE), np.int32)
    for t, d in enumerate(np.asarray(tile_dest)):
        par[int(d)] ^= bits[t]
    return (jnp.asarray(partials, jnp.float32),
            jnp.asarray(par.reshape(n_dest_tiles * ARENA_SUBLANES,
                                    ARENA_LANES)))


def arena_scatter_ref(dst2d: jnp.ndarray, src2d: jnp.ndarray,
                      tiles: np.ndarray) -> jnp.ndarray:
    """Oracle for the arena tile scatter."""
    from repro.core.arena import ARENA_SUBLANES as SL
    out = np.array(dst2d)
    src = np.asarray(src2d)
    for t in np.asarray(tiles):
        out[int(t) * SL:(int(t) + 1) * SL] = src[int(t) * SL:(int(t) + 1) * SL]
    return jnp.asarray(out)


def scatter_save_ref(dst: jnp.ndarray, src: jnp.ndarray,
                     rows: np.ndarray, block_rows: int) -> jnp.ndarray:
    """Oracle for the in-place block scatter: ``dst`` with the selected
    blocks' rows overwritten from ``src`` (row-matrix layout)."""
    out = np.array(dst)
    src = np.asarray(src)
    n_rows = out.shape[0]
    for b in np.asarray(rows):
        lo = int(b) * block_rows
        hi = min(lo + block_rows, n_rows)
        out[lo:hi] = src[lo:hi]
    return jnp.asarray(out)
