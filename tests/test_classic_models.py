"""The paper's experiment models: convergence + runner mechanics."""
import jax
import numpy as np
import pytest

from repro.core.policy import CheckpointPolicy
from repro.models.classic import make_model
from repro.training import (run_clean, run_with_failure,
                            run_with_perturbation)

# small-but-faithful configs so the whole file runs in ~2 min on CPU
SMALL = {
    "qp": {},
    "mlr": dict(n=600, dim=64, n_classes=5, batch=200),
    "mf": dict(m=120, n=180, rank=4),
    "lda": dict(n_docs=60, vocab=120, n_topics=5, doc_len_mean=40),
}


@pytest.mark.parametrize("name", list(SMALL))
def test_converges_to_eps(name):
    model = make_model(name, **SMALL[name])
    res = run_clean(model, max_iters=150, seed=0)
    assert min(res["losses"]) < model.eps


@pytest.mark.parametrize("name", ["qp", "mlr"])
def test_random_perturbation_costs_iterations(name):
    model = make_model(name, **SMALL[name])
    clean = run_clean(model, 200, seed=0)["losses"]
    res = run_with_perturbation(model, kind="random", at_iter=30, size=5.0,
                                max_iters=200, seed=0, clean_losses=clean)
    assert res["delta_norm"] == pytest.approx(5.0, rel=1e-4)
    assert res["kappa_perturbed"] <= 200


def test_adversarial_worse_than_random_qp():
    """Paper Fig. 5: adversarial perturbations cost at least as much."""
    model = make_model("qp")
    clean = run_clean(model, 400, seed=0)["losses"]
    costs = {}
    for kind in ("random", "adversarial"):
        cs = []
        for seed in range(5):
            r = run_with_perturbation(model, kind=kind, at_iter=30, size=2.0,
                                      max_iters=400, seed=seed,
                                      clean_losses=clean)
            cs.append(r["iteration_cost"])
        costs[kind] = np.mean(cs)
    assert costs["adversarial"] >= costs["random"] - 1.0


def test_reset_perturbation_scales_with_fraction():
    model = make_model("mlr", **SMALL["mlr"])
    clean = run_clean(model, 150, seed=0)["losses"]
    small = run_with_perturbation(model, kind="reset", at_iter=30,
                                  fraction=0.1, max_iters=150, seed=0,
                                  clean_losses=clean)
    large = run_with_perturbation(model, kind="reset", at_iter=30,
                                  fraction=0.9, max_iters=150, seed=0,
                                  clean_losses=clean)
    assert small["delta_norm"] <= large["delta_norm"]


def test_run_with_failure_records_recovery():
    model = make_model("mlr", **SMALL["mlr"])
    res = run_with_failure(model, CheckpointPolicy.scar(0.5, 4),
                           fail_iter=20, fail_fraction=0.5, max_iters=100,
                           seed=1)
    assert res["recovery"]["partial_sq"] <= res["recovery"]["full_sq"]
    assert res["controller_stats"]["saves"] > 0
    assert np.isfinite(res["losses"]).all()


def test_lda_scaled_tv_norm_available():
    model = make_model("lda", **SMALL["lda"])
    assert model.norm_aux is not None
    res = run_with_failure(
        model,
        CheckpointPolicy.scar(0.25, 8, norm="scaled_tv"),
        fail_iter=20, fail_fraction=0.5, max_iters=60, seed=0)
    assert np.isfinite(res["losses"]).all()
