"""Shared train-step builder (used by TrainLoop and launch/dryrun).

Implements microbatched gradient accumulation (``cfg.microbatch > 1``):
the global batch is split into MB microbatches processed by a ``lax.scan``
with an fp32 gradient accumulator sharded like the parameters. This is the
standard memory lever for the largest dense architectures — per-step
transient activation memory scales 1/MB while keeping the same global
batch semantics.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.api import ModelOps
from repro.optim.optimizers import Optimizer
from repro.sharding.partition import DistContext
from repro.training.train_state import TrainState

PyTree = Any


def make_train_step(ops: ModelOps, cfg: ModelConfig, ctx: DistContext,
                    optimizer: Optimizer):
    loss_and_grad = jax.value_and_grad(ops.train_loss)

    def train_step(state: TrainState, batch: PyTree):
        mb = max(cfg.microbatch, 1)
        if mb == 1:
            loss, grads = loss_and_grad(state.params, batch, cfg, ctx)
        else:
            def split(x):
                return x.reshape((mb, x.shape[0] // mb) + tuple(x.shape[1:]))

            mbatch = jax.tree_util.tree_map(split, batch)
            acc_dtype = jnp.dtype(cfg.opt_moment_dtype)
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dtype), state.params)

            def body(carry, bx):
                loss_sum, gacc = carry
                l, g = loss_and_grad(state.params, bx, cfg, ctx)
                gacc = jax.tree_util.tree_map(
                    lambda a, x: (a.astype(jnp.float32)
                                  + x.astype(jnp.float32)).astype(a.dtype),
                    gacc, g)
                return (loss_sum + l, gacc), None

            (loss, grads), _ = jax.lax.scan(
                body, (jnp.float32(0.0), g0), mbatch)
            loss = loss / mb
            grads = jax.tree_util.tree_map(lambda g: g / mb, grads)
        params, opt_state = optimizer.update(grads, state.opt_state,
                                             state.params)
        return TrainState(params, opt_state, state.step + 1), loss

    return train_step
