"""Figure 5: MLR iteration costs for random vs adversarial perturbations.

Paper findings reproduced as derived checks:
- random perturbations rarely exceed the bound and are *loose* against it;
- adversarial (away-from-optimum) perturbations approach the bound —
  it is a tight worst-case bound;
- adversarial costs ≥ random costs at matched ||δ||.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import MODEL_KW, csv_row, summarize
from repro.core.iteration_cost import (estimate_contraction,
                                       single_perturbation_bound)
from repro.models.classic import make_model
from repro.training import run_clean, run_with_perturbation


def run(trials: int = 12, quick: bool = False) -> list[str]:
    if quick:
        trials = 5
    model = make_model("mlr", **MODEL_KW["mlr"])
    max_iters = 250
    clean = run_clean(model, max_iters, seed=0)["losses"]
    errs = np.sqrt(np.maximum(np.asarray(clean) - min(clean) * 0.98, 1e-9))
    c = estimate_contraction(errs[:120], burn_in=3)
    import jax
    x0_err = model.distance(model.init(jax.random.PRNGKey(1)))

    rows = []
    T, size = 25, 2.0
    means = {}
    for kind in ("random", "adversarial"):
        costs = []
        for seed in range(trials):
            r = run_with_perturbation(model, kind=kind, at_iter=T, size=size,
                                      max_iters=max_iters, seed=seed,
                                      clean_losses=clean)
            costs.append(r["iteration_cost"])
        mean, sem = summarize(costs)
        means[kind] = mean
        bound = single_perturbation_bound(size, c, T=T, x0_err=x0_err)
        rows.append(csv_row(f"fig5_mlr_{kind}", 0.0,
                            f"mean_cost={mean:.1f}±{sem:.1f};worst={max(costs)};"
                            f"bound={bound:.1f}"))
    rows.append(csv_row("fig5_adversarial_geq_random", 0.0,
                        f"adv={means['adversarial']:.1f};"
                        f"rand={means['random']:.1f};"
                        f"holds={means['adversarial'] >= means['random'] - 1}"))
    return rows
